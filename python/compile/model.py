"""Layer-2: GPT-style transformer stages in JAX, calling the Pallas kernels.

The model is decomposed into *shape-uniform stages*, one AOT artifact each,
which is exactly the unit the Rust coordinator schedules and offloads:

    embed_fwd  (tokens, wte, wpe)                  -> x
    layer_fwd  (x, p0..p11)                        -> y
    layer_bwd  (x_ckpt, dy, p0..p11)               -> (dx, dp0..dp11)
    head_loss  (x, lnf_w, lnf_b, wte, targets)     -> (loss, dx, dlnf_w, dlnf_b, dwte)
    embed_bwd  (tokens, dx)                        -> (dwte, dwpe)
    adam_step  (hyper, p, m, v, g)                 -> (p', m', v')

`layer_bwd` is recompute-then-VJP: it takes the layer's *input activation
checkpoint* (per-layer activation checkpointing, paper §2.2) plus the upstream
gradient, replays the forward from the checkpoint, and emits the input
gradient and per-parameter gradients. Gradient *accumulation* across
micro-batches deliberately stays out of the graph — the vertical scheduler
(paper §3.4) keeps one accumulation buffer per layer resident in GPU memory
and adds each micro-batch's `dp` into it, so one compiled executable serves
every (layer, micro-batch) pair.

All transformer layers share one (B, T, D) shape, so a single `layer_fwd` /
`layer_bwd` executable serves all L layers with parameters fed as inputs —
the property (§6.2) that lets Ratel build a uniform prefetch pipeline, and
that makes parameter offloading trivially correct here.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.flash_attention import flash_attention
from .kernels.layernorm import layernorm
from .kernels import ref


class ModelConfig(NamedTuple):
    """Static shape configuration baked into the AOT artifacts."""

    micro_batch: int      # B: per-micro-batch sequences
    seq_len: int          # T
    hidden: int           # D
    n_heads: int          # H
    vocab: int            # V
    n_layers: int         # L (not baked into per-layer artifacts; for manifest)
    ffn_mult: int = 4
    adam_chunk: int = 1 << 20  # flat fp32 elements per optimizer-step call

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.n_heads == 0
        return self.hidden // self.n_heads

    def layer_param_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        """Ordered (name, shape) of the 12 per-layer parameter tensors.

        The order here *is* the artifact calling convention: `layer_fwd`
        args 1..12 and `layer_bwd` args 2..13 / outputs 1..12.
        """
        d, f = self.hidden, self.ffn_mult * self.hidden
        return [
            ("ln1_w", (d,)), ("ln1_b", (d,)),
            ("w_qkv", (d, 3 * d)), ("b_qkv", (3 * d,)),
            ("w_o", (d, d)), ("b_o", (d,)),
            ("ln2_w", (d,)), ("ln2_b", (d,)),
            ("w_fc1", (d, f)), ("b_fc1", (f,)),
            ("w_fc2", (f, d)), ("b_fc2", (d,)),
        ]

    def layer_param_numel(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.layer_param_shapes())


# ---------------------------------------------------------------------------
# Transformer block
# ---------------------------------------------------------------------------


def block_fwd(x: jax.Array, params: tuple, cfg: ModelConfig) -> jax.Array:
    """Pre-LN GPT block: x + Attn(LN(x)), then + FFN(LN(.))."""
    (ln1_w, ln1_b, w_qkv, b_qkv, w_o, b_o,
     ln2_w, ln2_b, w_fc1, b_fc1, w_fc2, b_fc2) = params
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim

    a = layernorm(x, ln1_w, ln1_b)
    qkv = a @ w_qkv + b_qkv                                  # (B, T, 3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def to_heads(u):  # (B, T, D) -> (B*H, T, dh)
        return u.reshape(b, t, h, dh).transpose(0, 2, 1, 3).reshape(b * h, t, dh)

    o = flash_attention(to_heads(q), to_heads(k), to_heads(v), True, None)
    o = o.reshape(b, h, t, dh).transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + (o @ w_o + b_o)

    f = layernorm(x, ln2_w, ln2_b)
    f = ref.gelu(f @ w_fc1 + b_fc1) @ w_fc2 + b_fc2
    return x + f


def block_bwd(x_ckpt: jax.Array, dy: jax.Array, params: tuple, cfg: ModelConfig):
    """Recompute the block from its input checkpoint, then VJP.

    Returns (dx, dp0..dp11) — the per-micro-batch gradients the coordinator
    accumulates into the layer's resident buffer.
    """
    _, vjp = jax.vjp(lambda xx, ps: block_fwd(xx, ps, cfg), x_ckpt, params)
    dx, dps = vjp(dy)
    return (dx, *dps)


# ---------------------------------------------------------------------------
# Embedding and head
# ---------------------------------------------------------------------------


def embed_fwd(tokens: jax.Array, wte: jax.Array, wpe: jax.Array) -> jax.Array:
    """Token + learned positional embeddings; tokens i32 (B, T)."""
    return wte[tokens] + wpe[None, : tokens.shape[1], :]


def embed_bwd(tokens: jax.Array, dx: jax.Array, vocab: int):
    """Scatter-add gradients back to the embedding tables (tied head adds its
    own dwte contribution on the Rust side)."""
    dwte = jnp.zeros((vocab, dx.shape[-1]), dtype=dx.dtype).at[tokens].add(dx)
    dwpe = jnp.sum(dx, axis=0)
    return dwte, dwpe


def head_loss(x: jax.Array, lnf_w: jax.Array, lnf_b: jax.Array,
              wte: jax.Array, targets: jax.Array):
    """Final LN + tied LM head + mean token cross-entropy, with gradients.

    Emits (loss, dx, dlnf_w, dlnf_b, dwte) in one artifact so the backward
    pass can start immediately from the head (paper Fig. 2(b) step 1).
    """

    def loss_fn(xx, w, b, emb):
        h = layernorm(xx, w, b)
        logits = h @ emb.T                                   # (B, T, V)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll)

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3))(
        x, lnf_w, lnf_b, wte)
    return (loss, *grads)


# ---------------------------------------------------------------------------
# Whole-model reference (used only by tests — never lowered for the runtime)
# ---------------------------------------------------------------------------


def full_forward_loss(tokens, targets, wte, wpe, lnf_w, lnf_b, layers, cfg: ModelConfig):
    """End-to-end loss through all stages; oracle for integration tests."""
    x = embed_fwd(tokens, wte, wpe)
    for p in layers:
        x = block_fwd(x, p, cfg)
    loss, *_ = head_loss(x, lnf_w, lnf_b, wte, targets)
    return loss
