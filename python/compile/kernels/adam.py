"""Layer-1 Pallas kernel: fused Adam(W) optimizer step — the `cpu_adam` analog.

ZeRO-Infinity (which the paper builds on) implements the CPU optimizer step as
an AVX loop fused over {load p/m/v/g, update, store}. Here the same fusion is
a Pallas kernel blocked along a flattened parameter chunk: each program pulls
one (BLOCK,) tile of the four state vectors into VMEM, performs the
element-wise update on the VPU, and writes the three outputs — a single pass
over memory, which is exactly why the fused loop beats a chain of BLAS-1 ops.

§6.5 of the paper notes ZeRO-Infinity's scalar remainder handling perturbs
reproducibility; like GreedySnake we keep *everything* vectorized — the Rust
coordinator pads every chunk to a BLOCK multiple (grads padded with zeros, p/m/v
with anything) so no scalar tail exists, and results are invariant to how the
parameter vector is partitioned into chunks.

Hyper-parameters arrive as an 8-wide fp32 vector so one compiled executable
serves every step and every layer:
    hyper = [lr, beta1, beta2, eps, weight_decay, bias_corr1, bias_corr2, grad_scale]
with bias_corr_i = 1 - beta_i^t precomputed by the coordinator and grad_scale
multiplying the incoming gradient (loss-scaling / gradient-clipping factor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024  # 8×128 VPU lanes


def _adam_kernel(hyper_ref, p_ref, m_ref, v_ref, g_ref, po_ref, mo_ref, vo_ref):
    h = hyper_ref[...]
    lr, b1, b2, eps = h[0], h[1], h[2], h[3]
    wd, bc1, bc2, gscale = h[4], h[5], h[6], h[7]
    p = p_ref[...]
    g = g_ref[...] * gscale
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    m_hat = m / bc1
    v_hat = v / bc2
    upd = m_hat / (jnp.sqrt(v_hat) + eps) + wd * p
    po_ref[...] = p - lr * upd
    mo_ref[...] = m
    vo_ref[...] = v


def adam_step(p: jax.Array, m: jax.Array, v: jax.Array, g: jax.Array,
              hyper: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Adam(W) update over flat fp32 vectors of length n (n % BLOCK == 0
    preferred; any n that admits a power-of-two block still works)."""
    (n,) = p.shape
    block = BLOCK
    while block > 1 and n % block != 0:
        block //= 2
    grid = (n // block,)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i: (0,)), vec, vec, vec, vec],
        out_specs=(vec, vec, vec),
        out_shape=(out, out, out),
        interpret=True,
    )(hyper, p, m, v, g)


def pack_hyper(lr: float, beta1: float = 0.9, beta2: float = 0.999,
               eps: float = 1e-8, weight_decay: float = 0.0, step: int = 1,
               grad_scale: float = 1.0) -> jax.Array:
    """Build the 8-wide hyper vector for step t (1-based)."""
    return jnp.array([lr, beta1, beta2, eps, weight_decay,
                      1.0 - beta1 ** step, 1.0 - beta2 ** step, grad_scale],
                     dtype=jnp.float32)
