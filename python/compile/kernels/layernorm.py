"""Layer-1 Pallas kernel: fused LayerNorm (mean/var/normalize/affine in one pass).

Grid is one program per row-block; each program holds a (block_rows, D) tile
in VMEM, reduces along the feature axis on the VPU, and applies the affine in
the same pass — one HBM read + one HBM write per element instead of the four
separate passes an unfused mean/var/normalize/scale sequence would need.

A `jax.custom_vjp` supplies the standard LayerNorm backward in closed form so
Layer-2 `jax.vjp` differentiates through the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _ln_kernel(x_ref, w_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, D)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    xhat = xc * jax.lax.rsqrt(var + eps)
    o_ref[...] = (xhat * w_ref[...] + b_ref[...]).astype(o_ref.dtype)


def _ln_fwd(x2d, w, b, *, eps: float, block_rows: int):
    n, d = x2d.shape
    while n % block_rows != 0:
        block_rows //= 2
    grid = (n // block_rows,)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x2d.dtype),
        interpret=True,
    )(x2d, w, b)


@jax.custom_vjp
def layernorm(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused LayerNorm over the last axis of x (any leading shape)."""
    shape = x.shape
    y = _ln_fwd(x.reshape(-1, shape[-1]), w, b, eps=1e-5,
                block_rows=DEFAULT_BLOCK_ROWS)
    return y.reshape(shape)


def _fwd_rule(x, w, b):
    return layernorm(x, w, b), (x, w)


def _bwd_rule(res, dy):
    x, w = res
    eps = 1e-5
    d = x.shape[-1]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = xc * rstd
    # dL/dxhat
    dxhat = dy * w
    # closed-form layernorm backward
    dx = (dxhat - jnp.mean(dxhat, axis=-1, keepdims=True)
          - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)) * rstd
    red_axes = tuple(range(x.ndim - 1))
    dw = jnp.sum(dy * xhat, axis=red_axes)
    db = jnp.sum(dy, axis=red_axes)
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(w.dtype)


layernorm.defvjp(_fwd_rule, _bwd_rule)
