"""Layer-1 Pallas kernel: fused causal flash attention (online softmax).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA flash-attention
schedule — one threadblock per (head, q-tile), K/V streamed through SRAM — is
re-expressed for the TPU model. The grid is (batch·heads, q-blocks); each
program holds one q tile in VMEM (via BlockSpec) and streams K/V tiles with an
online-softmax accumulator in registers/VMEM scratch. QKᵀ and PV are MXU
matmuls with fp32 `preferred_element_type` accumulation.

The kernel is lowered with `interpret=True` so the emitted HLO runs on any
PJRT backend (the repo's Rust CPU runtime). A `jax.custom_vjp` attaches the
standard flash-attention backward (recomputing P from the saved logsumexp) so
Layer-2's `jax.vjp` can differentiate straight through the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30  # large-negative instead of -inf: keeps exp() NaN-free on fully masked rows


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k: int,
                      scale: float, causal: bool, seq_len: int):
    """One (bh, q-block) program: stream K/V tiles, online softmax."""
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    qi = pl.program_id(1)
    q = q_ref[0, :, :].astype(jnp.float32)  # (block_q, d) tile resident in VMEM

    num_kb = seq_len // block_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        # MXU contraction, fp32 accumulate.
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))

    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[0, :] = (m + jnp.log(l)).astype(lse_ref.dtype)


def _flash_fwd(q, k, v, *, block_q: int, block_k: int, scale: float, causal: bool):
    bh, t, d = q.shape
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)
    grid = (bh, t // block_q)
    kernel = functools.partial(_flash_fwd_kernel, block_k=block_k, scale=scale,
                               causal=causal, seq_len=t)
    out_shapes = (
        jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        jax.ShapeDtypeStruct((bh, t), jnp.float32),
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ),
        out_shape=out_shapes,
        interpret=True,  # CPU-PJRT executable HLO; Mosaic lowering is TPU-only
    )(q, k, v)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None) -> jax.Array:
    """Fused causal attention over (BH, T, d); equals `ref.attention`."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    o, _ = _flash_fwd(q, k, v, block_q=_pick_block(q.shape[1], DEFAULT_BLOCK_Q),
                      block_k=_pick_block(q.shape[1], DEFAULT_BLOCK_K),
                      scale=scale, causal=causal)
    return o


def _pick_block(t: int, preferred: int) -> int:
    """Largest power-of-two block ≤ preferred that divides T."""
    b = preferred
    while b > 1 and t % b != 0:
        b //= 2
    return b


def _fwd_rule(q, k, v, causal, scale):
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    o, lse = _flash_fwd(q, k, v, block_q=_pick_block(q.shape[1], DEFAULT_BLOCK_Q),
                        block_k=_pick_block(q.shape[1], DEFAULT_BLOCK_K),
                        scale=scale, causal=causal)
    return o, (q, k, v, o, lse)


def _bwd_rule(causal, scale, res, do):
    """Standard flash-attention backward: rebuild P row-blocks from lse.

    Written in plain jnp (differentiation target is the kernel's math, the
    backward itself needs no second kernel on this CPU substrate — see
    DESIGN.md). Matches grad-of-`ref.attention` to fp32 tolerance.
    """
    q, k, v, o, lse = res
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    t = q.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, :, :], s, _NEG_INF)
    p = jnp.exp(s - lse[..., None])  # exact softmax, recomputed from residual
    dv = jnp.einsum("bqk,bqd->bkd", p, do)
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    delta = jnp.sum(do * o, axis=-1, keepdims=True)  # row dot(dO, O)
    ds = p * (dp - delta)
    dq = jnp.einsum("bqk,bkd->bqd", ds, k) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd_rule, _bwd_rule)
