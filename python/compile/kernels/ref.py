"""Pure-jnp reference oracles for every Pallas kernel (Layer 1).

These are the *correctness ground truth*: pytest checks each Pallas kernel
against the function of the same name here, and the JAX model (Layer 2) is
unit-tested against compositions of these references.

Everything is plain differentiable jax.numpy — no Pallas, no custom_vjp —
so `jax.grad` through these definitions also serves as the oracle for the
hand-written backward rules attached to the kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              scale: float | None = None) -> jax.Array:
    """Scaled dot-product attention over (BH, T, d) tensors.

    BH is the flattened batch*heads dimension. Matches the Pallas
    flash-attention kernel's semantics (fp32 accumulation, causal mask).
    """
    _, t, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def attention_lse(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                  scale: float | None = None) -> tuple[jax.Array, jax.Array]:
    """Attention plus per-row logsumexp — the residuals the flash kernel saves."""
    _, t, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, :, :], s, -jnp.inf)
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    p = jnp.exp(s - lse[..., None])
    return jnp.einsum("bqk,bkd->bqd", p, v), lse


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis; x: (..., D), w/b: (D,)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + eps)
    return xhat * w + b


# ---------------------------------------------------------------------------
# Adam (the cpu_adam analog)
# ---------------------------------------------------------------------------


def adam_step(p: jax.Array, m: jax.Array, v: jax.Array, g: jax.Array,
              *, lr: float, beta1: float = 0.9, beta2: float = 0.999,
              eps: float = 1e-8, weight_decay: float = 0.0,
              bias_corr1: float = 1.0, bias_corr2: float = 1.0
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused Adam(W) update on flat fp32 vectors.

    `bias_corr1/2` are the precomputed (1 - beta^t) factors — the paper's
    cpu_adam precomputes these per step instead of calling pow in the loop.
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)
    m_hat = m_new / bias_corr1
    v_hat = v_new / bias_corr2
    update = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p
    return p - lr * update, m_new, v_new


# ---------------------------------------------------------------------------
# GELU (used by the FFN; reference for the fused-FFN path)
# ---------------------------------------------------------------------------


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximated GELU, the GPT-2/Megatron variant."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))
