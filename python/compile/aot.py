"""AOT bridge: lower every Layer-2 stage to HLO *text* + a manifest for Rust.

Run once at build time (`make artifacts`); Python never runs on the training
path. The interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` crate) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids, so text round-trips cleanly.

Usage:
    python -m compile.aot --preset tiny --out-dir ../artifacts/tiny
    python -m compile.aot --preset e2e  --out-dir ../artifacts/e2e

Each preset directory receives one `<stage>.hlo.txt` per stage plus
`manifest.json` describing shapes, the parameter calling convention, and
initialization — everything the Rust runtime needs to allocate, initialize,
chunk, and offload the training state without importing Python.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import adam as adam_kernel

PRESETS: dict[str, model.ModelConfig] = {
    # Fast preset for unit/integration tests and the quickstart example.
    "tiny": model.ModelConfig(micro_batch=2, seq_len=32, hidden=64, n_heads=4,
                              vocab=256, n_layers=2, adam_chunk=1 << 14),
    # ~10M params — CI-sized end-to-end runs.
    "small": model.ModelConfig(micro_batch=2, seq_len=64, hidden=256, n_heads=8,
                               vocab=4096, n_layers=4, adam_chunk=1 << 18),
    # ~100M params — the EXPERIMENTS.md end-to-end training run (GPT-2-small
    # scale: D=768, L=12, H=12; vocab 16k, seq 128).
    "e2e": model.ModelConfig(micro_batch=2, seq_len=128, hidden=768, n_heads=12,
                             vocab=16384, n_layers=12, adam_chunk=1 << 20),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_stages(cfg: model.ModelConfig) -> dict[str, str]:
    """Lower every stage for `cfg`; returns {stage_name: hlo_text}."""
    b, t, d, v = cfg.micro_batch, cfg.seq_len, cfg.hidden, cfg.vocab
    act = _spec((b, t, d))
    tok = _spec((b, t), jnp.int32)
    pspecs = [_spec(s) for _, s in cfg.layer_param_shapes()]

    def layer_fwd_fn(x, *params):
        return (model.block_fwd(x, params, cfg),)

    def layer_bwd_fn(x_ckpt, dy, *params):
        return model.block_bwd(x_ckpt, dy, params, cfg)

    def embed_fwd_fn(tokens, wte, wpe):
        return (model.embed_fwd(tokens, wte, wpe),)

    def embed_bwd_fn(tokens, dx):
        return model.embed_bwd(tokens, dx, v)

    def head_loss_fn(x, lnf_w, lnf_b, wte, targets):
        return model.head_loss(x, lnf_w, lnf_b, wte, targets)

    def adam_fn(p, m, vv, g, hyper):
        return adam_kernel.adam_step(p, m, vv, g, hyper)

    chunk = _spec((cfg.adam_chunk,))
    stages = {
        "embed_fwd": jax.jit(embed_fwd_fn, keep_unused=True).lower(tok, _spec((v, d)), _spec((t, d))),
        "layer_fwd": jax.jit(layer_fwd_fn, keep_unused=True).lower(act, *pspecs),
        "layer_bwd": jax.jit(layer_bwd_fn, keep_unused=True).lower(act, act, *pspecs),
        "head_loss": jax.jit(head_loss_fn, keep_unused=True).lower(
            act, _spec((d,)), _spec((d,)), _spec((v, d)), tok),
        "embed_bwd": jax.jit(embed_bwd_fn, keep_unused=True).lower(tok, act),
        "adam_step": jax.jit(adam_fn, keep_unused=True).lower(chunk, chunk, chunk, chunk, _spec((8,))),
    }
    return {name: to_hlo_text(lowered) for name, lowered in stages.items()}


def _init_kind(name: str) -> str:
    """Initialization class per tensor name (GPT-2 scheme)."""
    if name.endswith("_b") or name in ("lnf_b",) or name.startswith("b_"):
        return "zeros"
    if name in ("ln1_w", "ln2_w", "lnf_w"):
        return "ones"
    if name in ("w_o", "w_fc2"):
        return "normal_residual"  # std 0.02 / sqrt(2 L)
    return "normal"  # std 0.02


def build_manifest(cfg: model.ModelConfig, preset: str,
                   artifacts: dict[str, str]) -> dict:
    layer_params = [
        {"name": n, "shape": list(s), "numel": int(functools.reduce(lambda a, b: a * b, s, 1)),
         "init": _init_kind(n)}
        for n, s in cfg.layer_param_shapes()
    ]
    embed_params = [
        {"name": "wte", "shape": [cfg.vocab, cfg.hidden],
         "numel": cfg.vocab * cfg.hidden, "init": "normal"},
        {"name": "wpe", "shape": [cfg.seq_len, cfg.hidden],
         "numel": cfg.seq_len * cfg.hidden, "init": "normal_pos"},
    ]
    head_params = [
        {"name": "lnf_w", "shape": [cfg.hidden], "numel": cfg.hidden, "init": "ones"},
        {"name": "lnf_b", "shape": [cfg.hidden], "numel": cfg.hidden, "init": "zeros"},
    ]
    return {
        "preset": preset,
        "config": {
            "micro_batch": cfg.micro_batch,
            "seq_len": cfg.seq_len,
            "hidden": cfg.hidden,
            "n_heads": cfg.n_heads,
            "vocab": cfg.vocab,
            "n_layers": cfg.n_layers,
            "ffn_mult": cfg.ffn_mult,
            "adam_chunk": cfg.adam_chunk,
        },
        "artifacts": {name: f"{name}.hlo.txt" for name in artifacts},
        "layer_params": layer_params,
        "embed_params": embed_params,
        "head_params": head_params,
        "calling_convention": {
            "embed_fwd": "(tokens i32[B,T], wte[V,D], wpe[T,D]) -> (x[B,T,D],)",
            "layer_fwd": "(x[B,T,D], p0..p11) -> (y[B,T,D],)",
            "layer_bwd": "(x_ckpt[B,T,D], dy[B,T,D], p0..p11) -> (dx, dp0..dp11)",
            "head_loss": "(x, lnf_w, lnf_b, wte, targets) -> (loss, dx, dlnf_w, dlnf_b, dwte)",
            "embed_bwd": "(tokens, dx) -> (dwte, dwpe)",
            "adam_step": "(p[C], m[C], v[C], g[C], hyper[8]) -> (p', m', v')",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--out-dir", default=None,
                    help="default: ../artifacts/<preset>")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    out_dir = args.out_dir or os.path.join("..", "artifacts", args.preset)
    os.makedirs(out_dir, exist_ok=True)

    texts = build_stages(cfg)
    for name, text in texts.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text) / 1024:.0f} KiB)")

    manifest = build_manifest(cfg, args.preset, texts)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
