"""L2 correctness: staged model vs monolithic oracle, schedule equivalence.

The key property the Rust coordinator relies on is proved here in miniature:
running stages (embed → layer×L → head → layer_bwd×L → embed_bwd) with
gradient accumulation over micro-batches — in EITHER horizontal or vertical
order — produces exactly the gradients of the monolithic loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = model.ModelConfig(micro_batch=2, seq_len=16, hidden=32, n_heads=4,
                        vocab=64, n_layers=2, adam_chunk=1 << 10)


def init_params(cfg: model.ModelConfig, key):
    ks = iter(jax.random.split(key, 64))

    def tensor(name, shape):
        if name.startswith(("b_", "ln1_b", "ln2_b", "lnf_b")) or name.endswith("_b"):
            return jnp.zeros(shape)
        if name in ("ln1_w", "ln2_w", "lnf_w"):
            return jnp.ones(shape)
        std = 0.02 / (2 * cfg.n_layers) ** 0.5 if name in ("w_o", "w_fc2") else 0.02
        return jax.random.normal(next(ks), shape) * std

    layers = [tuple(tensor(n, s) for n, s in cfg.layer_param_shapes())
              for _ in range(cfg.n_layers)]
    wte = jax.random.normal(next(ks), (cfg.vocab, cfg.hidden)) * 0.02
    wpe = jax.random.normal(next(ks), (cfg.seq_len, cfg.hidden)) * 0.01
    lnf_w, lnf_b = jnp.ones(cfg.hidden), jnp.zeros(cfg.hidden)
    return layers, wte, wpe, lnf_w, lnf_b


def batch(cfg, key):
    tokens = jax.random.randint(key, (cfg.micro_batch, cfg.seq_len), 0, cfg.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    return tokens, targets


class TestStages:
    def setup_method(self):
        self.layers, self.wte, self.wpe, self.lnf_w, self.lnf_b = \
            init_params(CFG, jax.random.PRNGKey(0))
        self.tokens, self.targets = batch(CFG, jax.random.PRNGKey(1))

    def test_block_fwd_finite_and_shaped(self):
        x = model.embed_fwd(self.tokens, self.wte, self.wpe)
        y = model.block_fwd(x, self.layers[0], CFG)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()

    def test_block_bwd_matches_autodiff(self):
        x = model.embed_fwd(self.tokens, self.wte, self.wpe)
        dy = jax.random.normal(jax.random.PRNGKey(2), x.shape)
        outs = model.block_bwd(x, dy, self.layers[0], CFG)
        # oracle: vjp of block_fwd directly
        _, vjp = jax.vjp(lambda xx, ps: model.block_fwd(xx, ps, CFG),
                         x, self.layers[0])
        dx_ref, dps_ref = vjp(dy)
        np.testing.assert_allclose(outs[0], dx_ref, atol=1e-5, rtol=1e-5)
        for a, b in zip(outs[1:], dps_ref):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_head_loss_gradients_match_numeric(self):
        x = model.embed_fwd(self.tokens, self.wte, self.wpe)
        loss, dx, dlnf_w, dlnf_b, dwte = model.head_loss(
            x, self.lnf_w, self.lnf_b, self.wte, self.targets)
        assert loss.shape == ()
        # directional numerical check on dx
        eps = 1e-3
        direction = jax.random.normal(jax.random.PRNGKey(3), x.shape)
        direction = direction / jnp.linalg.norm(direction)

        def f(xx):
            return model.head_loss(xx, self.lnf_w, self.lnf_b, self.wte,
                                   self.targets)[0]

        num = (f(x + eps * direction) - f(x - eps * direction)) / (2 * eps)
        ana = jnp.vdot(dx, direction)
        np.testing.assert_allclose(num, ana, atol=5e-4, rtol=5e-2)

    def test_embed_bwd_scatter(self):
        dx = jax.random.normal(jax.random.PRNGKey(4),
                               (CFG.micro_batch, CFG.seq_len, CFG.hidden))
        dwte, dwpe = model.embed_bwd(self.tokens, dx, CFG.vocab)
        assert dwte.shape == (CFG.vocab, CFG.hidden)
        assert dwpe.shape == (CFG.seq_len, CFG.hidden)
        # rows of untouched vocab entries are zero
        used = set(np.asarray(self.tokens).ravel().tolist())
        unused = [i for i in range(CFG.vocab) if i not in used][:5]
        for i in unused:
            np.testing.assert_array_equal(np.asarray(dwte[i]), 0.0)
        np.testing.assert_allclose(dwpe, dx.sum(0), atol=1e-6)

    def test_staged_loss_equals_monolithic(self):
        x = model.embed_fwd(self.tokens, self.wte, self.wpe)
        for p in self.layers:
            x = model.block_fwd(x, p, CFG)
        loss_staged = model.head_loss(x, self.lnf_w, self.lnf_b, self.wte,
                                      self.targets)[0]
        loss_mono = model.full_forward_loss(
            self.tokens, self.targets, self.wte, self.wpe, self.lnf_w,
            self.lnf_b, self.layers, CFG)
        np.testing.assert_allclose(loss_staged, loss_mono, atol=1e-6)


class TestScheduleEquivalence:
    """Horizontal and vertical gradient accumulation produce identical grads."""

    def setup_method(self):
        self.cfg = CFG
        self.layers, self.wte, self.wpe, self.lnf_w, self.lnf_b = \
            init_params(CFG, jax.random.PRNGKey(10))
        keys = jax.random.split(jax.random.PRNGKey(11), 3)
        self.mbs = [batch(CFG, k) for k in keys]  # 3 micro-batches

    def _staged_grads(self, order: str):
        """Run the staged pipeline with horizontal or vertical scheduling."""
        cfg, L, M = self.cfg, self.cfg.n_layers, len(self.mbs)
        ckpts = [[None] * (L + 1) for _ in range(M)]  # [mb][layer] input ckpt
        # ---- forward ----
        if order == "horizontal":
            for m, (tok, _) in enumerate(self.mbs):
                x = model.embed_fwd(tok, self.wte, self.wpe)
                for l in range(L):
                    ckpts[m][l] = x
                    x = model.block_fwd(x, self.layers[l], cfg)
                ckpts[m][L] = x
        else:  # vertical: all micro-batches per layer, alternating order
            xs = [model.embed_fwd(tok, self.wte, self.wpe) for tok, _ in self.mbs]
            for l in range(L):
                mb_order = range(M) if l % 2 == 0 else reversed(range(M))
                for m in mb_order:
                    ckpts[m][l] = xs[m]
                    xs[m] = model.block_fwd(xs[m], self.layers[l], cfg)
            for m in range(M):
                ckpts[m][L] = xs[m]

        # ---- head + backward with accumulation ----
        acc = [None] * L
        dwte_acc, dwpe_acc = 0.0, 0.0
        dlnfw_acc, dlnfb_acc = 0.0, 0.0
        dxs = [None] * M
        loss_sum = 0.0
        for m in range(M):
            _, tgt = self.mbs[m]
            loss, dx, dlw, dlb, dwte = model.head_loss(
                ckpts[m][L], self.lnf_w, self.lnf_b, self.wte, tgt)
            loss_sum += loss
            dxs[m] = dx
            dlnfw_acc += dlw
            dlnfb_acc += dlb
            dwte_acc += dwte

        def bwd_layer(l, m):
            nonlocal acc
            outs = model.block_bwd(ckpts[m][l], dxs[m], self.layers[l], self.cfg)
            dxs[m] = outs[0]
            grads = outs[1:]
            acc[l] = grads if acc[l] is None else tuple(
                a + g for a, g in zip(acc[l], grads))

        if order == "horizontal":
            for m in range(M):
                for l in reversed(range(L)):
                    bwd_layer(l, m)
        else:
            for l in reversed(range(L)):
                mb_order = range(M) if l % 2 == 0 else reversed(range(M))
                for m in mb_order:
                    bwd_layer(l, m)

        for m in range(M):
            tok, _ = self.mbs[m]
            dwte_e, dwpe_e = model.embed_bwd(tok, dxs[m], self.cfg.vocab)
            dwte_acc += dwte_e
            dwpe_acc += dwpe_e
        return loss_sum, acc, dwte_acc, dwpe_acc, dlnfw_acc, dlnfb_acc

    def _monolithic_grads(self):
        def total_loss(layers, wte, wpe, lnf_w, lnf_b):
            s = 0.0
            for tok, tgt in self.mbs:
                s += model.full_forward_loss(tok, tgt, wte, wpe, lnf_w, lnf_b,
                                             layers, self.cfg)
            return s

        return jax.value_and_grad(total_loss, argnums=(0, 1, 2, 3, 4))(
            self.layers, self.wte, self.wpe, self.lnf_w, self.lnf_b)

    @pytest.mark.parametrize("order", ["horizontal", "vertical"])
    def test_schedule_matches_monolithic_autodiff(self, order):
        loss, acc, dwte, dwpe, dlw, dlb = self._staged_grads(order)
        loss_ref, (dlayers, dwte_ref, dwpe_ref, dlw_ref, dlb_ref) = \
            self._monolithic_grads()
        np.testing.assert_allclose(loss, loss_ref, atol=1e-5, rtol=1e-5)
        for l in range(self.cfg.n_layers):
            for a, b in zip(acc[l], dlayers[l]):
                np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)
        np.testing.assert_allclose(dwte, dwte_ref, atol=3e-5, rtol=3e-5)
        np.testing.assert_allclose(dwpe, dwpe_ref, atol=3e-5, rtol=3e-5)
        np.testing.assert_allclose(dlw, dlw_ref, atol=3e-5, rtol=3e-5)
        np.testing.assert_allclose(dlb, dlb_ref, atol=3e-5, rtol=3e-5)

    def test_horizontal_equals_vertical_exactly(self):
        lh = self._staged_grads("horizontal")
        lv = self._staged_grads("vertical")
        np.testing.assert_allclose(lh[0], lv[0], atol=1e-6)
        for l in range(self.cfg.n_layers):
            for a, b in zip(lh[1][l], lv[1][l]):
                # identical op sequence per accumulate -> tight tolerance
                np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)


class TestGelu:
    def test_matches_tanh_formula(self):
        x = jnp.linspace(-4, 4, 101)
        got = ref.gelu(x)
        want = 0.5 * x * (1 + np.tanh(np.sqrt(2 / np.pi) * (x + 0.044715 * x ** 3)))
        np.testing.assert_allclose(got, np.asarray(want), atol=1e-6)
