"""AOT bridge tests: artifacts lower, parse, and the manifest is consistent."""

from __future__ import annotations

import json
import os

import pytest

from compile import aot, model

TINY = aot.PRESETS["tiny"]


@pytest.fixture(scope="module")
def stages():
    return aot.build_stages(TINY)


class TestLowering:
    def test_all_stages_present(self, stages):
        assert set(stages) == {"embed_fwd", "layer_fwd", "layer_bwd",
                               "head_loss", "embed_bwd", "adam_step"}

    def test_hlo_text_has_entry(self, stages):
        for name, text in stages.items():
            assert "ENTRY" in text, name
            assert "HloModule" in text, name

    @staticmethod
    def _entry_param_count(text: str) -> int:
        """Count parameter() instructions inside the ENTRY computation only
        (nested while-loop computations from the interpret-mode Pallas
        lowering have their own parameters)."""
        lines = text.splitlines()
        start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        n = 0
        for line in lines[start + 1:]:
            if line.startswith("}"):
                break
            if " parameter(" in line:
                n += 1
        return n

    def test_layer_fwd_signature(self, stages):
        # 1 activation + 12 params = 13 parameters in the entry computation.
        assert self._entry_param_count(stages["layer_fwd"]) == 13

    def test_layer_bwd_signature(self, stages):
        assert self._entry_param_count(stages["layer_bwd"]) == 14  # x, dy, 12 p

    def test_adam_signature(self, stages):
        assert self._entry_param_count(stages["adam_step"]) == 5

    def test_no_custom_calls(self, stages):
        """interpret=True Pallas must lower to plain HLO — a Mosaic
        custom-call would be unexecutable on the CPU PJRT plugin."""
        for name, text in stages.items():
            assert "mosaic" not in text.lower(), name


class TestManifest:
    def test_roundtrip_and_consistency(self, stages, tmp_path):
        man = aot.build_manifest(TINY, "tiny", stages)
        path = tmp_path / "manifest.json"
        path.write_text(json.dumps(man))
        man2 = json.loads(path.read_text())
        assert man2 == man
        cfg = man["config"]
        assert cfg["hidden"] % cfg["n_heads"] == 0
        total = sum(p["numel"] for p in man["layer_params"])
        assert total == TINY.layer_param_numel()
        d, f = cfg["hidden"], cfg["ffn_mult"] * cfg["hidden"]
        assert total == 12 * d * d // 1 + 0 + (4 * d + 2 * f + 3 * d + d + d + d + d) \
            or total > 0  # exact identity checked below
        # closed form: ln(4d) + qkv(3d^2+3d) + proj(d^2+d) + fc1(d f + f) + fc2(f d + d)
        closed = 4 * d + 3 * d * d + 3 * d + d * d + d + d * f + f + f * d + d
        assert total == closed

    def test_init_kinds(self):
        man = aot.build_manifest(TINY, "tiny", {})
        kinds = {p["name"]: p["init"] for p in man["layer_params"]}
        assert kinds["ln1_w"] == "ones"
        assert kinds["b_qkv"] == "zeros"
        assert kinds["w_o"] == "normal_residual"
        assert kinds["w_qkv"] == "normal"

    def test_presets_are_sane(self):
        for name, cfg in aot.PRESETS.items():
            assert cfg.hidden % cfg.n_heads == 0, name
            assert cfg.seq_len % 2 == 0, name
            assert cfg.adam_chunk & (cfg.adam_chunk - 1) == 0, name

    def test_e2e_preset_is_about_100m_params(self):
        cfg = aot.PRESETS["e2e"]
        total = (cfg.n_layers * cfg.layer_param_numel()
                 + cfg.vocab * cfg.hidden + cfg.seq_len * cfg.hidden
                 + 2 * cfg.hidden)
        assert 80e6 < total < 130e6, total


class TestCLI:
    def test_main_writes_artifacts(self, tmp_path, monkeypatch):
        out = tmp_path / "arts"
        monkeypatch.setattr("sys.argv",
                            ["aot", "--preset", "tiny", "--out-dir", str(out)])
        aot.main()
        files = sorted(os.listdir(out))
        assert "manifest.json" in files
        assert sum(f.endswith(".hlo.txt") for f in files) == 6
