"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the power-of-two block-picking logic) so the
kernels are exercised well away from the single shape the AOT path bakes in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.adam import BLOCK, adam_step, pack_hyper
from compile.kernels.flash_attention import _pick_block, flash_attention
from compile.kernels.layernorm import layernorm

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize("bh,t,d", [(1, 8, 4), (2, 32, 16), (3, 64, 16),
                                        (4, 128, 32), (2, 256, 64)])
    def test_fwd_matches_ref(self, bh, t, d):
        ks = jax.random.split(jax.random.PRNGKey(t + d), 3)
        q, k, v = (_rand(kk, (bh, t, d)) for kk in ks)
        np.testing.assert_allclose(flash_attention(q, k, v),
                                   ref.attention(q, k, v), atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("causal", [True, False])
    def test_causal_flag(self, causal):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q, k, v = (_rand(kk, (2, 64, 16)) for kk in ks)
        got = flash_attention(q, k, v, causal, None)
        want = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_custom_scale(self):
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q, k, v = (_rand(kk, (2, 32, 8)) for kk in ks)
        np.testing.assert_allclose(flash_attention(q, k, v, True, 0.25),
                                   ref.attention(q, k, v, scale=0.25),
                                   atol=2e-5, rtol=2e-5)

    def test_grad_matches_autodiff_of_ref(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q, k, v = (_rand(kk, (2, 64, 16)) for kk in ks)

        def loss_kernel(q, k, v):
            return jnp.sum(flash_attention(q, k, v) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(ref.attention(q, k, v) ** 2)

        g = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g, gr):
            np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)

    def test_first_row_attends_to_itself_only(self):
        # Row 0 under causal masking = v[0] exactly.
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q, k, v = (_rand(kk, (1, 32, 8)) for kk in ks)
        o = flash_attention(q, k, v)
        np.testing.assert_allclose(o[0, 0], v[0, 0], atol=1e-5, rtol=1e-5)

    def test_softmax_rows_are_convex_combinations(self):
        # With v == const, output must be that const (softmax sums to 1).
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        q, k = (_rand(kk, (2, 64, 16)) for kk in ks)
        v = jnp.ones((2, 64, 16)) * 3.5
        np.testing.assert_allclose(flash_attention(q, k, v), v, atol=1e-5)

    def test_numerical_stability_large_logits(self):
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        q, k, v = (_rand(kk, (1, 32, 8)) * 30.0 for kk in ks)
        o = flash_attention(q, k, v)
        assert np.isfinite(np.asarray(o)).all()
        np.testing.assert_allclose(o, ref.attention(q, k, v), atol=1e-4, rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(bh=st.integers(1, 4),
           t_pow=st.integers(2, 7),
           d=st.sampled_from([4, 8, 16, 32]))
    def test_hypothesis_shape_sweep(self, bh, t_pow, d):
        t = 1 << t_pow
        ks = jax.random.split(jax.random.PRNGKey(bh * 1000 + t * 10 + d), 3)
        q, k, v = (_rand(kk, (bh, t, d)) for kk in ks)
        np.testing.assert_allclose(flash_attention(q, k, v),
                                   ref.attention(q, k, v), atol=3e-5, rtol=3e-5)

    @given(t=st.integers(1, 512), pref=st.sampled_from([32, 64, 128]))
    @settings(max_examples=50, deadline=None)
    def test_pick_block_divides(self, t, pref):
        b = _pick_block(t, pref)
        assert b >= 1 and (b == 1 or t % b == 0)
        assert b <= pref


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


class TestLayerNorm:
    @pytest.mark.parametrize("shape", [(4, 16), (2, 32, 48), (1, 8, 64), (3, 5, 7)])
    def test_fwd_matches_ref(self, shape):
        ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 3)
        x = _rand(ks[0], shape)
        w, b = _rand(ks[1], shape[-1:]), _rand(ks[2], shape[-1:])
        np.testing.assert_allclose(layernorm(x, w, b), ref.layernorm(x, w, b),
                                   atol=1e-5, rtol=1e-5)

    def test_output_rows_are_normalized(self):
        x = _rand(jax.random.PRNGKey(0), (8, 128)) * 5 + 3
        y = layernorm(x, jnp.ones(128), jnp.zeros(128))
        np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.std(np.asarray(y), -1), 1.0, atol=1e-3)

    def test_grads_match_ref_autodiff(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        x, w, b = _rand(ks[0], (4, 16, 32)), _rand(ks[1], (32,)), _rand(ks[2], (32,))

        def f(fn):
            return jax.grad(lambda x, w, b: jnp.sum(jnp.sin(fn(x, w, b))),
                            argnums=(0, 1, 2))(x, w, b)

        for a, bb in zip(f(layernorm), f(ref.layernorm)):
            np.testing.assert_allclose(a, bb, atol=2e-4, rtol=2e-4)

    @settings(max_examples=15, deadline=None)
    @given(rows=st.integers(1, 33), d=st.sampled_from([8, 16, 48, 96, 128]))
    def test_hypothesis_shape_sweep(self, rows, d):
        ks = jax.random.split(jax.random.PRNGKey(rows * 1000 + d), 3)
        x = _rand(ks[0], (rows, d))
        w, b = _rand(ks[1], (d,)), _rand(ks[2], (d,))
        np.testing.assert_allclose(layernorm(x, w, b), ref.layernorm(x, w, b),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# adam
# ---------------------------------------------------------------------------


class TestAdam:
    def _state(self, n, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        return (_rand(ks[0], (n,)), jnp.abs(_rand(ks[1], (n,))) * 0.1,
                jnp.abs(_rand(ks[2], (n,))) * 0.01, _rand(ks[3], (n,)))

    @pytest.mark.parametrize("n", [BLOCK, 4 * BLOCK, 256, 1 << 14])
    def test_matches_ref(self, n):
        p, m, v, g = self._state(n)
        hy = pack_hyper(3e-4, step=5, weight_decay=0.1)
        got = adam_step(p, m, v, g, hy)
        want = ref.adam_step(p, m, v, g, lr=3e-4, weight_decay=0.1,
                             bias_corr1=1 - 0.9 ** 5, bias_corr2=1 - 0.999 ** 5)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)

    def test_partition_invariance(self):
        """§6.5: results must not depend on how the vector is chunked."""
        n = 4 * BLOCK
        p, m, v, g = self._state(n, seed=1)
        hy = pack_hyper(1e-3, step=2)
        whole = adam_step(p, m, v, g, hy)
        halves = [adam_step(p[i:i + n // 2], m[i:i + n // 2], v[i:i + n // 2],
                            g[i:i + n // 2], hy) for i in (0, n // 2)]
        for j in range(3):
            np.testing.assert_array_equal(
                np.asarray(whole[j]),
                np.concatenate([np.asarray(h[j]) for h in halves]))

    def test_zero_grad_pure_decay(self):
        n = BLOCK
        p, m, v, _ = self._state(n, seed=2)
        m = jnp.zeros(n)
        v = jnp.zeros(n)
        hy = pack_hyper(1e-2, step=1, weight_decay=0.5)
        p2, m2, v2 = adam_step(p, m, v, jnp.zeros(n), hy)
        np.testing.assert_allclose(p2, p * (1 - 1e-2 * 0.5), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(m2), np.zeros(n))
        np.testing.assert_array_equal(np.asarray(v2), np.zeros(n))

    def test_grad_scale_folded_in(self):
        n = BLOCK
        p, m, v, g = self._state(n, seed=3)
        scaled = adam_step(p, m, v, g, pack_hyper(1e-3, step=1, grad_scale=0.5))
        manual = adam_step(p, m, v, 0.5 * g, pack_hyper(1e-3, step=1))
        for a, b in zip(scaled, manual):
            np.testing.assert_allclose(a, b, atol=1e-7)

    @settings(max_examples=10, deadline=None)
    @given(step=st.integers(1, 1000),
           lr=st.floats(1e-5, 1e-1),
           n_pow=st.integers(8, 13))
    def test_hypothesis_param_sweep(self, step, lr, n_pow):
        n = 1 << n_pow
        p, m, v, g = self._state(n, seed=step)
        hy = pack_hyper(lr, step=step)
        got = adam_step(p, m, v, g, hy)
        want = ref.adam_step(p, m, v, g, lr=lr,
                             bias_corr1=1 - 0.9 ** step,
                             bias_corr2=1 - 0.999 ** step)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
