//! End-to-end validation run (EXPERIMENTS.md): train a ~100M-parameter GPT
//! through the FULL stack — AOT Pallas/JAX artifacts executed via PJRT, the
//! vertical scheduler, real file-backed SSD offload of optimizer states with
//! throttled bandwidth, and the delayed-α optimizer overlap — on a synthetic
//! Zipf+bigram corpus, logging the loss curve.
//!
//!     make artifacts-e2e
//!     cargo run --release --example train_e2e -- --steps 200
//!
//! Use `--preset small` (~13M params) for a faster smoke run.

use greedysnake::coordinator::TrainerConfig;
use greedysnake::runtime::Manifest;
use greedysnake::trainer::{train, ScheduleKind};
use greedysnake::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("train_e2e", "end-to-end ~100M GPT training run")
        .opt("preset", "artifact preset (e2e|small|tiny)", Some("e2e"))
        .opt("steps", "iterations", Some("200"))
        .opt("micro-batches", "micro-batches per iteration", Some("2"))
        .opt("alpha", "delay ratio", Some("0.25"))
        .opt("ssd-read-gbps", "SSD read throttle (GB/s)", Some("3.0"))
        .opt("ssd-write-gbps", "SSD write throttle (GB/s)", Some("2.8"))
        .opt("out", "loss-curve TSV path", Some("bench_out/train_e2e_loss.tsv"))
        .parse()?;
    let preset = cli.get("preset").unwrap();
    let manifest = Manifest::load(format!("artifacts/{preset}"))?;
    let shape = manifest.config;
    println!(
        "e2e run: preset={preset} D={} L={} V={} T={} B={} — {:.1}M params",
        shape.hidden,
        shape.n_layers,
        shape.vocab,
        shape.seq_len,
        shape.micro_batch,
        manifest.total_numel() as f64 / 1e6
    );
    let r: f64 = cli.get_parsed("ssd-read-gbps")?;
    let w: f64 = cli.get_parsed("ssd-write-gbps")?;
    let cfg = TrainerConfig {
        alpha: cli.get_parsed("alpha")?,
        opt_on_ssd: true,
        ssd_read_bps: r * 1e9,
        ssd_write_bps: w * 1e9,
        ..Default::default()
    };
    let m: usize = cli.get_parsed("micro-batches")?;
    let steps: u64 = cli.get_parsed("steps")?;
    let t0 = std::time::Instant::now();
    let log = train(manifest, cfg, ScheduleKind::Vertical, steps, m, 10)?;
    let wall = t0.elapsed().as_secs_f64();

    // persist the loss curve
    let out = cli.get("out").unwrap();
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut tsv = String::from("#step\tloss\tgrad_norm\tseconds\n");
    for (i, ((l, g), s)) in log
        .losses
        .iter()
        .zip(&log.grad_norms)
        .zip(&log.step_seconds)
        .enumerate()
    {
        tsv.push_str(&format!("{i}\t{l:.5}\t{g:.4}\t{s:.3}\n"));
    }
    std::fs::write(&out, tsv)?;

    let tokens_per_step = m * shape.micro_batch * shape.seq_len;
    println!(
        "\n=== e2e summary ===\nsteps: {}\nloss: {:.4} -> {:.4}\nwall: {:.1}s ({:.2}s/step, {:.0} tokens/s)\nssd read/written: {} / {}\nloss curve: {}",
        log.losses.len(),
        log.losses[0],
        log.final_loss(),
        wall,
        wall / steps as f64,
        log.tokens_per_s(tokens_per_step),
        greedysnake::util::stats::fmt_bytes(log.ssd_read as f64),
        greedysnake::util::stats::fmt_bytes(log.ssd_written as f64),
        out,
    );
    assert!(
        log.final_loss() < log.losses[0],
        "loss must decrease over the run"
    );
    Ok(())
}
