//! Run Algorithm 1 (the LP-based configuration search) for every paper
//! evaluation point and print the chosen micro-batch count, delay ratio α,
//! and storage ratios — the configurations Figure 10 is driven by.
//!
//!     cargo run --release --example config_search

use greedysnake::lp::find_optimal_config;
use greedysnake::machine::{MACHINE1_A5000, MACHINE2_A100};
use greedysnake::modelcfg::{GPT_175B, GPT_30B, GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::SystemParams;
use greedysnake::util::table::Table;

fn main() -> anyhow::Result<()> {
    let points = [
        ("GPT-30B", GPT_30B, MACHINE1_A5000, 1u64),
        ("GPT-30B", GPT_30B, MACHINE1_A5000, 4),
        ("GPT-65B", GPT_65B, MACHINE1_A5000, 1),
        ("GPT-65B", GPT_65B, MACHINE2_A100, 1),
        ("GPT-65B", GPT_65B, MACHINE2_A100, 4),
        ("GPT-175B", GPT_175B, MACHINE2_A100, 1),
    ];
    let mut t = Table::new(
        "Algorithm 1 — optimal configurations per evaluation point",
        &["model", "machine", "gpus", "M*", "alpha*", "ckpt/param/opt CPU", "tokens/s"],
    );
    for (name, model, machine, gpus) in points {
        let sp = SystemParams::new(machine.with_gpus(gpus), model, 2, SEQ_LEN);
        match find_optimal_config(&sp) {
            Some(b) => {
                t.row(&[
                    name.into(),
                    machine.name.into(),
                    gpus.to_string(),
                    b.m.to_string(),
                    format!("{:.2}", b.alpha),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        b.ratios.ckpt_cpu, b.ratios.param_cpu, b.ratios.opt_cpu
                    ),
                    format!("{:.0}", b.tokens_per_s),
                ]);
            }
            None => {
                t.row(&[
                    name.into(),
                    machine.name.into(),
                    gpus.to_string(),
                    "-".into(),
                    "-".into(),
                    "infeasible".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t.emit(Some("bench_out/config_search.tsv"));
    Ok(())
}
