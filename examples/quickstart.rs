//! Quickstart: train the tiny preset for 20 iterations with the GreedySnake
//! vertical scheduler and watch the loss drop.
//!
//!     make artifacts && cargo run --release --example quickstart

use greedysnake::coordinator::TrainerConfig;
use greedysnake::runtime::Manifest;
use greedysnake::trainer::{train, ScheduleKind};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts/tiny")?;
    println!(
        "model: {} layers × {} hidden, {} params total",
        manifest.config.n_layers,
        manifest.config.hidden,
        manifest.total_numel()
    );
    let cfg = TrainerConfig {
        alpha: 0.25,     // delay a quarter of every optimizer step into the next forward
        opt_on_ssd: true, // optimizer states round-trip through the (file-backed) SSD tier
        ..Default::default()
    };
    let shape = manifest.config;
    let log = train(manifest, cfg, ScheduleKind::Vertical, 20, 4, 5)?;
    let tokens_per_step = 4 * shape.micro_batch * shape.seq_len;
    println!(
        "\nloss {:.3} -> {:.3} over {} steps ({:.0} tokens/s)",
        log.losses[0],
        log.final_loss(),
        log.losses.len(),
        log.tokens_per_s(tokens_per_step),
    );
    assert!(log.final_loss() < log.losses[0], "training must reduce loss");
    println!("quickstart OK");
    Ok(())
}
