//! Compare vertical (GreedySnake) vs horizontal (ZeRO-Infinity) scheduling
//! on the REAL stack: identical model/seed/data, measure loss equivalence
//! (Fig. 13 in miniature), parameter-load counts, and SSD traffic.
//!
//!     cargo run --release --example schedule_compare

use greedysnake::coordinator::TrainerConfig;
use greedysnake::runtime::Manifest;
use greedysnake::trainer::{train, ScheduleKind};
use greedysnake::util::table::Table;

fn cfg(tag: &str, alpha: f64) -> TrainerConfig {
    TrainerConfig {
        alpha,
        opt_on_ssd: true,
        ssd_path: std::env::temp_dir().join(format!("gs_cmp_{tag}_{}", std::process::id())),
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    let steps = 15u64;
    let m = 4usize;

    let vlog = train(
        Manifest::load("artifacts/tiny")?,
        cfg("v", 0.25),
        ScheduleKind::Vertical,
        steps,
        m,
        0,
    )?;
    let hlog = train(
        Manifest::load("artifacts/tiny")?,
        cfg("h", 0.0),
        ScheduleKind::Horizontal,
        steps,
        m,
        0,
    )?;

    let mut t = Table::new(
        "vertical (GreedySnake) vs horizontal (ZeRO-Infinity) — real stack",
        &["metric", "vertical", "horizontal"],
    );
    t.row(&[
        "first loss".into(),
        format!("{:.4}", vlog.losses[0]),
        format!("{:.4}", hlog.losses[0]),
    ]);
    t.row(&[
        "final loss".into(),
        format!("{:.4}", vlog.final_loss()),
        format!("{:.4}", hlog.final_loss()),
    ]);
    t.row(&[
        "ssd read".into(),
        greedysnake::util::stats::fmt_bytes(vlog.ssd_read as f64),
        greedysnake::util::stats::fmt_bytes(hlog.ssd_read as f64),
    ]);
    t.row(&[
        "ssd written".into(),
        greedysnake::util::stats::fmt_bytes(vlog.ssd_written as f64),
        greedysnake::util::stats::fmt_bytes(hlog.ssd_written as f64),
    ]);
    t.emit(None);

    // Fig. 13's claim: the two schedules train equivalently.
    let max_dev = vlog
        .losses
        .iter()
        .zip(&hlog.losses)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max per-step loss deviation: {max_dev:.5}");
    assert!(max_dev < 0.05, "schedules must train equivalently");
    println!("schedule_compare OK");
    Ok(())
}
