//! Compare vertical (GreedySnake), horizontal (ZeRO-Infinity), and
//! chunked-vertical scheduling on the REAL stack: identical model/seed/data,
//! measure loss equivalence (Fig. 13 in miniature), parameter-upload bytes
//! (the traffic the schedule controls), and SSD traffic. Then sweep the
//! async pipeline's `--io-depth` lookahead on the vertical schedule (every
//! depth must train bit-identically while depth ≥ 1 turns loads into
//! prefetch hits), and finally the data-parallel `--workers` dimension:
//! W ∈ {1, 2, 4} must be bit-identical end to end — the deterministic ring
//! all-reduce's contract — while the all-reduce traffic scales as 2(W−1).
//! A `--precision` sweep pins the storage-codec contract: strict f32
//! is the baseline, the mixed codecs halve checkpoint + parameter bytes
//! exactly while training within tolerance, deterministically. A final
//! planned-store run (DRAM + 2×NVMe + remote transfer plans) pins the
//! multi-path planner's bit-identity and counter-equality contract.
//!
//!     cargo run --release --example schedule_compare

use greedysnake::coordinator::TrainerConfig;
use greedysnake::runtime::Manifest;
use greedysnake::trainer::{train, RunLog, ScheduleKind};
use greedysnake::util::table::Table;

fn cfg(tag: &str, alpha: f64) -> TrainerConfig {
    TrainerConfig {
        alpha,
        opt_on_ssd: true,
        ssd_path: std::env::temp_dir().join(format!("gs_cmp_{tag}_{}", std::process::id())),
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    let steps = 15u64;
    let m = 4usize;

    // All Schedule policies run through the same StepEngine; the delayed-α
    // overlap stays on for the schedules that support it.
    let kinds = [
        ("vertical", ScheduleKind::Vertical, 0.25),
        ("chunked:2", ScheduleKind::ChunkedVertical(2), 0.25),
        ("cachesweep:2", ScheduleKind::CacheSweep(2), 0.25),
        ("horizontal", ScheduleKind::Horizontal, 0.0),
    ];
    let mut logs: Vec<(&str, RunLog)> = Vec::new();
    for (tag, kind, alpha) in kinds {
        let log = train(Manifest::load("artifacts/tiny")?, cfg(tag, alpha), kind, steps, m, 0)?;
        logs.push((tag, log));
    }

    let mut t = Table::new(
        "schedule comparison — real stack, shared StepEngine",
        &["metric", "vertical", "chunked:2", "cachesweep:2", "horizontal"],
    );
    let row = |name: &str, f: &dyn Fn(&RunLog) -> String| -> Vec<String> {
        let mut cells = vec![name.to_string()];
        cells.extend(logs.iter().map(|(_, l)| f(l)));
        cells
    };
    t.row(&row("first loss", &|l| format!("{:.4}", l.losses[0])));
    t.row(&row("final loss", &|l| format!("{:.4}", l.final_loss())));
    t.row(&row("param upload", &|l| {
        greedysnake::util::stats::fmt_bytes(l.param_bytes as f64)
    }));
    t.row(&row("ssd read", &|l| greedysnake::util::stats::fmt_bytes(l.ssd_read as f64)));
    t.row(&row("ssd written", &|l| {
        greedysnake::util::stats::fmt_bytes(l.ssd_written as f64)
    }));
    t.emit(None);

    // Fig. 13's claim: all schedules train equivalently.
    let mut max_dev = 0.0f64;
    for (_, log) in &logs[1..] {
        for (a, b) in logs[0].1.losses.iter().zip(&log.losses) {
            max_dev = max_dev.max((a - b).abs());
        }
    }
    println!("max per-step loss deviation vs vertical: {max_dev:.5}");
    assert!(max_dev < 0.05, "schedules must train equivalently");

    // §3.3/§3.4: parameter traffic orders vertical < chunked < horizontal,
    // and cachesweep:2 moves EXACTLY chunked:2's bytes (it only reorders
    // the backward chunk visits for DRAM-tier reuse).
    let (v, c, h) = (logs[0].1.param_bytes, logs[1].1.param_bytes, logs[3].1.param_bytes);
    println!("param bytes: vertical {v} < chunked:2 {c} < horizontal {h}");
    assert!(v < c && c < h, "schedule traffic ordering violated");
    assert_eq!(logs[2].1.param_bytes, c, "cachesweep must match chunked param traffic");
    assert_eq!(logs[2].1.ssd_read, logs[1].1.ssd_read, "cachesweep must match chunked reads");

    // --- async pipeline sweep: --io-depth ∈ {0, 1, 4} on vertical ---------
    // K = 0 is the synchronous engine; every depth must produce identical
    // losses and byte totals (the pipeline moves I/O, it never changes it),
    // and K ≥ 1 must report prefetch hits.
    let mut depth_logs: Vec<(usize, RunLog)> = Vec::new();
    for depth in [0usize, 1, 4] {
        let mut c = cfg(&format!("iod{depth}"), 0.25);
        c.io_depth = depth;
        let log =
            train(Manifest::load("artifacts/tiny")?, c, ScheduleKind::Vertical, steps, m, 0)?;
        depth_logs.push((depth, log));
    }
    let mut t = Table::new(
        "io-depth sweep — vertical schedule, async prefetch + write-behind",
        &["depth", "final loss", "prefetch hits", "misses", "i/o stall (s)"],
    );
    for (depth, log) in &depth_logs {
        t.row(&[
            depth.to_string(),
            format!("{:.4}", log.final_loss()),
            log.prefetch_hits.to_string(),
            log.prefetch_misses.to_string(),
            format!("{:.3}", log.io_stall_s),
        ]);
    }
    t.emit(None);
    let base = &depth_logs[0].1;
    assert_eq!(base.prefetch_hits, 0, "depth 0 must not prefetch");
    for (depth, log) in &depth_logs[1..] {
        assert_eq!(base.losses, log.losses, "io-depth {depth} changed the loss trajectory");
        assert_eq!(base.ssd_read, log.ssd_read, "io-depth {depth} changed SSD reads");
        assert_eq!(base.ssd_written, log.ssd_written, "io-depth {depth} changed SSD writes");
        assert_eq!(base.param_bytes, log.param_bytes, "io-depth {depth} changed param traffic");
        assert!(log.prefetch_hits > 0, "io-depth {depth} produced no prefetch hits");
    }

    // --- data-parallel sweep: --workers ∈ {1, 2, 4} on vertical -----------
    // The dist engine's determinism contract: every W trains bit-identically
    // to the single engine (losses, grad norms, parameter/moment digests).
    let mut w_logs: Vec<(usize, RunLog)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let mut c = cfg(&format!("w{workers}"), 0.25);
        c.workers = workers;
        let log =
            train(Manifest::load("artifacts/tiny")?, c, ScheduleKind::Vertical, steps, m, 0)?;
        w_logs.push((workers, log));
    }
    let mut t = Table::new(
        "workers sweep — vertical schedule, deterministic ring all-reduce",
        &["W", "final loss", "all-reduce bytes", "i/o stall (s)"],
    );
    for (workers, log) in &w_logs {
        t.row(&[
            workers.to_string(),
            format!("{:.4}", log.final_loss()),
            greedysnake::util::stats::fmt_bytes(log.allreduce_bytes as f64),
            format!("{:.3}", log.io_stall_s),
        ]);
    }
    t.emit(None);
    let base = &w_logs[0].1;
    assert_eq!(base.allreduce_bytes, 0, "W=1 must not ring-reduce");
    for (workers, log) in &w_logs[1..] {
        assert_eq!(base.losses, log.losses, "workers={workers} changed the loss trajectory");
        assert_eq!(base.grad_norms, log.grad_norms, "workers={workers} changed grad norms");
        assert_eq!(
            base.param_sq_norm.to_bits(),
            log.param_sq_norm.to_bits(),
            "workers={workers} changed the parameters"
        );
        assert_eq!(
            base.moment_sq_norm.to_bits(),
            log.moment_sq_norm.to_bits(),
            "workers={workers} changed the optimizer moments"
        );
        assert!(log.allreduce_bytes > 0, "workers={workers} moved no ring traffic");
    }

    // --- sharded optimizer sweep: --shard-optimizer, W ∈ {1, 2, 4} --------
    // ZeRO-style: reduce-scatter + per-rank shard updates + parameter
    // all-gather. Must stay bit-identical to the unsharded W=1 baseline
    // (the Adam update is partition-invariant), while W > 1 reports both
    // reduce-scatter and all-gather ring traffic.
    let mut s_logs: Vec<(usize, RunLog)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let mut c = cfg(&format!("sh{workers}"), 0.25);
        c.workers = workers;
        c.shard_optimizer = true;
        let log =
            train(Manifest::load("artifacts/tiny")?, c, ScheduleKind::Vertical, steps, m, 0)?;
        s_logs.push((workers, log));
    }
    let mut t = Table::new(
        "shard-optimizer sweep — reduce-scatter + per-rank update + all-gather",
        &["W", "final loss", "reduce-scatter bytes", "all-gather bytes"],
    );
    for (workers, log) in &s_logs {
        t.row(&[
            workers.to_string(),
            format!("{:.4}", log.final_loss()),
            greedysnake::util::stats::fmt_bytes(log.allreduce_bytes as f64),
            greedysnake::util::stats::fmt_bytes(log.allgather_bytes as f64),
        ]);
    }
    t.emit(None);
    let base = &w_logs[0].1; // the unsharded W=1 run
    assert_eq!(s_logs[0].1.allgather_bytes, 0, "W=1 must not all-gather");
    for (workers, log) in &s_logs {
        assert_eq!(
            base.losses, log.losses,
            "shard-optimizer W={workers} changed the loss trajectory"
        );
        assert_eq!(base.grad_norms, log.grad_norms, "shard W={workers} changed grad norms");
        assert_eq!(
            base.param_sq_norm.to_bits(),
            log.param_sq_norm.to_bits(),
            "shard-optimizer W={workers} changed the parameters"
        );
        assert_eq!(
            base.moment_sq_norm.to_bits(),
            log.moment_sq_norm.to_bits(),
            "shard-optimizer W={workers} changed the optimizer moments"
        );
        if *workers > 1 {
            assert!(log.allreduce_bytes > 0, "W={workers} reduce-scattered nothing");
            assert!(log.allgather_bytes > 0, "W={workers} all-gathered nothing");
        }
    }
    // --- store-backend sweep: single SSD vs striped:2 vs DRAM-cached ------
    // The pluggable TensorStore contract: backends only change where bytes
    // live, so all three train bit-identically; striping accounts the same
    // SSD bytes over parallel paths, while the cache tier absorbs them
    // (the counters drop to the closed form's zero residual).
    let mut b_logs: Vec<(&str, RunLog)> = Vec::new();
    for (tag, ssds, cache_mb) in
        [("ssd", 1usize, 0usize), ("striped:2", 2, 0), ("cached", 1, 256)]
    {
        let mut c = cfg(&format!("store_{ssds}_{cache_mb}"), 0.25);
        c.ssds = ssds;
        c.cpu_cache_mb = cache_mb;
        let log =
            train(Manifest::load("artifacts/tiny")?, c, ScheduleKind::Vertical, steps, m, 0)?;
        b_logs.push((tag, log));
    }
    let mut t = Table::new(
        "store-backend sweep — pluggable TensorStore, vertical schedule",
        &["backend", "final loss", "ssd read", "ssd written", "cache hit/miss/evict"],
    );
    for (tag, log) in &b_logs {
        t.row(&[
            tag.to_string(),
            format!("{:.4}", log.final_loss()),
            greedysnake::util::stats::fmt_bytes(log.ssd_read as f64),
            greedysnake::util::stats::fmt_bytes(log.ssd_written as f64),
            format!("{}/{}/{}", log.cache_hits, log.cache_misses, log.cache_evictions),
        ]);
    }
    t.emit(None);
    let base = &b_logs[0].1;
    for (tag, log) in &b_logs[1..] {
        assert_eq!(base.losses, log.losses, "store backend {tag} changed the losses");
        assert_eq!(base.grad_norms, log.grad_norms, "{tag} changed grad norms");
        assert_eq!(
            base.param_sq_norm.to_bits(),
            log.param_sq_norm.to_bits(),
            "store backend {tag} changed the parameters"
        );
        assert_eq!(
            base.moment_sq_norm.to_bits(),
            log.moment_sq_norm.to_bits(),
            "store backend {tag} changed the optimizer moments"
        );
    }
    let striped = &b_logs[1].1;
    assert_eq!(base.ssd_read, striped.ssd_read, "striping must account the same bytes");
    assert_eq!(base.ssd_written, striped.ssd_written);
    let cached = &b_logs[2].1;
    assert!(base.ssd_read > 0);
    assert_eq!(cached.ssd_read, 0, "a fitting cache absorbs every read");
    assert!(cached.cache_hits > 0, "the cache tier never hit");

    // --- precision sweep: --precision ∈ {f32, mixed:f16, mixed:bf16} ------
    // The two-tier equivalence contract: strict f32 is the bit-identity
    // baseline; the mixed codecs halve the checkpoint byte stream and the
    // parameter-upload accounting EXACTLY (2 B/elem vs 4) while training
    // within tolerance of the f32 run, and every mixed run is
    // self-deterministic (bit-identical on repeat). The store carries ONLY
    // checkpoints here (opt on CPU), so the byte ratio is pure codec
    // arithmetic.
    use greedysnake::memory::Precision;
    let mut p_logs: Vec<(&str, RunLog)> = Vec::new();
    for (i, prec) in ["f32", "mixed:f16", "mixed:bf16", "mixed:f16"].into_iter().enumerate() {
        let mut c = cfg(&format!("prec{i}"), 0.25);
        c.opt_on_ssd = false;
        c.ckpt_on_ssd = true;
        c.precision = Precision::parse(prec)?;
        let log =
            train(Manifest::load("artifacts/tiny")?, c, ScheduleKind::Vertical, steps, m, 0)?;
        p_logs.push((prec, log));
    }
    let mut t = Table::new(
        "precision sweep — storage codecs, vertical schedule, ckpt-on-ssd",
        &["precision", "final loss", "param upload", "ssd read", "ssd written"],
    );
    for (tag, log) in &p_logs {
        t.row(&[
            tag.to_string(),
            format!("{:.4}", log.final_loss()),
            greedysnake::util::stats::fmt_bytes(log.param_bytes as f64),
            greedysnake::util::stats::fmt_bytes(log.ssd_read as f64),
            greedysnake::util::stats::fmt_bytes(log.ssd_written as f64),
        ]);
    }
    t.emit(None);
    let strict = &p_logs[0].1;
    assert!(strict.ssd_read > 0 && strict.ssd_written > 0);
    for (tag, log) in &p_logs[1..] {
        let mut dev = 0.0f64;
        for (a, b) in strict.losses.iter().zip(&log.losses) {
            dev = dev.max((a - b).abs());
        }
        println!("{tag}: max per-step loss deviation vs f32: {dev:.5}");
        assert!(dev < 0.1, "{tag} must train within tolerance of strict f32: {dev}");
        // the headline halving, at the real store counters: encoded
        // checkpoint traffic is exactly 0.5× (≤ the 0.55× acceptance bound)
        assert_eq!(2 * log.ssd_read, strict.ssd_read, "{tag}: reads must halve");
        assert_eq!(2 * log.ssd_written, strict.ssd_written, "{tag}: writes must halve");
        assert_eq!(2 * log.param_bytes, strict.param_bytes, "{tag}: param accounting halves");
    }
    let (first, repeat) = (&p_logs[1].1, &p_logs[3].1);
    assert_eq!(first.losses, repeat.losses, "mixed:f16 must be self-deterministic");
    assert_eq!(first.param_sq_norm.to_bits(), repeat.param_sq_norm.to_bits());
    assert_eq!(first.moment_sq_norm.to_bits(), repeat.moment_sq_norm.to_bits());

    // --- planned multi-path store: DRAM + 2×NVMe + remote ----------------
    // The planner's equivalence contract: a transfer plan only changes
    // WHICH path carries each extent, never the bytes — so the planned run
    // is bit-identical to the single-SSD baseline and its whole-object
    // trait counters match byte-for-byte.
    let mut c = cfg("planned", 0.25);
    c.planned = true;
    c.ssds = 2;
    c.cpu_cache_mb = 16;
    c.remote_mbps = 200.0;
    let planned =
        train(Manifest::load("artifacts/tiny")?, c, ScheduleKind::Vertical, steps, m, 0)?;
    let base = &b_logs[0].1;
    assert_eq!(base.losses, planned.losses, "planned store changed the losses");
    assert_eq!(base.grad_norms, planned.grad_norms, "planned store changed grad norms");
    assert_eq!(
        base.param_sq_norm.to_bits(),
        planned.param_sq_norm.to_bits(),
        "planned store changed the parameters"
    );
    assert_eq!(
        base.moment_sq_norm.to_bits(),
        planned.moment_sq_norm.to_bits(),
        "planned store changed the optimizer moments"
    );
    assert_eq!(base.ssd_read, planned.ssd_read, "planned counters must match the baseline");
    assert_eq!(base.ssd_written, planned.ssd_written);
    println!(
        "planned store (dram+2xnvme+remote): final loss {:.4}, ssd r/w {}/{} — bit-identical",
        planned.final_loss(),
        greedysnake::util::stats::fmt_bytes(planned.ssd_read as f64),
        greedysnake::util::stats::fmt_bytes(planned.ssd_written as f64),
    );

    println!("schedule_compare OK");
    Ok(())
}
