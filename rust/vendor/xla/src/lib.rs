//! Vendored stand-in for the `xla` crate (xla-rs), exposing exactly the API
//! subset `greedysnake::runtime` touches.
//!
//! Two halves, with very different fidelity:
//!
//! * [`Literal`] / [`ArrayShape`] are REAL pure-Rust implementations of the
//!   host-side literal container (typed buffer + dims + reshape + tuple
//!   decomposition). Host-tensor round trips work exactly like the native
//!   crate's.
//! * The PJRT types ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`], [`HloModuleProto`], [`XlaComputation`]) are stubs:
//!   [`PjRtClient::cpu`] returns an error, so any code path needing actual
//!   XLA execution fails fast with a clear message instead of at link time.
//!   Artifact-driven tests gate on `Manifest::load_if_built` and skip.
//!
//! Replace this path dependency with the real `xla` crate (plus the XLA
//! native libraries) to run the PJRT paths; no consumer source changes.

use std::fmt;
use std::marker::PhantomData;

/// Error type mirroring `xla::Error` closely enough for `?`/`Context` use.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

const STUB_MSG: &str = "PJRT unavailable: built with the vendored xla stub \
     (swap in the real `xla` crate + XLA native libraries to execute artifacts)";

// ---------------------------------------------------------------------------
// Literals (real implementation)
// ---------------------------------------------------------------------------

/// Typed storage behind a literal.
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Tuple(Vec<Literal>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::F64(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::I64(v) => v.len(),
            Storage::Tuple(v) => v.len(),
        }
    }
}

/// Element types a literal can hold.
pub trait NativeType: Copy + Sized {
    fn wrap(data: Vec<Self>) -> Storage;
    fn unwrap(storage: &Storage) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn wrap(data: Vec<Self>) -> Storage {
                Storage::$variant(data)
            }
            fn unwrap(storage: &Storage) -> Option<Vec<Self>> {
                match storage {
                    Storage::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(f64, F64);
native!(i32, I32);
native!(i64, I64);

/// Dense array shape (dims only; element type lives in [`Storage`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side literal: typed buffer + dims, or a tuple of literals.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a native element slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: T::wrap(data.to_vec()) }
    }

    /// Tuple literal (what stage executables return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![elems.len() as i64], storage: Storage::Tuple(elems) }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return err("cannot reshape a tuple literal");
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.storage.len() {
            return err(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            ));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// The array shape (errors on tuple literals, like the real crate).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return err("array_shape of a tuple literal");
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .ok_or_else(|| Error("to_vec: element type mismatch".to_string()))
    }

    /// Number of elements (tuple literals: number of members).
    pub fn element_count(&self) -> usize {
        self.storage.len()
    }

    /// Split a tuple literal into its members.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.storage, Storage::Tuple(Vec::new())) {
            Storage::Tuple(elems) => Ok(elems),
            other => {
                self.storage = other;
                err("decompose_tuple on a non-tuple literal")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT (stubbed)
// ---------------------------------------------------------------------------

/// Raw PJRT handles are not `Send`; the stub keeps that property so thread
/// discipline bugs surface even without the native backend.
type NotSend = PhantomData<*mut ()>;

/// Parsed HLO module (stub: never constructible without the native backend).
pub struct HloModuleProto {
    _p: NotSend,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        err(STUB_MSG)
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _p: NotSend,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: PhantomData }
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _p: NotSend,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err(STUB_MSG)
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _p: NotSend,
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _buffers: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(STUB_MSG)
    }
}

/// PJRT client handle (stub: construction fails fast).
pub struct PjRtClient {
    _p: NotSend,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        err(STUB_MSG)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        err(STUB_MSG)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(STUB_MSG)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decomposes_once() {
        let mut t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2i32, 3])]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2, 3]);
        let mut plain = Literal::vec1(&[1.0f32]);
        assert!(plain.decompose_tuple().is_err());
        assert_eq!(plain.to_vec::<f32>().unwrap(), vec![1.0]);
    }

    #[test]
    fn pjrt_stub_fails_fast() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
