//! Vendored, dependency-free stand-in for the `anyhow` crate, covering the
//! API subset this workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error values carry a message plus an optional source
//! chain; `Display` shows the outermost message, `{:?}` shows the chain —
//! matching how the real crate renders in practice.
//!
//! Swap this path dependency for crates.io `anyhow` at any time; no source
//! changes are needed in the consuming crate.

use std::fmt::{self, Display};

/// An error with a message and an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = &e.source;
        }
        msgs.into_iter()
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        match &self.source {
            Some(e) => e.root_cause(),
            None => &self.msg,
        }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(mut cur) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            loop {
                write!(f, "\n    {}", cur.msg)?;
                match cur.source.as_deref() {
                    Some(next) => cur = next,
                    None => break,
                }
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that keeps the blanket `From` below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            out = Some(Error { msg: m, source: out.map(Box::new) });
        }
        out.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_renders() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(e.root_cause(), "gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u8>.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let v = 3;
        assert_eq!(anyhow!("v={v}").to_string(), "v=3");
        assert_eq!(anyhow!("{} and {}", 1, 2).to_string(), "1 and 2");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 2, "math is fine");
            bail!("after ensure: {}", "boom")
        }
        assert_eq!(f().unwrap_err().to_string(), "after ensure: boom");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let n: u32 = "12x".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }
}
