//! Runtime-level end-to-end tests: the staged AOT artifacts compose into a
//! numerically sane model (finite outputs, decreasing loss under SGD-like
//! perturbation, head/embed gradient consistency).

use greedysnake::runtime::tensor::{HostTensor, TokenTensor};
use greedysnake::runtime::{Manifest, Runtime, Stage};
use greedysnake::util::prng::Prng;

struct Ctx {
    m: Manifest,
    rt: Runtime,
    layers: Vec<Vec<HostTensor>>,
    embed: Vec<HostTensor>, // wte, wpe, lnf_w, lnf_b
}

/// `None` (skip) when artifacts were never built or PJRT is stubbed.
fn ctx() -> Option<Ctx> {
    let m = greedysnake::runtime::test_artifacts("artifacts/tiny")?;
    let rt = Runtime::load(&m).expect("compile");
    let mut rng = Prng::new(99);
    let layers = (0..m.config.n_layers)
        .map(|_| {
            m.layer_params
                .iter()
                .map(|s| HostTensor::init(s, m.config.n_layers, &mut rng))
                .collect()
        })
        .collect();
    let embed = m
        .embed_params
        .iter()
        .chain(m.head_params.iter())
        .map(|s| HostTensor::init(s, m.config.n_layers, &mut rng))
        .collect();
    Some(Ctx { m, rt, layers, embed })
}

fn batch(c: &Ctx, seed: u64) -> (TokenTensor, TokenTensor) {
    let cfg = c.m.config;
    let mut rng = Prng::new(seed);
    let toks: Vec<i32> = (0..cfg.micro_batch * cfg.seq_len)
        .map(|_| rng.next_below(cfg.vocab as u64) as i32)
        .collect();
    let mut tgts = toks.clone();
    tgts.rotate_left(1);
    (
        TokenTensor::new(&[cfg.micro_batch, cfg.seq_len], toks).unwrap(),
        TokenTensor::new(&[cfg.micro_batch, cfg.seq_len], tgts).unwrap(),
    )
}

fn forward(c: &Ctx, toks: &TokenTensor) -> HostTensor {
    let out = c
        .rt
        .execute(
            Stage::EmbedFwd,
            &[
                toks.to_literal().unwrap(),
                c.embed[0].to_literal().unwrap(),
                c.embed[1].to_literal().unwrap(),
            ],
        )
        .unwrap();
    let mut x = HostTensor::from_literal(&out[0]).unwrap();
    for params in &c.layers {
        let mut inputs = vec![x.to_literal().unwrap()];
        inputs.extend(params.iter().map(|p| p.to_literal().unwrap()));
        let out = c.rt.execute(Stage::LayerFwd, &inputs).unwrap();
        x = HostTensor::from_literal(&out[0]).unwrap();
    }
    x
}

fn loss_of(c: &Ctx, x: &HostTensor, tgts: &TokenTensor) -> f32 {
    let out = c
        .rt
        .execute(
            Stage::HeadLoss,
            &[
                x.to_literal().unwrap(),
                c.embed[2].to_literal().unwrap(),
                c.embed[3].to_literal().unwrap(),
                c.embed[0].to_literal().unwrap(),
                tgts.to_literal().unwrap(),
            ],
        )
        .unwrap();
    out[0].to_vec::<f32>().unwrap()[0]
}

#[test]
fn initial_loss_near_uniform_entropy() {
    let Some(c) = ctx() else { return };
    let (toks, tgts) = batch(&c, 0);
    let x = forward(&c, &toks);
    let loss = loss_of(&c, &x, &tgts);
    let uniform = (c.m.config.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 0.5,
        "init loss {loss} should be ≈ ln(V) = {uniform}"
    );
}

#[test]
fn dx_is_a_descent_direction() {
    let Some(mut c) = ctx() else { return };
    let (toks, tgts) = batch(&c, 1);
    let x = forward(&c, &toks);
    let out = c
        .rt
        .execute(
            Stage::HeadLoss,
            &[
                x.to_literal().unwrap(),
                c.embed[2].to_literal().unwrap(),
                c.embed[3].to_literal().unwrap(),
                c.embed[0].to_literal().unwrap(),
                tgts.to_literal().unwrap(),
            ],
        )
        .unwrap();
    let loss0 = out[0].to_vec::<f32>().unwrap()[0];
    let dwte = HostTensor::from_literal(&out[4]).unwrap();
    // gradient-descend wte a little; loss must drop
    for (p, g) in c.embed[0].data.iter_mut().zip(&dwte.data) {
        *p -= 0.5 * g;
    }
    let x1 = forward(&c, &toks);
    let loss1 = loss_of(&c, &x1, &tgts);
    assert!(loss1 < loss0, "{loss1} !< {loss0}");
}

#[test]
fn layer_bwd_dx_matches_finite_difference() {
    let Some(c) = ctx() else { return };
    let cfg = c.m.config;
    let mut rng = Prng::new(5);
    let shape = [cfg.micro_batch, cfg.seq_len, cfg.hidden];
    let mut x = HostTensor::zeros(&shape);
    rng.fill_normal(&mut x.data, 1.0);
    let mut dy = HostTensor::zeros(&shape);
    rng.fill_normal(&mut dy.data, 1.0);

    let mut inputs = vec![x.to_literal().unwrap(), dy.to_literal().unwrap()];
    inputs.extend(c.layers[0].iter().map(|p| p.to_literal().unwrap()));
    let out = c.rt.execute(Stage::LayerBwd, &inputs).unwrap();
    let dx = HostTensor::from_literal(&out[0]).unwrap();

    // directional finite difference of <layer_fwd(x), dy>
    let mut dir = HostTensor::zeros(&shape);
    rng.fill_normal(&mut dir.data, 1.0);
    let norm = (dir.sq_sum() as f32).sqrt();
    for v in dir.data.iter_mut() {
        *v /= norm;
    }
    let eval = |xx: &HostTensor| -> f64 {
        let mut inputs = vec![xx.to_literal().unwrap()];
        inputs.extend(c.layers[0].iter().map(|p| p.to_literal().unwrap()));
        let y = c.rt.execute(Stage::LayerFwd, &inputs).unwrap();
        let y = HostTensor::from_literal(&y[0]).unwrap();
        y.data.iter().zip(&dy.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
    };
    let eps = 1e-3f32;
    let mut xp = x.clone();
    let mut xm = x.clone();
    for i in 0..xp.data.len() {
        xp.data[i] += eps * dir.data[i];
        xm.data[i] -= eps * dir.data[i];
    }
    let num = (eval(&xp) - eval(&xm)) / (2.0 * eps as f64);
    let ana: f64 = dx.data.iter().zip(&dir.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
    assert!(
        (num - ana).abs() < 5e-2 * (1.0 + ana.abs()),
        "finite diff {num} vs analytic {ana}"
    );
}

#[test]
fn embed_bwd_scatter_rows() {
    let Some(c) = ctx() else { return };
    let cfg = c.m.config;
    let toks = TokenTensor::new(
        &[cfg.micro_batch, cfg.seq_len],
        vec![7; cfg.micro_batch * cfg.seq_len], // every position is token 7
    )
    .unwrap();
    let mut dx = HostTensor::zeros(&[cfg.micro_batch, cfg.seq_len, cfg.hidden]);
    dx.data.fill(1.0);
    let out = c
        .rt
        .execute(Stage::EmbedBwd, &[toks.to_literal().unwrap(), dx.to_literal().unwrap()])
        .unwrap();
    let dwte = HostTensor::from_literal(&out[0]).unwrap();
    // all gradient mass lands on row 7
    let row7: f32 = dwte.data[7 * cfg.hidden..8 * cfg.hidden].iter().sum();
    let total: f32 = dwte.data.iter().sum();
    assert!((row7 - total).abs() < 1e-3, "{row7} vs {total}");
    assert!((total - (cfg.micro_batch * cfg.seq_len * cfg.hidden) as f32).abs() < 1e-1);
}

#[test]
fn stage_call_counters_track() {
    let Some(c) = ctx() else { return };
    let (toks, _) = batch(&c, 3);
    let before = c.rt.call_count(Stage::LayerFwd);
    forward(&c, &toks);
    assert_eq!(
        c.rt.call_count(Stage::LayerFwd) - before,
        c.m.config.n_layers as u64
    );
}
