//! Property-based tests over coordinator/substrate invariants, using the
//! in-tree property harness (`util::prop`): routing (micro-batch order),
//! batching (gradient accumulation), state placement (LP constraints,
//! packing), and the discrete-event engine.

use greedysnake::coordinator::dist::{partition, ring_traffic_bytes, RingReduce};
use greedysnake::coordinator::VerticalScheduler;
use greedysnake::lp::simplex::{LinProg, LpOutcome};
use greedysnake::lp::solve_config;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::memory::pinned::{naive_total, plan_packing, plan_total};
use greedysnake::modelcfg::{ModelCfg, GPT_65B, SEQ_LEN};
use greedysnake::optimizer::{adam_step_rust, chunk_ranges, AdamParams, AdamState};
use greedysnake::perfmodel::SystemParams;
use greedysnake::sim::engine::{DiscreteSim, Resource};
use greedysnake::traffic::Workload;
use greedysnake::util::prng::Prng;
use greedysnake::util::prop::{check, gen};

/// Routing: the alternating micro-batch order is always a permutation, and
/// consecutive layers share their boundary micro-batch (the §4.2 trick that
/// keeps one activation resident).
#[test]
fn prop_mb_order_is_alternating_permutation() {
    check("mb-order", 200, |rng| {
        let m = gen::usize_in(rng, 1, 32);
        let l = gen::usize_in(rng, 0, 63);
        let order = VerticalScheduler::mb_order(l, m);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        if sorted != (0..m).collect::<Vec<_>>() {
            return Err(format!("not a permutation: {order:?}"));
        }
        let next = VerticalScheduler::mb_order(l + 1, m);
        if order.last() != next.first() {
            return Err(format!("boundary mb not shared: {order:?} -> {next:?}"));
        }
        Ok(())
    });
}

/// Batching: gradient accumulation is associative — any split of M
/// micro-batch gradients into groups sums to the same total.
#[test]
fn prop_grad_accumulation_grouping_invariant() {
    check("grad-accum", 100, |rng| {
        let n = gen::usize_in(rng, 1, 256);
        let m = gen::usize_in(rng, 1, 8);
        let grads: Vec<Vec<f32>> = (0..m).map(|_| gen::vec_f32(rng, n, 1.0)).collect();
        let direct: Vec<f64> = (0..n)
            .map(|i| grads.iter().map(|g| g[i] as f64).sum())
            .collect();
        // random grouping
        let n_groups = gen::usize_in(rng, 1, m);
        let parts = gen::partition(rng, m, n_groups);
        let mut grouped = vec![0.0f64; n];
        let mut idx = 0;
        for p in parts {
            let mut partial = vec![0.0f32; n];
            for g in &grads[idx..idx + p] {
                for (a, b) in partial.iter_mut().zip(g) {
                    *a += b;
                }
            }
            for (a, b) in grouped.iter_mut().zip(&partial) {
                *a += *b as f64;
            }
            idx += p;
        }
        for i in 0..n {
            if (grouped[i] - direct[i]).abs() > 1e-3 {
                return Err(format!("i={i}: {} vs {}", grouped[i], direct[i]));
            }
        }
        Ok(())
    });
}

/// State placement: every feasible LP solution respects the CPU-memory
/// capacity and the §4.4 gradient-reuse constraint.
#[test]
fn prop_lp_solutions_respect_constraints() {
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    check("lp-constraints", 40, |rng| {
        let m = gen::usize_in(rng, 1, 64) as u64;
        let alpha = gen::f64_in(rng, 0.01, 0.5);
        let Some(res) = solve_config(&sp, m, alpha) else {
            return Ok(()); // infeasible is a valid outcome
        };
        let x = res.ratios;
        for v in [x.ckpt_cpu, x.param_cpu, x.opt_cpu] {
            if !(-1e-9..=1.0 + 1e-9).contains(&v) {
                return Err(format!("ratio out of box: {x:?}"));
            }
        }
        let used = sp.cpu_bytes_vertical(m, x);
        if used > sp.dram_share() * 1.001 {
            return Err(format!("memory violated: {used} > {}", sp.dram_share()));
        }
        // §4.4 reuse: α·g ≤ xp·p + xc·m·c
        let lhs = alpha * sp.g_fp();
        let rhs = x.param_cpu * sp.p_lp() + x.ckpt_cpu * m as f64 * sp.c_bytes();
        if lhs > rhs * 1.001 {
            return Err(format!("grad-reuse violated: {lhs} > {rhs}"));
        }
        // LP times are at least the compute lower bounds
        if res.t_f < m as f64 * sp.t_fwd_mb() - 1e-9 {
            return Err("t_f below compute bound".into());
        }
        Ok(())
    });
}

/// Ring all-reduce: for arbitrary tensor lengths, contribution counts, and
/// chunk splits, the deterministic chunked ring equals the straight
/// left-fold sum bit-for-bit — chunking is element-local, so it cannot
/// perturb the fixed reduction order.
#[test]
fn prop_ring_all_reduce_equals_straight_sum() {
    check("ring-sum", 120, |rng| {
        let n = gen::usize_in(rng, 1, 400);
        let k = gen::usize_in(rng, 1, 9);
        let parts: Vec<Vec<f32>> = (0..k).map(|_| gen::vec_f32(rng, n, 2.0)).collect();
        let refs: Vec<&[f32]> = parts.iter().map(|v| v.as_slice()).collect();
        let mut want = parts[0].clone();
        for p in &parts[1..] {
            for (a, b) in want.iter_mut().zip(p) {
                *a += b;
            }
        }
        for _ in 0..3 {
            let chunk = gen::usize_in(rng, 1, n + 16);
            let got = RingReduce { chunk_elems: chunk }.reduce(&refs);
            if got.len() != n {
                return Err(format!("length {} != {n}", got.len()));
            }
            for i in 0..n {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!(
                        "chunk={chunk} i={i}: {} != {} (bits differ)",
                        got[i], want[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Ring all-reduce: the engine's reduce pipeline is invariant to worker
/// COMPLETION order. Mirrors `DataParallelEngine::step`'s structure — the
/// canonically-tagged contributions are partitioned into contiguous worker
/// shares, the workers' lists are merged in a RANDOM completion order,
/// sorted by canonical tag, and ring-folded — and the result must equal an
/// independently computed straight sum in canonical tag order, bit for bit,
/// for every completion permutation.
#[test]
fn prop_ring_reduce_invariant_to_completion_order() {
    check("ring-order", 80, |rng| {
        let n = gen::usize_in(rng, 1, 200);
        let k = gen::usize_in(rng, 1, 8);
        let parts: Vec<Vec<f32>> = (0..k).map(|_| gen::vec_f32(rng, n, 1.0)).collect();
        // independent baseline: sequential left-fold in canonical tag order
        let mut want = parts[0].clone();
        for p in &parts[1..] {
            for (a, b) in want.iter_mut().zip(p) {
                *a += b;
            }
        }
        // contiguous worker shares of the tag space, arriving in a random
        // completion order
        let workers = gen::usize_in(rng, 1, k);
        let mut order: Vec<std::ops::Range<usize>> = partition(k, workers);
        rng.shuffle(&mut order);
        let mut tagged: Vec<(usize, &[f32])> = Vec::with_capacity(k);
        for share in &order {
            tagged.extend(share.clone().map(|i| (i, parts[i].as_slice())));
        }
        // the engine's recovery step: sort by canonical tag, then fold
        tagged.sort_by_key(|&(i, _)| i);
        let refs: Vec<&[f32]> = tagged.iter().map(|&(_, p)| p).collect();
        let ring = RingReduce { chunk_elems: gen::usize_in(rng, 1, n + 4) };
        let got = ring.reduce(&refs);
        for i in 0..n {
            if got[i].to_bits() != want[i].to_bits() {
                return Err(format!(
                    "i={i}: completion order {order:?} changed the result"
                ));
            }
        }
        Ok(())
    });
}

/// The micro-batch partition: contiguous, covering, balanced to within one
/// micro-batch, for any (m, workers).
#[test]
fn prop_partition_contiguous_and_balanced() {
    check("dp-partition", 100, |rng| {
        let m = gen::usize_in(rng, 0, 64);
        let w = gen::usize_in(rng, 1, 12);
        let parts = partition(m, w);
        if parts.len() != w {
            return Err(format!("{} ranges for {w} workers", parts.len()));
        }
        let mut next = 0;
        for r in &parts {
            if r.start != next {
                return Err(format!("gap before {r:?}"));
            }
            next = r.end;
        }
        if next != m {
            return Err(format!("covered {next} of {m}"));
        }
        let sizes: Vec<usize> = parts.iter().map(|r| r.len()).collect();
        let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        if hi - lo > 1 {
            return Err(format!("unbalanced {sizes:?}"));
        }
        Ok(())
    });
}

/// Multi-worker traffic closed forms: W = 1 collapses EXACTLY to the
/// single-worker forms, shares always cover M, vertical parameter traffic
/// scales with the active worker count, horizontal's total is W-invariant,
/// and the ring formula is 0 at W = 1.
#[test]
fn prop_traffic_dp_collapses_to_single_worker() {
    check("traffic-dp", 60, |rng| {
        let model = ModelCfg::new("t", 4 + rng.next_below(60), 8, 512 * (1 + rng.next_below(16)));
        let w1 = Workload {
            model,
            micro_batch: 1 + rng.next_below(8),
            seq_len: 512,
            m: 1 + rng.next_below(32),
            shards: 1,
        };
        let workers = 1 + rng.next_below(10);
        if w1.vertical_dp(1) != w1.vertical()
            || w1.horizontal_dp(1) != w1.horizontal()
            || w1.chunked_vertical_dp(2, 1) != w1.chunked_vertical(2)
        {
            return Err("W=1 must collapse to the single-worker forms".into());
        }
        let shares = w1.dp_shares(workers);
        if shares.iter().sum::<u64>() != w1.m {
            return Err(format!("shares {shares:?} don't cover m={}", w1.m));
        }
        let active = shares.len() as u64;
        if w1.vertical_dp(workers).param_load != active * 2 * w1.ms_lp() {
            return Err("vertical param traffic must scale with active workers".into());
        }
        if w1.horizontal_dp(workers).param_load != w1.horizontal().param_load {
            return Err("horizontal param traffic must be W-invariant".into());
        }
        if w1.allreduce_bytes_per_worker(1) != 0 {
            return Err("no ring traffic for a single worker".into());
        }
        // the ring runs among the EFFECTIVE workers: with more than one
        // active rank it must move bytes; with a single active rank (e.g.
        // W > 1 but M = 1) it must move none — the runtime's accounting
        let eff = w1.effective_workers(workers);
        if eff > 1 && w1.allreduce_bytes_per_worker(workers) == 0 {
            return Err("multi-worker ring traffic must be positive".into());
        }
        if eff <= 1 && w1.allreduce_bytes_per_worker(workers) != 0 {
            return Err("a lone active worker must move no ring traffic".into());
        }
        if ring_traffic_bytes(1, 1234) != 0 {
            return Err("ring totals must vanish at one rank".into());
        }
        Ok(())
    });
}

/// The satellite byte-consistency property: for W ∈ {1..8} and every M
/// (including M < W), the closed-form all-reduce total equals the runtime's
/// `ring_traffic_bytes` over the effective worker count, per-worker × active
/// covers the total within one worker's rounding slack, and the sharded
/// reduce-scatter + all-gather halves reassemble the all-reduce identity on
/// a common payload.
#[test]
fn prop_ring_bytes_consistent_between_runtime_and_closed_form() {
    use greedysnake::coordinator::dist::{ring_allgather_bytes, ring_reduce_scatter_bytes};
    check("ring-bytes", 80, |rng| {
        let model = ModelCfg::new("t", 4 + rng.next_below(60), 8, 512 * (1 + rng.next_below(16)));
        let m = 1 + rng.next_below(12);
        let w = Workload { model, micro_batch: 1 + rng.next_below(8), seq_len: 512, m, shards: 1 };
        for workers in 1..=8u64 {
            let active = w.effective_workers(workers);
            if active != workers.min(m) {
                return Err(format!("m={m} W={workers}: effective {active}"));
            }
            let total = w.allreduce_bytes_total(workers);
            if total != ring_traffic_bytes(active as usize, w.grad_fp()) {
                return Err(format!("m={m} W={workers}: closed form != runtime total"));
            }
            let per = w.allreduce_bytes_per_worker(workers);
            if per * active < total || per * active >= total + active {
                return Err(format!(
                    "m={m} W={workers}: per {per} × active {active} vs total {total}"
                ));
            }
            // sharded halves: rs + ag of a common payload == the all-reduce
            let payload = w.grad_fp();
            let rs = ring_reduce_scatter_bytes(workers as usize, payload);
            let ag = ring_allgather_bytes(workers as usize, payload);
            if rs + ag != ring_traffic_bytes(workers as usize, payload) {
                return Err(format!("W={workers}: rs {rs} + ag {ag} != all-reduce"));
            }
            if w.reduce_scatter_bytes_total(workers) != rs {
                return Err(format!("W={workers}: traffic rs diverged from helper"));
            }
            // per-rank optimizer SSD round trips shrink ~1/W
            let full = w.opt_ssd_round_trip_bytes();
            let per_rank = w.sharded_opt_ssd_bytes_per_rank(workers);
            if per_rank != full.div_ceil(workers) {
                return Err(format!("W={workers}: per-rank opt bytes {per_rank}"));
            }
        }
        Ok(())
    });
}

/// Traffic model: vertical parameter traffic never depends on M; horizontal
/// grows linearly; totals are consistent under sharding.
#[test]
fn prop_traffic_scaling_laws() {
    check("traffic-scaling", 60, |rng| {
        let model = ModelCfg::new("t", 4 + rng.next_below(60), 8, 512 * (1 + rng.next_below(16)));
        let w1 = Workload {
            model,
            micro_batch: 1 + rng.next_below(8),
            seq_len: 512,
            m: 2 + rng.next_below(30),
            shards: 1,
        };
        let w2 = Workload { m: w1.m * 2, ..w1 };
        let v1 = w1.vertical();
        let v2 = w2.vertical();
        if v1.param_load != v2.param_load {
            return Err("vertical param traffic must not scale with M".into());
        }
        let h1 = w1.horizontal();
        let h2 = w2.horizontal();
        if h2.param_load != 2 * h1.param_load {
            return Err("horizontal param traffic must double with M".into());
        }
        // sharding divides param/grad traffic exactly
        let ws = Workload { shards: 2, ..w1 };
        if ws.horizontal().param_load * 2 != h1.param_load {
            return Err("sharding must halve param traffic".into());
        }
        Ok(())
    });
}

/// Packing: the DP plan always covers demand, never loses to naive
/// per-buffer padding, and only emits power-of-two slabs.
#[test]
fn prop_packing_optimality_bounds() {
    check("packing", 150, |rng| {
        let n = gen::usize_in(rng, 1, 40) as u64;
        let size = gen::usize_in(rng, 1, 100_000) as u64;
        let plan = plan_packing(n, size);
        let covered: u64 = plan.iter().map(|s| s.buffers).sum();
        if covered != n {
            return Err(format!("covered {covered} != {n}"));
        }
        for s in &plan {
            if !s.slab_bytes.is_power_of_two() || s.slab_bytes < s.buffers * size {
                return Err(format!("bad slab {s:?}"));
            }
        }
        let total = plan_total(&plan);
        if total > naive_total(n, size) {
            return Err(format!("DP {total} worse than naive {}", naive_total(n, size)));
        }
        if total < n * size {
            return Err("allocated less than demanded".into());
        }
        Ok(())
    });
}

/// Adam: partition invariance over random chunkings (§6.5's reproducibility
/// property) and exactness of chunk_ranges.
#[test]
fn prop_adam_chunking_invariance() {
    check("adam-chunks", 60, |rng| {
        let n = gen::usize_in(rng, 1, 2000);
        let chunk = gen::usize_in(rng, 1, n.max(2));
        let ranges = chunk_ranges(n, chunk);
        if ranges.first().map(|r| r.0) != Some(0) || ranges.last().map(|r| r.1) != Some(n) {
            return Err(format!("ranges don't cover: {ranges:?}"));
        }
        let mut p1 = gen::vec_f32(rng, n, 1.0);
        let g = gen::vec_f32(rng, n, 0.1);
        let mut p2 = p1.clone();
        let mut s1 = AdamState::zeros(n);
        let mut s2 = AdamState::zeros(n);
        let hp = AdamParams::default();
        adam_step_rust(&mut p1, &mut s1, &g, &hp, 1, 1.0, 0, n);
        for (lo, hi) in &ranges {
            adam_step_rust(&mut p2, &mut s2, &g, &hp, 1, 1.0, *lo, *hi);
        }
        if p1 != p2 {
            return Err("chunked Adam diverged from whole-vector Adam".into());
        }
        Ok(())
    });
}

/// Discrete-event engine: makespan is at least every resource's busy time
/// and at most the serial sum; adding a dependency never reduces makespan.
#[test]
fn prop_sim_makespan_bounds() {
    check("sim-bounds", 60, |rng| {
        let n_res = gen::usize_in(rng, 1, 4);
        let n_ops = gen::usize_in(rng, 1, 40);
        let mut sim = DiscreteSim::new(n_res);
        let mut serial_sum = 0.0;
        let mut ids = Vec::new();
        for i in 0..n_ops {
            let dur = gen::f64_in(rng, 0.0, 5.0);
            serial_sum += dur;
            // random deps among earlier ops
            let mut deps = Vec::new();
            if i > 0 && rng.next_f64() < 0.5 {
                deps.push(ids[rng.next_below(i as u64) as usize]);
            }
            ids.push(sim.op(Resource(rng.next_below(n_res as u64) as usize), dur, &deps));
        }
        let st = sim.run();
        for busy in &st.busy {
            if *busy > st.makespan + 1e-9 {
                return Err(format!("busy {busy} > makespan {}", st.makespan));
            }
        }
        if st.makespan > serial_sum + 1e-9 {
            return Err(format!("makespan {} > serial {serial_sum}", st.makespan));
        }
        Ok(())
    });
}

/// Simplex: on random box-bounded LPs the reported optimum is feasible and
/// no corner of the box beats it.
#[test]
fn prop_simplex_beats_box_corners() {
    check("simplex-corners", 50, |rng| {
        let n = gen::usize_in(rng, 1, 3);
        let mut lp = LinProg::new(n);
        let c: Vec<f64> = (0..n).map(|_| gen::f64_in(rng, -2.0, 2.0)).collect();
        lp.maximize(&c);
        let bounds: Vec<f64> = (0..n).map(|_| gen::f64_in(rng, 0.5, 3.0)).collect();
        for (i, b) in bounds.iter().enumerate() {
            let mut a = vec![0.0; n];
            a[i] = 1.0;
            lp.leq(&a, *b);
        }
        let LpOutcome::Optimal(_, v) = lp.solve() else {
            return Err("box LP must be solvable".into());
        };
        // enumerate corners
        for mask in 0..(1u32 << n) {
            let corner: f64 = (0..n)
                .map(|i| if mask >> i & 1 == 1 { c[i] * bounds[i] } else { 0.0 })
                .sum();
            if corner > v + 1e-6 {
                return Err(format!("corner {corner} beats simplex {v}"));
            }
        }
        Ok(())
    });
}

/// PRNG streams do not collide across nearby seeds.
#[test]
fn prop_prng_stream_independence() {
    check("prng-streams", 30, |rng| {
        let seed = rng.next_u64();
        let mut a = Prng::new(seed);
        let mut b = Prng::new(seed.wrapping_add(1));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        if same > 0 {
            return Err(format!("{same}/64 collisions between adjacent seeds"));
        }
        Ok(())
    });
}

/// Store-backend equivalence: a [`StripedStore`] over any N ∈ 1..4 devices
/// is content-identical AND byte-accounting-consistent with the
/// single-device `SsdBackend` across arbitrary key/size sequences —
/// puts (incl. overwrites with different lengths), deletes, and gets. This
/// is the property that makes `--ssds N` bit-identical to the seed path:
/// striping only changes where bytes live.
#[test]
fn prop_striped_store_matches_ssd_backend() {
    use greedysnake::memory::{SsdStorage, StripedStore, TensorStore};
    use std::sync::atomic::{AtomicU64, Ordering};
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    check("striped-store-equiv", 25, |rng| {
        let n = gen::usize_in(rng, 1, 4);
        let uniq = UNIQ.fetch_add(1, Ordering::Relaxed);
        let base = std::env::temp_dir().join(format!(
            "gs_prop_store_{}_{uniq}",
            std::process::id()
        ));
        let flat = std::env::temp_dir().join(format!(
            "gs_prop_store_flat_{}_{uniq}",
            std::process::id()
        ));
        let ssd = SsdStorage::create_unthrottled(flat).map_err(|e| e.to_string())?;
        let striped = StripedStore::create(&base, n, f64::INFINITY, f64::INFINITY)
            .map_err(|e| e.to_string())?;
        let keys = ["a", "b", "c", "d", "e"];
        for op in 0..40 {
            let key = keys[gen::usize_in(rng, 0, keys.len() - 1)];
            match gen::usize_in(rng, 0, 3) {
                0 | 1 => {
                    let len = gen::usize_in(rng, 0, 5000);
                    let fill = gen::usize_in(rng, 0, 255) as u8;
                    let data: Vec<u8> =
                        (0..len).map(|i| fill.wrapping_add(i as u8)).collect();
                    ssd.put(key, &data).map_err(|e| e.to_string())?;
                    striped.put(key, &data).map_err(|e| e.to_string())?;
                }
                2 => {
                    let a = ssd.delete(key);
                    let b = striped.delete(key);
                    if a != b {
                        return Err(format!("op {op}: delete('{key}') {a} vs {b}"));
                    }
                }
                _ => {
                    let mut x = Vec::new();
                    let mut y = Vec::new();
                    let ra = ssd.get(key, &mut x);
                    let rb = striped.get(key, &mut y);
                    if ra.is_ok() != rb.is_ok() {
                        return Err(format!(
                            "op {op}: get('{key}') presence {} vs {}",
                            ra.is_ok(),
                            rb.is_ok()
                        ));
                    }
                    if ra.is_ok() && x != y {
                        return Err(format!(
                            "op {op}: get('{key}') content mismatch ({} vs {} bytes)",
                            x.len(),
                            y.len()
                        ));
                    }
                }
            }
            if ssd.contains(key) != striped.contains(key) {
                return Err(format!("op {op}: contains('{key}') diverged"));
            }
            if ssd.len_of(key) != striped.len_of(key) {
                return Err(format!(
                    "op {op}: len_of('{key}') {:?} vs {:?}",
                    ssd.len_of(key),
                    striped.len_of(key)
                ));
            }
            if ssd.bytes_read() != striped.bytes_read() {
                return Err(format!(
                    "op {op}: read accounting {} vs {}",
                    ssd.bytes_read(),
                    striped.bytes_read()
                ));
            }
            if ssd.bytes_written() != striped.bytes_written() {
                return Err(format!(
                    "op {op}: write accounting {} vs {}",
                    ssd.bytes_written(),
                    striped.bytes_written()
                ));
            }
        }
        Ok(())
    });
}

/// Multi-path planner equivalence: a [`PlannedStore`] over ANY path split —
/// 1..4 NVMe devices × DRAM path on/off (incl. capacities small enough to
/// force spill) × remote path on/off — is content/len/presence-identical
/// and trait-counter-identical to the flat `SsdBackend` across arbitrary
/// op sequences, and after every op the per-path attribution conserves the
/// object bytes exactly: Σ path bytes == trait counter bytes.
#[test]
fn prop_planned_store_matches_ssd_backend() {
    use greedysnake::memory::{PlannedConfig, PlannedStore, SsdStorage, TensorStore};
    use std::sync::atomic::{AtomicU64, Ordering};
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    check("planned-store-equiv", 25, |rng| {
        let uniq = UNIQ.fetch_add(1, Ordering::Relaxed);
        let base = std::env::temp_dir().join(format!(
            "gs_prop_planned_{}_{uniq}",
            std::process::id()
        ));
        let flat = std::env::temp_dir().join(format!(
            "gs_prop_planned_flat_{}_{uniq}",
            std::process::id()
        ));
        let ssd = SsdStorage::create_unthrottled(flat).map_err(|e| e.to_string())?;
        let pc = PlannedConfig {
            nvme: vec![(f64::INFINITY, f64::INFINITY); gen::usize_in(rng, 1, 4)],
            // off / spill-forcing tiny / comfortably large
            dram_capacity: [0u64, 2048, 1 << 20][gen::usize_in(rng, 0, 2)],
            dram_bps: 0.0,
            remote_bps: if gen::usize_in(rng, 0, 1) == 1 { 200e6 } else { 0.0 },
        };
        let planned = PlannedStore::create(&base, &pc).map_err(|e| e.to_string())?;
        let keys = ["a", "b", "c", "d", "e"];
        for op in 0..40 {
            let key = keys[gen::usize_in(rng, 0, keys.len() - 1)];
            match gen::usize_in(rng, 0, 3) {
                0 | 1 => {
                    let len = gen::usize_in(rng, 0, 5000);
                    let fill = gen::usize_in(rng, 0, 255) as u8;
                    let data: Vec<u8> =
                        (0..len).map(|i| fill.wrapping_add(i as u8)).collect();
                    ssd.put(key, &data).map_err(|e| e.to_string())?;
                    planned.put(key, &data).map_err(|e| e.to_string())?;
                }
                2 => {
                    let a = ssd.delete(key);
                    let b = planned.delete(key);
                    if a != b {
                        return Err(format!("op {op}: delete('{key}') {a} vs {b}"));
                    }
                }
                _ => {
                    let mut x = Vec::new();
                    let mut y = Vec::new();
                    let ra = ssd.get(key, &mut x);
                    let rb = planned.get(key, &mut y);
                    if ra.is_ok() != rb.is_ok() {
                        return Err(format!(
                            "op {op}: get('{key}') presence {} vs {}",
                            ra.is_ok(),
                            rb.is_ok()
                        ));
                    }
                    if ra.is_ok() && x != y {
                        return Err(format!(
                            "op {op}: get('{key}') content mismatch ({} vs {} bytes)",
                            x.len(),
                            y.len()
                        ));
                    }
                }
            }
            if ssd.contains(key) != planned.contains(key) {
                return Err(format!("op {op}: contains('{key}') diverged"));
            }
            if ssd.len_of(key) != planned.len_of(key) {
                return Err(format!(
                    "op {op}: len_of('{key}') {:?} vs {:?}",
                    ssd.len_of(key),
                    planned.len_of(key)
                ));
            }
            if ssd.bytes_read() != planned.bytes_read()
                || ssd.bytes_written() != planned.bytes_written()
            {
                return Err(format!(
                    "op {op}: accounting r/w {}/{} vs {}/{}",
                    ssd.bytes_read(),
                    ssd.bytes_written(),
                    planned.bytes_read(),
                    planned.bytes_written()
                ));
            }
            // per-path byte conservation: the plan-level attribution always
            // sums back to the whole-object trait counters
            let ps = planned.path_stats();
            if ps.total_read() != planned.bytes_read() {
                return Err(format!(
                    "op {op}: path reads {} != counter {}",
                    ps.total_read(),
                    planned.bytes_read()
                ));
            }
            if ps.total_written() != planned.bytes_written() {
                return Err(format!(
                    "op {op}: path writes {} != counter {}",
                    ps.total_written(),
                    planned.bytes_written()
                ));
            }
        }
        Ok(())
    });
}

/// The batching determinism contract: an [`SsdStorage`] on a profiled,
/// `--io-batch`-batched device is byte-identical to the unthrottled store —
/// same contents, presence, lengths, and byte counters — over arbitrary
/// put/get/delete sequences AND a concurrent multi-thread put burst (the
/// traffic shape that actually opens coalescing windows). Only timing may
/// differ; any divergence in what's stored is a bug in the batcher.
#[test]
fn prop_batched_ssd_matches_unbatched() {
    use greedysnake::memory::{BatchConfig, DeviceProfile, SsdStorage};
    use std::sync::atomic::{AtomicU64, Ordering};
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    check("batched-ssd-equiv", 15, |rng| {
        let uniq = UNIQ.fetch_add(1, Ordering::Relaxed);
        let flat_path = std::env::temp_dir()
            .join(format!("gs_prop_batch_flat_{}_{uniq}", std::process::id()));
        let dev_path = std::env::temp_dir()
            .join(format!("gs_prop_batch_dev_{}_{uniq}", std::process::id()));
        let flat = SsdStorage::create_unthrottled(flat_path).map_err(|e| e.to_string())?;
        // infinite peaks keep the test fast; the latency floor + window are
        // what the batcher actually exercises
        let profile = DeviceProfile {
            read_bps: f64::INFINITY,
            write_bps: f64::INFINITY,
            qd_knee: gen::usize_in(rng, 1, 8) as u32,
            sat_bytes: 1 << 20,
            mix_penalty: 0.1,
            op_latency_s: 30e-6,
        };
        let batch = BatchConfig { max_bytes: 1 << 20, max_ops: gen::usize_in(rng, 2, 16) as u64 };
        let batched = SsdStorage::with_profile(&dev_path, profile, Some(batch))
            .map_err(|e| e.to_string())?;
        // phase 1: mirrored random sequential ops
        let keys = ["a", "b", "c", "d", "e"];
        for op in 0..30 {
            let key = keys[gen::usize_in(rng, 0, keys.len() - 1)];
            match gen::usize_in(rng, 0, 3) {
                0 | 1 => {
                    let len = gen::usize_in(rng, 0, 5000);
                    let fill = gen::usize_in(rng, 0, 255) as u8;
                    let data: Vec<u8> = (0..len).map(|i| fill.wrapping_add(i as u8)).collect();
                    flat.put(key, &data).map_err(|e| e.to_string())?;
                    batched.put(key, &data).map_err(|e| e.to_string())?;
                }
                2 => {
                    let a = flat.delete(key);
                    let b = batched.delete(key);
                    if a != b {
                        return Err(format!("op {op}: delete('{key}') {a} vs {b}"));
                    }
                }
                _ => {
                    let mut x = Vec::new();
                    let mut y = Vec::new();
                    let ra = flat.get(key, &mut x);
                    let rb = batched.get(key, &mut y);
                    if ra.is_ok() != rb.is_ok() {
                        return Err(format!(
                            "op {op}: get('{key}') presence {} vs {}",
                            ra.is_ok(),
                            rb.is_ok()
                        ));
                    }
                    if ra.is_ok() && x != y {
                        return Err(format!("op {op}: get('{key}') content mismatch"));
                    }
                }
            }
            if flat.contains(key) != batched.contains(key) {
                return Err(format!("op {op}: contains('{key}') diverged"));
            }
            if flat.len_of(key) != batched.len_of(key) {
                return Err(format!("op {op}: len_of('{key}') diverged"));
            }
            if flat.bytes_read() != batched.bytes_read()
                || flat.bytes_written() != batched.bytes_written()
            {
                return Err(format!(
                    "op {op}: accounting r/w {}/{} vs {}/{}",
                    flat.bytes_read(),
                    flat.bytes_written(),
                    batched.bytes_read(),
                    batched.bytes_written()
                ));
            }
        }
        // phase 2: concurrent disjoint-key burst on each store — the shape
        // that opens coalescing windows on the batched device
        let n_threads = 4usize;
        let per = 6usize;
        for store in [&flat, &batched] {
            std::thread::scope(|s| {
                for t in 0..n_threads {
                    let store = &*store;
                    s.spawn(move || {
                        for i in 0..per {
                            let data: Vec<u8> =
                                (0..2048).map(|j| (t * 31 + i * 7 + j) as u8).collect();
                            store.put(&format!("t{t}_k{i}"), &data).unwrap();
                        }
                    });
                }
            });
        }
        for t in 0..n_threads {
            for i in 0..per {
                let key = format!("t{t}_k{i}");
                let mut x = Vec::new();
                let mut y = Vec::new();
                flat.get(&key, &mut x).map_err(|e| e.to_string())?;
                batched.get(&key, &mut y).map_err(|e| e.to_string())?;
                if x != y {
                    return Err(format!("burst: '{key}' content diverged"));
                }
            }
        }
        if flat.bytes_written() != batched.bytes_written() {
            return Err(format!(
                "burst: write accounting {} vs {}",
                flat.bytes_written(),
                batched.bytes_written()
            ));
        }
        flat.check_consistency().map_err(|e| e.to_string())?;
        batched.check_consistency().map_err(|e| e.to_string())?;
        Ok(())
    });
}

/// The DRAM-cache residual closed form composes with the schedule traffic
/// forms: for any M and capacity, the residual is either 0 (fits) or the
/// full store traffic (doesn't) — never anything in between — and the
/// working set is monotone in the offloaded share.
#[test]
fn prop_cache_residual_is_all_or_nothing() {
    check("cache-residual", 60, |rng| {
        let m = gen::usize_in(rng, 1, 32) as u64;
        let w = Workload {
            model: GPT_65B,
            micro_batch: 2,
            seq_len: SEQ_LEN,
            m,
            shards: 1,
        };
        let opt = gen::usize_in(rng, 0, 1) == 1;
        let ckpt = gen::usize_in(rng, 0, 1) == 1;
        let ws = w.store_working_set_bytes(opt, ckpt);
        let cap = (gen::f64_in(rng, 0.0, 2.0) * ws as f64) as u64;
        let residual = w.cached_store_read_bytes(opt, ckpt, cap);
        let full = w.store_read_bytes(opt, ckpt);
        if residual != 0 && residual != full {
            return Err(format!("residual {residual} not in {{0, {full}}}"));
        }
        if ws > 0 && cap >= ws && residual != 0 {
            return Err(format!("cap {cap} >= ws {ws} must absorb everything"));
        }
        if cap < ws && residual != full {
            return Err(format!("cap {cap} < ws {ws} must absorb nothing"));
        }
        // working set monotone in the offloaded share
        let both = w.store_working_set_bytes(true, true);
        if both < ws {
            return Err("working set must grow with the offloaded share".into());
        }
        Ok(())
    });
}

/// Codec length laws: `encoded_len(n) = n · bytes_per_elem` for every
/// codec, `encode_into` produces exactly that many bytes, decode inverts
/// the length, and a misaligned byte object is REJECTED (not truncated).
#[test]
fn prop_codec_length_laws() {
    use greedysnake::memory::Codec;
    check("codec-length-laws", 100, |rng| {
        let n = gen::usize_in(rng, 0, 4096);
        for codec in [Codec::F32, Codec::F16, Codec::BF16] {
            let w = codec.bytes_per_elem() as usize;
            if codec.encoded_len(n) != n * w {
                return Err(format!("{}: encoded_len({n}) != {n}*{w}", codec.name()));
            }
            let src = gen::vec_f32(rng, n, 4.0);
            let mut enc = Vec::new();
            codec.encode_into(&src, &mut enc);
            if enc.len() != n * w {
                return Err(format!("{}: encoded {} bytes, want {}", codec.name(), enc.len(), n * w));
            }
            let mut dec = Vec::new();
            codec.decode_into("k", &enc, &mut dec).map_err(|e| e.to_string())?;
            if dec.len() != n {
                return Err(format!("{}: decoded {} elems, want {n}", codec.name(), dec.len()));
            }
            // misaligned object: one stray byte must error, never truncate
            enc.push(0xAB);
            if codec.decode_into("k", &enc, &mut dec).is_ok() {
                return Err(format!("{}: accepted a misaligned object", codec.name()));
            }
        }
        Ok(())
    });
}

/// decode ∘ encode ≡ requantize, bit for bit — the contract that makes the
/// optimizer's delayed in-place gradient conversion equivalent to an SSD
/// round trip through the codec.
#[test]
fn prop_codec_roundtrip_equals_requantize() {
    use greedysnake::memory::Codec;
    check("codec-roundtrip", 100, |rng| {
        let n = gen::usize_in(rng, 1, 2048);
        // mix magnitudes across the whole dynamic range, incl. overflow
        // territory for f16 (|x| > 65504) and tiny values
        let scale = 10f32.powi(gen::usize_in(rng, 0, 10) as i32 - 5);
        let src = gen::vec_f32(rng, n, scale);
        for codec in [Codec::F32, Codec::F16, Codec::BF16] {
            let mut enc = Vec::new();
            codec.encode_into(&src, &mut enc);
            let mut dec = Vec::new();
            codec.decode_into("k", &enc, &mut dec).map_err(|e| e.to_string())?;
            let mut req = src.clone();
            codec.requantize(&mut req);
            for (i, (d, q)) in dec.iter().zip(&req).enumerate() {
                if d.to_bits() != q.to_bits() {
                    return Err(format!(
                        "{} elem {i}: decode {d:e} ({:#010x}) != requantize {q:e} ({:#010x})",
                        codec.name(),
                        d.to_bits(),
                        q.to_bits()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// ULP error bounds on the half codecs: for in-range normal inputs the
/// relative roundtrip error is ≤ 2⁻¹¹ (f16, 10 significand bits) and
/// ≤ 2⁻⁸ (bf16, 7 explicit bits) — round-to-nearest-even half-ULP bounds.
#[test]
fn prop_codec_ulp_error_bounds() {
    use greedysnake::memory::Codec;
    check("codec-ulp-bounds", 200, |rng| {
        let n = gen::usize_in(rng, 1, 512);
        let scale = 10f32.powi(gen::usize_in(rng, 0, 8) as i32 - 4);
        let src = gen::vec_f32(rng, n, scale);
        for (codec, bound, lo, hi) in [
            (Codec::F16, 2f64.powi(-11), 6.2e-5f32, 65504.0f32),
            (Codec::BF16, 2f64.powi(-8), f32::MIN_POSITIVE, f32::MAX / 2.0),
        ] {
            let mut dec = src.clone();
            codec.requantize(&mut dec);
            for (i, (&x, &y)) in src.iter().zip(&dec).enumerate() {
                if x.abs() < lo || x.abs() > hi {
                    continue; // subnormal/overflow territory: no normal bound
                }
                let rel = ((y as f64 - x as f64) / x as f64).abs();
                if rel > bound {
                    return Err(format!(
                        "{} elem {i}: x={x:e} -> {y:e}, rel err {rel:e} > {bound:e}",
                        codec.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Specials survive the half codecs: NaN stays NaN, ±Inf stays ±Inf with
/// its sign, ±0 keeps its sign bit, f16 saturates overflow to ±Inf, and
/// f32 subnormals map to a same-signed value no larger than f32's smallest
/// normal (gradual or total underflow — never a sign flip or a blow-up).
#[test]
fn prop_codec_specials_preserved() {
    use greedysnake::memory::Codec;
    check("codec-specials", 50, |rng| {
        let sub = f32::from_bits(1 + rng.next_below(0x007F_FFFF) as u32); // subnormal
        for codec in [Codec::F16, Codec::BF16] {
            let name = codec.name();
            let q = |x: f32| {
                let mut v = [x];
                codec.requantize(&mut v);
                v[0]
            };
            if !q(f32::NAN).is_nan() {
                return Err(format!("{name}: NaN lost"));
            }
            if q(f32::INFINITY) != f32::INFINITY || q(f32::NEG_INFINITY) != f32::NEG_INFINITY {
                return Err(format!("{name}: Inf lost"));
            }
            if q(0.0).to_bits() != 0.0f32.to_bits() || q(-0.0).to_bits() != (-0.0f32).to_bits() {
                return Err(format!("{name}: signed zero lost"));
            }
            for s in [sub, -sub] {
                let y = q(s);
                if y.abs() > f32::MIN_POSITIVE || (y != 0.0 && y.signum() != s.signum()) {
                    return Err(format!("{name}: subnormal {s:e} -> {y:e}"));
                }
            }
        }
        // f16-only: overflow saturates to ±Inf (bf16 never overflows first)
        let big = 70000.0f32 * (1.0 + rng.next_f32());
        let mut v = [big, -big];
        Codec::F16.requantize(&mut v);
        if v[0] != f32::INFINITY || v[1] != f32::NEG_INFINITY {
            return Err(format!("f16: {big:e} must saturate to ±Inf, got {v:?}"));
        }
        Ok(())
    });
}

/// Serving: the forward-only engine's parameter-read bytes equal the
/// training forward leg of the traffic closed forms for EVERY schedule
/// grouping and io-depth — per token step, `⌈B/G⌉ × model bytes` of base
/// image and `N·⌈B/G⌉` layer loads, with the uncached store moving exactly
/// the metered bytes (`serve_param_loads` / `serve_param_read_bytes`
/// realized by real store traffic).
#[test]
fn prop_serve_decode_bytes_equal_forward_closed_form() {
    use greedysnake::coordinator::schedule::ChunkedVerticalSchedule;
    use greedysnake::coordinator::serve::{provision, Batch, ServeModel};
    use greedysnake::coordinator::ServeEngine;
    use greedysnake::memory::{SsdStorage, TensorStore};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    check("serve-byte-law", 25, |rng| {
        let n_layers = gen::usize_in(rng, 1, 5);
        let layer_numel = gen::usize_in(rng, 8, 128);
        let lanes = gen::usize_in(rng, 1, 6) as u64;
        // g=1 ≡ horizontal reloads, g ≥ lanes ≡ vertical — the sweep covers
        // both degeneracies plus the ragged middle
        let g = gen::usize_in(rng, 1, lanes as usize + 2) as u64;
        let tokens = gen::usize_in(rng, 1, 3);
        let model = ServeModel::synthetic(n_layers, layer_numel, 16, 997);
        let sched = ChunkedVerticalSchedule::new(g as usize);
        for depth in [0usize, 2] {
            let uniq = UNIQ.fetch_add(1, Ordering::Relaxed);
            let path = std::env::temp_dir().join(format!(
                "gs_prop_serve_{}_{uniq}",
                std::process::id()
            ));
            let store: Arc<dyn TensorStore> =
                Arc::new(SsdStorage::create_unthrottled(path).map_err(|e| e.to_string())?);
            provision(store.as_ref(), &model, 2, 7).map_err(|e| e.to_string())?;
            let mut eng = ServeEngine::new(model.clone(), Arc::clone(&store), depth, 11);
            let batch = Batch { tenant: 1, requests: (0..lanes).collect() };
            eng.decode(&sched, &batch, tokens, None).map_err(|e| e.to_string())?;
            let s = eng.stats();
            let loads = n_layers as u64 * lanes.div_ceil(g) * tokens as u64;
            let base = lanes.div_ceil(g)
                * (n_layers as u64 * model.base_layer_bytes())
                * tokens as u64;
            if s.param_loads != loads {
                return Err(format!(
                    "nl={n_layers} B={lanes} g={g} depth={depth}: loads {} != {loads}",
                    s.param_loads
                ));
            }
            if s.base_bytes_loaded != base {
                return Err(format!(
                    "nl={n_layers} B={lanes} g={g} depth={depth}: base bytes {} != {base}",
                    s.base_bytes_loaded
                ));
            }
            if s.adapter_bytes_loaded != loads * model.adapter_layer_bytes() {
                return Err(format!("g={g} depth={depth}: adapter bytes off"));
            }
            let metered = s.base_bytes_loaded + s.adapter_bytes_loaded + s.embed_bytes_loaded;
            if s.store_bytes_read != metered {
                return Err(format!(
                    "g={g} depth={depth}: store read {} != metered {metered}",
                    s.store_bytes_read
                ));
            }
        }
        // the analytic family agrees: the serve form is exactly half the
        // chunked schedule's parameter round trip (forward leg only)
        let wl = Workload { model: GPT_65B, micro_batch: 2, seq_len: SEQ_LEN, m: lanes, shards: 1 };
        if 2 * wl.serve_param_read_bytes(g) != wl.chunked_vertical(g).param_load {
            return Err(format!("g={g}: analytic serve form is not the forward leg"));
        }
        Ok(())
    });
}

/// Serving: batch formation is a pure function of the request SET — any
/// arrival permutation forms byte-identical batches, every batch is
/// single-tenant with ascending ids and ≤ max_batch lanes, and no request
/// is dropped or duplicated.
#[test]
fn prop_serve_batcher_arrival_order_invariant() {
    use greedysnake::coordinator::serve::{form_batches, Request};
    check("serve-batcher", 100, |rng| {
        let tenants = gen::usize_in(rng, 1, 5) as u64;
        let n = gen::usize_in(rng, 0, 40);
        let max_batch = gen::usize_in(rng, 1, 6);
        let mut reqs: Vec<Request> = (0..n as u64)
            .map(|id| Request { tenant: rng.next_below(tenants), id })
            .collect();
        let baseline = form_batches(&reqs, max_batch);
        for _ in 0..3 {
            // Fisher–Yates arrival shuffle
            for i in (1..reqs.len()).rev() {
                let j = rng.next_below(i as u64 + 1) as usize;
                reqs.swap(i, j);
            }
            if form_batches(&reqs, max_batch) != baseline {
                return Err(format!("arrival order changed the batches (n={n})"));
            }
        }
        let mut seen: Vec<(u64, u64)> = Vec::new();
        for b in &baseline {
            if b.requests.is_empty() || b.requests.len() > max_batch {
                return Err(format!("batch size {} out of [1, {max_batch}]", b.requests.len()));
            }
            if !b.requests.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("ids not ascending: {:?}", b.requests));
            }
            seen.extend(b.requests.iter().map(|&id| (b.tenant, id)));
        }
        let mut expect: Vec<(u64, u64)> = reqs.iter().map(|r| (r.tenant, r.id)).collect();
        seen.sort_unstable();
        expect.sort_unstable();
        if seen != expect {
            return Err("requests dropped or duplicated".to_string());
        }
        Ok(())
    });
}
