//! Cross-module integration tests over the REAL stack (PJRT + artifacts):
//! schedule equivalence (Figure 13's property), α ablations, SSD-offload
//! modes, and the analytic stack's cross-consistency.

use greedysnake::coordinator::TrainerConfig;
use greedysnake::lp;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::SystemParams;
use greedysnake::runtime::Manifest;
use greedysnake::sim::{simulate, Schedule};
use greedysnake::trainer::{train, RunLog, ScheduleKind};

fn cfg(tag: &str) -> TrainerConfig {
    TrainerConfig {
        alpha: 0.0,
        opt_on_ssd: false,
        overlap: false,
        ssd_path: std::env::temp_dir().join(format!("gs_itest_{tag}_{}", std::process::id())),
        ..Default::default()
    }
}

fn run(tag: &str, kind: ScheduleKind, c: TrainerConfig, steps: u64, m: usize) -> RunLog {
    let _ = tag;
    train(Manifest::load("artifacts/tiny").unwrap(), c, kind, steps, m, 0).unwrap()
}

/// Figure 13: vertical and horizontal scheduling produce the same loss
/// trajectory (identical data/seed; fp noise from different accumulation
/// orders only).
#[test]
fn fig13_loss_equivalence_vertical_vs_horizontal() {
    let v = run("f13v", ScheduleKind::Vertical, cfg("f13v"), 10, 3);
    let h = run("f13h", ScheduleKind::Horizontal, cfg("f13h"), 10, 3);
    for (i, (a, b)) in v.losses.iter().zip(&h.losses).enumerate() {
        assert!((a - b).abs() < 2e-2, "step {i}: {a} vs {b}");
    }
    // and training actually learns
    assert!(v.final_loss() < v.losses[0]);
}

/// The delayed optimizer step (α > 0) must not change training outcomes —
/// only timing (§4.4: same update, later).
#[test]
fn alpha_delay_preserves_training_trajectory() {
    let base = run("a0", ScheduleKind::Vertical, cfg("a0"), 8, 2);
    for alpha in [0.25, 0.5] {
        let mut c = cfg(&format!("a{alpha}"));
        c.alpha = alpha;
        let delayed = run("ad", ScheduleKind::Vertical, c, 8, 2);
        for (i, (a, b)) in base.losses.iter().zip(&delayed.losses).enumerate() {
            // α delays the tail update by one iteration, which perturbs the
            // trajectory slightly from step 2 on; it must stay close and
            // converge the same way.
            assert!((a - b).abs() < 0.15, "α={alpha} step {i}: {a} vs {b}");
        }
        assert!(delayed.final_loss() < delayed.losses[0]);
    }
}

/// Optimizer states on the throttled SSD tier: same numerics, real I/O.
#[test]
fn ssd_offloaded_optimizer_matches_cpu_resident() {
    let a = run("ssd_off", ScheduleKind::Vertical, cfg("ssd_off"), 6, 2);
    let mut c = cfg("ssd_on");
    c.opt_on_ssd = true;
    let b = run("ssd_on", ScheduleKind::Vertical, c, 6, 2);
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
    assert!(b.ssd_read > 0, "offloaded run must actually read the SSD");
    assert!(b.ssd_written > 0);
    assert_eq!(a.ssd_read, 0, "resident run must not touch the SSD");
}

/// Checkpoints on SSD (Figure 12's 100 % offload stress): still trains.
#[test]
fn full_ssd_offload_trains() {
    let mut c = cfg("full");
    c.opt_on_ssd = true;
    c.ckpt_on_ssd = true;
    c.ssd_read_bps = 2e9; // throttled like the paper's testbed
    c.ssd_write_bps = 2e9;
    let log = run("full", ScheduleKind::Vertical, c, 6, 2);
    assert!(log.final_loss() < log.losses[0]);
    assert!(log.ssd_read > 1024 * 1024, "checkpoints must flow through SSD");
}

/// The AOT Pallas Adam kernel on the hot path: equivalent training.
#[test]
fn hlo_adam_path_trains_identically() {
    let a = run("radam", ScheduleKind::Vertical, cfg("radam"), 5, 2);
    let mut c = cfg("hadam");
    c.use_hlo_adam = true;
    let b = run("hadam", ScheduleKind::Vertical, c, 5, 2);
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

/// Overlapped optimizer worker vs inline: identical numerics.
#[test]
fn overlap_does_not_change_results() {
    let a = run("inline", ScheduleKind::Vertical, cfg("inline"), 6, 3);
    let mut c = cfg("ovl");
    c.overlap = true;
    c.alpha = 0.3;
    let b = run("ovl", ScheduleKind::Vertical, c, 6, 3);
    // α perturbs timing; with overlap+delay the trajectory stays close
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 0.15, "{x} vs {y}");
    }
}

/// Gradient clipping (speculative): a tight threshold must fire and record
/// violations without breaking training.
#[test]
fn speculative_clipping_fires_and_trains() {
    let mut c = cfg("clip");
    c.clip_norm = 0.5;
    let log = run("clip", ScheduleKind::Vertical, c, 8, 2);
    assert!(log.grad_norms.iter().any(|&n| n > 0.5), "{:?}", log.grad_norms);
    assert!(log.final_loss() < log.losses[0]);
}

/// Cross-consistency: LP, closed-form perfmodel, and the discrete-event
/// simulator agree on who wins at the 65B/A100 point.
#[test]
fn analytics_agree_on_the_headline_comparison() {
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let best = lp::find_optimal_config(&sp).expect("feasible");
    let v = simulate(&sp, best.m, Schedule::GreedySnake { alpha: best.alpha, x: best.ratios });
    let z = simulate(&sp, best.m, Schedule::ZeroInfinity);
    assert!(
        v.tokens_per_s > 1.5 * z.tokens_per_s,
        "sim: {} vs {}",
        v.tokens_per_s,
        z.tokens_per_s
    );
    // LP prediction within 2× of simulated (bubbles + boundary stages)
    let ratio = v.tokens_per_s / best.tokens_per_s;
    assert!(ratio > 0.5 && ratio < 2.0, "sim/lp = {ratio}");
}

/// Different seeds give different data but training still converges.
#[test]
fn seeds_vary_but_converge() {
    let mut c1 = cfg("s1");
    c1.seed = 1;
    let mut c2 = cfg("s2");
    c2.seed = 2;
    let a = run("s1", ScheduleKind::Vertical, c1, 8, 2);
    let b = run("s2", ScheduleKind::Vertical, c2, 8, 2);
    assert_ne!(a.losses[0], b.losses[0]);
    assert!(a.final_loss() < a.losses[0]);
    assert!(b.final_loss() < b.losses[0]);
}
