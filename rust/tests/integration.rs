//! Cross-module integration tests over the REAL stack (PJRT + artifacts):
//! schedule equivalence (Figure 13's property), α ablations, SSD-offload
//! modes, and the analytic stack's cross-consistency.
//!
//! Tests that execute stages gate on `runtime::test_artifacts`: they skip
//! (with a notice) when the AOT artifacts were never built or PJRT is the
//! vendored stub, so `cargo test -q` is meaningful on a fresh clone.

use greedysnake::coordinator::TrainerConfig;
use greedysnake::lp;
use greedysnake::machine::MACHINE2_A100;
use greedysnake::memory::Precision;
use greedysnake::modelcfg::{GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::SystemParams;
use greedysnake::runtime::test_artifacts;
use greedysnake::sim::{simulate, Schedule};
use greedysnake::trainer::{train, RunLog, ScheduleKind};

fn cfg(tag: &str) -> TrainerConfig {
    TrainerConfig::for_test(tag)
}

/// `None` (skip) when artifacts/PJRT are unavailable.
fn run(tag: &str, kind: ScheduleKind, c: TrainerConfig, steps: u64, m: usize) -> Option<RunLog> {
    let _ = tag;
    let manifest = test_artifacts("artifacts/tiny")?;
    Some(train(manifest, c, kind, steps, m, 0).unwrap())
}

/// Figure 13: vertical and horizontal scheduling produce the same loss
/// trajectory (identical data/seed; fp noise from different accumulation
/// orders only).
#[test]
fn fig13_loss_equivalence_vertical_vs_horizontal() {
    let Some(v) = run("f13v", ScheduleKind::Vertical, cfg("f13v"), 10, 3) else { return };
    let h = run("f13h", ScheduleKind::Horizontal, cfg("f13h"), 10, 3).unwrap();
    for (i, (a, b)) in v.losses.iter().zip(&h.losses).enumerate() {
        assert!((a - b).abs() < 2e-2, "step {i}: {a} vs {b}");
    }
    // and training actually learns
    assert!(v.final_loss() < v.losses[0]);
}

/// The gradient-equivalence property over ALL registered Schedule impls:
/// at α = 0 every traversal policy computes the same gradients, so the
/// loss trajectories and gradient norms coincide (modulo accumulation-order
/// rounding) — while the parameter traffic strictly orders
/// vertical < chunked:2 < horizontal (§3.3 vs §3.4).
#[test]
fn all_schedules_equivalent_gradients_and_ordered_traffic() {
    let kinds = [
        ScheduleKind::Vertical,
        ScheduleKind::ChunkedVertical(2),
        ScheduleKind::Horizontal,
    ];
    let mut logs = Vec::new();
    for kind in kinds {
        let tag = format!("eq_{kind}").replace(':', "_");
        let Some(log) = run(&tag, kind, cfg(&tag), 8, 4) else { return };
        logs.push(log);
    }
    for (k, log) in logs.iter().enumerate().skip(1) {
        for (i, (a, b)) in logs[0].losses.iter().zip(&log.losses).enumerate() {
            assert!((a - b).abs() < 2e-2, "{:?} step {i}: {a} vs {b}", kinds[k]);
        }
        for (i, (a, b)) in logs[0].grad_norms.iter().zip(&log.grad_norms).enumerate() {
            assert!(
                (a - b).abs() < 1e-2 * (1.0 + a.abs()),
                "{:?} grad norm step {i}: {a} vs {b}",
                kinds[k]
            );
        }
        assert!(log.final_loss() < log.losses[0], "{:?} must learn", kinds[k]);
    }
    // schedule-controlled traffic: bytes of parameters crossing the
    // host→device boundary (chunked:2 reloads twice per pass at M=4)
    let (v, c, h) = (logs[0].param_bytes, logs[1].param_bytes, logs[2].param_bytes);
    assert!(v < c && c < h, "traffic must order vertical {v} < chunked {c} < horizontal {h}");
    assert_eq!(c, 2 * v, "chunked:2 at M=4 is exactly two vertical passes of traffic");
    assert_eq!(h, 4 * v, "horizontal at M=4 reloads per micro-batch");
}

/// Same property on the SSD tier: with checkpoints spilled to SSD the
/// runtime's measured bytes READ stay equal across schedules (every (layer,
/// micro-batch) checkpoint round-trips exactly once), while the analytic
/// model's schedule-dependent read traffic orders vertical ≤ chunked ≤
/// horizontal — cross-checked here against `ScheduleKind::traffic`.
#[test]
fn ssd_reads_and_traffic_model_cross_check() {
    use greedysnake::traffic::Workload;
    let w = Workload { model: GPT_65B, micro_batch: 8, seq_len: SEQ_LEN, m: 4, shards: 1 };
    let v = ScheduleKind::Vertical.traffic(&w).total_load();
    let c = ScheduleKind::ChunkedVertical(2).traffic(&w).total_load();
    let h = ScheduleKind::Horizontal.traffic(&w).total_load();
    assert!(v < c && c < h, "analytic reads: {v} < {c} < {h}");

    // real stack (when artifacts exist): checkpoint SSD traffic is
    // schedule-independent, parameter traffic is what differs
    let mut base = cfg("ssd_v");
    base.ckpt_on_ssd = true;
    let Some(vl) = run("ssd_v", ScheduleKind::Vertical, base, 4, 4) else { return };
    let mut cc = cfg("ssd_c");
    cc.ckpt_on_ssd = true;
    let cl = run("ssd_c", ScheduleKind::ChunkedVertical(2), cc, 4, 4).unwrap();
    assert_eq!(vl.ssd_read, cl.ssd_read, "ckpt round trips are order-independent");
    assert!(vl.param_bytes < cl.param_bytes);
}

/// The delayed optimizer step (α > 0) must not change training outcomes —
/// only timing (§4.4: same update, later).
#[test]
fn alpha_delay_preserves_training_trajectory() {
    let Some(base) = run("a0", ScheduleKind::Vertical, cfg("a0"), 8, 2) else { return };
    for alpha in [0.25, 0.5] {
        let mut c = cfg(&format!("a{alpha}"));
        c.alpha = alpha;
        let delayed = run("ad", ScheduleKind::Vertical, c, 8, 2).unwrap();
        for (i, (a, b)) in base.losses.iter().zip(&delayed.losses).enumerate() {
            // α delays the tail update by one iteration, which perturbs the
            // trajectory slightly from step 2 on; it must stay close and
            // converge the same way.
            assert!((a - b).abs() < 0.15, "α={alpha} step {i}: {a} vs {b}");
        }
        assert!(delayed.final_loss() < delayed.losses[0]);
    }
}

/// The delayed split also composes with the chunked schedule (the forward
/// waits on each layer's pending update at its first visit of the pass).
#[test]
fn alpha_delay_works_under_chunked_schedule() {
    let Some(base) = run("ca0", ScheduleKind::ChunkedVertical(2), cfg("ca0"), 8, 4) else {
        return;
    };
    let mut c = cfg("ca25");
    c.alpha = 0.25;
    let delayed = run("ca25", ScheduleKind::ChunkedVertical(2), c, 8, 4).unwrap();
    for (i, (a, b)) in base.losses.iter().zip(&delayed.losses).enumerate() {
        assert!((a - b).abs() < 0.15, "step {i}: {a} vs {b}");
    }
    assert!(delayed.final_loss() < delayed.losses[0]);
}

/// The async I/O pipeline acceptance property: for every schedule, training
/// at `io_depth` ∈ {0, 1, 4} is *bit-identical* — same losses, grad norms,
/// SSD byte totals, and parameter traffic — because the pipeline moves I/O
/// off the compute thread without changing a single operation. Depth 0 is
/// the synchronous engine; depth ≥ 1 must additionally report prefetch hits.
#[test]
fn io_depth_gradient_equivalence_across_schedules() {
    let kinds = [
        ScheduleKind::Vertical,
        ScheduleKind::ChunkedVertical(2),
        ScheduleKind::Horizontal,
    ];
    for kind in kinds {
        let mut logs: Vec<(usize, RunLog)> = Vec::new();
        for depth in [0usize, 1, 4] {
            let tag = format!("iod{depth}_{kind}").replace(':', "_");
            let mut c = cfg(&tag);
            c.io_depth = depth;
            c.opt_on_ssd = true;
            c.ckpt_on_ssd = true;
            let Some(log) = run(&tag, kind, c, 5, 3) else { return };
            logs.push((depth, log));
        }
        let (_, base) = &logs[0];
        assert_eq!(base.prefetch_hits, 0, "{kind:?}: depth 0 must not prefetch");
        assert!(base.ssd_read > 0, "{kind:?}: offloaded run must touch the SSD");
        for (depth, log) in &logs[1..] {
            assert_eq!(base.losses, log.losses, "{kind:?} io-depth {depth}: losses diverged");
            assert_eq!(
                base.grad_norms, log.grad_norms,
                "{kind:?} io-depth {depth}: grad norms diverged"
            );
            assert_eq!(
                base.ssd_read, log.ssd_read,
                "{kind:?} io-depth {depth}: SSD read totals diverged"
            );
            assert_eq!(
                base.ssd_written, log.ssd_written,
                "{kind:?} io-depth {depth}: SSD write totals diverged"
            );
            assert_eq!(
                base.param_bytes, log.param_bytes,
                "{kind:?} io-depth {depth}: parameter traffic diverged"
            );
            assert!(
                log.prefetch_hits > 0,
                "{kind:?} io-depth {depth}: the lookahead never hit"
            );
        }
    }
}

/// The set of data-parallel worker counts the equivalence suite compares
/// against the W = 1 baseline. CI's `--workers` matrix narrows it via
/// `GS_TEST_WORKERS` (comma-separated) so each job pins one W.
fn test_worker_set() -> Vec<usize> {
    std::env::var("GS_TEST_WORKERS")
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect::<Vec<usize>>())
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![2, 4])
}

/// The data-parallel acceptance property: for every schedule × io-depth
/// {0, 2} × W in the matrix, training is BIT-identical to the W = 1
/// single-engine baseline — same losses, gradient norms, SSD byte totals,
/// and (through the Σx² digests) the exact same parameters and optimizer
/// moments. This is the determinism contract of `coordinator::dist`: the
/// ring all-reduce replays the schedule's canonical accumulation order.
#[test]
fn dp_workers_bit_identical_to_single_engine() {
    let kinds = [
        ScheduleKind::Vertical,
        ScheduleKind::ChunkedVertical(2),
        ScheduleKind::Horizontal,
    ];
    for kind in kinds {
        for depth in [0usize, 2] {
            let mk = |w: usize| {
                let tag = format!("dpw{w}_d{depth}_{kind}").replace(':', "_");
                let mut c = cfg(&tag);
                c.io_depth = depth;
                c.workers = w;
                c.opt_on_ssd = true;
                c.ckpt_on_ssd = true;
                c
            };
            let Some(base) = run("dp_base", kind, mk(1), 4, 4) else { return };
            assert!(base.ssd_read > 0, "{kind:?}: offloaded run must touch the SSD");
            for w in test_worker_set() {
                let log = run("dp_w", kind, mk(w), 4, 4).unwrap();
                assert_eq!(
                    base.losses, log.losses,
                    "{kind:?} depth {depth} W={w}: losses diverged"
                );
                assert_eq!(
                    base.grad_norms, log.grad_norms,
                    "{kind:?} depth {depth} W={w}: grad norms diverged"
                );
                assert_eq!(
                    base.ssd_read, log.ssd_read,
                    "{kind:?} depth {depth} W={w}: SSD read totals diverged"
                );
                assert_eq!(
                    base.ssd_written, log.ssd_written,
                    "{kind:?} depth {depth} W={w}: SSD write totals diverged"
                );
                assert_eq!(
                    base.param_sq_norm.to_bits(),
                    log.param_sq_norm.to_bits(),
                    "{kind:?} depth {depth} W={w}: parameters diverged"
                );
                assert_eq!(
                    base.moment_sq_norm.to_bits(),
                    log.moment_sq_norm.to_bits(),
                    "{kind:?} depth {depth} W={w}: optimizer moments diverged"
                );
                if w > 1 {
                    assert!(
                        log.allreduce_bytes > 0,
                        "{kind:?} W={w}: the ring moved no bytes"
                    );
                }
            }
        }
    }
}

/// The sharded-optimizer acceptance property (tentpole): for every schedule
/// × io-depth {0, 2} × W in the matrix, `--shard-optimizer` training is
/// BIT-identical to the W = 1 unsharded single-engine baseline — same
/// losses, gradient norms, SSD byte totals (each rank round-trips only its
/// 1/W moment shard, but the shards tile the tensor so totals are equal),
/// and the exact same parameters and optimizer moments through the Σx²
/// digests (the sharded SSD layout reads back in ascending element order,
/// so even the digest's f64 fold is the same addition sequence). W > 1 must
/// additionally report both reduce-scatter and all-gather ring traffic.
#[test]
fn shard_optimizer_bit_identical_to_single_engine() {
    let kinds = [
        ScheduleKind::Vertical,
        ScheduleKind::ChunkedVertical(2),
        ScheduleKind::Horizontal,
    ];
    for kind in kinds {
        for depth in [0usize, 2] {
            let mk = |w: usize, shard: bool| {
                let tag = format!("shw{w}_{shard}_d{depth}_{kind}").replace(':', "_");
                let mut c = cfg(&tag);
                c.io_depth = depth;
                c.workers = w;
                c.shard_optimizer = shard;
                c.opt_on_ssd = true;
                c.ckpt_on_ssd = true;
                c
            };
            let Some(base) = run("sh_base", kind, mk(1, false), 4, 4) else { return };
            assert!(base.ssd_read > 0, "{kind:?}: offloaded run must touch the SSD");
            for w in test_worker_set() {
                let log = run("sh_w", kind, mk(w, true), 4, 4).unwrap();
                assert_eq!(
                    base.losses, log.losses,
                    "{kind:?} depth {depth} sharded W={w}: losses diverged"
                );
                assert_eq!(
                    base.grad_norms, log.grad_norms,
                    "{kind:?} depth {depth} sharded W={w}: grad norms diverged"
                );
                assert_eq!(
                    base.ssd_read, log.ssd_read,
                    "{kind:?} depth {depth} sharded W={w}: SSD read totals diverged"
                );
                assert_eq!(
                    base.ssd_written, log.ssd_written,
                    "{kind:?} depth {depth} sharded W={w}: SSD write totals diverged"
                );
                assert_eq!(
                    base.param_sq_norm.to_bits(),
                    log.param_sq_norm.to_bits(),
                    "{kind:?} depth {depth} sharded W={w}: parameters diverged"
                );
                assert_eq!(
                    base.moment_sq_norm.to_bits(),
                    log.moment_sq_norm.to_bits(),
                    "{kind:?} depth {depth} sharded W={w}: optimizer moments diverged"
                );
                if w > 1 {
                    assert!(
                        log.allreduce_bytes > 0,
                        "{kind:?} sharded W={w}: no reduce-scatter traffic"
                    );
                    assert!(
                        log.allgather_bytes > 0,
                        "{kind:?} sharded W={w}: no all-gather traffic"
                    );
                } else {
                    assert_eq!(log.allgather_bytes, 0, "{kind:?} W=1 must not gather");
                }
            }
        }
    }
}

/// The α = 0.25 sharded case the acceptance criteria single out: per-shard
/// α splits move the eager/delayed boundary, but with a stable speculative
/// scale the update values are timing-invariant, so sharded W ∈ {2, 4}
/// stays bit-identical to the unsharded W = 1 baseline at α > 0 too.
#[test]
fn shard_optimizer_bit_identical_under_alpha_delay() {
    let mk = |w: usize, shard: bool| {
        let mut c = cfg(&format!("sha_{w}_{shard}"));
        c.alpha = 0.25;
        c.opt_on_ssd = true;
        c.workers = w;
        c.shard_optimizer = shard;
        c
    };
    let Some(base) = run("sha1", ScheduleKind::Vertical, mk(1, false), 6, 4) else { return };
    for w in test_worker_set() {
        let sharded = run("shaw", ScheduleKind::Vertical, mk(w, true), 6, 4).unwrap();
        assert_eq!(base.losses, sharded.losses, "α-delay sharded losses diverged at W={w}");
        assert_eq!(base.grad_norms, sharded.grad_norms, "W={w}");
        // (SSD byte totals are NOT asserted here: per-shard α splits move
        // the eager/delayed byte boundary, and the last step's delayed
        // round trip retires in drain() outside the per-step deltas — the
        // Σx² digests below are the strong equivalence checks at α > 0.)
        assert_eq!(base.param_sq_norm.to_bits(), sharded.param_sq_norm.to_bits(), "W={w}");
        assert_eq!(base.moment_sq_norm.to_bits(), sharded.moment_sq_norm.to_bits(), "W={w}");
    }
}

/// Inactive ranks (W > M) are not reported as fake 0-stall workers: the
/// per-worker stall vector has one entry per ACTIVE worker and still sums
/// to the aggregate.
#[test]
fn dp_worker_stalls_report_active_ranks_only() {
    let mut c = cfg("dpidle");
    c.workers = 4;
    c.ckpt_on_ssd = true;
    c.ssd_read_bps = 3e6;
    c.ssd_write_bps = 3e6;
    // M = 2 < W = 4: only two ranks get a micro-batch share
    let Some(log) = run("dpidle", ScheduleKind::Vertical, c, 3, 2) else { return };
    assert_eq!(
        log.worker_stall_s.len(),
        2,
        "only the active workers may report stalls: {:?}",
        log.worker_stall_s
    );
    let sum: f64 = log.worker_stall_s.iter().sum();
    assert!(
        (sum - log.io_stall_s).abs() <= 1e-9 * (1.0 + log.io_stall_s.abs()),
        "active-worker stalls {sum} must sum to the aggregate {}",
        log.io_stall_s
    );
}

/// The delayed-α split composes with data parallelism: the shared
/// coordinator makes every worker's first forward visit of a layer wait on
/// its pending delayed update, so W = 2 stays bit-identical to W = 1 even
/// at α > 0 (where update/compute overlap is at its most tangled).
#[test]
fn dp_workers_bit_identical_under_alpha_delay() {
    let mk = |w: usize| {
        let mut c = cfg(&format!("dpa_{w}"));
        c.alpha = 0.25;
        c.opt_on_ssd = true;
        c.workers = w;
        c
    };
    let Some(base) = run("dpa1", ScheduleKind::Vertical, mk(1), 6, 4) else { return };
    let two = run("dpa2", ScheduleKind::Vertical, mk(2), 6, 4).unwrap();
    assert_eq!(base.losses, two.losses, "α-delay losses diverged at W=2");
    assert_eq!(base.grad_norms, two.grad_norms);
    assert_eq!(base.param_sq_norm.to_bits(), two.param_sq_norm.to_bits());
    assert_eq!(base.moment_sq_norm.to_bits(), two.moment_sq_norm.to_bits());
}

/// Worker-level stall accounting must stay consistent on a throttled
/// shared SSD: the aggregate `io_stall_s` is exactly the sum of the
/// per-worker shares, and every configured worker gets an entry.
#[test]
fn dp_worker_stall_accounting_sums_consistently() {
    let mut c = cfg("dpstall");
    c.workers = 2;
    c.ckpt_on_ssd = true;
    c.ssd_read_bps = 3e6;
    c.ssd_write_bps = 3e6;
    let Some(log) = run("dpstall", ScheduleKind::Vertical, c, 3, 4) else { return };
    assert_eq!(log.worker_stall_s.len(), 2);
    let sum: f64 = log.worker_stall_s.iter().sum();
    assert!(
        (sum - log.io_stall_s).abs() <= 1e-9 * (1.0 + log.io_stall_s.abs()),
        "per-worker stalls {sum} must sum to the aggregate {}",
        log.io_stall_s
    );
    assert!(log.io_stall_s > 0.0, "a throttled offloaded run must stall");
}

/// On a throttled SSD with checkpoints offloaded, the lookahead pipeline
/// must strictly reduce the compute thread's I/O stall versus the
/// synchronous engine while training identically — the runtime half of the
/// overlap win the sim predicts (Figs. 6–8).
#[test]
fn throttled_ssd_prefetch_reduces_stall() {
    // Checkpoint traffic only (opt states stay CPU-resident — their inline
    // round trips are identical in both runs and would drown the signal),
    // throttled low enough that each transfer costs milliseconds on the
    // tiny model's ~16 KB checkpoints.
    let mk = |tag: &str, depth: usize| {
        let mut c = cfg(tag);
        c.io_depth = depth;
        c.ckpt_on_ssd = true;
        c.opt_on_ssd = false;
        c.ssd_read_bps = 3e6;
        c.ssd_write_bps = 3e6;
        c
    };
    let Some(sync) = run("thr0", ScheduleKind::Vertical, mk("thr0", 0), 4, 3) else { return };
    let pre = run("thr4", ScheduleKind::Vertical, mk("thr4", 4), 4, 3).unwrap();
    assert_eq!(sync.losses, pre.losses, "throttling must not change numerics");
    assert!(pre.prefetch_hits > 0);
    assert!(
        pre.io_stall_s < sync.io_stall_s,
        "prefetch stall {:.3}s must undercut synchronous stall {:.3}s",
        pre.io_stall_s,
        sync.io_stall_s
    );
}

/// Optimizer states on the throttled SSD tier: same numerics, real I/O.
#[test]
fn ssd_offloaded_optimizer_matches_cpu_resident() {
    let Some(a) = run("ssd_off", ScheduleKind::Vertical, cfg("ssd_off"), 6, 2) else { return };
    let mut c = cfg("ssd_on");
    c.opt_on_ssd = true;
    let b = run("ssd_on", ScheduleKind::Vertical, c, 6, 2).unwrap();
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
    assert!(b.ssd_read > 0, "offloaded run must actually read the SSD");
    assert!(b.ssd_written > 0);
    assert_eq!(a.ssd_read, 0, "resident run must not touch the SSD");
}

/// Checkpoints on SSD (Figure 12's 100 % offload stress): still trains.
#[test]
fn full_ssd_offload_trains() {
    let mut c = cfg("full");
    c.opt_on_ssd = true;
    c.ckpt_on_ssd = true;
    c.ssd_read_bps = 2e9; // throttled like the paper's testbed
    c.ssd_write_bps = 2e9;
    let Some(log) = run("full", ScheduleKind::Vertical, c, 6, 2) else { return };
    assert!(log.final_loss() < log.losses[0]);
    assert!(log.ssd_read > 1024 * 1024, "checkpoints must flow through SSD");
}

/// The AOT Pallas Adam kernel on the hot path: equivalent training.
#[test]
fn hlo_adam_path_trains_identically() {
    let Some(a) = run("radam", ScheduleKind::Vertical, cfg("radam"), 5, 2) else { return };
    let mut c = cfg("hadam");
    c.use_hlo_adam = true;
    let b = run("hadam", ScheduleKind::Vertical, c, 5, 2).unwrap();
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

/// Overlapped optimizer worker vs inline: identical numerics.
#[test]
fn overlap_does_not_change_results() {
    let Some(a) = run("inline", ScheduleKind::Vertical, cfg("inline"), 6, 3) else { return };
    let mut c = cfg("ovl");
    c.overlap = true;
    c.alpha = 0.3;
    let b = run("ovl", ScheduleKind::Vertical, c, 6, 3).unwrap();
    // α perturbs timing; with overlap+delay the trajectory stays close
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 0.15, "{x} vs {y}");
    }
}

/// Gradient clipping (speculative): a tight threshold must fire and record
/// violations without breaking training.
#[test]
fn speculative_clipping_fires_and_trains() {
    let mut c = cfg("clip");
    c.clip_norm = 0.5;
    let Some(log) = run("clip", ScheduleKind::Vertical, c, 8, 2) else { return };
    assert!(log.grad_norms.iter().any(|&n| n > 0.5), "{:?}", log.grad_norms);
    assert!(log.final_loss() < log.losses[0]);
}

/// Cross-consistency: LP, closed-form perfmodel, and the discrete-event
/// simulator agree on who wins at the 65B/A100 point.
#[test]
fn analytics_agree_on_the_headline_comparison() {
    let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN);
    let best = lp::find_optimal_config(&sp).expect("feasible");
    let v = simulate(&sp, best.m, Schedule::GreedySnake { alpha: best.alpha, x: best.ratios });
    let z = simulate(&sp, best.m, Schedule::ZeroInfinity);
    assert!(
        v.tokens_per_s > 1.5 * z.tokens_per_s,
        "sim: {} vs {}",
        v.tokens_per_s,
        z.tokens_per_s
    );
    // LP prediction within 2× of simulated (bubbles + boundary stages)
    let ratio = v.tokens_per_s / best.tokens_per_s;
    assert!(ratio > 0.5 && ratio < 2.0, "sim/lp = {ratio}");
}

/// Different seeds give different data but training still converges.
#[test]
fn seeds_vary_but_converge() {
    let mut c1 = cfg("s1");
    c1.seed = 1;
    let mut c2 = cfg("s2");
    c2.seed = 2;
    let Some(a) = run("s1", ScheduleKind::Vertical, c1, 8, 2) else { return };
    let b = run("s2", ScheduleKind::Vertical, c2, 8, 2).unwrap();
    assert_ne!(a.losses[0], b.losses[0]);
    assert!(a.final_loss() < a.losses[0]);
    assert!(b.final_loss() < b.losses[0]);
}

/// The store backends the equivalence suite compares against the
/// single-SSD baseline. CI's store matrix narrows it via `GS_TEST_STORE`
/// (comma-separated ∈ {ssd, striped, cached, planned}) so each job pins
/// one backend; "ssd" is the baseline itself and compares trivially.
fn test_store_set() -> Vec<String> {
    std::env::var("GS_TEST_STORE")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect::<Vec<String>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| {
            vec!["striped".to_string(), "cached".to_string(), "planned".to_string()]
        })
}

fn apply_store_backend(c: &mut TrainerConfig, backend: &str) {
    match backend {
        "ssd" => {}
        "striped" => c.ssds = 2,
        "cached" => c.cpu_cache_mb = 64,
        "planned" => {
            // the full multi-path split: DRAM + 2 NVMe + remote
            c.planned = true;
            c.ssds = 2;
            c.cpu_cache_mb = 16;
            c.remote_mbps = 200.0;
        }
        other => {
            panic!("unknown GS_TEST_STORE backend '{other}' (ssd|striped|cached|planned)")
        }
    }
}

/// The store-backend acceptance property (tentpole): every backend —
/// single SSD, striped 2-device, DRAM-cached, multi-path planned — trains
/// BIT-identically across schedules × io-depth {0, 2} × workers {1, 2}:
/// same losses, gradient norms, and Σx² parameter/moment digests. Backends
/// only change where bytes live. The striped backend must additionally
/// account the exact same SSD byte totals (its per-device shares sum to
/// the object sizes); the cached backend must strictly REDUCE `ssd_read` —
/// with a 64 MiB cache the tiny model's working set fits, so per the fit-
/// or-nothing closed form (`traffic::Workload::cached_store_read_bytes`)
/// the residual SSD traffic is exactly zero; the planned backend's whole-
/// object trait counters must equal the baseline's exactly (a transfer
/// plan only changes which path carries each extent, never the bytes).
#[test]
fn store_backends_bit_identical_to_seed() {
    let kinds = [
        ScheduleKind::Vertical,
        ScheduleKind::ChunkedVertical(2),
        ScheduleKind::CacheSweep(2),
        ScheduleKind::Horizontal,
    ];
    for kind in kinds {
        for depth in [0usize, 2] {
            for w in [1usize, 2] {
                let mk = |backend: &str| {
                    let tag =
                        format!("st_{backend}_w{w}_d{depth}_{kind}").replace(':', "_");
                    let mut c = cfg(&tag);
                    c.io_depth = depth;
                    c.workers = w;
                    c.opt_on_ssd = true;
                    c.ckpt_on_ssd = true;
                    apply_store_backend(&mut c, backend);
                    c
                };
                let Some(base) = run("st_base", kind, mk("ssd"), 3, 4) else { return };
                assert!(base.ssd_read > 0, "{kind:?}: offloaded run must touch the SSD");
                for backend in test_store_set() {
                    if backend == "ssd" {
                        continue; // the baseline itself
                    }
                    let log = run("st_b", kind, mk(&backend), 3, 4).unwrap();
                    assert_eq!(
                        base.losses, log.losses,
                        "{kind:?} d{depth} W={w} {backend}: losses diverged"
                    );
                    assert_eq!(
                        base.grad_norms, log.grad_norms,
                        "{kind:?} d{depth} W={w} {backend}: grad norms diverged"
                    );
                    assert_eq!(
                        base.param_sq_norm.to_bits(),
                        log.param_sq_norm.to_bits(),
                        "{kind:?} d{depth} W={w} {backend}: parameters diverged"
                    );
                    assert_eq!(
                        base.moment_sq_norm.to_bits(),
                        log.moment_sq_norm.to_bits(),
                        "{kind:?} d{depth} W={w} {backend}: moments diverged"
                    );
                    match backend.as_str() {
                        "striped" => {
                            assert_eq!(
                                base.ssd_read, log.ssd_read,
                                "{kind:?} d{depth} W={w}: striped read totals diverged"
                            );
                            assert_eq!(
                                base.ssd_written, log.ssd_written,
                                "{kind:?} d{depth} W={w}: striped write totals diverged"
                            );
                        }
                        "cached" => {
                            assert!(
                                log.ssd_read < base.ssd_read,
                                "{kind:?} d{depth} W={w}: cache must reduce SSD reads"
                            );
                            assert_eq!(
                                log.ssd_read, 0,
                                "{kind:?} d{depth} W={w}: a fitting cache's residual \
                                 SSD reads are exactly 0 (the closed form)"
                            );
                            assert!(
                                log.cache_hits > 0,
                                "{kind:?} d{depth} W={w}: the cache never hit"
                            );
                        }
                        "planned" => {
                            assert_eq!(
                                base.ssd_read, log.ssd_read,
                                "{kind:?} d{depth} W={w}: planned read totals diverged"
                            );
                            assert_eq!(
                                base.ssd_written, log.ssd_written,
                                "{kind:?} d{depth} W={w}: planned write totals diverged"
                            );
                        }
                        _ => {}
                    }
                }
            }
        }
    }
}

/// The NVMe device-model legs the bit-identity suite compares against the
/// flat-throttle baseline. CI's nvme matrix narrows it via `GS_TEST_NVME`
/// (comma-separated ∈ {flat, profiled, batched}); "flat" is the baseline
/// itself and compares trivially.
fn test_nvme_set() -> Vec<String> {
    std::env::var("GS_TEST_NVME")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect::<Vec<String>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec!["profiled".to_string(), "batched".to_string()])
}

fn apply_nvme_leg(c: &mut TrainerConfig, leg: &str) {
    use greedysnake::memory::{BatchConfig, DeviceProfile};
    // every curve effect on, rates left at the config's (unthrottled)
    // peaks so the suite stays fast: the curve shapes TIMING only, which
    // is exactly what bit-identity must be invariant to
    let curvy = DeviceProfile {
        read_bps: f64::INFINITY,
        write_bps: f64::INFINITY,
        qd_knee: 4,
        sat_bytes: 1 << 20,
        mix_penalty: 0.1,
        op_latency_s: 20e-6,
    };
    match leg {
        "flat" => {}
        "profiled" => c.nvme = Some(curvy),
        "batched" => {
            c.nvme = Some(curvy);
            c.io_batch = Some(BatchConfig { max_bytes: 1 << 20, max_ops: 8 });
        }
        other => panic!("unknown GS_TEST_NVME leg '{other}' (flat|profiled|batched)"),
    }
}

/// The device-model determinism contract (tentpole): a profiled NVMe curve
/// (QD ramp + size ramp + mix penalty + latency floor) and the `--io-batch`
/// submission window change ONLY timing — losses, gradient norms, Σx²
/// parameter/moment digests, and the SSD byte counters are bit-identical
/// to the flat-throttle seed at every schedule × io-depth, including the
/// striped multi-device store.
#[test]
fn nvme_device_model_bit_identical_to_seed() {
    let kinds = [ScheduleKind::Vertical, ScheduleKind::ChunkedVertical(2)];
    for kind in kinds {
        for depth in [0usize, 2] {
            for ssds in [1usize, 2] {
                let mk = |leg: &str| {
                    let tag = format!("nv_{leg}_d{depth}_s{ssds}_{kind}").replace(':', "_");
                    let mut c = cfg(&tag);
                    c.io_depth = depth;
                    c.ssds = ssds;
                    c.opt_on_ssd = true;
                    c.ckpt_on_ssd = true;
                    apply_nvme_leg(&mut c, leg);
                    c
                };
                let Some(base) = run("nv_base", kind, mk("flat"), 3, 4) else { return };
                assert!(base.ssd_read > 0, "{kind:?}: offloaded run must touch the SSD");
                for leg in test_nvme_set() {
                    if leg == "flat" {
                        continue; // the baseline itself
                    }
                    let log = run("nv_leg", kind, mk(&leg), 3, 4).unwrap();
                    assert_eq!(
                        base.losses, log.losses,
                        "{kind:?} d{depth} ssds={ssds} {leg}: losses diverged"
                    );
                    assert_eq!(
                        base.grad_norms, log.grad_norms,
                        "{kind:?} d{depth} ssds={ssds} {leg}: grad norms diverged"
                    );
                    assert_eq!(
                        base.param_sq_norm.to_bits(),
                        log.param_sq_norm.to_bits(),
                        "{kind:?} d{depth} ssds={ssds} {leg}: parameters diverged"
                    );
                    assert_eq!(
                        base.moment_sq_norm.to_bits(),
                        log.moment_sq_norm.to_bits(),
                        "{kind:?} d{depth} ssds={ssds} {leg}: moments diverged"
                    );
                    // the byte laws: a curve reprices transfers, it never
                    // changes what moves
                    assert_eq!(
                        base.ssd_read, log.ssd_read,
                        "{kind:?} d{depth} ssds={ssds} {leg}: read bytes diverged"
                    );
                    assert_eq!(
                        base.ssd_written, log.ssd_written,
                        "{kind:?} d{depth} ssds={ssds} {leg}: written bytes diverged"
                    );
                }
            }
        }
    }
}

/// The striping acceptance property (runtime half): under a throttled SSD
/// with both moments and checkpoints offloaded, striping over 2 devices
/// strictly reduces wall-clock — each device carries half the bytes on its
/// OWN full-rate throttle, in parallel — while training identically.
#[test]
fn throttled_striped_store_reduces_wall_clock() {
    let mk = |tag: &str, ssds: usize| {
        let mut c = cfg(tag);
        c.opt_on_ssd = true;
        c.ckpt_on_ssd = true;
        c.io_depth = 0; // serial I/O: the striping win is isolated
        c.ssd_read_bps = 4e6;
        c.ssd_write_bps = 4e6;
        c.ssds = ssds;
        c
    };
    let Some(single) = run("strt1", ScheduleKind::Vertical, mk("strt1", 1), 2, 2) else {
        return;
    };
    let striped = run("strt2", ScheduleKind::Vertical, mk("strt2", 2), 2, 2).unwrap();
    assert_eq!(single.losses, striped.losses, "striping must not change numerics");
    assert_eq!(single.ssd_read, striped.ssd_read, "same bytes, different paths");
    let t1: f64 = single.step_seconds.iter().sum();
    let t2: f64 = striped.step_seconds.iter().sum();
    assert!(
        t2 < t1,
        "striped-2 wall clock {t2:.3}s must strictly undercut single-device {t1:.3}s"
    );
}

/// The cache acceptance property (runtime half): a DRAM cache that fits
/// the working set absorbs ALL store traffic — the measured counters drop
/// to exactly the closed form's residual (zero) — while training stays
/// bit-identical and the per-category counters attribute the hits.
#[test]
fn cached_store_absorbs_all_ssd_traffic() {
    let mk = |tag: &str, cache_mb: usize| {
        let mut c = cfg(tag);
        c.opt_on_ssd = true;
        c.ckpt_on_ssd = true;
        c.cpu_cache_mb = cache_mb;
        c
    };
    let Some(base) = run("cch0", ScheduleKind::Vertical, mk("cch0", 0), 4, 3) else {
        return;
    };
    let cached = run("cch1", ScheduleKind::Vertical, mk("cch1", 256), 4, 3).unwrap();
    assert_eq!(base.losses, cached.losses, "caching must not change numerics");
    assert_eq!(
        base.param_sq_norm.to_bits(),
        cached.param_sq_norm.to_bits()
    );
    assert_eq!(
        base.moment_sq_norm.to_bits(),
        cached.moment_sq_norm.to_bits()
    );
    assert!(base.ssd_read > 0 && base.ssd_written > 0);
    // fit-or-nothing closed form: residual reads AND writes are exactly 0
    assert_eq!(cached.ssd_read, 0, "every get must be a DRAM hit");
    assert_eq!(cached.ssd_written, 0, "write-back never triggered (no eviction)");
    assert!(cached.cache_hits > 0);
    assert_eq!(cached.cache_evictions, 0);
    assert!(
        cached.cache_by_cat.iter().any(|(cat, c)| cat == "OptimizerStates" && c[0] > 0),
        "per-category counters must attribute moment hits: {:?}",
        cached.cache_by_cat
    );
}

/// The precision legs the equivalence suite runs against the strict-f32
/// baseline. CI's precision matrix narrows it via `GS_TEST_PRECISION`
/// (comma-separated ∈ {f32, f16, bf16}) so each job pins one codec; "f32"
/// re-asserts that the explicit strict policy is bit-identical to the
/// default (no codec layer at all).
fn test_precision_set() -> Vec<String> {
    std::env::var("GS_TEST_PRECISION")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect::<Vec<String>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec!["f16".to_string(), "bf16".to_string()])
}

fn apply_precision(c: &mut TrainerConfig, prec: &str) {
    c.precision = match prec {
        "f32" => Precision::F32,
        "f16" => Precision::MixedF16,
        "bf16" => Precision::MixedBf16,
        other => panic!("unknown GS_TEST_PRECISION leg '{other}' (f32|f16|bf16)"),
    };
}

/// The mixed-precision acceptance property (tentpole): with moments AND
/// checkpoints offloaded, every precision leg trains within tolerance of
/// the strict-f32 baseline across schedules × io-depth {0, 2} × workers
/// {1, 2}, and the half-precision checkpoint stream strictly REDUCES the
/// measured SSD byte counters (moments stay f32 under the mixed policies,
/// so the reduction is checkpoint-width only). The explicit `f32` leg is
/// BIT-identical to the default config — the codec layer at strict f32 is
/// the identity by construction.
#[test]
fn mixed_precision_tolerance_equivalence_to_f32() {
    let kinds = [
        ScheduleKind::Vertical,
        ScheduleKind::ChunkedVertical(2),
        ScheduleKind::Horizontal,
    ];
    for kind in kinds {
        for depth in [0usize, 2] {
            for w in [1usize, 2] {
                let mk = |prec: &str| {
                    let tag = format!("pr_{prec}_w{w}_d{depth}_{kind}").replace(':', "_");
                    let mut c = cfg(&tag);
                    c.io_depth = depth;
                    c.workers = w;
                    c.opt_on_ssd = true;
                    c.ckpt_on_ssd = true;
                    apply_precision(&mut c, prec);
                    c
                };
                let mut base_cfg = mk("f32");
                base_cfg.precision = Precision::F32; // the default — no codec
                let Some(base) = run("pr_base", kind, base_cfg, 3, 4) else { return };
                assert!(base.ssd_read > 0, "{kind:?}: offloaded run must touch the SSD");
                for prec in test_precision_set() {
                    let log = run("pr_leg", kind, mk(&prec), 3, 4).unwrap();
                    if prec == "f32" {
                        // strict f32 is bit-identical to the bare stack
                        assert_eq!(
                            base.losses, log.losses,
                            "{kind:?} d{depth} W={w}: strict f32 losses diverged"
                        );
                        assert_eq!(
                            base.param_sq_norm.to_bits(),
                            log.param_sq_norm.to_bits(),
                            "{kind:?} d{depth} W={w}: strict f32 parameters diverged"
                        );
                        assert_eq!(
                            base.moment_sq_norm.to_bits(),
                            log.moment_sq_norm.to_bits(),
                            "{kind:?} d{depth} W={w}: strict f32 moments diverged"
                        );
                        assert_eq!(base.ssd_read, log.ssd_read);
                        assert_eq!(base.ssd_written, log.ssd_written);
                        continue;
                    }
                    // mixed legs: tolerance-pinned trajectory …
                    for (i, (a, b)) in base.losses.iter().zip(&log.losses).enumerate() {
                        assert!(
                            (a - b).abs() < 0.1,
                            "{kind:?} d{depth} W={w} {prec} step {i}: {a} vs {b}"
                        );
                    }
                    // … and strictly fewer stored bytes (2 B checkpoints).
                    assert!(
                        log.ssd_read < base.ssd_read,
                        "{kind:?} d{depth} W={w} {prec}: half-precision checkpoints \
                         must shrink SSD reads ({} vs {})",
                        log.ssd_read,
                        base.ssd_read
                    );
                    assert!(
                        log.ssd_written < base.ssd_written,
                        "{kind:?} d{depth} W={w} {prec}: half-precision checkpoints \
                         must shrink SSD writes ({} vs {})",
                        log.ssd_written,
                        base.ssd_written
                    );
                    // mixed runs are themselves deterministic (spot-check on
                    // the cheapest cell to bound suite cost)
                    if kind == ScheduleKind::Vertical && depth == 0 && w == 1 {
                        let again = run("pr_det", kind, mk(&prec), 3, 4).unwrap();
                        assert_eq!(log.losses, again.losses, "{prec}: nondeterministic");
                        assert_eq!(
                            log.param_sq_norm.to_bits(),
                            again.param_sq_norm.to_bits(),
                            "{prec}: nondeterministic parameters"
                        );
                    }
                }
            }
        }
    }
}

/// The fault phases the kill-a-worker suite injects. CI's fault matrix
/// narrows it via `GS_TEST_FAULT` (comma-separated ∈ {forward, reduce,
/// delayed}) so each job pins one crash phase.
fn test_fault_set() -> Vec<String> {
    std::env::var("GS_TEST_FAULT")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect::<Vec<String>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| {
            vec!["forward".to_string(), "reduce".to_string(), "delayed".to_string()]
        })
}

/// The (site, nth-hit) a fault phase arms. Each phase lands the crash in a
/// different part of the step — the forward's parameter load, the moment
/// right after the reduce-scatter (gradients combined, no state advanced),
/// and the delayed optimizer dispatch (`nth = 2` is the start of step 2,
/// since the dispatch site is hit once per step).
fn fault_arm_for(phase: &str) -> (&'static str, u64) {
    match phase {
        "forward" => ("engine:forward", 3),
        "reduce" => ("dist:post-reduce", 1),
        "delayed" => ("opt:delayed", 2),
        other => panic!("unknown GS_TEST_FAULT phase '{other}' (forward|reduce|delayed)"),
    }
}

/// The crash-consistency acceptance property (tentpole): for every
/// schedule × io-depth {0, 2} × W {2, 4}, a journaled `--param-persist`
/// run that loses a worker mid-step — at the forward prefetch, after the
/// reduce-scatter, or inside the delayed optimizer dispatch — replays from
/// the last committed epoch boundary and ends BIT-identical to the
/// uninterrupted run: same loss curve, gradient norms, and Σx²
/// parameter/moment digests. The uninterrupted journaled run is itself
/// bit-identical to the plain W = 1 baseline (persistence sharding and the
/// journal change where bytes live and when they commit, never a value),
/// and its per-rank parameter-shard counters carry ~1/W of a W-invariant
/// byte total each — the elastic-sharding scaling the closed forms predict.
#[test]
fn kill_a_worker_replays_bit_identical() {
    use greedysnake::util::fault;
    let kinds = [
        ScheduleKind::Vertical,
        ScheduleKind::ChunkedVertical(2),
        ScheduleKind::Horizontal,
    ];
    for kind in kinds {
        for depth in [0usize, 2] {
            let mk = |w: usize, leg: &str| {
                let tag = format!("kw_{leg}_w{w}_d{depth}_{kind}").replace(':', "_");
                let mut c = cfg(&tag);
                c.io_depth = depth;
                c.workers = w;
                c.shard_optimizer = w > 1;
                c.opt_on_ssd = true;
                c.param_persist = true;
                c.journal = true;
                c
            };
            // plain (no persistence, no journal) W = 1 reference digests
            let mut base_cfg = mk(1, "base");
            base_cfg.param_persist = false;
            base_cfg.journal = false;
            let Some(base) = run("kw_base", kind, base_cfg, 4, 4) else { return };
            let mut shard_read_totals = Vec::new();
            for w in [2usize, 4] {
                let clean = run("kw_clean", kind, mk(w, "clean"), 4, 4).unwrap();
                assert_eq!(clean.recoveries, 0, "{kind:?} d{depth} W={w}: clean run recovered");
                assert_eq!(
                    base.losses, clean.losses,
                    "{kind:?} d{depth} W={w}: journaled losses diverged from baseline"
                );
                assert_eq!(
                    base.param_sq_norm.to_bits(),
                    clean.param_sq_norm.to_bits(),
                    "{kind:?} d{depth} W={w}: journaled parameters diverged from baseline"
                );
                assert_eq!(
                    base.moment_sq_norm.to_bits(),
                    clean.moment_sq_norm.to_bits(),
                    "{kind:?} d{depth} W={w}: journaled moments diverged from baseline"
                );
                // ~1/W per-rank parameter round trips: one counter per rank,
                // each within 25 % of the fair share (contiguous partitioning
                // is element-exact; the slack only covers per-tensor rounding)
                let rd = &clean.param_shard_reads;
                assert_eq!(rd.len(), w, "{kind:?} d{depth}: one read counter per rank");
                let total: u64 = rd.iter().sum();
                assert!(total > 0, "{kind:?} d{depth} W={w}: no param shard traffic");
                let fair = total / w as u64;
                let slack = fair / 4;
                for (r, &b) in rd.iter().enumerate() {
                    assert!(
                        b <= fair + slack && b + slack >= fair,
                        "{kind:?} d{depth} W={w} rank {r}: {b} bytes vs fair share {fair}"
                    );
                }
                shard_read_totals.push(total);
                for phase in test_fault_set() {
                    // the delayed-dispatch site only runs under schedules
                    // that support the α split (horizontal is a baseline
                    // without it — the site would never be hit)
                    if phase == "delayed" && !kind.policy().supports_delay() {
                        continue;
                    }
                    let c = mk(w, &phase);
                    let (site, nth) = fault_arm_for(&phase);
                    fault::arm(&fault::scoped(site, &c.fault_scope), nth);
                    let faulted = run("kw_fault", kind, c, 4, 4).unwrap();
                    assert!(
                        faulted.recoveries >= 1,
                        "{kind:?} d{depth} W={w} {phase}: the injected fault never fired"
                    );
                    assert_eq!(
                        clean.losses, faulted.losses,
                        "{kind:?} d{depth} W={w} {phase}: replayed loss curve changed"
                    );
                    assert_eq!(
                        clean.grad_norms, faulted.grad_norms,
                        "{kind:?} d{depth} W={w} {phase}: replayed grad norms changed"
                    );
                    assert_eq!(
                        clean.param_sq_norm.to_bits(),
                        faulted.param_sq_norm.to_bits(),
                        "{kind:?} d{depth} W={w} {phase}: recovered parameters diverged"
                    );
                    assert_eq!(
                        clean.moment_sq_norm.to_bits(),
                        faulted.moment_sq_norm.to_bits(),
                        "{kind:?} d{depth} W={w} {phase}: recovered moments diverged"
                    );
                }
            }
            // the per-step parameter byte total is W-invariant (the ranks
            // tile it), so mean-per-rank scales exactly as total / W
            assert_eq!(
                shard_read_totals[0], shard_read_totals[1],
                "{kind:?} d{depth}: shard read totals must not depend on W"
            );
        }
    }
}

/// The serve matrix legs: `(tenants, cache MiB)` pairs the serving
/// equivalence suite runs. CI's serve matrix narrows it via
/// `GS_TEST_SERVE` (comma-separated `T:cacheMB` pairs, e.g. "4:64") so
/// each job pins one leg; the default covers tenants {1, 4} × cache
/// {0, 64 MiB}.
fn test_serve_set() -> Vec<(u64, u64)> {
    std::env::var("GS_TEST_SERVE")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|x| {
                    let (t, c) = x.trim().split_once(':')?;
                    Some((t.trim().parse().ok()?, c.trim().parse().ok()?))
                })
                .collect::<Vec<(u64, u64)>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![(1, 0), (1, 64), (4, 0), (4, 64)])
}

/// The serving acceptance property (tentpole): across every matrix leg —
/// tenant count × DRAM-cache config — and io-depth {0, 2}, the engine
/// serves BYTE-IDENTICAL token streams (storage topology may change where
/// bytes live, never what is generated), the per-token parameter-stream
/// bytes obey the closed-form law on the uncached legs, a fitting cache
/// absorbs SSD reads without changing tokens, and T tenants share one base
/// image (per-tenant footprint ≈ adapter bytes only).
#[test]
fn serve_matrix_token_streams_and_byte_laws() {
    use greedysnake::coordinator::serve::{provision, synthetic_requests, ServeModel};
    use greedysnake::coordinator::ServeEngine;
    use greedysnake::memory::{CacheAdmission, CachedStore, SsdStorage, TensorStore};
    use std::collections::HashMap;
    use std::sync::Arc;

    let model = ServeModel::synthetic(3, 256, 64, 50021);
    let (n_requests, max_batch, new_tokens) = (8usize, 3usize, 2usize);
    let tmp = |tag: &str| {
        std::env::temp_dir().join(format!("gs_it_serve_{tag}_{}", std::process::id()))
    };
    // token-stream baseline per tenant count (plain store, synchronous I/O)
    let mut baselines: HashMap<u64, Vec<(u64, Vec<u32>)>> = HashMap::new();
    for (tenants, cache_mb) in test_serve_set() {
        for depth in [0usize, 2] {
            let tag = format!("t{tenants}_c{cache_mb}_d{depth}");
            let dev: Arc<dyn TensorStore> =
                Arc::new(SsdStorage::create_unthrottled(tmp(&tag)).unwrap());
            let store: Arc<dyn TensorStore> = if cache_mb > 0 {
                Arc::new(CachedStore::with_admission(
                    dev,
                    cache_mb << 20,
                    CacheAdmission::PerTenant {
                        per_tenant_bytes: (cache_mb << 20) / tenants,
                    },
                ))
            } else {
                dev
            };
            let rep = provision(store.as_ref(), &model, tenants, 5).unwrap();
            if cache_mb == 0 {
                // T tenants share ONE base image on the SSD: the footprint
                // grows only by each tenant's adapter set
                assert_eq!(
                    store.footprint(),
                    rep.base_bytes + tenants * rep.adapter_bytes_per_tenant,
                    "{tag}: footprint is not base + T x adapters"
                );
            }
            let requests = synthetic_requests(tenants, n_requests, 5);
            let mut eng = ServeEngine::new(model.clone(), Arc::clone(&store), depth, 9);
            let sched = ScheduleKind::Vertical.policy();
            let out = eng
                .serve(sched.as_ref(), &requests, max_batch, new_tokens, None)
                .unwrap();
            let s = eng.stats();
            // storage topology must never change what is generated
            let baseline = baselines.entry(tenants).or_insert_with(|| out.clone());
            assert_eq!(
                &out, baseline,
                "{tag}: token streams depend on the storage/io-depth config"
            );
            // byte law: metered bytes are exact on every leg; the store
            // moved exactly those bytes when uncached, at most them when
            // the DRAM cache absorbs re-reads
            let metered =
                s.base_bytes_loaded + s.adapter_bytes_loaded + s.embed_bytes_loaded;
            assert_eq!(
                s.base_bytes_loaded,
                s.param_loads * model.base_layer_bytes(),
                "{tag}: base bytes"
            );
            if cache_mb == 0 {
                assert_eq!(s.store_bytes_read, metered, "{tag}: uncached bytes");
            } else {
                assert!(s.store_bytes_read <= metered, "{tag}: cache added reads");
                let c = s.cache.total;
                assert!(c.hits > 0, "{tag}: a fitting cache must hit: {c:?}");
            }
        }
    }
}
