//! Mixed-precision Adam(W) with gradient accumulation, the delay-α split,
//! and global-norm clipping with speculative steps.
//!
//! Two interchangeable execution paths update the optimizer state:
//! * [`adam_step_rust`] — the in-process fused loop (the `cpu_adam` AVX
//!   analog; the compiler autovectorizes the single pass);
//! * the AOT `adam_step` Pallas kernel invoked through
//!   [`crate::runtime::Runtime`] (chunked by `adam_chunk`).
//!
//! Both are bit-tested against each other; like GreedySnake (§6.5) the
//! update is *partition-invariant*: chunking never changes results because
//! every lane computes the identical fused expression.

use anyhow::Result;

/// Adam hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct AdamParams {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

impl AdamParams {
    /// The 8-wide hyper vector consumed by the AOT kernel
    /// `[lr, b1, b2, eps, wd, bias_corr1, bias_corr2, grad_scale]`.
    pub fn hyper_vec(&self, step: u64, grad_scale: f32) -> [f32; 8] {
        [
            self.lr,
            self.beta1,
            self.beta2,
            self.eps,
            self.weight_decay,
            1.0 - self.beta1.powi(step as i32),
            1.0 - self.beta2.powi(step as i32),
            grad_scale,
        ]
    }
}

/// One parameter group's optimizer state (master params are the working
/// fp32 params themselves on this substrate; `m`/`v` are the moments).
#[derive(Clone, Debug, Default)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl AdamState {
    pub fn zeros(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.m.len()
    }
}

/// Fused in-place Adam(W) over a sub-range `[lo, hi)` — the range form is
/// what implements the delay-α split: the backward-phase step covers
/// `[0, split)` and the delayed share `[split, n)` runs during the next
/// iteration's forward (§4.4).
#[allow(clippy::too_many_arguments)]
pub fn adam_step_rust(
    p: &mut [f32],
    state: &mut AdamState,
    g: &[f32],
    hp: &AdamParams,
    step: u64,
    grad_scale: f32,
    lo: usize,
    hi: usize,
) {
    assert_eq!(p.len(), g.len());
    assert_eq!(p.len(), state.m.len());
    assert!(lo <= hi && hi <= p.len());
    let bc1 = 1.0 - hp.beta1.powi(step as i32);
    let bc2 = 1.0 - hp.beta2.powi(step as i32);
    let (b1, b2) = (hp.beta1, hp.beta2);
    // Single fused pass — load p/m/v/g once, store p/m/v once (the whole
    // point of cpu_adam; the paper's §6.5 notes full-SIMD execution keeps
    // results partition-invariant, which this expression is by construction).
    for i in lo..hi {
        let gi = g[i] * grad_scale;
        let m = b1 * state.m[i] + (1.0 - b1) * gi;
        let v = b2 * state.v[i] + (1.0 - b2) * gi * gi;
        let m_hat = m / bc1;
        let v_hat = v / bc2;
        p[i] -= hp.lr * (m_hat / (v_hat.sqrt() + hp.eps) + hp.weight_decay * p[i]);
        state.m[i] = m;
        state.v[i] = v;
    }
}

/// The delay-α split point for a parameter vector of length `n`: the first
/// `split` elements update in the backward phase, the tail α-fraction
/// `[split, n)` is delayed to the next forward.
///
/// The delayed share rounds UP (`split = n − ⌈n·α⌉`), so whenever
/// `α > 0 && n > 0` at least one element is delayed. The old
/// `(n·(1−α)).round()` quantized the tail to zero for small `n` (e.g.
/// `delay_split(1, 0.25) == 1` delayed nothing), silently disabling the
/// optimizer/forward overlap on small shards — exactly the regime the
/// sharded optimizer (`--shard-optimizer`) creates by splitting every
/// tensor into W per-rank pieces.
pub fn delay_split(n: usize, alpha: f64) -> usize {
    if alpha <= 0.0 || n == 0 {
        return n;
    }
    let delayed = ((n as f64) * alpha).ceil().min(n as f64) as usize;
    n - delayed
}

/// Gradient-clipping bookkeeping with speculative optimizer steps.
///
/// Computing the global L2 norm requires the *entire* backward pass, which
/// would serialize the optimizer behind it (§2.1). Like SuperOffload's
/// speculative step (cited by the paper), we apply the update with scale 1
/// as gradients arrive and *verify* afterwards: if the finished norm exceeds
/// the threshold, the event is recorded and the corrective scale is folded
/// into the next step's gradient scale (clipping rarely fires in practice).
#[derive(Clone, Debug)]
pub struct ClipMonitor {
    pub max_norm: f64,
    sq_sum: f64,
    /// Scale to fold into the next iteration (1.0 when no violation).
    pending_scale: f32,
    pub violations: u64,
}

impl ClipMonitor {
    pub fn new(max_norm: f64) -> Self {
        ClipMonitor { max_norm, sq_sum: 0.0, pending_scale: 1.0, violations: 0 }
    }

    /// Account one tensor's gradient as it is produced.
    pub fn accumulate(&mut self, sq_sum: f64) {
        self.sq_sum += sq_sum;
    }

    /// Scale to use for the CURRENT iteration's speculative steps.
    pub fn speculative_scale(&self) -> f32 {
        self.pending_scale
    }

    /// Snapshot `(pending_scale, violations)` at an iteration boundary —
    /// the part of the clip state that must survive a crash/recovery cycle
    /// (the in-flight `sq_sum` is always 0 at a committed boundary).
    pub fn snapshot(&self) -> (f32, u64) {
        (self.pending_scale, self.violations)
    }

    /// Restore a boundary snapshot taken by [`ClipMonitor::snapshot`].
    pub fn restore(&mut self, pending_scale: f32, violations: u64) {
        self.pending_scale = pending_scale;
        self.violations = violations;
        self.sq_sum = 0.0;
    }

    /// Finish the iteration: returns the global norm and updates the
    /// corrective scale for the next one.
    pub fn finish_iter(&mut self) -> f64 {
        let norm = self.sq_sum.sqrt();
        if norm > self.max_norm && norm > 0.0 {
            self.violations += 1;
            self.pending_scale = (self.max_norm / norm) as f32;
        } else {
            self.pending_scale = 1.0;
        }
        self.sq_sum = 0.0;
        norm
    }
}

/// Split a flat length into `chunk`-sized ranges (last may be short) — the
/// unit the AOT adam kernel consumes; short tails are zero-padded by the
/// caller, which is safe because the padded region is never copied back.
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<(usize, usize)> {
    assert!(chunk > 0);
    (0..n.div_ceil(chunk)).map(|i| (i * chunk, ((i + 1) * chunk).min(n))).collect()
}

/// Run one Adam step through the AOT Pallas kernel for `[lo, hi)` of a flat
/// vector, chunked and padded. Numerically identical to `adam_step_rust`.
#[allow(clippy::too_many_arguments)]
pub fn adam_step_hlo(
    rt: &crate::runtime::Runtime,
    chunk: usize,
    p: &mut [f32],
    state: &mut AdamState,
    g: &[f32],
    hp: &AdamParams,
    step: u64,
    grad_scale: f32,
    lo: usize,
    hi: usize,
) -> Result<()> {
    use crate::runtime::Stage;
    let hyper = hp.hyper_vec(step, grad_scale);
    let mut pad_p = vec![0.0f32; chunk];
    let mut pad_m = vec![0.0f32; chunk];
    let mut pad_v = vec![0.0f32; chunk];
    let mut pad_g = vec![0.0f32; chunk];
    let mut pos = lo;
    while pos < hi {
        let end = (pos + chunk).min(hi);
        let len = end - pos;
        pad_p[..len].copy_from_slice(&p[pos..end]);
        pad_m[..len].copy_from_slice(&state.m[pos..end]);
        pad_v[..len].copy_from_slice(&state.v[pos..end]);
        pad_g[..len].copy_from_slice(&g[pos..end]);
        if len < chunk {
            pad_p[len..].fill(0.0);
            pad_m[len..].fill(0.0);
            pad_v[len..].fill(0.0);
            pad_g[len..].fill(0.0);
        }
        let out = rt.execute(
            Stage::AdamStep,
            &[
                xla::Literal::vec1(&pad_p),
                xla::Literal::vec1(&pad_m),
                xla::Literal::vec1(&pad_v),
                xla::Literal::vec1(&pad_g),
                xla::Literal::vec1(&hyper[..]),
            ],
        )?;
        let new_p = out[0].to_vec::<f32>()?;
        let new_m = out[1].to_vec::<f32>()?;
        let new_v = out[2].to_vec::<f32>()?;
        p[pos..end].copy_from_slice(&new_p[..len]);
        state.m[pos..end].copy_from_slice(&new_m[..len]);
        state.v[pos..end].copy_from_slice(&new_v[..len]);
        pos = end;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn setup(n: usize, seed: u64) -> (Vec<f32>, AdamState, Vec<f32>) {
        let mut rng = Prng::new(seed);
        let mut p = vec![0.0f32; n];
        rng.fill_normal(&mut p, 1.0);
        let mut g = vec![0.0f32; n];
        rng.fill_normal(&mut g, 0.1);
        (p, AdamState::zeros(n), g)
    }

    #[test]
    fn decreases_loss_on_quadratic() {
        // minimize f(p) = ½p² with g = p: must converge toward 0.
        let mut p = vec![5.0f32];
        let mut st = AdamState::zeros(1);
        let hp = AdamParams { lr: 0.1, ..Default::default() };
        for step in 1..=500 {
            let g = vec![p[0]];
            adam_step_rust(&mut p, &mut st, &g, &hp, step, 1.0, 0, 1);
        }
        assert!(p[0].abs() < 0.1, "{}", p[0]);
    }

    #[test]
    fn partition_invariance() {
        let (p0, st0, g) = setup(1000, 1);
        let hp = AdamParams::default();
        let (mut p1, mut st1) = (p0.clone(), st0.clone());
        adam_step_rust(&mut p1, &mut st1, &g, &hp, 1, 1.0, 0, 1000);
        let (mut p2, mut st2) = (p0.clone(), st0.clone());
        adam_step_rust(&mut p2, &mut st2, &g, &hp, 1, 1.0, 500, 1000);
        adam_step_rust(&mut p2, &mut st2, &g, &hp, 1, 1.0, 0, 500);
        assert_eq!(p1, p2);
        assert_eq!(st1.m, st2.m);
    }

    #[test]
    fn delay_split_boundaries() {
        assert_eq!(delay_split(100, 0.0), 100);
        assert_eq!(delay_split(100, 1.0), 0);
        assert_eq!(delay_split(100, 0.25), 75);
        assert_eq!(delay_split(0, 0.5), 0);
    }

    /// Regression: α > 0 must always delay at least one element for n > 0 —
    /// `.round()` used to quantize the tail to zero on small shards (e.g.
    /// `delay_split(1, 0.25)` was 1, delaying nothing).
    #[test]
    fn delay_split_small_shards_always_delay() {
        assert_eq!(delay_split(1, 0.25), 0); // the single element is delayed
        assert_eq!(delay_split(2, 0.25), 1);
        assert_eq!(delay_split(3, 0.1), 2);
        for n in 1..64usize {
            for alpha in [0.01, 0.1, 0.25, 0.3, 0.5, 0.9, 1.0] {
                let split = delay_split(n, alpha);
                assert!(split < n, "n={n} α={alpha}: no delayed element");
                // and the eager share never exceeds the (1-α) fraction
                assert!(
                    split as f64 <= (n as f64) * (1.0 - alpha) + 1e-9,
                    "n={n} α={alpha}: eager share {split} too large"
                );
            }
            // α = 0 keeps everything eager
            assert_eq!(delay_split(n, 0.0), n);
        }
    }

    #[test]
    fn delayed_update_equals_eager_when_completed() {
        // Updating [0,split) then [split,n) with the same step must equal
        // one full update — the α-delay changes timing, not values.
        let (p0, st0, g) = setup(256, 2);
        let hp = AdamParams::default();
        let (mut p1, mut st1) = (p0.clone(), st0.clone());
        adam_step_rust(&mut p1, &mut st1, &g, &hp, 3, 1.0, 0, 256);
        let (mut p2, mut st2) = (p0.clone(), st0.clone());
        let split = delay_split(256, 0.3);
        adam_step_rust(&mut p2, &mut st2, &g, &hp, 3, 1.0, 0, split);
        adam_step_rust(&mut p2, &mut st2, &g, &hp, 3, 1.0, split, 256);
        assert_eq!(p1, p2);
    }

    #[test]
    fn weight_decay_applied() {
        let mut p = vec![2.0f32];
        let mut st = AdamState::zeros(1);
        let hp = AdamParams { lr: 0.01, weight_decay: 0.5, ..Default::default() };
        adam_step_rust(&mut p, &mut st, &[0.0], &hp, 1, 1.0, 0, 1);
        assert!((p[0] - (2.0 - 0.01 * 0.5 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn clip_monitor_speculative_flow() {
        let mut cm = ClipMonitor::new(1.0);
        assert_eq!(cm.speculative_scale(), 1.0);
        cm.accumulate(4.0); // norm 2 > 1
        let norm = cm.finish_iter();
        assert!((norm - 2.0).abs() < 1e-12);
        assert_eq!(cm.violations, 1);
        assert!((cm.speculative_scale() - 0.5).abs() < 1e-6);
        // next iteration within bounds resets the scale
        cm.accumulate(0.25);
        cm.finish_iter();
        assert_eq!(cm.speculative_scale(), 1.0);
    }

    #[test]
    fn chunk_ranges_cover() {
        assert_eq!(chunk_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(chunk_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(chunk_ranges(3, 8), vec![(0, 3)]);
    }

    #[test]
    fn grad_scale_equivalent_to_scaling_grads() {
        let (p0, st0, g) = setup(64, 5);
        let hp = AdamParams::default();
        let (mut p1, mut st1) = (p0.clone(), st0.clone());
        adam_step_rust(&mut p1, &mut st1, &g, &hp, 1, 0.5, 0, 64);
        let g2: Vec<f32> = g.iter().map(|x| x * 0.5).collect();
        let (mut p2, mut st2) = (p0.clone(), st0.clone());
        adam_step_rust(&mut p2, &mut st2, &g2, &hp, 1, 1.0, 0, 64);
        assert_eq!(p1, p2);
    }

    #[test]
    fn hlo_kernel_matches_rust_path() {
        let manifest = crate::runtime::Manifest::load("artifacts/tiny").unwrap();
        let rt = crate::runtime::Runtime::load(&manifest).unwrap();
        let n = manifest.config.adam_chunk + 123; // force padding of the tail
        let (p0, st0, g) = setup(n, 9);
        let hp = AdamParams { lr: 3e-4, weight_decay: 0.01, ..Default::default() };
        let (mut p1, mut st1) = (p0.clone(), st0.clone());
        adam_step_rust(&mut p1, &mut st1, &g, &hp, 7, 1.0, 0, n);
        let (mut p2, mut st2) = (p0.clone(), st0.clone());
        adam_step_hlo(&rt, manifest.config.adam_chunk, &mut p2, &mut st2, &g, &hp, 7, 1.0, 0, n)
            .unwrap();
        for i in 0..n {
            assert!(
                (p1[i] - p2[i]).abs() <= 1e-6 * (1.0 + p1[i].abs()),
                "i={i}: {} vs {}",
                p1[i],
                p2[i]
            );
        }
    }
}
