//! Model zoo (paper Table 2) and the size/FLOP arithmetic every analytic
//! component shares: per-layer parameter counts, activation-checkpoint
//! sizes, optimizer-state footprints, and forward/backward FLOPs.
//!
//! The §3.4 key insight lives here as arithmetic: per-layer parameter count
//! scales *quadratically* with the hidden dimension (≈ 12·D²) while the
//! per-micro-batch checkpoint scales *linearly* (B·T·D), so parameter reuse
//! dominates for large models.

/// Bytes per element.
pub const BYTES_LP: u64 = 2; // low-precision (bf16) parameters/activations
pub const BYTES_FP: u64 = 4; // full-precision master/grad/optimizer states

/// A GPT-style model configuration (paper Table 2 uses GPT-2/3 shapes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelCfg {
    pub name: &'static str,
    pub n_layers: u64,
    pub n_heads: u64,
    pub hidden: u64,
    pub vocab: u64,
    pub ffn_mult: u64,
}

impl ModelCfg {
    pub const fn new(
        name: &'static str,
        n_layers: u64,
        n_heads: u64,
        hidden: u64,
    ) -> Self {
        ModelCfg { name, n_layers, n_heads, hidden, vocab: 50_257, ffn_mult: 4 }
    }

    /// Parameters in one transformer layer:
    /// 2 LN (2·2D) + QKV (3D²+3D) + proj (D²+D) + FFN (2·4D² + 5D... exact below).
    pub fn params_per_layer(&self) -> u64 {
        let d = self.hidden;
        let f = self.ffn_mult * d;
        // ln1 (2d) + qkv (3d²+3d) + proj (d²+d) + ln2 (2d) + fc1 (d·f+f) + fc2 (f·d+d)
        4 * d + 3 * d * d + 3 * d + d * d + d + d * f + f + f * d + d
    }

    /// Embedding + head parameters (tied LM head, learned positions).
    pub fn params_embed(&self, seq_len: u64) -> u64 {
        self.vocab * self.hidden + seq_len * self.hidden + 2 * self.hidden
    }

    /// Total parameters at a given sequence length.
    pub fn params_total(&self, seq_len: u64) -> u64 {
        self.n_layers * self.params_per_layer() + self.params_embed(seq_len)
    }

    /// Low-precision bytes of one layer's parameters (what moves H2D).
    pub fn layer_param_bytes_lp(&self) -> u64 {
        self.params_per_layer() * BYTES_LP
    }

    /// Full-precision bytes of one layer's gradient buffer.
    pub fn layer_grad_bytes_fp(&self) -> u64 {
        self.params_per_layer() * BYTES_FP
    }

    /// Optimizer-state bytes per layer: master + momentum + variance, FP32.
    pub fn layer_opt_state_bytes(&self) -> u64 {
        3 * self.params_per_layer() * BYTES_FP
    }

    /// One micro-batch's inter-layer activation checkpoint, low precision:
    /// B · T · D elements (the paper's §3.4 example: 8·2048·8192 ≈ 1.34e8).
    pub fn ckpt_bytes_lp(&self, micro_batch: u64, seq_len: u64) -> u64 {
        micro_batch * seq_len * self.hidden * BYTES_LP
    }

    /// Elements in one checkpoint (for the §3.4 ratio).
    pub fn ckpt_elems(&self, micro_batch: u64, seq_len: u64) -> u64 {
        micro_batch * seq_len * self.hidden
    }

    /// Approximate forward FLOPs for one layer on one micro-batch
    /// (2·params·tokens for the matmuls + attention's 2·B·H·T²·dh ×2).
    pub fn layer_fwd_flops(&self, micro_batch: u64, seq_len: u64) -> f64 {
        let tokens = (micro_batch * seq_len) as f64;
        let matmul = 2.0 * self.params_per_layer() as f64 * tokens;
        let attn = 4.0 * micro_batch as f64 * seq_len as f64 * seq_len as f64
            * self.hidden as f64;
        matmul + attn
    }

    /// Backward ≈ 2× forward; with recomputation the backward *stage* costs
    /// forward + 2×forward = 3× (the paper's per-layer recompute).
    pub fn layer_bwd_flops_with_recompute(&self, micro_batch: u64, seq_len: u64) -> f64 {
        3.0 * self.layer_fwd_flops(micro_batch, seq_len)
    }

    /// Whole-iteration FLOPs for M micro-batches (fwd + recompute + bwd).
    pub fn iter_flops(&self, micro_batch: u64, seq_len: u64, m: u64) -> f64 {
        self.n_layers as f64
            * m as f64
            * (self.layer_fwd_flops(micro_batch, seq_len)
                + self.layer_bwd_flops_with_recompute(micro_batch, seq_len))
    }
}

/// Table 2 of the paper.
pub const GPT_30B: ModelCfg = ModelCfg::new("GPT-30B", 48, 56, 7_168);
pub const GPT_65B: ModelCfg = ModelCfg::new("GPT-65B", 80, 64, 8_192);
pub const GPT_175B: ModelCfg = ModelCfg::new("GPT-175B", 96, 96, 12_288);

pub const TABLE2: [ModelCfg; 3] = [GPT_30B, GPT_65B, GPT_175B];

/// The paper's evaluation sequence length.
pub const SEQ_LEN: u64 = 2_048;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_total_params_match_names() {
        // Within ~15% of the nominal size (names are rounded marketing sizes).
        for (cfg, nominal) in [(GPT_30B, 30e9), (GPT_65B, 65e9), (GPT_175B, 175e9)] {
            let total = cfg.params_total(SEQ_LEN) as f64;
            let rel = (total - nominal).abs() / nominal;
            assert!(rel < 0.15, "{}: {total:.3e} vs {nominal:.1e} ({rel:.2})", cfg.name);
        }
    }

    #[test]
    fn paper_65b_examples_hold() {
        // §3.4: per-layer params ≈ 8.05e8 for GPT-65B…
        let per_layer = GPT_65B.params_per_layer() as f64;
        assert!((per_layer - 8.05e8).abs() / 8.05e8 < 0.02, "{per_layer:.3e}");
        // …and a micro-batch-8 checkpoint is 8·2048·8192 ≈ 1.34e8 elements,
        // ≈ 6× smaller than the layer.
        let ckpt = GPT_65B.ckpt_elems(8, SEQ_LEN) as f64;
        assert!((ckpt - 1.342e8).abs() / 1.342e8 < 0.01, "{ckpt:.3e}");
        assert!((per_layer / ckpt - 6.0).abs() < 0.5);
    }

    #[test]
    fn param_scaling_is_quadratic_ckpt_linear() {
        let d1 = ModelCfg::new("x", 1, 8, 4096);
        let d2 = ModelCfg::new("y", 1, 8, 8192);
        let p_ratio = d2.params_per_layer() as f64 / d1.params_per_layer() as f64;
        let c_ratio =
            d2.ckpt_elems(4, 1024) as f64 / d1.ckpt_elems(4, 1024) as f64;
        assert!((p_ratio - 4.0).abs() < 0.05, "quadratic: {p_ratio}");
        assert!((c_ratio - 2.0).abs() < 1e-9, "linear: {c_ratio}");
    }

    #[test]
    fn optimizer_state_is_12_bytes_per_param() {
        assert_eq!(GPT_65B.layer_opt_state_bytes(), GPT_65B.params_per_layer() * 12);
    }

    #[test]
    fn flops_positive_and_scale_with_m() {
        let f1 = GPT_30B.iter_flops(8, SEQ_LEN, 1);
        let f4 = GPT_30B.iter_flops(8, SEQ_LEN, 4);
        assert!(f1 > 0.0);
        assert!((f4 / f1 - 4.0).abs() < 1e-9);
    }
}
