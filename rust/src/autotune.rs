//! `greedysnake autotune` — sim-driven configuration search over the FULL
//! CLI knob surface for a measured hardware profile.
//!
//! Algorithm 1 ([`crate::lp`]) optimizes the paper's three knobs — micro-
//! batch count, delay ratio α, and the storage placement ratios — under a
//! *flat* SSD bandwidth pair. The runtime grew many more knobs (schedule
//! family and chunk group G, `--io-depth`, `--ssds`, `--cpu-cache-mb`,
//! `--workers`, `--shard-optimizer`, `--param-persist`, `--precision`,
//! `--io-batch`), and a real NVMe is not flat: its delivered bandwidth
//! ramps with queue depth and request size, pays a mix penalty and a
//! per-op latency floor ([`DeviceProfile`]). This module closes that gap:
//!
//! 1. **Seed** from Algorithm 1: `lp::find_optimal_config` picks the
//!    micro-batch count, and `lp::solve_config` keeps every candidate's
//!    (α, placement) CPU-memory-feasible on the profiled machine.
//! 2. **Refine** by coordinate descent over the discrete knobs, one knob at
//!    a time, keeping a move only when it improves the objective; repeat
//!    until a full sweep finds nothing better (≤ [`SWEEPS`] rounds).
//! 3. **Objective**: [`crate::sim::simulate_dist_dev`] — the discrete-event
//!    simulator with the SSD tier priced by the profile's QD/size curves
//!    and the `--io-batch` window amortization, so the search *sees* that
//!    a deeper io-depth rides the QD ramp and that batching amortizes the
//!    latency floor. Hand-tuned flat-model configs systematically misprice
//!    both.
//!
//! The search starts FROM the hand-picked default configuration
//! ([`default_knobs`]) and only ever keeps improvements, so the tuned
//! result is never worse than the default under the same objective — the
//! fig19 acceptance bar. Output is a [`TunedConfig`]: the winning knobs,
//! ready-to-paste `greedysnake train` flags ([`TunedConfig::cli_flags`]),
//! and the predicted gap to the §3.1 roofline envelope.
//!
//! Hardware profiles come from JSON ([`HwProfile::parse`], format in the
//! [`crate::memory`] module docs) or from the built-in Table 1 machines
//! ([`HwProfile::builtin`]).

use anyhow::{ensure, Context, Result};

use crate::lp;
use crate::machine::{Machine, GIB};
use crate::memory::{BatchConfig, DeviceProfile, Precision};
use crate::modelcfg::{ModelCfg, SEQ_LEN};
use crate::perfmodel::{ByteMults, StorageRatios, SystemParams};
use crate::roofline::Roofline;
use crate::sim::{simulate_dist_dev, DistConfig, SimResult};
use crate::trainer::ScheduleKind;
use crate::util::json::Json;

/// Full coordinate-descent sweeps before giving up on further improvement.
const SWEEPS: usize = 3;

/// Micro-batch-count cap for the sim objective: the event sim's cost grows
/// with M and the throughput ranking of the *other* knobs is stable well
/// below Algorithm 1's stopping M, so the search evaluates at
/// `min(seed M, 12)` and reports that M.
const M_EVAL_CAP: u64 = 12;

/// A measured machine: Table 1 numbers plus per-device NVMe curves.
#[derive(Clone, Debug)]
pub struct HwProfile {
    /// Capacities, PCIe/link bandwidths, sustained compute. The flat SSD
    /// bandwidth pair is the first device's peaks (the sim re-prices it
    /// through the curve).
    pub machine: Machine,
    /// One [`DeviceProfile`] per physical NVMe; `--ssds N` stripes over the
    /// first N. Non-empty.
    pub devices: Vec<DeviceProfile>,
}

impl HwProfile {
    /// A built-in Table 1 machine wearing a generic datacenter-NVMe curve
    /// (QD knee 8, 256 KiB saturating request, 10 % mix penalty, 60 µs op
    /// latency) re-rated to its measured sequential peaks.
    pub fn builtin(machine: Machine) -> HwProfile {
        let dev = DeviceProfile {
            read_bps: machine.ssd_read_bw,
            write_bps: machine.ssd_write_bw,
            qd_knee: 8,
            sat_bytes: 256 << 10,
            mix_penalty: 0.1,
            op_latency_s: 60e-6,
        };
        HwProfile { machine, devices: vec![dev] }
    }

    /// Parse the hardware-profile JSON (format in the [`crate::memory`]
    /// module docs): `gpu_mem_gib`, `cpu_mem_gib`, `pcie_gbps`,
    /// `link_gbps`, `gpu_tflops`, `cpu_adam_gelems`, and a non-empty
    /// `devices` array of NVMe curve objects.
    pub fn parse(text: &str) -> Result<HwProfile> {
        let j = Json::parse(text).context("hardware profile JSON")?;
        let f = |key: &str| -> Result<f64> {
            j.get(key)?.as_f64().with_context(|| format!("hardware profile field '{key}'"))
        };
        let devices: Vec<DeviceProfile> = j
            .get("devices")?
            .as_arr()
            .context("'devices' must be an array")?
            .iter()
            .map(DeviceProfile::from_json)
            .collect::<Result<_>>()?;
        ensure!(!devices.is_empty(), "hardware profile needs at least one device");
        let machine = Machine {
            // `Machine::name` is &'static; every JSON-loaded machine is
            // reported under this constant label.
            name: "custom",
            gpu_mem: (f("gpu_mem_gib")? * GIB as f64) as u64,
            cpu_mem: (f("cpu_mem_gib")? * GIB as f64) as u64,
            pcie_bw: f("pcie_gbps")? * 1e9,
            link_bw: f("link_gbps")? * 1e9,
            ssd_read_bw: devices[0].read_bps,
            ssd_write_bw: devices[0].write_bps,
            gpu_flops: f("gpu_tflops")? * 1e12,
            cpu_adam_elems_per_s: f("cpu_adam_gelems")? * 1e9,
        };
        ensure!(machine.gpu_mem > 0 && machine.cpu_mem > 0, "memory capacities must be positive");
        ensure!(
            machine.pcie_bw > 0.0 && machine.link_bw > 0.0 && machine.gpu_flops > 0.0,
            "bandwidths and compute must be positive"
        );
        Ok(HwProfile { machine, devices })
    }

    /// The device curve `--ssds n` runs each stripe member at (devices are
    /// assumed symmetric; the first profile speaks for the stripe set).
    fn device(&self) -> &DeviceProfile {
        &self.devices[0]
    }
}

/// One point in the search space — the `greedysnake train` knob surface.
#[derive(Clone, Copy, Debug)]
pub struct Knobs {
    pub schedule: ScheduleKind,
    pub alpha: f64,
    /// Storage placement (CPU-DRAM fractions) — always an LP-feasible
    /// solution for (α, precision) on this machine, never a free variable.
    pub ratios: StorageRatios,
    /// Micro-batches per iteration.
    pub m: u64,
    pub io_depth: usize,
    pub ssds: usize,
    pub cache_mb: u64,
    pub workers: usize,
    pub shard_optimizer: bool,
    pub param_persist: bool,
    pub precision: Precision,
    /// `None` = unbatched submissions.
    pub io_batch: Option<BatchConfig>,
}

/// The search result: winning knobs plus the sim's prediction for them.
#[derive(Clone, Copy, Debug)]
pub struct TunedConfig {
    pub knobs: Knobs,
    /// Predicted steady-state seconds per iteration.
    pub t_iter: f64,
    /// Predicted training throughput, tokens/s.
    pub tokens_per_s: f64,
    /// §3.1 roofline envelope at the tuned M — the best any system could do.
    pub ideal_tokens_per_s: f64,
}

impl TunedConfig {
    /// Predicted fraction of the roofline envelope achieved ∈ (0, 1].
    pub fn roofline_frac(&self) -> f64 {
        (self.tokens_per_s / self.ideal_tokens_per_s).min(1.0)
    }

    /// Ready-to-paste `greedysnake train` flags for the tuned point.
    pub fn cli_flags(&self) -> String {
        let k = &self.knobs;
        let mut s = format!(
            "--schedule {} --alpha {:.2} --micro-batches {} --io-depth {} --ssds {} \
             --cpu-cache-mb {} --workers {} --precision {}",
            k.schedule, k.alpha, k.m, k.io_depth, k.ssds, k.cache_mb, k.workers, k.precision,
        );
        if let Some(b) = k.io_batch {
            s.push_str(&format!(" --io-batch {}:{}", b.max_bytes, b.max_ops));
        }
        if k.shard_optimizer {
            s.push_str(" --shard-optimizer");
        }
        if k.param_persist {
            s.push_str(" --param-persist");
        }
        s
    }
}

/// The operating point the objective runs at (the dist sim models the GPUs
/// explicitly, so the node is always `with_gpus(1)`).
fn sys(hw: &HwProfile, model: ModelCfg, micro_batch: u64) -> SystemParams {
    SystemParams::new(hw.machine.with_gpus(1), model, micro_batch, SEQ_LEN)
}

/// An LP-feasible placement for (α, precision) at `m` micro-batches —
/// `None` when the configuration cannot fit CPU memory.
fn feasible_ratios(
    sp: &SystemParams,
    m: u64,
    alpha: f64,
    precision: Precision,
) -> Option<StorageRatios> {
    let sp = sp.with_byte_mults(ByteMults::for_precision(precision));
    lp::solve_config(&sp, m, alpha).map(|r| r.ratios)
}

/// Evaluate one knob point with the device-curve simulator — the search
/// objective, public so the fig19 bench and the tests can score the
/// hand-picked default with the *same* ruler as the tuned result.
pub fn eval_knobs(hw: &HwProfile, model: ModelCfg, micro_batch: u64, k: &Knobs) -> SimResult {
    let sp = sys(hw, model, micro_batch);
    let alpha = if k.schedule.supports_delay() { k.alpha } else { 0.0 };
    let sched = k.schedule.sim_schedule(alpha, k.ratios);
    let cfg = DistConfig {
        workers: k.workers.max(1),
        ssds: k.ssds.max(1),
        io_depth: k.io_depth,
        shard_optimizer: k.shard_optimizer,
        param_persist: k.param_persist,
        cache_bytes: k.cache_mb << 20,
        byte_mults: ByteMults::for_precision(k.precision),
    };
    // Steady request size: one layer's low-precision parameter object,
    // split across the stripe set — the dominant transfer the lanes issue.
    let req = (model.layer_param_bytes_lp() / k.ssds.max(1) as u64).max(4096);
    let batch_ops = match k.io_batch {
        Some(b) => b.max_ops.min(b.max_bytes / req).max(1),
        None => 1,
    };
    simulate_dist_dev(&sp, k.m, sched, cfg, hw.device(), req, req, batch_ops)
}

/// The hand-picked default configuration — what a careful operator writes
/// down from the paper without a device model: vertical schedule, α = 0.25
/// (LP placement at that α), `--io-depth 2`, one SSD, no cache, one
/// worker, strict f32, unbatched. Also the point the search starts from.
pub fn default_knobs(hw: &HwProfile, model: ModelCfg, micro_batch: u64) -> Knobs {
    let sp = sys(hw, model, micro_batch);
    let seed = lp::find_optimal_config(&sp);
    let m = seed.map(|s| s.m).unwrap_or(8).clamp(1, M_EVAL_CAP);
    let alpha = 0.25;
    let ratios =
        feasible_ratios(&sp, m, alpha, Precision::F32).unwrap_or(StorageRatios::ALL_SSD);
    Knobs {
        schedule: ScheduleKind::Vertical,
        alpha,
        ratios,
        m,
        io_depth: 2,
        ssds: 1,
        cache_mb: 0,
        workers: 1,
        shard_optimizer: false,
        param_persist: false,
        precision: Precision::F32,
        io_batch: None,
    }
}

/// Run the search. Returns the tuned configuration; never worse than
/// [`default_knobs`] under [`eval_knobs`] (the search starts there and
/// keeps only improvements).
pub fn autotune(hw: &HwProfile, model: ModelCfg, micro_batch: u64) -> Result<TunedConfig> {
    ensure!(!hw.devices.is_empty(), "hardware profile needs at least one device");
    let sp = sys(hw, model, micro_batch);
    let mut best = default_knobs(hw, model, micro_batch);
    let mut best_r = eval_knobs(hw, model, micro_batch, &best);

    // One knob move: keep it iff it strictly improves the objective.
    let consider = |cand: Knobs, best: &mut Knobs, best_r: &mut SimResult| {
        let r = eval_knobs(hw, model, micro_batch, &cand);
        if r.tokens_per_s > best_r.tokens_per_s {
            *best = cand;
            *best_r = r;
        }
    };

    for _ in 0..SWEEPS {
        let at_entry = best_r.tokens_per_s;

        // schedule family × chunk group
        for schedule in [
            ScheduleKind::Vertical,
            ScheduleKind::ChunkedVertical(2),
            ScheduleKind::ChunkedVertical(4),
            ScheduleKind::ChunkedVertical(8),
            ScheduleKind::CacheSweep(2),
            ScheduleKind::CacheSweep(4),
            ScheduleKind::CacheSweep(8),
            ScheduleKind::Horizontal,
        ] {
            consider(Knobs { schedule, ..best }, &mut best, &mut best_r);
        }

        // io-depth rides the device's QD ramp
        for io_depth in [1usize, 2, 4, 8, 16] {
            consider(Knobs { io_depth, ..best }, &mut best, &mut best_r);
        }

        // stripe width, bounded by the physical device count
        for ssds in 1..=hw.devices.len() {
            consider(Knobs { ssds, ..best }, &mut best, &mut best_r);
        }

        // DRAM cache tier, bounded by the machine's CPU memory
        let cpu_mb = hw.machine.cpu_mem >> 20;
        for cache_mb in [0u64, 4096, 16384, 65536] {
            if cache_mb < cpu_mb {
                consider(Knobs { cache_mb, ..best }, &mut best, &mut best_r);
            }
        }

        // data-parallel workers + the two sharding switches
        for workers in [1usize, 2, 4] {
            consider(Knobs { workers, ..best }, &mut best, &mut best_r);
        }
        for shard_optimizer in [false, true] {
            consider(Knobs { shard_optimizer, ..best }, &mut best, &mut best_r);
        }
        for param_persist in [false, true] {
            consider(Knobs { param_persist, ..best }, &mut best, &mut best_r);
        }

        // storage precision — placement must be re-solved per precision
        for precision in [Precision::F32, Precision::MixedF16, Precision::MixedBf16] {
            if let Some(ratios) = feasible_ratios(&sp, best.m, best.alpha, precision) {
                consider(Knobs { precision, ratios, ..best }, &mut best, &mut best_r);
            }
        }

        // submission batching amortizes the latency floor
        for io_batch in [
            None,
            Some(BatchConfig::default()),
            Some(BatchConfig { max_bytes: 4 << 20, max_ops: 64 }),
        ] {
            consider(Knobs { io_batch, ..best }, &mut best, &mut best_r);
        }

        // delay ratio α on the shared Algorithm 1 grid (every 5th point),
        // with its LP placement
        for alpha in lp::alpha_grid().into_iter().skip(4).step_by(5) {
            if let Some(ratios) = feasible_ratios(&sp, best.m, alpha, best.precision) {
                consider(Knobs { alpha, ratios, ..best }, &mut best, &mut best_r);
            }
        }

        if best_r.tokens_per_s <= at_entry * 1.0001 {
            break; // converged: a full sweep moved nothing
        }
    }

    let roofline =
        Roofline { node: hw.machine.with_gpus(1), model, micro_batch, seq_len: SEQ_LEN };
    Ok(TunedConfig {
        knobs: best,
        t_iter: best_r.t_iter,
        tokens_per_s: best_r.tokens_per_s,
        ideal_tokens_per_s: roofline.ideal_tokens_per_s(best.m),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::{MACHINE1_A5000, MACHINE2_A100};
    use crate::modelcfg::{GPT_30B, GPT_65B};

    /// A memory-starved host forces SSD-resident optimizer states even for
    /// shortened test models, so the device curve actually binds.
    fn tight_hw(base: Machine, cpu_gib: u64) -> HwProfile {
        let mut m = base;
        m.cpu_mem = cpu_gib * GIB;
        HwProfile::builtin(m)
    }

    fn short(model: ModelCfg, n_layers: u64) -> ModelCfg {
        let mut m = model;
        m.n_layers = n_layers;
        m
    }

    #[test]
    fn hw_profile_json_parses() {
        let hw = HwProfile::parse(
            r#"{"gpu_mem_gib": 24, "cpu_mem_gib": 128, "pcie_gbps": 16,
                "link_gbps": 56, "gpu_tflops": 70, "cpu_adam_gelems": 2.0,
                "devices": [{"read_gbps": 3.2, "write_gbps": 2.8,
                             "qd_knee": 8, "sat_kib": 256,
                             "mix_penalty": 0.15, "op_latency_us": 80},
                            {"read_gbps": 3.2, "write_gbps": 2.8}]}"#,
        )
        .unwrap();
        assert_eq!(hw.machine.name, "custom");
        assert_eq!(hw.machine.gpu_mem, 24 * GIB);
        assert_eq!(hw.machine.cpu_mem, 128 * GIB);
        assert_eq!(hw.devices.len(), 2);
        assert_eq!(hw.machine.ssd_read_bw, 3.2e9);
        assert_eq!(hw.devices[0].qd_knee, 8);
        assert!(hw.devices[1].is_flat());
        assert!(HwProfile::parse(r#"{"gpu_mem_gib": 24}"#).is_err());
        assert!(HwProfile::parse(
            r#"{"gpu_mem_gib": 24, "cpu_mem_gib": 128, "pcie_gbps": 16,
                "link_gbps": 56, "gpu_tflops": 70, "cpu_adam_gelems": 2.0,
                "devices": []}"#
        )
        .is_err());
    }

    /// The acceptance bar: on ≥ 2 (hardware profile × model) pairs the
    /// tuned configuration strictly beats the hand-picked default under
    /// the same sim objective. The defaults misprice the QD ramp
    /// (`--io-depth 2` on a knee-8 device leaves 4× read bandwidth on the
    /// table), so the search must find a strict win, not a tie.
    #[test]
    fn tuned_beats_handpicked_default_on_two_pairs() {
        let pairs = [
            (tight_hw(MACHINE1_A5000, 16), short(GPT_65B, 8)),
            (tight_hw(MACHINE2_A100, 8), short(GPT_30B, 8)),
        ];
        for (hw, model) in &pairs {
            let def = default_knobs(hw, *model, 2);
            let def_r = eval_knobs(hw, *model, 2, &def);
            let tuned = autotune(hw, *model, 2).unwrap();
            assert!(
                tuned.tokens_per_s > def_r.tokens_per_s,
                "{}: tuned {} must strictly beat default {} ({})",
                model.name,
                tuned.tokens_per_s,
                def_r.tokens_per_s,
                tuned.cli_flags(),
            );
            assert!(tuned.roofline_frac() > 0.0 && tuned.roofline_frac() <= 1.0);
        }
    }

    #[test]
    fn cli_flags_round_trip_the_knob_surface() {
        let hw = tight_hw(MACHINE1_A5000, 16);
        let model = short(GPT_65B, 8);
        let tuned = autotune(&hw, model, 2).unwrap();
        let flags = tuned.cli_flags();
        for needle in
            ["--schedule ", "--alpha ", "--micro-batches ", "--io-depth ", "--precision "]
        {
            assert!(flags.contains(needle), "'{needle}' missing from '{flags}'");
        }
        // every emitted schedule spelling parses back through the grammar
        let k: ScheduleKind = tuned.knobs.schedule.to_string().parse().unwrap();
        assert_eq!(k, tuned.knobs.schedule);
    }
}
