//! Performance model: per-layer effective times and iteration-time
//! composition for every schedule the paper evaluates.
//!
//! This is the "simple yet accurate performance model" of §4.5 — the same
//! arithmetic parameterizes the LP (Algorithm 1), predicts the "performance
//! model" series in Figure 10, and seeds the discrete-event simulator. All
//! per-GPU quantities assume FSDP sharding of parameters / gradients /
//! optimizer states over `node.n_gpus` and data-parallel micro-batches.
//!
//! Conventions:
//! * storage ratios `x ∈ [0,1]` are the fraction resident in **CPU DRAM**;
//!   the `1-x` remainder lives on SSD (gradients are 100 % CPU, like the
//!   paper).
//! * SSD reads and writes proceed on independent full-duplex channels
//!   (NVMe), each at its own bandwidth, shared across GPUs — a stage's SSD
//!   time is the max of its read time and its write time.
//! * PCIe is full-duplex: H2D and D2H progress concurrently, so a stage's
//!   PCIe time is the max of the two directions.

use crate::machine::NodeSpec;
use crate::modelcfg::{ModelCfg, BYTES_FP, BYTES_LP};

/// Fraction of DRAM reserved for pinned working buffers and the allocator.
const WORK_RESERVE: f64 = 0.04;

/// Live per-layer gradient buffers in the vertical pipeline (grad offload →
/// optimizer step → write-back spans three stages, Fig. 7).
const GRAD_PIPELINE_DEPTH: f64 = 3.0;

/// Storage placement ratios (fraction in CPU DRAM; remainder on SSD).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StorageRatios {
    pub ckpt_cpu: f64,
    pub param_cpu: f64,
    pub opt_cpu: f64,
}

impl StorageRatios {
    pub const ALL_SSD: StorageRatios =
        StorageRatios { ckpt_cpu: 0.0, param_cpu: 0.0, opt_cpu: 0.0 };
    pub const ALL_CPU: StorageRatios =
        StorageRatios { ckpt_cpu: 1.0, param_cpu: 1.0, opt_cpu: 1.0 };
}

/// Horizontal-schedule placement: storage ratios + the CPU-resident share of
/// the full gradient-accumulation buffer (the remainder spills to SSD).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HPlacement {
    pub x: StorageRatios,
    pub grad_cpu: f64,
}

/// What bounds a stage — for reporting which roofline is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Pcie,
    Ssd,
    CpuAdam,
}

/// Per-category storage byte multipliers, applied on top of the paper's
/// wire widths the closed forms assume (params/checkpoints 2 B lp,
/// gradients/optimizer state 4 B fp). [`ByteMults::ONE`] — the default on
/// every existing path — reproduces the historical model unchanged; the
/// `--precision` sweeps use [`ByteMults::for_precision`] to model the
/// runtime's actual storage widths instead.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ByteMults {
    /// Low-precision parameter stream (`p_lp`).
    pub param: f64,
    /// Activation-checkpoint traffic (`c_bytes`).
    pub ckpt: f64,
    /// Gradient spill traffic (`g_fp`).
    pub grad: f64,
    /// Optimizer-state round trips (`o_bytes`).
    pub opt: f64,
}

impl ByteMults {
    /// The identity: the paper's wire widths, i.e. the historical model.
    pub const ONE: ByteMults = ByteMults { param: 1.0, ckpt: 1.0, grad: 1.0, opt: 1.0 };

    /// Multipliers modeling the RUNTIME's storage widths for a
    /// `--precision` choice, relative to the paper widths: strict f32
    /// stores parameters and checkpoints at 4 B/elem (2× the lp
    /// assumption), the mixed policies store them at 2 B (1×) and
    /// requantize gradients to half (0.5×); Adam moments are f32
    /// everywhere (1×).
    pub fn for_precision(p: crate::memory::codec::Precision) -> ByteMults {
        match p {
            crate::memory::codec::Precision::F32 => {
                ByteMults { param: 2.0, ckpt: 2.0, grad: 1.0, opt: 1.0 }
            }
            _ => ByteMults { param: 1.0, ckpt: 1.0, grad: 0.5, opt: 1.0 },
        }
    }
}

/// One (machine, model, micro-batch, seq) operating point.
#[derive(Clone, Copy, Debug)]
pub struct SystemParams {
    pub node: NodeSpec,
    pub model: ModelCfg,
    pub micro_batch: u64,
    pub seq_len: u64,
    /// Storage byte multipliers (see [`ByteMults`]); [`ByteMults::ONE`]
    /// unless a precision sweep overrides them via
    /// [`SystemParams::with_byte_mults`].
    pub byte_mults: ByteMults,
}

/// Iteration-time estimate.
#[derive(Clone, Copy, Debug)]
pub struct IterEstimate {
    /// Effective forward phase, seconds.
    pub t_fwd: f64,
    /// Effective backward(+overlapped optimizer) phase, seconds.
    pub t_bwd: f64,
    /// Optimizer time not hidden by any compute.
    pub t_opt_exposed: f64,
    /// Full iteration, seconds.
    pub t_iter: f64,
    /// Training throughput in tokens/s across the node.
    pub tokens_per_s: f64,
    /// Model FLOPs per GPU per second.
    pub tflops_per_gpu: f64,
    /// What bounds the forward / backward stages.
    pub fwd_bound: Bound,
    pub bwd_bound: Bound,
}

fn argmax4(compute: f64, pcie: f64, ssd: f64, cpu: f64) -> (f64, Bound) {
    let mut best = (compute, Bound::Compute);
    if pcie > best.0 {
        best = (pcie, Bound::Pcie);
    }
    if ssd > best.0 {
        best = (ssd, Bound::Ssd);
    }
    if cpu > best.0 {
        best = (cpu, Bound::CpuAdam);
    }
    best
}

impl SystemParams {
    pub fn new(node: NodeSpec, model: ModelCfg, micro_batch: u64, seq_len: u64) -> Self {
        SystemParams { node, model, micro_batch, seq_len, byte_mults: ByteMults::ONE }
    }

    /// The same operating point with `mults` applied to every storage byte
    /// primitive (`p_lp`, `g_fp`, `o_bytes`, `c_bytes`).
    pub fn with_byte_mults(mut self, mults: ByteMults) -> Self {
        self.byte_mults = mults;
        self
    }

    // ---- per-GPU per-layer primitives -----------------------------------

    fn shards(&self) -> f64 {
        self.node.n_gpus as f64
    }

    /// Low-precision parameter bytes of one layer, per shard.
    pub fn p_lp(&self) -> f64 {
        (self.model.params_per_layer() * BYTES_LP) as f64 / self.shards() * self.byte_mults.param
    }

    /// FP32 gradient bytes of one layer, per shard.
    pub fn g_fp(&self) -> f64 {
        (self.model.params_per_layer() * BYTES_FP) as f64 / self.shards() * self.byte_mults.grad
    }

    /// Optimizer-state bytes (master+m+v, FP32) of one layer, per shard.
    pub fn o_bytes(&self) -> f64 {
        (self.model.layer_opt_state_bytes()) as f64 / self.shards() * self.byte_mults.opt
    }

    /// One micro-batch's per-layer checkpoint bytes (per GPU; data parallel).
    pub fn c_bytes(&self) -> f64 {
        self.model.ckpt_bytes_lp(self.micro_batch, self.seq_len) as f64 * self.byte_mults.ckpt
    }

    /// One micro-batch forward compute time for one layer.
    pub fn t_fwd_mb(&self) -> f64 {
        self.model.layer_fwd_flops(self.micro_batch, self.seq_len) / self.node.machine.gpu_flops
    }

    /// One micro-batch backward(+recompute) compute time for one layer.
    pub fn t_bwd_mb(&self) -> f64 {
        self.model.layer_bwd_flops_with_recompute(self.micro_batch, self.seq_len)
            / self.node.machine.gpu_flops
    }

    /// CPU Adam time for one layer's shard.
    pub fn t_adam_layer(&self) -> f64 {
        (self.model.params_per_layer() as f64 / self.shards())
            / self.node.machine.cpu_adam_elems_per_s
    }

    fn ssd_r(&self) -> f64 {
        self.node.ssd_read_bw() / self.shards()
    }

    fn ssd_w(&self) -> f64 {
        self.node.ssd_write_bw() / self.shards()
    }

    fn pcie(&self) -> f64 {
        self.node.pcie_bw_per_gpu()
    }

    fn ssd_time(&self, read: f64, write: f64) -> f64 {
        (read / self.ssd_r()).max(write / self.ssd_w())
    }

    /// Usable DRAM per GPU shard.
    pub fn dram_share(&self) -> f64 {
        self.node.machine.usable_dram() as f64 / self.shards()
    }

    // ---- CPU memory accounting (the LP's capacity constraint) -----------

    /// CPU bytes consumed by a vertical-schedule configuration.
    ///
    /// Gradients are 100 % CPU but only ~3 layers' buffers are live at once
    /// (the pipelined optimizer consumes them, Fig. 7); the α-delayed share
    /// reuses reclaimed parameter/checkpoint memory (§4.4) so it adds no
    /// footprint — that is enforced by the LP's reuse constraint instead.
    pub fn cpu_bytes_vertical(&self, m: u64, x: StorageRatios) -> f64 {
        let n = self.model.n_layers as f64;
        let grads = GRAD_PIPELINE_DEPTH * self.g_fp();
        let params = x.param_cpu * n * self.p_lp();
        let opt = x.opt_cpu * n * self.o_bytes();
        let ckpts = x.ckpt_cpu * n * m as f64 * self.c_bytes();
        let work = WORK_RESERVE * self.dram_share()
            + 6.0 * self.p_lp()
            + 4.0 * m as f64 * self.c_bytes();
        grads + params + opt + ckpts + work
    }

    // ---- vertical schedule (GreedySnake, §4.2–4.4) -----------------------

    /// Per-layer effective (t_f, t_b) under vertical scheduling with `m`
    /// micro-batches, delay ratio `alpha`, placement `x`.
    pub fn vertical_layer_times(
        &self,
        m: u64,
        alpha: f64,
        x: StorageRatios,
    ) -> ((f64, Bound), (f64, Bound)) {
        let mf = m as f64;
        let (p, g, o, c) = (self.p_lp(), self.g_fp(), self.o_bytes(), self.c_bytes());

        // Forward stage (Fig. 6 + the Fig. 8 delayed-optimizer additions).
        let compute_f = mf * self.t_fwd_mb();
        let h2d_f = p + (mf - 1.0) * c; // params + all but the resident ckpt
        let d2h_f = mf * c;
        let pcie_f = h2d_f.max(d2h_f) / self.pcie();
        let ssd_read_f = (1.0 - x.param_cpu) * p + alpha * (1.0 - x.opt_cpu) * o;
        let ssd_write_f = alpha * (1.0 - x.opt_cpu) * o
            + alpha * (1.0 - x.param_cpu) * p
            + (1.0 - x.ckpt_cpu) * mf * c;
        let ssd_f = self.ssd_time(ssd_read_f, ssd_write_f);
        let cpu_f = alpha * self.t_adam_layer();
        let tf = argmax4(compute_f, pcie_f, ssd_f, cpu_f);

        // Backward stage (Fig. 7): recompute + bwd for all micro-batches,
        // overlapped with the (1-α) share of the optimizer step.
        let compute_b = mf * self.t_bwd_mb();
        let h2d_b = p + mf * c + (mf - 1.0) * c; // params + ckpts + grads-in
        let d2h_b = (mf - 1.0) * c + g; // grads-out + accumulated param grads
        let pcie_b = h2d_b.max(d2h_b) / self.pcie();
        let ssd_read_b = (1.0 - x.ckpt_cpu) * mf * c
            + (1.0 - x.param_cpu) * p
            + (1.0 - alpha) * (1.0 - x.opt_cpu) * o;
        let ssd_write_b = (1.0 - alpha) * (1.0 - x.opt_cpu) * o
            + (1.0 - alpha) * (1.0 - x.param_cpu) * p;
        let ssd_b = self.ssd_time(ssd_read_b, ssd_write_b);
        let cpu_b = (1.0 - alpha) * self.t_adam_layer();
        let tb = argmax4(compute_b, pcie_b, ssd_b, cpu_b);

        (tf, tb)
    }

    /// Full vertical-schedule iteration estimate.
    pub fn vertical_iter(&self, m: u64, alpha: f64, x: StorageRatios) -> IterEstimate {
        let ((tf, fb), (tb, bb)) = self.vertical_layer_times(m, alpha, x);
        let n = self.model.n_layers as f64;
        // Embedding + head: roughly one extra layer's fwd+bwd of compute
        // plus the vocab-matmul; fold in as 1.5 layer-equivalents.
        let overhead = 1.5 * (tf + tb);
        let t_iter = n * (tf + tb) + overhead;
        self.finish(m, t_iter, n * tf, n * tb, 0.0, fb, bb)
    }

    // ---- horizontal schedule (ZeRO-Infinity, §3.3) ------------------------

    /// ZeRO-Infinity's placement heuristic: gradients first (spilling to SSD
    /// when DRAM is short — horizontal accumulation keeps ALL N layers'
    /// fp32 buffers live across the whole iteration), then checkpoints, then
    /// as many parameters as fit; optimizer states stay on SSD.
    pub fn zero_infinity_placement(&self, m: u64) -> HPlacement {
        let n = self.model.n_layers as f64;
        let dram = self.dram_share() * (1.0 - WORK_RESERVE);
        let grads = n * self.g_fp();
        let ckpts = m as f64 * n * self.c_bytes(); // horizontal keeps M×N ckpts live
        let grad_cpu = (dram / grads).clamp(0.0, 1.0);
        let mut left = (dram - grads).max(0.0);
        let ckpt_cpu = (left / ckpts).clamp(0.0, 1.0);
        left -= ckpt_cpu * ckpts;
        let params = n * self.p_lp();
        let param_cpu = (left / params).clamp(0.0, 1.0);
        HPlacement {
            x: StorageRatios { ckpt_cpu, param_cpu, opt_cpu: 0.0 },
            grad_cpu,
        }
    }

    /// Per-micro-batch per-layer effective times under horizontal
    /// scheduling.
    pub fn horizontal_mb_times(&self, pl: HPlacement) -> ((f64, Bound), (f64, Bound)) {
        let x = pl.x;
        let (p, g, c) = (self.p_lp(), self.g_fp(), self.c_bytes());
        // fwd: load params every micro-batch, store this micro-batch's ckpts.
        let pcie_f = p.max(c) / self.pcie();
        let ssd_f = self.ssd_time((1.0 - x.param_cpu) * p, (1.0 - x.ckpt_cpu) * c);
        let tf = argmax4(self.t_fwd_mb(), pcie_f, ssd_f, 0.0);
        // bwd: params + ckpt + grad buffer in; inter-layer grad + grad buffer
        // out. Gradients cross PCIe in HALF precision (ZeRO ships fp16 grads
        // and promotes in the CPU fp32 buffer); the SSD-spilled share
        // round-trips every micro-batch at full precision.
        let h2d_b = p + c + g / 2.0;
        let d2h_b = c + g / 2.0;
        let pcie_b = h2d_b.max(d2h_b) / self.pcie();
        let grad_spill = (1.0 - pl.grad_cpu) * g;
        let ssd_b = self.ssd_time(
            (1.0 - x.ckpt_cpu) * c + (1.0 - x.param_cpu) * p + grad_spill,
            grad_spill,
        );
        let tb = argmax4(self.t_bwd_mb(), pcie_b, ssd_b, 0.0);
        (tf, tb)
    }

    /// Optimizer-step time for one layer (SSD round trip of the SSD-resident
    /// share + CPU Adam, pipelined → max).
    pub fn t_opt_layer(&self, x: StorageRatios) -> f64 {
        let o = self.o_bytes();
        let io = self.ssd_time((1.0 - x.opt_cpu) * o, (1.0 - x.opt_cpu) * o);
        io.max(self.t_adam_layer())
    }

    /// Full horizontal iteration: M sequential micro-batch passes, then the
    /// optimizer step of which only the last micro-batch's backward (N-1
    /// layers) can hide any part (§3.3).
    pub fn horizontal_iter(&self, m: u64, pl: HPlacement) -> IterEstimate {
        let ((tf, fb), (tb, bb)) = self.horizontal_mb_times(pl);
        let n = self.model.n_layers as f64;
        let t_fwd = n * m as f64 * tf;
        let t_bwd = n * m as f64 * tb;
        let t_opt = n * self.t_opt_layer(pl.x);
        let overlap_budget = (n - 1.0) * tb; // last micro-batch's backward
        let exposed = (t_opt - overlap_budget).max(0.0);
        let overhead = 1.5 * m as f64 * (tf + tb);
        let t_iter = t_fwd + t_bwd + exposed + overhead;
        self.finish(m, t_iter, t_fwd, t_bwd, exposed, fb, bb)
    }

    /// TeraIO: horizontal scheduling with lifetime-optimal placement —
    /// search the placement grid for the best horizontal iteration.
    pub fn teraio_iter(&self, m: u64) -> IterEstimate {
        let mut best: Option<IterEstimate> = None;
        let grad_cpu = self.zero_infinity_placement(m).grad_cpu;
        for pc in [0.0, 0.25, 0.5, 0.75, 1.0] {
            for cc in [0.0, 0.25, 0.5, 0.75, 1.0] {
                for oc in [0.0, 0.25, 0.5] {
                    let x = StorageRatios { ckpt_cpu: cc, param_cpu: pc, opt_cpu: oc };
                    let pl = HPlacement { x, grad_cpu };
                    if self.cpu_bytes_horizontal(m, pl) > self.dram_share() {
                        continue;
                    }
                    let est = self.horizontal_iter(m, pl);
                    if best.is_none_or(|b| est.t_iter < b.t_iter) {
                        best = Some(est);
                    }
                }
            }
        }
        best.unwrap_or_else(|| {
            self.horizontal_iter(m, HPlacement { x: StorageRatios::ALL_SSD, grad_cpu })
        })
    }

    /// CPU bytes for a horizontal configuration (keeps M×N checkpoints and
    /// the CPU share of the full gradient buffer resident).
    pub fn cpu_bytes_horizontal(&self, m: u64, pl: HPlacement) -> f64 {
        let n = self.model.n_layers as f64;
        pl.grad_cpu * n * self.g_fp()
            + pl.x.param_cpu * n * self.p_lp()
            + pl.x.opt_cpu * n * self.o_bytes()
            + pl.x.ckpt_cpu * m as f64 * n * self.c_bytes()
            + WORK_RESERVE * self.dram_share()
    }

    // ---- single-pass schedule (Ratel, §3.2) -------------------------------

    /// Largest single-pass batch that fits GPU memory. `extra_ckpt` adds the
    /// attention/FFN boundary checkpoint, stretching max batch by 1.5×
    /// (Figure 4) at the cost of doubling checkpoint traffic.
    pub fn single_pass_max_batch(&self, extra_ckpt: bool) -> u64 {
        let d = self.model.hidden as f64;
        let t = self.seq_len as f64;
        let h = self.model.n_heads as f64;
        // Live working set per sample for one layer's backward: recovered
        // intra-layer activations (qkv 3D + attn out D + FFN intermediates
        // 8D + residuals 2D ≈ 14·T·D) plus ~3 live T×T attention buffers
        // per head (scores, softmax, mask — non-flash kernels), calibrated
        // so GPT-65B on a 40 GB A100 caps near batch 16 (paper Fig. 4).
        let per_sample = (14.0 * t * d + 3.0 * h * t * t) * BYTES_LP as f64;
        let per_sample = if extra_ckpt { per_sample / 1.5 } else { per_sample };
        let budget = self.node.machine.usable_gpu() as f64
            - 2.0 * self.p_lp() // resident layer params (double-buffered)
            - self.g_fp(); // gradient staging
        ((budget / per_sample).floor() as u64).max(1)
    }

    /// Ratel iteration at single-pass batch `batch`.
    pub fn single_pass_iter(&self, batch: u64, extra_ckpt: bool) -> IterEstimate {
        let scale = batch as f64 / self.micro_batch as f64;
        let ckpt_mult = if extra_ckpt { 2.0 } else { 1.0 };
        let (p, c) = (self.p_lp(), self.c_bytes() * scale * ckpt_mult);
        let x = self.zero_infinity_placement(1).x;
        let tf_c = scale * self.t_fwd_mb();
        let pcie_f = p.max(c) / self.pcie();
        let ssd_f = self.ssd_time((1.0 - x.param_cpu) * p, (1.0 - x.ckpt_cpu) * c);
        let (tf, fb) = argmax4(tf_c, pcie_f, ssd_f, 0.0);
        let tb_c = scale * self.t_bwd_mb();
        let pcie_b = (p + c).max(c + self.g_fp()) / self.pcie();
        let ssd_b = self.ssd_time((1.0 - x.ckpt_cpu) * c + (1.0 - x.param_cpu) * p, 0.0);
        let (tb, bb) = argmax4(tb_c, pcie_b, ssd_b, 0.0);
        let n = self.model.n_layers as f64;
        let t_opt = n * self.t_opt_layer(x);
        let exposed = (t_opt - (n - 1.0) * tb).max(0.0);
        let overhead = 1.5 * (tf + tb);
        let t_iter = n * (tf + tb) + exposed + overhead;
        // tokens for `batch` samples in one pass
        let tokens = (self.node.n_gpus * batch * self.seq_len) as f64;
        let flops = self.model.iter_flops(batch, self.seq_len, 1);
        IterEstimate {
            t_fwd: n * tf,
            t_bwd: n * tb,
            t_opt_exposed: exposed,
            t_iter,
            tokens_per_s: tokens / t_iter,
            tflops_per_gpu: flops / t_iter / 1e12,
            fwd_bound: fb,
            bwd_bound: bb,
        }
    }

    // ---- shared ----------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn finish(
        &self,
        m: u64,
        t_iter: f64,
        t_fwd: f64,
        t_bwd: f64,
        exposed: f64,
        fwd_bound: Bound,
        bwd_bound: Bound,
    ) -> IterEstimate {
        let tokens = (self.node.n_gpus * m * self.micro_batch * self.seq_len) as f64;
        let flops = self.model.iter_flops(self.micro_batch, self.seq_len, m);
        IterEstimate {
            t_fwd,
            t_bwd,
            t_opt_exposed: exposed,
            t_iter,
            tokens_per_s: tokens / t_iter,
            tflops_per_gpu: flops / t_iter / 1e12,
            fwd_bound,
            bwd_bound,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::{MACHINE1_A5000, MACHINE2_A100};
    use crate::modelcfg::{GPT_30B, GPT_65B, SEQ_LEN};

    fn sp65() -> SystemParams {
        SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN)
    }

    #[test]
    fn paper_time_credit_example() {
        // §6.4: one micro-batch of GPT-65B fwd+bwd ≈ 16.4 s vs ~1.1 s of
        // extra checkpoint I/O. Require the same order of magnitude and the
        // compute ≫ I/O relationship that creates the time credit.
        let sp = sp65();
        let n = GPT_65B.n_layers as f64;
        let compute = n * (sp.t_fwd_mb() + sp.t_bwd_mb());
        // extra ckpt traffic per added micro-batch under the optimal config
        // (checkpoints CPU-resident → PCIe): fwd store+load, bwd ckpt load +
        // inter-layer grads both ways ≈ 5·C per layer.
        let io = n * 5.0 * sp.c_bytes() / sp.pcie();
        assert!((compute - 16.4).abs() / 16.4 < 0.25, "compute {compute} vs paper 16.4 s");
        assert!((io - 1.1).abs() / 1.1 < 0.5, "io {io} vs paper 1.1 s");
        assert!(io < compute / 4.0, "io {io} vs compute {compute}");
    }

    #[test]
    fn vertical_throughput_saturates() {
        let sp = sp65();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let t4 = sp.vertical_iter(4, 0.3, x).tokens_per_s;
        let t64 = sp.vertical_iter(64, 0.3, x).tokens_per_s;
        let t128 = sp.vertical_iter(128, 0.3, x).tokens_per_s;
        assert!(t64 > t4);
        // saturated: doubling batch beyond the knee gains <5 %
        assert!((t128 - t64) / t64 < 0.05, "{t64} -> {t128}");
    }

    #[test]
    fn vertical_beats_horizontal_when_saturated() {
        let sp = sp65();
        let pl = sp.zero_infinity_placement(8);
        let h = sp.horizontal_iter(64, pl);
        let xv = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let v = sp.vertical_iter(64, 0.3, xv);
        assert!(
            v.tokens_per_s > 1.5 * h.tokens_per_s,
            "vertical {} vs horizontal {}",
            v.tokens_per_s,
            h.tokens_per_s
        );
    }

    #[test]
    fn delayed_step_shifts_the_knee_not_the_ceiling() {
        // Figure 11: α>0 lifts throughput in the transition region (the
        // backward phase is SSD-bound while forward has compute slack) and
        // reaches the same saturated throughput with a smaller batch.
        let sp = sp65();
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.3, opt_cpu: 0.1 };
        // α chosen by argmax over the paper's grid (what Algorithm 1 does).
        let best_alpha = |m: u64| {
            (0..=50)
                .map(|i| sp.vertical_iter(m, i as f64 / 100.0, x).tokens_per_s)
                .fold(0.0_f64, f64::max)
        };
        let mid_a0 = sp.vertical_iter(20, 0.0, x).tokens_per_s;
        let mid_best = best_alpha(20);
        assert!(mid_best > mid_a0 * 1.08, "{mid_a0} -> {mid_best}");
        let big_a0 = sp.vertical_iter(128, 0.0, x).tokens_per_s;
        let big_best = best_alpha(128);
        assert!((big_best - big_a0).abs() / big_a0 < 0.10, "{big_a0} vs {big_best}");
        // saturation batch: smallest m within 2 % of the m=256 ceiling,
        // with α=0 vs the per-m argmax α.
        let ceiling = sp.vertical_iter(256, 0.0, x).tokens_per_s;
        let sat_a0 = (1..256u64)
            .find(|&m| sp.vertical_iter(m, 0.0, x).tokens_per_s > 0.98 * ceiling)
            .unwrap();
        let sat_best = (1..256u64).find(|&m| best_alpha(m) > 0.98 * ceiling).unwrap();
        assert!(sat_best < sat_a0, "{sat_best} !< {sat_a0}");
    }

    #[test]
    fn ssd_only_reaches_similar_saturation() {
        // Figure 12: with everything on SSD, vertical scheduling still
        // reaches a similar saturated throughput, just at larger batch.
        let sp = sp65();
        let xbest = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let sat_best = sp.vertical_iter(64, 0.3, xbest).tokens_per_s;
        let sat_ssd = sp.vertical_iter(256, 0.3, StorageRatios::ALL_SSD).tokens_per_s;
        assert!(
            (sat_best - sat_ssd).abs() / sat_best < 0.15,
            "best {sat_best} vs ssd-only {sat_ssd}"
        );
        // …but at small m the SSD-only config is clearly slower.
        let small_best = sp.vertical_iter(8, 0.3, xbest).tokens_per_s;
        let small_ssd = sp.vertical_iter(8, 0.3, StorageRatios::ALL_SSD).tokens_per_s;
        assert!(small_ssd < small_best);
    }

    #[test]
    fn horizontal_optimizer_overlap_does_not_scale_with_m() {
        let sp = sp65();
        let pl = sp.zero_infinity_placement(4);
        let e8 = sp.horizontal_iter(8, pl);
        let e32 = sp.horizontal_iter(32, pl);
        // exposed optimizer time identical regardless of M (§3.3)
        assert!((e8.t_opt_exposed - e32.t_opt_exposed).abs() < 1e-6);
        assert!(e8.t_opt_exposed > 0.0, "65B opt step must not be fully hidden");
    }

    #[test]
    fn teraio_at_least_as_good_as_zero_infinity() {
        let sp = sp65();
        for m in [4, 16, 48] {
            let z = sp.horizontal_iter(m, sp.zero_infinity_placement(m));
            let t = sp.teraio_iter(m);
            assert!(t.tokens_per_s >= z.tokens_per_s * 0.999, "m={m}");
        }
    }

    #[test]
    fn ratel_max_batch_post_extra_ckpt_is_1_5x() {
        let sp = SystemParams::new(MACHINE1_A5000.with_gpus(1), GPT_65B, 2, SEQ_LEN);
        let b1 = sp.single_pass_max_batch(false);
        let b2 = sp.single_pass_max_batch(true);
        let ratio = b2 as f64 / b1 as f64;
        assert!((ratio - 1.5).abs() < 0.25, "{b1} -> {b2}");
    }

    #[test]
    fn ratel_stays_below_saturation() {
        // Figure 10: single-pass cannot reach the saturated throughput.
        let sp = sp65();
        let batch = sp.single_pass_max_batch(true);
        let r = sp.single_pass_iter(batch, true);
        let xv = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let v = sp.vertical_iter(64, 0.3, xv);
        assert!(r.tokens_per_s < 0.7 * v.tokens_per_s);
    }

    #[test]
    fn tflops_reported_in_plausible_band() {
        let sp = SystemParams::new(MACHINE2_A100.with_gpus(4), GPT_65B, 2, SEQ_LEN);
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.5, opt_cpu: 0.2 };
        let est = sp.vertical_iter(64, 0.3, x);
        // §6.2: saturated GreedySnake ≈ 63–130 TFLOPs/GPU depending on node.
        assert!(
            est.tflops_per_gpu > 40.0 && est.tflops_per_gpu < 140.0,
            "{}",
            est.tflops_per_gpu
        );
    }

    #[test]
    fn memory_accounting_monotone_in_ratios() {
        let sp = sp65();
        let lo = sp.cpu_bytes_vertical(8, StorageRatios::ALL_SSD);
        let hi = sp.cpu_bytes_vertical(8, StorageRatios::ALL_CPU);
        assert!(hi > lo);
    }

    #[test]
    fn gpt30b_less_bound_than_65b() {
        let sp30 = SystemParams::new(MACHINE1_A5000.with_gpus(1), GPT_30B, 2, SEQ_LEN);
        let sp65 = SystemParams::new(MACHINE1_A5000.with_gpus(1), GPT_65B, 2, SEQ_LEN);
        let x = StorageRatios { ckpt_cpu: 1.0, param_cpu: 0.3, opt_cpu: 0.1 };
        let t30 = sp30.vertical_iter(16, 0.2, x).tokens_per_s;
        let t65 = sp65.vertical_iter(16, 0.2, x).tokens_per_s;
        assert!(t30 > t65, "smaller model trains faster per token");
    }
}
