//! # GreedySnake
//!
//! A from-scratch reproduction of *GreedySnake: Accelerating SSD-Offloaded LLM
//! Training with Efficient Scheduling and Optimizer Step Overlapping*, built
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time)** — Pallas kernels and the JAX transformer are
//!   AOT-lowered to HLO-text artifacts by `python/compile/aot.py`.
//! * **Layer 3 (this crate)** — the paper's system contribution: the
//!   schedule-agnostic step engine with pluggable traversal schedules
//!   (vertical / horizontal / chunked-vertical / cache-sweep), the three offload
//!   coordinators, the delayed optimizer step (delay ratio α), and the
//!   LP-based configuration search, all driving the AOT artifacts through
//!   the PJRT C API.
//!
//! Python never runs on the training path.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`autotune`] | sim-driven configuration search over the full CLI knob surface (`greedysnake autotune`): hardware-profile JSON in ([`autotune::HwProfile`]: machine capacities + per-device NVMe curves), Algorithm-1 seed, coordinate descent over schedule × io-depth × ssds × cache × workers × sharding × precision × io-batch with [`sim::simulate_dist_dev`] as the objective, ready-to-paste train flags + predicted roofline gap out ([`autotune::TunedConfig`]) |
//! | [`util`] | PRNG, stats, f16/bf16 conversion, TSV tables, CLI parsing, bench + property-test harnesses, the deterministic fault-injection registry ([`util::fault`]: arm a named site to fail on its n-th hit; scope-qualified names keep parallel tests disjoint) |
//! | [`exec`] | thread pool and dependency-aware lane executor (the asyncio-pipeline substrate; lane panics surface as errors, not deadlocks) |
//! | [`memory`] | GPU/CPU tier accounting, file-backed throttled SSD (positioned I/O, concurrent read/write lanes, atomic layout transitions, shrinking high-water mark) under the QD-aware NVMe device model ([`memory::DeviceProfile`]: per-device QD→bandwidth curve, request-size ramp, read/write mix penalty, per-op latency floor — flat profile ≡ the plain throttle bit- and timing-identically) with io_uring-style submission batching ([`memory::BatchConfig`], `--io-batch`: concurrent sub-saturating submissions coalesce into one ring window, amortizing the latency floor; timing-only, results bit-identical), the pluggable [`memory::store::TensorStore`] object tier (single SSD / striped multi-SSD `--ssds N` / DRAM-cached `--cpu-cache-mb` / the multi-path [`memory::store::PlannedStore`] planner `--planned`: every object splits into per-path extents served concurrently from the DRAM tier + each NVMe device + the simulated `--remote-mbps` tier, bandwidth-proportional shares, per-path depth gates, [`memory::store::PathStats`] byte attribution) under the crash-consistency layer ([`memory::store::JournalStore`], `--journal`: write-behind undo journal + epoch markers, `recover()` rolls an in-flight epoch back to the last committed boundary) and the mixed-precision codec layer ([`memory::codec::CodecStore`]: per-category `--precision` policies, f16/bf16 wire formats; two-tier equivalence contract — backends are byte-identical under any fixed codec, strict f32 is bit-identical to the bare stack, mixed policies are tolerance-pinned), pinned-buffer pool |
//! | [`modelcfg`] | Table 2 model zoo and per-layer size/FLOP arithmetic |
//! | [`machine`] | Table 1 machine specs (bandwidths, capacities, compute rates) |
//! | [`traffic`] | analytic data-movement model: horizontal vs vertical vs single-pass, per-worker data-parallel forms (`*_dp`), the sharded-optimizer closed forms (reduce-scatter / all-gather ring bytes, per-rank ~1/W optimizer SSD round trips), the persistence-sharded parameter forms (per-rank ~1/W parameter SSD round trips under `--param-persist`), the DRAM-cache absorption forms (fit-or-nothing working-set law + runtime store byte mirrors), the encoded-byte `*_enc` family (per-[`memory::codec::PrecisionPolicy`] store bytes matching the runtime counters exactly), the multi-path `planned_*` forms (per-path byte splits under the planner's weights, conserving the aggregate exactly), and the `serve_*` family (per-token-step decode loads/bytes — the forward leg of the schedule forms — plus the multi-tenant shared-base working-set law) |
//! | [`roofline`] | the §3.1 I/O + compute roofline |
//! | [`lp`] | dense simplex solver + Algorithm 1 configuration search, incl. the cache-aware solve ([`lp::solve_config_cached`] + [`lp::ssd_working_set`]: DRAM-cache fit-or-nothing absorption folded into the placement objective) |
//! | [`perfmodel`] | per-layer time prediction and iteration-time composition |
//! | [`sim`] | discrete-event pipeline simulator (ZeRO-Infinity / Ratel / TeraIO / GreedySnake / chunked), incl. the multi-worker shared-SSD builder ([`sim::simulate_dist`]: first-class inter-GPU link resource for the ring legs, delayed-α modeling, rank-0 or ZeRO-style sharded optimizer), the storage-tier mirror ([`sim::simulate_store`]: `--ssds` striping bandwidth, DRAM-cache absorption; [`sim::simulate_store_prec`]: per-category `--precision` byte multipliers; [`sim::simulate_planned`] + [`sim::planned_bandwidth`]: the multi-path planner's aggregate-bandwidth law; [`sim::simulate_io_dev`] + [`sim::simulate_dist_dev`]: the SSD tier priced by an NVMe [`memory::DeviceProfile`] curve with `--io-batch` window amortization, flat profile = exact identity), and the serving twin ([`sim::simulate_serve`] + [`sim::serve_token_bound`]: steady-state tokens/sec of schedule-ordered decode under io-depth gating, striping, and the fit-or-nothing cache law) |
//! | [`runtime`] | PJRT client wrapper, artifact manifests, executable cache |
//! | [`optimizer`] | mixed-precision Adam, gradient accumulation, delay-α split, clipping |
//! | [`coordinator`] | the three coordinators + the schedule-agnostic [`coordinator::StepEngine`], pluggable [`coordinator::Schedule`] policies (vertical, horizontal, `chunked:G`, the cache-friendly `cachesweep:G` subgroup sweep), the phase-generic streaming core ([`coordinator::LayerStreamer`]: one-layer parameter residency + depth-K lookahead + per-layer byte metering, shared by training and serving), the async [`coordinator::io::IoPipeline`] (`--io-depth K` lookahead prefetch + write-behind; K=0 ≡ synchronous), the forward-only multi-tenant serving engine ([`coordinator::ServeEngine`], `greedysnake serve`: schedule-ordered decode passes streaming one shared base image + per-tenant adapter deltas, deterministic arrival-order-invariant batching, per-tenant [`memory::store::CacheAdmission`]), and the data-parallel [`coordinator::dist::DataParallelEngine`] (`--workers W`, deterministic chunked ring all-reduce — or, with `--shard-optimizer`, ZeRO-style reduce-scatter + per-rank shard updates + parameter all-gather; every W bit-identical to W=1 either way), plus persistence-sharded master parameters (`--param-persist`: each rank round-trips ~1/W of the parameter bytes per step, embedding/head group included) with deterministic elastic re-shard (`coordinator::opt::reshard_store`, W→W′ bit-identical to a fresh run at W′) |
//! | [`trainer`] | end-to-end training loop; [`trainer::ScheduleKind`] names schedules uniformly across runtime, simulator, and traffic model; with `--journal` the loop commits an epoch boundary per step and replays a mid-step failure from the last committed boundary (kill-a-worker recovery, bit-identical loss curve) |

pub mod autotune;
pub mod coordinator;
pub mod exec;
pub mod lp;
pub mod machine;
pub mod memory;
pub mod modelcfg;
pub mod optimizer;
pub mod perfmodel;
pub mod roofline;
pub mod runtime;
pub mod sim;
pub mod traffic;
pub mod trainer;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
