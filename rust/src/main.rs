//! `greedysnake` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   train     — real training through the AOT artifacts + PJRT runtime
//!   serve     — forward-only multi-tenant token generation off the SSD tier
//!   simulate  — discrete-event simulation of a paper configuration
//!   search    — LP-based configuration search (Algorithm 1)
//!   autotune  — sim-driven search over the full knob surface for a
//!               hardware profile (device curves + machine + model)
//!   roofline  — print the §3.1 roofline for a model/machine
//!
//! `greedysnake <subcommand> --help` lists options.

use anyhow::{bail, Result};

use greedysnake::coordinator::TrainerConfig;
use greedysnake::lp;
use greedysnake::machine::{MACHINE1_A5000, MACHINE2_A100};
use greedysnake::memory::{BatchConfig, DeviceProfile, Precision};
use greedysnake::modelcfg::{ModelCfg, GPT_175B, GPT_30B, GPT_65B, SEQ_LEN};
use greedysnake::perfmodel::{ByteMults, SystemParams};
use greedysnake::roofline::Roofline;
use greedysnake::runtime::Manifest;
use greedysnake::sim::{simulate_dist, simulate_store_prec, DistConfig, Schedule};
use greedysnake::trainer::{train, ScheduleKind};
use greedysnake::util::cli::Cli;
use greedysnake::util::json::Json;
use greedysnake::util::table::Table;

fn model_by_name(name: &str) -> Result<ModelCfg> {
    Ok(match name {
        "30b" | "gpt-30b" => GPT_30B,
        "65b" | "gpt-65b" => GPT_65B,
        "175b" | "gpt-175b" => GPT_175B,
        other => bail!("unknown model '{other}' (30b|65b|175b)"),
    })
}

/// `--io-depth` grammar for `simulate`: a lookahead K, or `unbounded`/`inf`
/// for the sim's historical infinite-prefetch assumption.
fn parse_io_depth(s: &str) -> Result<usize> {
    match s {
        "unbounded" | "inf" => Ok(usize::MAX),
        _ => s
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --io-depth '{s}' (K or 'unbounded'): {e}")),
    }
}

fn machine_by_name(name: &str) -> Result<greedysnake::machine::Machine> {
    Ok(match name {
        "a5000" | "machine1" => MACHINE1_A5000,
        "a100" | "machine2" => MACHINE2_A100,
        other => bail!("unknown machine '{other}' (a5000|a100)"),
    })
}

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("usage: greedysnake <train|serve|simulate|search|autotune|roofline> [options]");
        std::process::exit(2);
    }
    let sub = args.remove(0);
    match sub.as_str() {
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "simulate" => cmd_simulate(args),
        "search" => cmd_search(args),
        "autotune" => cmd_autotune(args),
        "roofline" => cmd_roofline(args),
        other => bail!("unknown subcommand '{other}'"),
    }
}

fn cmd_train(args: Vec<String>) -> Result<()> {
    // --schedule grammar (shared with `simulate --system` and the analytic
    // models): `vertical` (GreedySnake §3.4, alias `greedysnake`),
    // `horizontal` (ZeRO-Infinity §3.3, alias `zero-infinity`),
    // `chunked:G` — vertical sweeps over chunks of G micro-batches
    // (G=1 ≡ horizontal parameter reloads, G≥M ≡ fully vertical) — or
    // `cachesweep:G`, chunked:G with the backward chunk order reversed
    // (MLP-Offload's cache-friendly subgroup ordering: same bytes, better
    // DRAM-tier reuse).
    let cli = Cli::new("greedysnake train", "train through the AOT artifacts")
        .opt("artifacts", "artifact directory", Some("artifacts/tiny"))
        .opt(
            "schedule",
            "vertical|horizontal|chunked:G|cachesweep:G (G = micro-batches per \
             vertical chunk)",
            Some("vertical"),
        )
        .opt("steps", "training iterations", Some("20"))
        .opt("micro-batches", "micro-batches per iteration (M)", Some("4"))
        .opt("alpha", "delay ratio α", Some("0.25"))
        .opt("lr", "learning rate", Some("3e-4"))
        .opt("seed", "rng seed", Some("42"))
        .opt("ssd-read-gbps", "simulated SSD read bandwidth (GB/s; 0 = unthrottled)", Some("0"))
        .opt("ssd-write-gbps", "simulated SSD write bandwidth (GB/s; 0 = unthrottled)", Some("0"))
        .opt(
            "nvme-profile",
            "JSON file with an NVMe device-curve object (read_gbps/write_gbps required; \
             qd_knee, sat_kib, mix_penalty, op_latency_us optional — see the memory \
             module docs). Shapes every backing device's timing; explicit \
             --ssd-read/write-gbps re-rate the curve's peaks. Results stay \
             bit-identical to the flat throttle — only timing changes",
            None,
        )
        .opt(
            "io-batch",
            "io_uring-style submission-batching window BYTES[:OPS] (default OPS 32): \
             concurrent sub-saturating transfers on one device coalesce into one ring \
             submission, amortizing the profile's per-op latency floor. Timing-only; \
             losses and digests are bit-identical at any window",
            None,
        )
        .opt(
            "io-depth",
            "async I/O lookahead K: prefetch the next K visits' parameter loads and \
             checkpoint reads, write checkpoints behind (0 = synchronous I/O, \
             bit-identical to the pre-pipeline engine)",
            Some("2"),
        )
        .opt(
            "ssds",
            "stripe the store across N independent SSD devices (one backing file and \
             throttle each; objects split round-robin, shares move in parallel) — \
             the runtime twin of `simulate --ssds`; bit-identical to 1",
            Some("1"),
        )
        .opt(
            "cpu-cache-mb",
            "bounded CPU-DRAM write-back cache in front of the store, MiB (LRU with \
             dirty write-back; absorbed reads/writes never reach the SSD tier; \
             0 = off; bit-identical either way)",
            Some("0"),
        )
        .opt(
            "workers",
            "data-parallel worker count W: micro-batches split contiguously across W \
             model replicas sharing the SSD, gradients combined by a deterministic \
             chunked ring all-reduce (bit-identical to --workers 1 for every W)",
            Some("1"),
        )
        .opt(
            "remote-mbps",
            "simulated remote/object-store tier bandwidth (MB/s; 0 = no remote path). \
             Only meaningful with --planned: the planner adds a remote path weighted \
             by this bandwidth to every object's transfer plan",
            Some("0"),
        )
        .opt(
            "precision",
            "storage precision policy: f32 (strict, bit-identical baseline) or \
             mixed:f16|mixed:bf16 (checkpoints + parameter accounting in half \
             precision, gradients requantized in place during the optimizer \
             update; master weights and Adam moments stay f32)",
            Some("f32"),
        )
        .opt("log-every", "print every k steps", Some("1"))
        .flag(
            "shard-optimizer",
            "ZeRO-style sharded optimizer states: reduce-scatter gradients, each rank \
             updates its contiguous parameter shard (α-split per shard, ~1/W of the \
             optimizer SSD round trip per rank), parameter all-gather before the next \
             iteration's prefetch — still bit-identical to --workers 1",
        )
        .flag(
            "planned",
            "multi-path planned store: serve each object concurrently from the DRAM \
             cache tier (--cpu-cache-mb), all N NVMe devices (--ssds), and the \
             optional remote tier (--remote-mbps) via a per-object transfer plan — \
             bit-identical to the stacked backends at --precision f32",
        )
        .flag(
            "param-persist",
            "persistence-sharded master parameters: each rank round-trips its own \
             param_* shard objects through the store every update (~1/W of the \
             parameter bytes per rank), making the store the parameter home — \
             bit-identical to the host-resident update; requires SSD-resident \
             optimizer states (not --opt-on-cpu)",
        )
        .flag(
            "journal",
            "crash-consistent write-behind journal: undo-log the first write to each \
             key per step, commit an epoch marker at every step boundary, and replay \
             a failed step from the last committed boundary with the same batch \
             (requires --param-persist)",
        )
        .flag("opt-on-cpu", "keep optimizer states CPU-resident (default: SSD)")
        .flag("ckpt-on-ssd", "spill activation checkpoints to SSD")
        .flag("hlo-adam", "run Adam through the AOT Pallas kernel")
        .flag("no-overlap", "disable optimizer/compute overlap")
        .parse_from(args)?;

    let kind: ScheduleKind = cli.get("schedule").unwrap().parse()?;
    let alpha: f64 = cli.get_parsed("alpha")?;
    let r: f64 = cli.get_parsed("ssd-read-gbps")?;
    let w: f64 = cli.get_parsed("ssd-write-gbps")?;
    let nvme: Option<DeviceProfile> = match cli.get("nvme-profile") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading --nvme-profile '{path}': {e}"))?;
            Some(DeviceProfile::from_json(&Json::parse(&text)?)?)
        }
        None => None,
    };
    let io_batch: Option<BatchConfig> = match cli.get("io-batch") {
        Some(s) => Some(BatchConfig::parse(&s)?),
        None => None,
    };
    // explicit bandwidth flags win; otherwise a profile supplies its own
    // measured peaks; otherwise unthrottled
    let read_bps = if r > 0.0 {
        r * 1e9
    } else {
        nvme.map(|p| p.read_bps).unwrap_or(f64::INFINITY)
    };
    let write_bps = if w > 0.0 {
        w * 1e9
    } else {
        nvme.map(|p| p.write_bps).unwrap_or(f64::INFINITY)
    };
    let cfg = TrainerConfig {
        alpha: if kind.supports_delay() { alpha } else { 0.0 },
        opt_on_ssd: !cli.has_flag("opt-on-cpu"),
        ckpt_on_ssd: cli.has_flag("ckpt-on-ssd"),
        use_hlo_adam: cli.has_flag("hlo-adam"),
        overlap: !cli.has_flag("no-overlap"),
        io_depth: cli.get_parsed("io-depth")?,
        workers: cli.get_parsed::<usize>("workers")?.max(1),
        shard_optimizer: cli.has_flag("shard-optimizer"),
        adam: greedysnake::optimizer::AdamParams {
            lr: cli.get_parsed("lr")?,
            weight_decay: 0.01,
            ..Default::default()
        },
        ssd_read_bps: read_bps,
        ssd_write_bps: write_bps,
        nvme,
        io_batch,
        ssds: cli.get_parsed::<usize>("ssds")?.max(1),
        cpu_cache_mb: cli.get_parsed("cpu-cache-mb")?,
        planned: cli.has_flag("planned"),
        remote_mbps: cli.get_parsed("remote-mbps")?,
        precision: Precision::parse(&cli.get("precision").unwrap())?,
        param_persist: cli.has_flag("param-persist"),
        journal: cli.has_flag("journal"),
        seed: cli.get_parsed("seed")?,
        ..Default::default()
    };
    let manifest = Manifest::load(cli.get("artifacts").unwrap())?;
    let shape = manifest.config;
    let m: usize = cli.get_parsed("micro-batches")?;
    let steps: u64 = cli.get_parsed("steps")?;
    println!(
        "training {} ({} params) schedule={kind} M={m} alpha={} steps={steps} io-depth={} workers={}{}{} ssds={} cpu-cache={}MiB{} precision={}",
        manifest.preset,
        manifest.total_numel(),
        cfg.alpha,
        cfg.io_depth,
        cfg.workers,
        if cfg.shard_optimizer { " shard-optimizer" } else { "" },
        match (cfg.param_persist, cfg.journal) {
            (true, true) => " param-persist journal",
            (true, false) => " param-persist",
            _ => "",
        },
        cfg.ssds,
        cfg.cpu_cache_mb,
        if cfg.planned {
            format!(" planned(remote={}MB/s)", cfg.remote_mbps)
        } else {
            String::new()
        },
        cfg.precision,
    );
    let workers = cfg.workers;
    let sharded = cfg.shard_optimizer && workers > 1;
    let cached = cfg.cpu_cache_mb > 0;
    let log = train(manifest, cfg, kind, steps, m, cli.get_parsed("log-every")?)?;
    let tokens_per_step = m * shape.micro_batch * shape.seq_len;
    println!(
        "done: final loss {:.4}, {:.0} tokens/s, ssd r/w {}/{}, \
         prefetch hit/miss {}/{}, i/o stall {:.2}s",
        log.final_loss(),
        log.tokens_per_s(tokens_per_step),
        greedysnake::util::stats::fmt_bytes(log.ssd_read as f64),
        greedysnake::util::stats::fmt_bytes(log.ssd_written as f64),
        log.prefetch_hits,
        log.prefetch_misses,
        log.io_stall_s,
    );
    if workers > 1 {
        // worker_stall_s has one entry per ACTIVE worker (idle ranks under
        // W > M are not reported as fake 0-stall workers)
        let stalls: Vec<String> = log.worker_stall_s.iter().map(|s| format!("{s:.2}s")).collect();
        let idle = workers.saturating_sub(log.worker_stall_s.len());
        let idle_note = if idle > 0 {
            format!(" ({idle} idle rank{})", if idle == 1 { "" } else { "s" })
        } else {
            String::new()
        };
        println!(
            "workers: per-active-worker i/o stall [{}]{idle_note}, {} {:.2}s / {}",
            stalls.join(", "),
            if sharded { "reduce-scatter" } else { "all-reduce" },
            log.allreduce_s,
            greedysnake::util::stats::fmt_bytes(log.allreduce_bytes as f64),
        );
        if sharded {
            println!(
                "workers: param all-gather {}",
                greedysnake::util::stats::fmt_bytes(log.allgather_bytes as f64),
            );
        }
    }
    if cached {
        println!(
            "cpu-cache: hit/miss/evict {}/{}/{}",
            log.cache_hits, log.cache_misses, log.cache_evictions,
        );
        for (cat, [h, mi, e]) in &log.cache_by_cat {
            println!("cpu-cache: {cat}: hit/miss/evict {h}/{mi}/{e}");
        }
    }
    if !log.param_shard_reads.is_empty() {
        let rd: u64 = log.param_shard_reads.iter().sum();
        let wr: u64 = log.param_shard_writes.iter().sum();
        println!(
            "param-persist: shard r/w {}/{} over {} rank(s)",
            greedysnake::util::stats::fmt_bytes(rd as f64),
            greedysnake::util::stats::fmt_bytes(wr as f64),
            log.param_shard_reads.len(),
        );
    }
    if log.recoveries > 0 {
        println!(
            "journal: {} mid-step failure(s) replayed from the last epoch boundary",
            log.recoveries
        );
    }
    Ok(())
}

fn cmd_serve(args: Vec<String>) -> Result<()> {
    use greedysnake::coordinator::serve::{provision, synthetic_requests, ServeModel};
    use greedysnake::coordinator::ServeEngine;
    use greedysnake::memory::{
        CacheAdmission, CachedStore, SsdStorage, StripedStore, TensorStore,
    };
    use std::sync::Arc;

    let cli = Cli::new(
        "greedysnake serve",
        "forward-only multi-tenant token generation: decode passes stream a shared \
         base image plus per-tenant adapter deltas from the SSD tier through the \
         same schedule/io-depth machinery as training",
    )
    .opt("tenants", "fine-tuned variants sharing one base image (T)", Some("4"))
    .opt("requests", "synthetic generation requests (heavy-concurrent-load traffic)", Some("16"))
    .opt("tokens", "new tokens generated per request", Some("8"))
    .opt("max-batch", "decode lanes per batch (batches are single-tenant)", Some("4"))
    .opt(
        "schedule",
        "decode sweep order over the (layer x lane) grid: vertical|horizontal|\
         chunked:G|cachesweep:G — same grammar as training; vertical streams each \
         layer once per token step",
        Some("vertical"),
    )
    .opt("io-depth", "async parameter-prefetch lookahead K (0 = synchronous)", Some("2"))
    .opt("ssds", "stripe the store across N throttled SSD devices", Some("1"))
    .opt(
        "cpu-cache-mb",
        "bounded DRAM cache in front of the SSD tier, MiB (0 = off). Serving uses \
         per-tenant admission: each tenant's adapter objects get an equal slice, \
         the shared base image is admitted unconditionally",
        Some("0"),
    )
    .opt("ssd-read-gbps", "simulated SSD read bandwidth (GB/s; 0 = unthrottled)", Some("0"))
    .opt("ssd-write-gbps", "simulated SSD write bandwidth (GB/s; 0 = unthrottled)", Some("0"))
    .opt("layers", "synthetic model: layer count", Some("8"))
    .opt("layer-kb", "synthetic model: f32 KiB per layer", Some("1024"))
    .opt("embed-kb", "synthetic model: f32 KiB of shared embeddings", Some("256"))
    .opt("vocab", "synthetic model: vocabulary size", Some("50257"))
    .opt("seed", "rng seed (provisioning, traffic, and token hashes)", Some("42"))
    .parse_from(args)?;

    let kind: ScheduleKind = cli.get("schedule").unwrap().parse()?;
    let tenants: u64 = cli.get_parsed::<u64>("tenants")?.max(1);
    let n_requests: usize = cli.get_parsed("requests")?;
    let new_tokens: usize = cli.get_parsed("tokens")?;
    let max_batch: usize = cli.get_parsed::<usize>("max-batch")?.max(1);
    let io_depth: usize = cli.get_parsed("io-depth")?;
    let ssds: usize = cli.get_parsed::<usize>("ssds")?.max(1);
    let cache_mb: u64 = cli.get_parsed("cpu-cache-mb")?;
    let seed: u64 = cli.get_parsed("seed")?;
    let r: f64 = cli.get_parsed("ssd-read-gbps")?;
    let w: f64 = cli.get_parsed("ssd-write-gbps")?;
    let read_bps = if r > 0.0 { r * 1e9 } else { f64::INFINITY };
    let write_bps = if w > 0.0 { w * 1e9 } else { f64::INFINITY };

    let model = ServeModel::synthetic(
        cli.get_parsed("layers")?,
        cli.get_parsed::<usize>("layer-kb")?.max(1) * 1024 / 4,
        cli.get_parsed::<usize>("embed-kb")?.max(1) * 1024 / 4,
        cli.get_parsed("vocab")?,
    );

    // store stack: (striped) SSD tier, optionally fronted by the DRAM cache
    // with the serve-side per-tenant admission bound
    let ssd_path = std::env::temp_dir().join(format!("gs_serve_{}", std::process::id()));
    let dev: Arc<dyn TensorStore> = if ssds > 1 {
        Arc::new(StripedStore::create(&ssd_path, ssds, read_bps, write_bps)?)
    } else {
        Arc::new(SsdStorage::create(&ssd_path, read_bps, write_bps)?)
    };
    let store: Arc<dyn TensorStore> = if cache_mb > 0 {
        Arc::new(CachedStore::with_admission(
            dev,
            cache_mb << 20,
            CacheAdmission::PerTenant { per_tenant_bytes: (cache_mb << 20) / tenants },
        ))
    } else {
        dev
    };

    let rep = provision(store.as_ref(), &model, tenants, seed)?;
    println!(
        "serving {} layers x {} KiB, {} tenants over one base image \
         (base {}, adapters {}/tenant), schedule={kind} io-depth={io_depth} \
         ssds={ssds} cpu-cache={cache_mb}MiB",
        model.n_layers,
        model.base_layer_bytes() / 1024,
        tenants,
        greedysnake::util::stats::fmt_bytes(rep.base_bytes as f64),
        greedysnake::util::stats::fmt_bytes(rep.adapter_bytes_per_tenant as f64),
    );

    let requests = synthetic_requests(tenants, n_requests, seed);
    let mut eng = ServeEngine::new(model, store, io_depth, seed);
    let t0 = std::time::Instant::now();
    let out = eng.serve(kind.policy().as_ref(), &requests, max_batch, new_tokens, None)?;
    let wall = t0.elapsed().as_secs_f64();
    let s = eng.stats();
    println!(
        "done: {} requests, {} tokens in {:.2}s ({:.0} tokens/s), \
         param loads {}, base/adapter/embed read {}/{}/{}",
        out.len(),
        s.tokens,
        wall,
        s.tokens as f64 / wall.max(1e-9),
        s.param_loads,
        greedysnake::util::stats::fmt_bytes(s.base_bytes_loaded as f64),
        greedysnake::util::stats::fmt_bytes(s.adapter_bytes_loaded as f64),
        greedysnake::util::stats::fmt_bytes(s.embed_bytes_loaded as f64),
    );
    println!(
        "io: prefetch hit/miss {}/{}, stall {:.2}s, store r/w {}/{}",
        s.prefetch_hits,
        s.prefetch_misses,
        s.stall_seconds,
        greedysnake::util::stats::fmt_bytes(s.store_bytes_read as f64),
        greedysnake::util::stats::fmt_bytes(s.store_bytes_written as f64),
    );
    if cache_mb > 0 {
        println!(
            "cpu-cache: hit/miss/evict {}/{}/{}",
            s.cache.total.hits, s.cache.total.misses, s.cache.total.evictions,
        );
        for (cat, c) in &s.cache.by_cat {
            println!("cpu-cache: {cat:?}: hit/miss/evict {}/{}/{}", c.hits, c.misses, c.evictions);
        }
    }
    Ok(())
}

fn cmd_simulate(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("greedysnake simulate", "discrete-event simulation of a paper config")
        .opt("model", "30b|65b|175b", Some("65b"))
        .opt("machine", "a5000|a100", Some("a100"))
        .opt("gpus", "number of GPUs", Some("1"))
        .opt("micro-batch", "micro-batch size B", Some("2"))
        .opt("m", "micro-batch count M", Some("16"))
        .opt(
            "system",
            "greedysnake|zero-infinity|teraio|ratel|chunked:G|cachesweep:G",
            Some("greedysnake"),
        )
        .opt("alpha", "delay ratio (greedysnake)", Some("0.3"))
        .opt(
            "io-depth",
            "mirror of the runtime's --io-depth lookahead in the event sim: \
             a parameter load may run at most K visits ahead of compute \
             (0 = synchronous loads; 'unbounded' = the pre-pipeline sim)",
            Some("unbounded"),
        )
        .opt(
            "workers",
            "data-parallel workers W: per-worker compute resources over shared SSDs, \
             ring all-reduce + rank-0 optimizer (M is the GLOBAL micro-batch count, \
             split contiguously across workers)",
            Some("1"),
        )
        .opt("ssds", "modeled SSDs shared by the workers (round-robin)", Some("1"))
        .opt(
            "cpu-cache-mb",
            "modeled CPU-DRAM cache tier, MiB: when the schedule's SSD-resident \
             working set fits, its traffic is served from DRAM (the runtime \
             --cpu-cache-mb mirror; fit-or-nothing LRU law, see traffic::Workload)",
            Some("0"),
        )
        .opt(
            "precision",
            "model the runtime storage precision: f32 (strict, 2x the paper's \
             half-precision wire widths for params/ckpts) or mixed:f16|mixed:bf16 \
             (paper widths + requantized gradient stream). Omit to model the \
             paper's analytic wire widths unchanged",
            None,
        )
        .flag(
            "shard-optimizer",
            "ZeRO-style sharded optimizer in the dist sim: reduce-scatter legs on the \
             inter-GPU link, per-rank 1/W CPU update + optimizer SSD round trip, \
             parameter all-gather before the next forward",
        )
        .flag(
            "param-persist",
            "model persistence-sharded master parameters: every update reads the full \
             parameter bytes from SSD before Adam and writes them back after \
             (split 1/W per rank under --shard-optimizer), mirroring the runtime's \
             --param-persist store traffic",
        )
        .parse_from(args)?;
    let sp = SystemParams::new(
        machine_by_name(&cli.get("machine").unwrap())?.with_gpus(cli.get_parsed("gpus")?),
        model_by_name(&cli.get("model").unwrap())?,
        cli.get_parsed("micro-batch")?,
        SEQ_LEN,
    );
    let m: u64 = cli.get_parsed("m")?;
    let schedule = match cli.get("system").unwrap().as_str() {
        "teraio" => Schedule::TeraIo,
        "ratel" => Schedule::Ratel,
        // everything else goes through the runtime schedule grammar
        // (vertical|greedysnake | horizontal|zero-infinity | chunked:G |
        // cachesweep:G), so every alias of the same schedule takes the
        // same path
        other => {
            let kind: ScheduleKind = other
                .parse()
                .map_err(|e| anyhow::anyhow!("unknown system '{other}': {e}"))?;
            let alpha: f64 = cli.get_parsed("alpha")?;
            let alpha = if kind.supports_delay() { alpha } else { 0.0 };
            // LP solve needs a strictly positive delay ratio (fig10 style)
            let x = lp::solve_config(&sp, m, alpha.max(0.01))
                .map(|r| r.ratios)
                .unwrap_or(greedysnake::perfmodel::StorageRatios::ALL_SSD);
            kind.sim_schedule(alpha, x)
        }
    };
    let io_depth = parse_io_depth(&cli.get("io-depth").unwrap())?;
    let workers: usize = cli.get_parsed("workers")?;
    let ssds: usize = cli.get_parsed("ssds")?;
    let cache_bytes = (cli.get_parsed::<u64>("cpu-cache-mb")?) << 20;
    let shard_optimizer = cli.has_flag("shard-optimizer");
    let param_persist = cli.has_flag("param-persist");
    // only an explicit --precision changes the modeled byte widths; the
    // default keeps the sim's historical paper-width outputs bit-identical
    let byte_mults = match cli.get("precision") {
        Some(s) => ByteMults::for_precision(Precision::parse(&s)?),
        None => ByteMults::ONE,
    };
    let r = if workers > 1 || ssds > 1 || shard_optimizer || param_persist {
        // the dist sim models each GPU as an explicit worker with its own
        // resources (tokens are global-M, SSD bandwidth per modeled device);
        // simulate_io instead folds n_gpus into its rates — mixing the two
        // normalizations would make the numbers incomparable
        if sp.node.n_gpus != 1 {
            bail!(
                "--workers/--ssds model the GPUs explicitly; use --gpus 1 (got {})",
                sp.node.n_gpus
            );
        }
        let cfg = DistConfig {
            workers: workers.max(1),
            ssds: ssds.max(1),
            io_depth,
            shard_optimizer,
            param_persist,
            cache_bytes,
            byte_mults,
        };
        simulate_dist(&sp, m, schedule, cfg)
    } else {
        simulate_store_prec(&sp, m, schedule, io_depth, 1, cache_bytes, byte_mults)
    };
    println!(
        "{} {} x{} M={m} W={}: {:.1}s/iter, {:.0} tokens/s, {:.1} TFLOPs/GPU, GPU util {:.0}%",
        sp.model.name,
        sp.node.machine.name,
        sp.node.n_gpus,
        workers.max(1),
        r.t_iter,
        r.tokens_per_s,
        r.tflops_per_gpu,
        100.0 * r.gpu_util
    );
    Ok(())
}

fn cmd_search(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("greedysnake search", "Algorithm 1: LP-based configuration search")
        .opt("model", "30b|65b|175b", Some("65b"))
        .opt("machine", "a5000|a100", Some("a100"))
        .opt("gpus", "number of GPUs", Some("1"))
        .opt("micro-batch", "micro-batch size B", Some("2"))
        .parse_from(args)?;
    let sp = SystemParams::new(
        machine_by_name(&cli.get("machine").unwrap())?.with_gpus(cli.get_parsed("gpus")?),
        model_by_name(&cli.get("model").unwrap())?,
        cli.get_parsed("micro-batch")?,
        SEQ_LEN,
    );
    match lp::find_optimal_config(&sp) {
        Some(best) => {
            println!(
                "optimal: M={} alpha={:.2} ratios(ckpt/param/opt CPU) = {:.2}/{:.2}/{:.2}",
                best.m, best.alpha, best.ratios.ckpt_cpu, best.ratios.param_cpu,
                best.ratios.opt_cpu
            );
            println!(
                "  per-layer t_f={:.2}s t_b={:.2}s, iter {:.1}s, {:.0} tokens/s",
                best.t_f, best.t_b, best.t_iter, best.tokens_per_s
            );
        }
        None => println!("no feasible configuration"),
    }
    Ok(())
}

fn cmd_autotune(args: Vec<String>) -> Result<()> {
    use greedysnake::autotune::{autotune, default_knobs, eval_knobs, HwProfile};
    let cli = Cli::new(
        "greedysnake autotune",
        "sim-driven configuration search: seed with Algorithm 1, refine every CLI knob \
         (schedule, io-depth, ssds, cache, workers, sharding, precision, io-batch) by \
         coordinate descent with the NVMe-device-curve simulator as the objective, and \
         print the winning train flags plus the predicted roofline gap",
    )
    .opt(
        "hw",
        "hardware-profile JSON file: machine capacities/compute plus a 'devices' array \
         of NVMe curve objects (see the memory module docs). Omit to use --machine's \
         built-in profile",
        None,
    )
    .opt("machine", "a5000|a100 built-in profile when no --hw file is given", Some("a100"))
    .opt("model", "30b|65b|175b", Some("65b"))
    .opt("micro-batch", "micro-batch size B", Some("2"))
    .parse_from(args)?;

    let hw = match cli.get("hw") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading --hw '{path}': {e}"))?;
            HwProfile::parse(&text)?
        }
        None => HwProfile::builtin(machine_by_name(&cli.get("machine").unwrap())?),
    };
    let model = model_by_name(&cli.get("model").unwrap())?;
    let micro_batch: u64 = cli.get_parsed("micro-batch")?;

    let dev = &hw.devices[0];
    println!(
        "autotuning {} on {} ({} device(s): {:.1}/{:.1} GB/s r/w, QD knee {}, \
         sat {} KiB, mix {:.0}%, op latency {:.0}us)",
        model.name,
        hw.machine.name,
        hw.devices.len(),
        dev.read_bps / 1e9,
        dev.write_bps / 1e9,
        dev.qd_knee,
        dev.sat_bytes >> 10,
        100.0 * dev.mix_penalty,
        dev.op_latency_s * 1e6,
    );

    let def = default_knobs(&hw, model, micro_batch);
    let def_r = eval_knobs(&hw, model, micro_batch, &def);
    let tuned = autotune(&hw, model, micro_batch)?;
    println!(
        "hand-picked default: {:.1}s/iter, {:.0} tokens/s (schedule={} io-depth={})",
        def_r.t_iter, def_r.tokens_per_s, def.schedule, def.io_depth,
    );
    println!(
        "tuned:               {:.1}s/iter, {:.0} tokens/s ({:.2}x default, \
         {:.0}% of the roofline envelope's {:.0} tokens/s)",
        tuned.t_iter,
        tuned.tokens_per_s,
        tuned.tokens_per_s / def_r.tokens_per_s.max(1e-9),
        100.0 * tuned.roofline_frac(),
        tuned.ideal_tokens_per_s,
    );
    println!("greedysnake train {}", tuned.cli_flags());
    Ok(())
}

fn cmd_roofline(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("greedysnake roofline", "print the paper's roofline")
        .opt("model", "30b|65b|175b", Some("65b"))
        .opt("machine", "a5000|a100", Some("a100"))
        .opt("gpus", "number of GPUs", Some("1"))
        .opt("micro-batch", "micro-batch size B", Some("2"))
        .parse_from(args)?;
    let r = Roofline {
        node: machine_by_name(&cli.get("machine").unwrap())?.with_gpus(cli.get_parsed("gpus")?),
        model: model_by_name(&cli.get("model").unwrap())?,
        micro_batch: cli.get_parsed("micro-batch")?,
        seq_len: SEQ_LEN,
    };
    let mut t = Table::new(
        &format!("roofline {} on {}", r.model.name, r.node.machine.name),
        &["M", "io-bound tok/s", "compute-bound tok/s", "ideal tok/s"],
    );
    for m in [1u64, 2, 4, 8, 16, 32, 64, 128] {
        t.row(&[
            m.to_string(),
            format!("{:.0}", r.io_bound_tokens_per_s(m)),
            format!("{:.0}", r.compute_bound_tokens_per_s()),
            format!("{:.0}", r.ideal_tokens_per_s(m)),
        ]);
    }
    t.emit(None);
    println!("knee at M = {:.1}; opt-state I/O {:.0}s/iter", r.knee_m(), r.t_io_opt_states());
    Ok(())
}
