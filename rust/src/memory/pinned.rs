//! Pinned-buffer pool with the §5 dynamic-programming power-of-two packing.
//!
//! PyTorch pads each individual pinned-memory request to a power-of-two
//! size, wasting up to half the allocation. GreedySnake observes that its
//! coordinators allocate *many buffers of the same size* (one checkpoint
//! buffer per (layer, micro-batch), one parameter chunk per micro-batch, …)
//! and instead packs k same-size buffers into one power-of-two slab, using
//! dynamic programming to pick the slab multiset with minimum waste.
//!
//! `plan_packing(n, size)` reproduces that DP exactly; [`PinnedPool`] then
//! hands out sub-slices of the planned slabs.

use std::sync::Mutex;

use anyhow::{bail, Result};

/// Round up to the next power of two (min 1).
pub fn pow2_ceil(x: u64) -> u64 {
    x.max(1).next_power_of_two()
}

/// One slab in a packing plan: `count` buffers packed into a `slab_bytes`
/// power-of-two allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slab {
    pub buffers: u64,
    pub slab_bytes: u64,
}

/// DP: pack `n` buffers of `size` bytes into power-of-two slabs minimizing
/// total allocated bytes. Returns the chosen slabs (grouped, ascending).
///
/// dp[i] = min over k in 1..=i of dp[i-k] + pow2_ceil(k * size).
pub fn plan_packing(n: u64, size: u64) -> Vec<Slab> {
    assert!(size > 0);
    if n == 0 {
        return vec![];
    }
    let n = n as usize;
    let mut dp = vec![u64::MAX; n + 1];
    let mut choice = vec![0usize; n + 1];
    dp[0] = 0;
    for i in 1..=n {
        for k in 1..=i {
            let cost = dp[i - k].saturating_add(pow2_ceil(k as u64 * size));
            if cost < dp[i] {
                dp[i] = cost;
                choice[i] = k;
            }
        }
    }
    // reconstruct
    let mut slabs: Vec<Slab> = Vec::new();
    let mut i = n;
    while i > 0 {
        let k = choice[i];
        slabs.push(Slab { buffers: k as u64, slab_bytes: pow2_ceil(k as u64 * size) });
        i -= k;
    }
    // group identical slabs together for readability/stable ordering
    slabs.sort_by_key(|s| (s.slab_bytes, s.buffers));
    slabs
}

/// Total allocated bytes for a plan.
pub fn plan_total(slabs: &[Slab]) -> u64 {
    slabs.iter().map(|s| s.slab_bytes).sum()
}

/// Naive PyTorch-style allocation: each buffer padded to a power of two.
pub fn naive_total(n: u64, size: u64) -> u64 {
    n * pow2_ceil(size)
}

/// A pool of same-size pinned buffers backed by the DP packing plan.
///
/// (On this CPU-only substrate "pinned" means page-aligned process memory;
/// what matters for the reproduction is the *waste accounting* and the
/// acquire/release lifecycle the coordinators depend on.)
pub struct PinnedPool {
    buf_size: usize,
    slabs: Vec<Box<[u8]>>,
    free: Mutex<Vec<(usize, usize)>>, // (slab index, offset)
    total_allocated: u64,
}

/// Handle to a leased buffer; release via [`PinnedPool::release`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    slab: usize,
    offset: usize,
}

impl PinnedPool {
    /// Build a pool of `n` buffers of `buf_size` bytes using the DP plan.
    pub fn new(n: u64, buf_size: usize) -> Self {
        let plan = plan_packing(n, buf_size as u64);
        let mut slabs = Vec::new();
        let mut free = Vec::new();
        for s in &plan {
            let slab_idx = slabs.len();
            slabs.push(vec![0u8; s.slab_bytes as usize].into_boxed_slice());
            for b in 0..s.buffers {
                free.push((slab_idx, b as usize * buf_size));
            }
        }
        PinnedPool {
            buf_size,
            slabs,
            free: Mutex::new(free),
            total_allocated: plan_total(&plan),
        }
    }

    pub fn buf_size(&self) -> usize {
        self.buf_size
    }

    pub fn total_allocated(&self) -> u64 {
        self.total_allocated
    }

    pub fn available(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Lease one buffer (fails when exhausted — coordinators size pools
    /// exactly, so exhaustion is a scheduling bug, not a retry condition).
    pub fn acquire(&self) -> Result<Lease> {
        match self.free.lock().unwrap().pop() {
            Some((slab, offset)) => Ok(Lease { slab, offset }),
            None => bail!("pinned pool exhausted (size {})", self.buf_size),
        }
    }

    pub fn release(&self, lease: Lease) {
        self.free.lock().unwrap().push((lease.slab, lease.offset));
    }

    /// Access a leased buffer. Unsafe-free: one mutable borrow at a time is
    /// the caller's responsibility at the *logical* level; physically we
    /// return a raw pointer wrapped in a slice each call.
    #[allow(clippy::mut_from_ref)]
    pub fn slice(&self, lease: Lease) -> &mut [u8] {
        // Each lease maps to a disjoint region; the pool hands out any region
        // at most once between acquire/release, so aliasing cannot occur as
        // long as callers don't clone Leases (enforced by convention; Lease
        // is Copy only for storage in coordinator tables).
        unsafe {
            let base = self.slabs[lease.slab].as_ptr() as *mut u8;
            std::slice::from_raw_parts_mut(base.add(lease.offset), self.buf_size)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_ceil_values() {
        assert_eq!(pow2_ceil(0), 1);
        assert_eq!(pow2_ceil(1), 1);
        assert_eq!(pow2_ceil(3), 4);
        assert_eq!(pow2_ceil(4), 4);
        assert_eq!(pow2_ceil(5), 8);
        assert_eq!(pow2_ceil(1025), 2048);
    }

    #[test]
    fn dp_beats_or_ties_naive_always() {
        for n in 1..=32u64 {
            for size in [1u64, 3, 100, 768, 1000, 4096, 5000] {
                let plan = plan_packing(n, size);
                assert_eq!(plan.iter().map(|s| s.buffers).sum::<u64>(), n);
                let dp = plan_total(&plan);
                assert!(dp <= naive_total(n, size), "n={n} size={size}");
                assert!(dp >= n * size, "cannot allocate less than demanded");
                for s in &plan {
                    assert!(s.slab_bytes.is_power_of_two());
                    assert!(s.slab_bytes >= s.buffers * size);
                }
            }
        }
    }

    #[test]
    fn dp_finds_tight_packing() {
        // 3 buffers of 1000B: naive = 3*1024 = 3072; DP can pack 2 in 2048
        // (waste 48) + 1 in 1024 → 3072, or 3 in 4096 (waste 1096) → 4096,
        // or find that pairs tie. For size 600: naive 3*1024=3072;
        // DP: 3*600=1800 → one 2048 slab. Strictly better.
        let plan = plan_packing(3, 600);
        assert_eq!(plan_total(&plan), 2048);
        assert_eq!(naive_total(3, 600), 3072);
    }

    #[test]
    fn exact_power_of_two_sizes_have_zero_waste() {
        let plan = plan_packing(8, 1024);
        assert_eq!(plan_total(&plan), 8 * 1024);
    }

    #[test]
    fn pool_acquire_release_cycle() {
        let pool = PinnedPool::new(4, 600);
        assert_eq!(pool.available(), 4);
        let l1 = pool.acquire().unwrap();
        let l2 = pool.acquire().unwrap();
        assert_eq!(pool.available(), 2);
        pool.slice(l1)[0] = 7;
        pool.slice(l2)[0] = 9;
        assert_eq!(pool.slice(l1)[0], 7); // disjoint regions
        pool.release(l1);
        pool.release(l2);
        assert_eq!(pool.available(), 4);
    }

    #[test]
    fn pool_exhaustion_errors() {
        let pool = PinnedPool::new(1, 64);
        let _l = pool.acquire().unwrap();
        assert!(pool.acquire().is_err());
    }

    #[test]
    fn pool_total_matches_plan() {
        let pool = PinnedPool::new(3, 600);
        assert_eq!(pool.total_allocated(), 2048);
    }

    #[test]
    fn leases_are_disjoint() {
        let pool = PinnedPool::new(8, 128);
        let leases: Vec<_> = (0..8).map(|_| pool.acquire().unwrap()).collect();
        for (i, l) in leases.iter().enumerate() {
            pool.slice(*l).fill(i as u8);
        }
        for (i, l) in leases.iter().enumerate() {
            assert!(pool.slice(*l).iter().all(|&b| b == i as u8));
        }
    }
}
