//! Three-tier memory hierarchy substrate: GPU / CPU capacity-accounted
//! tiers, a bandwidth-throttled file-backed SSD (the NVMe stand-in — see
//! DESIGN.md §Substitutions), the pluggable [`store::TensorStore`] object
//! tier the coordinators do all their I/O through (single SSD, striped
//! multi-SSD, DRAM-cached, or the multi-path [`store::PlannedStore`]
//! planner — backend-bit-identical by contract), the
//! [`store::JournalStore`] write-behind undo journal giving any backend
//! epoch-grained crash consistency (`commit_epoch`/`recover`), the
//! [`codec`] mixed-precision storage layer that encodes objects per
//! [`tier::Category`] (two-tier equivalence: bit-identity at f32,
//! tolerance-pinned at f16/bf16 — see `store.rs`), and the §5 pinned-buffer
//! pool with the dynamic-programming power-of-two packing.
//!
//! # The NVMe device model
//!
//! The SSD tier's timing comes from a [`DeviceProfile`] enforced by a
//! [`DeviceThrottle`] (see [`throttle`]). A profile shapes the flat peak
//! bandwidth pair with four effects, all disabled in the degenerate
//! [`DeviceProfile::flat`] form (which is bit- and timing-identical to the
//! pre-profile token-bucket [`Throttle`] pair):
//!
//! * **QD ramp** — delivered bandwidth × `min(1, QD / qd_knee)`, QD sampled
//!   from the transfers actually outstanding on the device;
//! * **size ramp** — × `min(1, request_bytes / sat_bytes)` (`sat_bytes` is
//!   the saturating request size; 0 disables);
//! * **mix penalty** — × `(1 − mix_penalty)` while the other direction has
//!   traffic in flight;
//! * **latency floor** — every submission pays `op_latency_s` up front,
//!   unless it coalesces into an open `--io-batch` submission window
//!   ([`BatchConfig`]): concurrent sub-`sat_bytes` submissions that arrive
//!   while the device is busy join one ring submission (≤ `max_ops` ops /
//!   `max_bytes` bytes) and only the window's first op pays the floor.
//!
//! Only *timing* depends on the profile and the batch window — stored
//! bytes, object layout, and every byte counter are invariant, so flat and
//! profiled runs are bit-identical (the batching determinism contract).
//!
//! # Hardware-profile JSON
//!
//! `greedysnake autotune --hw FILE` and `--nvme-profile FILE` read device
//! curves from JSON. A device object (parsed by
//! [`DeviceProfile::from_json`]) looks like:
//!
//! ```json
//! {"read_gbps": 3.2, "write_gbps": 2.8, "qd_knee": 8,
//!  "sat_kib": 256, "mix_penalty": 0.15, "op_latency_us": 80}
//! ```
//!
//! `read_gbps`/`write_gbps` are required; the curve fields default to the
//! flat profile. The full hardware profile (see [`crate::autotune`]) wraps
//! a machine description plus a `"devices"` array of these objects:
//!
//! ```json
//! {"gpu_mem_gib": 24, "cpu_mem_gib": 128, "pcie_gbps": 16,
//!  "link_gbps": 56, "gpu_tflops": 70, "cpu_adam_gelems": 2.0,
//!  "devices": [{"read_gbps": 3.2, "write_gbps": 2.8, "qd_knee": 8,
//!               "sat_kib": 256, "op_latency_us": 80}]}
//! ```

pub mod codec;
pub mod pinned;
pub mod ssd;
pub mod store;
pub mod throttle;
pub mod tier;

pub use codec::{Codec, CodecStore, Precision, PrecisionPolicy};
pub use pinned::PinnedPool;
pub use ssd::SsdStorage;
pub use store::{
    category_of, path_weight, plan_shares, tenant_of, CacheAdmission, CacheCounters, CacheStats,
    CachedStore, JournalStore, PathId, PathStats, PlannedConfig, PlannedStore, SsdBackend,
    StripedStore, TensorStore, TransferPlan,
};
pub use throttle::{BatchConfig, DeviceProfile, DeviceThrottle, Throttle};
pub use tier::{Category, Tier};
