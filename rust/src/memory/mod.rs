//! Three-tier memory hierarchy substrate: GPU / CPU capacity-accounted
//! tiers, a bandwidth-throttled file-backed SSD (the NVMe stand-in — see
//! DESIGN.md §Substitutions), and the §5 pinned-buffer pool with the
//! dynamic-programming power-of-two packing.

pub mod pinned;
pub mod ssd;
pub mod throttle;
pub mod tier;

pub use pinned::PinnedPool;
pub use ssd::SsdStorage;
pub use throttle::Throttle;
pub use tier::Tier;
