//! Three-tier memory hierarchy substrate: GPU / CPU capacity-accounted
//! tiers, a bandwidth-throttled file-backed SSD (the NVMe stand-in — see
//! DESIGN.md §Substitutions), the pluggable [`store::TensorStore`] object
//! tier the coordinators do all their I/O through (single SSD, striped
//! multi-SSD, DRAM-cached, or the multi-path [`store::PlannedStore`]
//! planner — backend-bit-identical by contract), the
//! [`store::JournalStore`] write-behind undo journal giving any backend
//! epoch-grained crash consistency (`commit_epoch`/`recover`), the
//! [`codec`] mixed-precision storage layer that encodes objects per
//! [`tier::Category`] (two-tier equivalence: bit-identity at f32,
//! tolerance-pinned at f16/bf16 — see `store.rs`), and the §5 pinned-buffer
//! pool with the dynamic-programming power-of-two packing.

pub mod codec;
pub mod pinned;
pub mod ssd;
pub mod store;
pub mod throttle;
pub mod tier;

pub use codec::{Codec, CodecStore, Precision, PrecisionPolicy};
pub use pinned::PinnedPool;
pub use ssd::SsdStorage;
pub use store::{
    category_of, path_weight, plan_shares, tenant_of, CacheAdmission, CacheCounters, CacheStats,
    CachedStore, JournalStore, PathId, PathStats, PlannedConfig, PlannedStore, SsdBackend,
    StripedStore, TensorStore, TransferPlan,
};
pub use throttle::Throttle;
pub use tier::{Category, Tier};
