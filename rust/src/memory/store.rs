//! Pluggable tensor-object storage — the extension point behind every
//! coordinator I/O path.
//!
//! The trainer's moments and checkpoints used to flow through one
//! hard-wired `Arc<SsdStorage>`; [`TensorStore`] abstracts that tier so the
//! storage backend is a runtime choice:
//!
//! * [`SsdBackend`] — the existing single file-backed throttled store
//!   ([`SsdStorage`]), byte-for-byte the historical path;
//! * [`StripedStore`] — stripes each object round-robin across N
//!   independent [`SsdStorage`] devices, each with its OWN throttle, and
//!   moves the per-device shares on parallel threads — one object's read or
//!   write proceeds over N paths at once (`--ssds N` on `greedysnake
//!   train`, the runtime twin of the sim's `--ssds` flag);
//! * [`CachedStore`] — a bounded CPU-DRAM write-back cache in front of any
//!   inner store (`--cpu-cache-mb`), capacity-accounted against a
//!   [`Tier`], LRU eviction with dirty write-back, and per-[`Category`]
//!   hit/miss/evict counters ([`CacheStats`]) surfaced through
//!   `StepStats`/`RunLog`;
//! * [`PlannedStore`] — the MLP-Offload-style multi-path planner
//!   (`--planned`): instead of nesting cache-then-stripe layers, each
//!   object gets a per-object **transfer plan** that splits its bytes into
//!   contiguous extents served *concurrently* from up to three tiers.
//!
//! ## Three-tier path model (the planned store)
//!
//! [`PlannedStore`] treats storage as a flat set of concurrent *paths*
//! rather than a hierarchy, in fixed plan order:
//!
//! 1. **DRAM** — a capacity-bounded in-memory extent (accounted against a
//!    [`Tier`], modeled bandwidth [`PlannedStore::DRAM_BPS`] by default);
//! 2. **NVMe devices** — one [`SsdStorage`] per device, each with its OWN
//!    heterogeneous read/write throttle (`--ssds N` rates);
//! 3. **Remote** — an optional simulated remote/object-store tier
//!    (`--remote-mbps`), slow but capacity-free.
//!
//! A plan splits an object's bytes proportionally to per-path weights
//! derived from path bandwidth ([`path_weight`], via [`plan_shares`]),
//! capping the DRAM extent at the tier's free capacity and spilling the
//! overflow to the remaining paths. Get/put move every extent on its own
//! thread behind a per-path in-flight gate, so aggregate throughput
//! approaches Σ path rates until one path saturates (the multi-path law
//! `sim::planned_bandwidth` mirrors and the fig16 bench pins). Per-tier
//! byte counters ([`PathStats`]) attribute every moved byte to its path;
//! the trait-level `bytes_read`/`bytes_written` report whole-object bytes
//! so the planned store is counter-identical to [`SsdBackend`].
//!
//! **Plan-equivalence contract:** a plan changes only where an object's
//! bytes live and how fast they move — never the bytes. For every plan
//! shape (any NVMe count × cache on/off × remote on/off) the planned
//! store is content/len/presence-identical to [`SsdBackend`] over any
//! operation sequence, and per-path bytes conserve exactly
//! (Σ path bytes == object bytes) — both pinned by
//! `prop_planned_store_matches_ssd_backend` in `rust/tests/proptests.rs`.
//!
//! Two layers sit *above* the backends. [`JournalStore`] (`--journal`)
//! wraps any backend with epoch-grained crash consistency: an undo log
//! (`gsj_undo_*` + `gsj_manifest`) captures each key's pre-image on its
//! first write per epoch, `commit_epoch` seals the epoch behind a durable
//! `gsj_epoch` marker, and `recover` rolls any in-flight epoch back to
//! the last committed boundary — see its type docs for the exact object
//! format and ordering protocol. [`super::codec::CodecStore`] applies a
//! [`super::codec::PrecisionPolicy`] at the typed `put_f32` / `get_f32`
//! boundary (`--precision {f32,mixed:f16,mixed:bf16}`), so every layer
//! below it — the journal's undo records included — sees *encoded* bytes.
//! Stack order is `CodecStore? → JournalStore? → CachedStore? → backend`.
//!
//! ## Two-tier equivalence contract
//!
//! A backend only changes **where bytes live and how fast they move** —
//! never the bytes. Every backend must return exactly the data last `put`
//! under a key. What those bytes *mean* is set by the precision policy,
//! which splits the determinism contract in two explicit tiers:
//!
//! 1. **Bit-identity at `--precision f32`** (the default): the codec layer
//!    is not even in the stack, so training through any backend is
//!    bit-identical to the seed `SsdBackend` path — same losses, gradient
//!    norms, and Σx² parameter/moment digests (pinned by the store-backend
//!    axis of the gradient-equivalence suite in
//!    `rust/tests/integration.rs` and the striped-vs-single property test
//!    in `rust/tests/proptests.rs`).
//! 2. **Tolerance-pinned at `mixed:f16` / `mixed:bf16`**: checkpoints and
//!    gradients are deliberately rounded to half precision, so runs are
//!    only required to match the strict-f32 baseline within per-codec
//!    bounds (losses/grad-norms within a relative tolerance, Σx² digests
//!    within the codec's ULP budget — relative rounding ≤ 2⁻¹¹ for f16,
//!    ≤ 2⁻⁸ for bf16). The mixed run itself is still deterministic:
//!    repeating it reproduces bit-identical results; only the cross-
//!    precision comparison is toleranced. Pinned by the precision axis of
//!    the integration suite (`GS_TEST_PRECISION`).
//!
//! Byte *accounting* may legitimately differ only for [`CachedStore`],
//! whose `bytes_read`/`bytes_written` report the traffic that actually
//! reached the backing store — cache absorption is the measured quantity.
//! [`PlannedStore`] keeps whole-object trait counters and moves the
//! per-tier attribution into [`PathStats`]. All counters below the codec
//! are stated in encoded bytes.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use anyhow::{anyhow, bail, ensure, Result};

use super::ssd::SsdStorage;
use super::throttle::Throttle;
use super::tier::{Category, Tier};

/// The pluggable storage tier every coordinator I/O path goes through.
///
/// Implementations must be internally synchronized (`&self` methods are
/// called concurrently from the I/O lanes and the optimizer worker) and
/// must never return torn bytes for racing same-key operations.
pub trait TensorStore: Send + Sync {
    /// Write `data` under `key`, replacing any previous object.
    fn put(&self, key: &str, data: &[u8]) -> Result<()>;

    /// Read the object at `key` into `out` (resized to fit).
    fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()>;

    /// Remove an object if present; returns whether it existed.
    fn delete(&self, key: &str) -> bool;

    fn contains(&self, key: &str) -> bool;

    /// Stored byte length of `key`, if present.
    fn len_of(&self, key: &str) -> Option<u64>;

    /// Total bytes moved through the backing read path.
    fn bytes_read(&self) -> u64;

    /// Total bytes moved through the backing write path.
    fn bytes_written(&self) -> u64;

    /// Backing-storage high-water mark (summed across devices).
    fn footprint(&self) -> u64;

    /// Cache-tier counters; all-zero for backends without a cache.
    fn cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }

    // Crash-consistency hooks (see [`JournalStore`]). -----------------------
    //
    // Plain backends are implicitly "always committed": every put is final,
    // so the epoch is a constant 0 and commit/recover are no-ops. Only the
    // journal layer (and wrappers above it, which must forward) override
    // these.

    /// Seal the current epoch: all writes since the previous commit become
    /// the recovery point. No-op for non-journaling stores.
    fn commit_epoch(&self) -> Result<()> {
        Ok(())
    }

    /// Roll the store back to the last committed epoch, undoing every
    /// uncommitted write/delete. No-op for non-journaling stores.
    fn recover(&self) -> Result<()> {
        Ok(())
    }

    /// Index of the last committed epoch (0 before any commit).
    fn committed_epoch(&self) -> u64 {
        0
    }

    // Typed helpers for the f32 tensors the trainer stores. ----------------

    fn put_f32(&self, key: &str, data: &[f32]) -> Result<()> {
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        self.put(key, bytes)
    }

    /// Read an f32 object; errors (instead of truncating) if the stored
    /// byte length is not a multiple of 4 — a corrupt or mistyped object.
    ///
    /// The raw staging buffer is a per-thread scratch reused across calls
    /// (taken out of the thread-local for the duration of the read, so a
    /// re-entrant call simply allocates afresh): `get_f32` sits on the
    /// prefetch hot path, where a fresh `Vec` per call was measurable
    /// allocator churn (see `micro_hotpath.rs`, `ssd/get_f32_reuse`).
    fn get_f32(&self, key: &str, out: &mut Vec<f32>) -> Result<()> {
        let mut raw = GET_F32_SCRATCH.with(|c| std::mem::take(&mut *c.borrow_mut()));
        let res = (|| {
            self.get(key, &mut raw)?;
            ensure!(
                raw.len() % 4 == 0,
                "object '{key}' not f32-aligned ({} bytes)",
                raw.len()
            );
            out.resize(raw.len() / 4, 0.0);
            unsafe {
                std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, raw.len());
            }
            Ok(())
        })();
        GET_F32_SCRATCH.with(|c| *c.borrow_mut() = raw);
        res
    }
}

thread_local! {
    /// Per-thread staging buffer backing the default [`TensorStore::get_f32`]
    /// byte→f32 conversion (and nothing else).
    static GET_F32_SCRATCH: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// The historical single-device backend: [`SsdStorage`] IS the store.
pub type SsdBackend = SsdStorage;

impl TensorStore for SsdStorage {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        SsdStorage::put(self, key, data)
    }

    fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        SsdStorage::get(self, key, out)
    }

    fn delete(&self, key: &str) -> bool {
        SsdStorage::delete(self, key)
    }

    fn contains(&self, key: &str) -> bool {
        SsdStorage::contains(self, key)
    }

    fn len_of(&self, key: &str) -> Option<u64> {
        SsdStorage::len_of(self, key)
    }

    fn bytes_read(&self) -> u64 {
        SsdStorage::bytes_read(self)
    }

    fn bytes_written(&self) -> u64 {
        SsdStorage::bytes_written(self)
    }

    fn footprint(&self) -> u64 {
        SsdStorage::footprint(self)
    }
}

// ---------------------------------------------------------------------------
// StripedStore
// ---------------------------------------------------------------------------

/// Multi-SSD striping: each object splits into fixed-size chunks assigned
/// round-robin across N independent [`SsdStorage`] devices (device `d` holds
/// chunks `d, d+N, d+2N, …` concatenated as one per-device sub-object), and
/// the per-device shares transfer on parallel threads — every device's
/// throttle runs at once, so a single object's read or write completes in
/// ~1/N the wall time of the single-device path.
///
/// The chunk size is `min(stripe, ⌈len/N⌉)`, so even objects smaller than
/// one stripe still spread over all devices (parallel paths for every
/// object, balanced shares). Same-key operations serialize on a per-key
/// lock so a racing overwrite can never hand a reader shares from two
/// different generations (the cross-device analog of `SsdStorage`'s
/// generation-validated reads); different keys proceed fully in parallel.
pub struct StripedStore {
    devices: Vec<SsdStorage>,
    stripe: u64,
    /// Per-key RwLock: writers (put/delete) exclusive, readers shared.
    locks: Mutex<HashMap<String, Arc<RwLock<()>>>>,
}

impl StripedStore {
    /// Default stripe-chunk size, bytes.
    pub const DEFAULT_STRIPE: u64 = 64 * 1024;

    /// Objects below this size move their shares sequentially: a thread
    /// spawn costs tens of microseconds, which dominates a sub-32 KiB
    /// transfer even at throttled rates — parallelism only pays on the
    /// large tensors that carry the byte volume. Layout is unaffected.
    const PARALLEL_MIN: usize = 32 * 1024;

    /// Create `devices` backing files `{base}.d{i}`, each throttled at the
    /// FULL per-device rates (independent paths — aggregate bandwidth
    /// scales with the device count, which is the point of striping).
    pub fn create<P: AsRef<Path>>(
        base: P,
        devices: usize,
        read_bps: f64,
        write_bps: f64,
    ) -> Result<Self> {
        Self::with_stripe(base, devices, read_bps, write_bps, Self::DEFAULT_STRIPE)
    }

    pub fn with_stripe<P: AsRef<Path>>(
        base: P,
        devices: usize,
        read_bps: f64,
        write_bps: f64,
        stripe: u64,
    ) -> Result<Self> {
        Self::create_profiled(
            base,
            devices,
            crate::memory::DeviceProfile::flat(read_bps, write_bps),
            None,
            stripe,
        )
    }

    /// [`StripedStore::with_stripe`] with a full device model: every device
    /// gets the same [`DeviceProfile`](crate::memory::DeviceProfile)
    /// (QD/size curves, latency floor) and the same optional `--io-batch`
    /// submission window. A flat profile without batching is exactly
    /// `with_stripe`.
    pub fn create_profiled<P: AsRef<Path>>(
        base: P,
        devices: usize,
        profile: crate::memory::DeviceProfile,
        batch: Option<crate::memory::BatchConfig>,
        stripe: u64,
    ) -> Result<Self> {
        ensure!(devices >= 1, "striped store needs at least one device");
        ensure!(stripe >= 1, "stripe chunk must be at least one byte");
        let devices = (0..devices)
            .map(|i| {
                let path = format!("{}.d{i}", base.as_ref().display());
                SsdStorage::with_profile(path, profile, batch)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(StripedStore { devices, stripe, locks: Mutex::new(HashMap::new()) })
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Per-device `(bytes_read, bytes_written)` counters, in device order
    /// — the attribution the cross-backend flush tests pin.
    pub fn per_device_bytes(&self) -> Vec<(u64, u64)> {
        self.devices.iter().map(|d| (d.bytes_read(), d.bytes_written())).collect()
    }

    fn key_lock(&self, key: &str) -> Arc<RwLock<()>> {
        self.locks
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(RwLock::new(())))
            .clone()
    }

    /// Chunk size for an object of `len` bytes: capped at ⌈len/N⌉ so every
    /// device participates, floored at 1.
    fn chunk_size(&self, len: u64) -> u64 {
        let n = self.devices.len() as u64;
        len.div_ceil(n).min(self.stripe).max(1)
    }
}

impl TensorStore for StripedStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let lock = self.key_lock(key);
        let _g = lock.write().unwrap();
        let n = self.devices.len();
        if n == 1 {
            return self.devices[0].put(key, data);
        }
        let chunk = self.chunk_size(data.len() as u64) as usize;
        let mut shares: Vec<Vec<u8>> = vec![Vec::new(); n];
        let mut j = 0usize;
        let mut off = 0usize;
        while off < data.len() {
            let end = (off + chunk).min(data.len());
            shares[j % n].extend_from_slice(&data[off..end]);
            j += 1;
            off = end;
        }
        // every device gets its (possibly empty) share
        if data.len() < Self::PARALLEL_MIN {
            for (dev, share) in self.devices.iter().zip(shares.iter()) {
                dev.put(key, share)?;
            }
            return Ok(());
        }
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .devices
                .iter()
                .zip(shares.iter())
                .map(|(dev, share)| s.spawn(move || dev.put(key, share)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("striped put thread")).collect()
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        let lock = self.key_lock(key);
        let _g = lock.read().unwrap();
        let n = self.devices.len();
        if n == 1 {
            return self.devices[0].get(key, out);
        }
        // device 0's share (~len/N) sizes the transfer; small objects skip
        // the per-device threads (see PARALLEL_MIN)
        let small = self
            .devices[0]
            .len_of(key)
            .is_some_and(|l| (l as usize).saturating_mul(n) < Self::PARALLEL_MIN);
        let mut shares = Vec::with_capacity(n);
        if small {
            for dev in &self.devices {
                let mut buf = Vec::new();
                dev.get(key, &mut buf)?;
                shares.push(buf);
            }
        } else {
            let reads: Vec<Result<Vec<u8>>> = std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .devices
                    .iter()
                    .map(|dev| {
                        s.spawn(move || {
                            let mut buf = Vec::new();
                            dev.get(key, &mut buf).map(|_| buf)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("striped get thread")).collect()
            });
            for r in reads {
                shares.push(r?);
            }
        }
        // de-interleave: the chunk layout is a pure function of the total
        // length, so the shares reassemble deterministically
        let len: usize = shares.iter().map(|s| s.len()).sum();
        let chunk = self.chunk_size(len as u64) as usize;
        out.clear();
        out.reserve(len);
        let mut offsets = vec![0usize; n];
        let mut j = 0usize;
        let mut taken = 0usize;
        while taken < len {
            let take = chunk.min(len - taken);
            let d = j % n;
            ensure!(
                offsets[d] + take <= shares[d].len(),
                "striped object '{key}': device {d} share too short ({} of {} bytes)",
                shares[d].len(),
                offsets[d] + take
            );
            out.extend_from_slice(&shares[d][offsets[d]..offsets[d] + take]);
            offsets[d] += take;
            j += 1;
            taken += take;
        }
        for (d, off) in offsets.iter().enumerate() {
            ensure!(
                *off == shares[d].len(),
                "striped object '{key}': device {d} share has {} trailing bytes",
                shares[d].len() - off
            );
        }
        Ok(())
    }

    fn delete(&self, key: &str) -> bool {
        let lock = self.key_lock(key);
        let _g = lock.write().unwrap();
        let mut any = false;
        for dev in &self.devices {
            any |= dev.delete(key);
        }
        // The lock entry deliberately stays in the map: a racer that already
        // cloned its Arc must keep serializing against later ops on the same
        // key — removing it would let that racer run unserialized against a
        // fresh lock (torn cross-device reads). The map is bounded by the
        // distinct-key universe (moment keys + the reused ckpt key set).
        any
    }

    fn contains(&self, key: &str) -> bool {
        self.devices[0].contains(key)
    }

    fn len_of(&self, key: &str) -> Option<u64> {
        // every device holds a (possibly empty) share of every object
        self.devices[0].len_of(key)?;
        Some(self.devices.iter().map(|d| d.len_of(key).unwrap_or(0)).sum())
    }

    fn bytes_read(&self) -> u64 {
        self.devices.iter().map(|d| d.bytes_read()).sum()
    }

    fn bytes_written(&self) -> u64 {
        self.devices.iter().map(|d| d.bytes_written()).sum()
    }

    fn footprint(&self) -> u64 {
        self.devices.iter().map(|d| d.footprint()).sum()
    }
}

// ---------------------------------------------------------------------------
// CachedStore
// ---------------------------------------------------------------------------

/// Hit/miss/evict counts for one slice of the cache tier.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Cumulative cache-tier counters, total and per data [`Category`].
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub total: CacheCounters,
    pub by_cat: BTreeMap<Category, CacheCounters>,
}

impl CacheStats {
    fn hit(&mut self, cat: Category) {
        self.total.hits += 1;
        self.by_cat.entry(cat).or_default().hits += 1;
    }

    fn miss(&mut self, cat: Category) {
        self.total.misses += 1;
        self.by_cat.entry(cat).or_default().misses += 1;
    }

    fn evict(&mut self, cat: Category) {
        self.total.evictions += 1;
        self.by_cat.entry(cat).or_default().evictions += 1;
    }
}

/// The data [`Category`] a store key belongs to (keys are structured:
/// `opt_*` moment objects, `ilc_*` inter-layer checkpoints/gradients,
/// `param_*` persisted master parameters / `base_*` serve base images,
/// `adapter_*` per-tenant serve deltas). Shared by [`CachedStore`]'s
/// per-category counters and the [`super::codec::PrecisionPolicy`] codec
/// selection — note the codec maps Parameters/Adapters through the
/// `working` class (f32 under every policy), so classifying them here
/// changes stats attribution only, never stored bytes.
pub fn category_of(key: &str) -> Category {
    if key.starts_with("opt_") {
        Category::OptimizerStates
    } else if key.starts_with("ilc_") {
        Category::Checkpoints
    } else if key.starts_with("param_") || key.starts_with("base_") {
        Category::Parameters
    } else if key.starts_with("adapter_") {
        Category::Adapters
    } else {
        Category::Working
    }
}

/// The serving tenant a store key belongs to, parsed from the
/// `adapter_{tenant}_…` key structure; `None` for every shared object
/// (base image, training state). The [`CachedStore`] per-tenant admission
/// policy keys on this.
pub fn tenant_of(key: &str) -> Option<u64> {
    let rest = key.strip_prefix("adapter_")?;
    rest[..rest.find('_')?].parse().ok()
}

/// Cache-admission policy for [`CachedStore`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CacheAdmission {
    /// Every object is cacheable — the training default, bit-identical to
    /// the pre-admission cache.
    #[default]
    All,
    /// Multi-tenant serve policy: shared objects (the base image, anything
    /// un-tenanted) cache freely, while each tenant's `adapter_*` objects
    /// may hold at most `per_tenant_bytes` of DRAM — non-admitted traffic
    /// bypasses the cache (write-through / read-without-fill), so one noisy
    /// tenant cannot flush the shared base image every other tenant hits.
    PerTenant { per_tenant_bytes: u64 },
}

struct CacheEntry {
    data: Vec<u8>,
    /// Written since last backing-store sync (write-back on eviction).
    dirty: bool,
    cat: Category,
    /// Owning serve tenant ([`tenant_of`]); `None` for shared objects.
    tenant: Option<u64>,
    last_used: u64,
}

struct CacheState {
    map: HashMap<String, CacheEntry>,
    tick: u64,
    /// Bumped by every put/delete. A miss-fill snapshots this before its
    /// unlocked backing-store read and only publishes the bytes into the
    /// cache if nothing mutated in between — otherwise a racing put that
    /// was immediately LRU-evicted (or a racing delete) would be shadowed
    /// by a stale clean entry.
    mutations: u64,
    /// Resident cache bytes per serve tenant (the [`CacheAdmission`]
    /// budget's meter; shared objects are not counted).
    tenant_bytes: HashMap<u64, u64>,
    stats: CacheStats,
}

impl CacheState {
    /// Drop `e`'s bytes from the per-tenant meter (entry leaving the map).
    fn release_tenant(&mut self, e: &CacheEntry) {
        if let Some(t) = e.tenant {
            if let Some(b) = self.tenant_bytes.get_mut(&t) {
                *b = b.saturating_sub(e.data.len() as u64);
            }
        }
    }
}

/// Bounded CPU-DRAM write-back cache in front of any [`TensorStore`].
///
/// `put` lands in DRAM (dirty) and only reaches the backing store when the
/// LRU eviction needs the room; `get` serves hits from DRAM without
/// touching the backing store at all. Capacity is accounted against an
/// owned [`Tier`] (per-[`Category`] budgeting like the GPU/CPU tiers), and
/// objects larger than the whole cache write through. `bytes_read` /
/// `bytes_written` report the INNER store's counters — the SSD-visible
/// traffic the cache is supposed to absorb — so a fitting working set shows
/// up as those counters simply not growing.
pub struct CachedStore {
    inner: Arc<dyn TensorStore>,
    tier: Tier,
    admission: CacheAdmission,
    state: Mutex<CacheState>,
}

impl CachedStore {
    pub fn new(inner: Arc<dyn TensorStore>, capacity_bytes: u64) -> Self {
        Self::with_admission(inner, capacity_bytes, CacheAdmission::All)
    }

    /// Build the cache under an explicit [`CacheAdmission`] policy — the
    /// multi-tenant serve path's constructor; [`CachedStore::new`] keeps
    /// the admit-everything training default.
    pub fn with_admission(
        inner: Arc<dyn TensorStore>,
        capacity_bytes: u64,
        admission: CacheAdmission,
    ) -> Self {
        CachedStore {
            inner,
            tier: Tier::new("cpu-cache", capacity_bytes),
            admission,
            state: Mutex::new(CacheState {
                map: HashMap::new(),
                tick: 0,
                mutations: 0,
                tenant_bytes: HashMap::new(),
                stats: CacheStats::default(),
            }),
        }
    }

    /// Would caching `bytes` more for `tenant` stay inside the admission
    /// policy's budget? Shared objects (`tenant == None`) always admit.
    fn admit(&self, st: &CacheState, tenant: Option<u64>, bytes: u64) -> bool {
        match (self.admission, tenant) {
            (CacheAdmission::All, _) | (_, None) => true,
            (CacheAdmission::PerTenant { per_tenant_bytes }, Some(t)) => {
                st.tenant_bytes.get(&t).copied().unwrap_or(0) + bytes <= per_tenant_bytes
            }
        }
    }

    /// The capacity-accounting tier (budget + per-category usage).
    pub fn tier(&self) -> &Tier {
        &self.tier
    }

    /// Bytes currently resident in the DRAM cache.
    pub fn cached_bytes(&self) -> u64 {
        self.tier.used()
    }

    /// Write all dirty entries back to the inner store (entries stay cached
    /// clean). Training never needs this — reads go through the same cache
    /// — but it makes the backing store complete at a quiescent point.
    pub fn flush(&self) -> Result<()> {
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        for (k, e) in st.map.iter_mut() {
            if e.dirty {
                self.inner.put(k, &e.data)?;
                e.dirty = false;
            }
        }
        Ok(())
    }

    /// Evict LRU entries (writing dirty ones back) until `bytes` fit.
    /// Caller holds the state lock — the write-back deliberately happens
    /// under it (releasing mid-eviction would reopen the stale-read windows
    /// the mutation counter closes). The cost only bites in the sustained-
    /// eviction regime, where the fit-or-nothing law already says the cache
    /// is mis-sized and absorbing nothing.
    fn make_room(&self, st: &mut CacheState, bytes: u64) -> Result<()> {
        while self.tier.free_bytes() < bytes {
            let victim = st
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| (*k).clone());
            let Some(k) = victim else {
                bail!("cpu-cache: cannot make room for {bytes} bytes (cache empty)");
            };
            let e = st.map.remove(&k).expect("victim exists");
            self.tier.release(e.data.len() as u64, e.cat);
            st.release_tenant(&e);
            if e.dirty {
                self.inner.put(&k, &e.data)?;
            }
            st.stats.evict(e.cat);
        }
        Ok(())
    }
}

impl TensorStore for CachedStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let cat = category_of(key);
        let tenant = tenant_of(key);
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        st.mutations += 1;
        if let Some(old) = st.map.remove(key) {
            // superseded in place: the old bytes never need a write-back
            self.tier.release(old.data.len() as u64, old.cat);
            st.release_tenant(&old);
        }
        let bytes = data.len() as u64;
        if bytes > self.tier.capacity() || !self.admit(st, tenant, bytes) {
            // larger than the whole cache, or over the tenant's admission
            // budget: write through
            return self.inner.put(key, data);
        }
        self.make_room(st, bytes)?;
        self.tier.reserve(bytes, cat).expect("make_room freed capacity");
        if let Some(t) = tenant {
            *st.tenant_bytes.entry(t).or_default() += bytes;
        }
        st.tick += 1;
        let tick = st.tick;
        st.map.insert(
            key.to_string(),
            CacheEntry { data: data.to_vec(), dirty: true, cat, tenant, last_used: tick },
        );
        Ok(())
    }

    fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        let cat = category_of(key);
        let tenant = tenant_of(key);
        let mut0 = {
            let mut guard = self.state.lock().unwrap();
            let st = &mut *guard;
            st.tick += 1;
            let tick = st.tick;
            if let Some(e) = st.map.get_mut(key) {
                e.last_used = tick;
                out.clear();
                out.extend_from_slice(&e.data);
                st.stats.hit(cat);
                return Ok(());
            }
            st.stats.miss(cat);
            st.mutations
        };
        // miss: fill from the backing store outside the lock
        let mut buf = Vec::new();
        self.inner.get(key, &mut buf)?;
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        if let Some(e) = st.map.get(key) {
            // a racing put published a newer object while we read the
            // backing store; theirs wins
            out.clear();
            out.extend_from_slice(&e.data);
            return Ok(());
        }
        let bytes = buf.len() as u64;
        // publish into the cache only if no put/delete raced the unlocked
        // read (see CacheState::mutations) — a stale clean entry would
        // shadow the newer generation the racer left in the backing store —
        // and the admission policy allows the fill
        if st.mutations == mut0
            && bytes <= self.tier.capacity()
            && self.admit(st, tenant, bytes)
        {
            self.make_room(st, bytes)?;
            self.tier.reserve(bytes, cat).expect("make_room freed capacity");
            if let Some(t) = tenant {
                *st.tenant_bytes.entry(t).or_default() += bytes;
            }
            st.tick += 1;
            let tick = st.tick;
            st.map.insert(
                key.to_string(),
                CacheEntry { data: buf.clone(), dirty: false, cat, tenant, last_used: tick },
            );
        }
        out.clear();
        out.extend_from_slice(&buf);
        Ok(())
    }

    fn delete(&self, key: &str) -> bool {
        // the inner delete stays under the state lock so a concurrent
        // miss-fill cannot read the object between our mutation bump and
        // its disappearance, then resurrect it into the cache
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        st.mutations += 1;
        let cached = if let Some(e) = st.map.remove(key) {
            self.tier.release(e.data.len() as u64, e.cat);
            st.release_tenant(&e);
            true
        } else {
            false
        };
        let inner = self.inner.delete(key);
        cached || inner
    }

    fn contains(&self, key: &str) -> bool {
        self.state.lock().unwrap().map.contains_key(key) || self.inner.contains(key)
    }

    fn len_of(&self, key: &str) -> Option<u64> {
        if let Some(e) = self.state.lock().unwrap().map.get(key) {
            return Some(e.data.len() as u64);
        }
        self.inner.len_of(key)
    }

    fn bytes_read(&self) -> u64 {
        self.inner.bytes_read()
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn footprint(&self) -> u64 {
        self.inner.footprint()
    }

    fn cache_stats(&self) -> CacheStats {
        self.state.lock().unwrap().stats.clone()
    }
}

// ---------------------------------------------------------------------------
// JournalStore
// ---------------------------------------------------------------------------

/// What `recover` must do to roll one touched key back to the epoch
/// boundary.
enum Undo {
    /// The key existed at epoch start; its prior bytes are saved under
    /// `gsj_undo_{key}` — restore them.
    Prior,
    /// The key did not exist at epoch start — delete it.
    Absent,
}

struct JournalState {
    /// Last committed epoch (0 before any commit).
    committed: u64,
    /// Keys written or deleted in the in-flight epoch, with their undo
    /// action. `BTreeMap` so the manifest serializes deterministically.
    touched: BTreeMap<String, Undo>,
}

/// Write-behind undo journal: wraps any [`TensorStore`] with epoch-grained
/// crash consistency (`--journal`).
///
/// ## Journal object format (all live in the inner store)
///
/// * `gsj_epoch` — 8 bytes, little-endian u64: the last **committed**
///   epoch. Absent ⇒ epoch 0 (nothing committed yet).
/// * `gsj_undo_{key}` — the byte image `{key}` had at the start of the
///   in-flight epoch (written once, on the first touch of `{key}`).
/// * `gsj_manifest` — UTF-8 text, first line `epoch {N}` naming the
///   in-flight epoch, then one line per touched key in sorted order:
///   `U {key}` (undo bytes saved — restore on rollback) or `N {key}`
///   (new this epoch — delete on rollback). Rewritten on every first
///   touch, deleted on commit.
///
/// ## Protocol
///
/// The first `put`/`delete` of each key per epoch saves the key's prior
/// bytes (or records its absence) and re-serializes the manifest **before**
/// the destructive write proceeds, so at any instant the inner store holds
/// enough to reconstruct the last committed state. `commit_epoch` writes
/// the bumped `gsj_epoch` marker FIRST and only then deletes the undo
/// objects and manifest — a crash between the two leaves a stale manifest
/// whose epoch is ≤ the marker, which `recover` recognizes as committed
/// and merely cleans up. `recover` with a manifest *newer* than the marker
/// rolls every touched key back (restore `Prior` bytes, delete `Absent`
/// keys), leaving the store byte-identical to the last commit.
///
/// Keys with the `gsj_` prefix are the journal's own and bypass
/// journaling; everything else is protected. The `store:tear_put` fault
/// site simulates a crash mid-write by persisting only half the object
/// and failing — exactly the corruption `recover` must undo.
///
/// Stacking: [`super::codec::CodecStore`] sits *above* this layer (it
/// forwards the epoch methods), so undo records hold encoded at-rest
/// bytes and rollback restores them byte-exactly regardless of precision
/// policy. Cache layers sit *below*, so journal objects share the store's
/// normal write-absorption path ("durable" here means "reached the store
/// stack" — crashes are simulated by injected errors, not process death).
pub struct JournalStore {
    inner: Arc<dyn TensorStore>,
    state: Mutex<JournalState>,
    /// Scope qualifier for this store's fault-site names (test isolation;
    /// see [`crate::util::fault::scoped`]). Empty in production.
    fault_scope: String,
}

impl JournalStore {
    const EPOCH_KEY: &'static str = "gsj_epoch";
    const MANIFEST_KEY: &'static str = "gsj_manifest";

    fn undo_key(key: &str) -> String {
        format!("gsj_undo_{key}")
    }

    fn is_journal_key(key: &str) -> bool {
        key.starts_with("gsj_")
    }

    /// Wrap `inner`, adopting any committed epoch marker already present
    /// and rolling back any in-flight epoch left behind by a crash.
    pub fn new(inner: Arc<dyn TensorStore>) -> Result<Self> {
        let store = JournalStore {
            inner,
            state: Mutex::new(JournalState { committed: 0, touched: BTreeMap::new() }),
            fault_scope: String::new(),
        };
        store.recover()?;
        Ok(store)
    }

    /// Scope-qualify this store's fault-site names
    /// ([`crate::util::fault::scoped`]): a test arming
    /// `store:tear_put@{scope}` only tears puts through THIS store, not
    /// through every journal a parallel test happens to be writing.
    pub fn with_fault_scope(mut self, scope: &str) -> Self {
        self.fault_scope = scope.to_string();
        self
    }

    fn read_epoch(&self) -> Result<u64> {
        if !self.inner.contains(Self::EPOCH_KEY) {
            return Ok(0);
        }
        let mut raw = Vec::new();
        self.inner.get(Self::EPOCH_KEY, &mut raw)?;
        ensure!(
            raw.len() == 8,
            "journal: epoch marker is {} bytes, want 8",
            raw.len()
        );
        let mut le = [0u8; 8];
        le.copy_from_slice(&raw);
        Ok(u64::from_le_bytes(le))
    }

    /// Save `key`'s pre-image (or record its absence) on its first touch
    /// this epoch, and persist the updated manifest. Caller holds the
    /// state lock; the destructive write must not proceed before this
    /// returns.
    fn record_undo(&self, st: &mut JournalState, key: &str) -> Result<()> {
        if st.touched.contains_key(key) {
            return Ok(());
        }
        let undo = if self.inner.contains(key) {
            let mut prior = Vec::new();
            self.inner.get(key, &mut prior)?;
            self.inner.put(&Self::undo_key(key), &prior)?;
            Undo::Prior
        } else {
            Undo::Absent
        };
        st.touched.insert(key.to_string(), undo);
        self.write_manifest(st)
    }

    fn write_manifest(&self, st: &JournalState) -> Result<()> {
        let mut text = format!("epoch {}\n", st.committed + 1);
        for (k, u) in &st.touched {
            text.push_str(match u {
                Undo::Prior => "U ",
                Undo::Absent => "N ",
            });
            text.push_str(k);
            text.push('\n');
        }
        self.inner.put(Self::MANIFEST_KEY, text.as_bytes())
    }
}

impl TensorStore for JournalStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        if Self::is_journal_key(key) {
            return self.inner.put(key, data);
        }
        {
            let mut st = self.state.lock().unwrap();
            self.record_undo(&mut st, key)?;
        }
        if crate::util::fault::any_armed()
            && crate::util::fault::should_fail(&crate::util::fault::scoped(
                "store:tear_put",
                &self.fault_scope,
            ))
        {
            // simulated crash mid-write: half the object lands, then the
            // "process dies" (the caller sees an error). recover() must
            // restore the pre-image the lines above just saved.
            self.inner.put(key, &data[..data.len() / 2])?;
            bail!("injected fault: torn put of '{key}'");
        }
        self.inner.put(key, data)
    }

    fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        self.inner.get(key, out)
    }

    fn delete(&self, key: &str) -> bool {
        if !Self::is_journal_key(key) {
            let mut st = self.state.lock().unwrap();
            // a delete whose undo cannot be saved must not proceed — it
            // would be unrecoverable; the backing store failing here is
            // as fatal as it failing anywhere else
            self.record_undo(&mut st, key)
                .expect("journal: save undo record for delete");
        }
        self.inner.delete(key)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }

    fn len_of(&self, key: &str) -> Option<u64> {
        self.inner.len_of(key)
    }

    fn bytes_read(&self) -> u64 {
        self.inner.bytes_read()
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn footprint(&self) -> u64 {
        self.inner.footprint()
    }

    fn cache_stats(&self) -> CacheStats {
        self.inner.cache_stats()
    }

    fn commit_epoch(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let next = st.committed + 1;
        // ordering is the whole protocol: the epoch marker lands BEFORE
        // the undo set is discarded, so a crash between the two reads as
        // "committed, cleanup pending" — never as an in-flight epoch
        self.inner.put(Self::EPOCH_KEY, &next.to_le_bytes())?;
        let touched = std::mem::take(&mut st.touched);
        for (k, u) in touched {
            if matches!(u, Undo::Prior) {
                self.inner.delete(&Self::undo_key(&k));
            }
        }
        self.inner.delete(Self::MANIFEST_KEY);
        st.committed = next;
        Ok(())
    }

    fn recover(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        // the durable marker is the truth — an in-flight epoch never
        // bumped it
        st.committed = self.read_epoch()?;
        if self.inner.contains(Self::MANIFEST_KEY) {
            let mut raw = Vec::new();
            self.inner.get(Self::MANIFEST_KEY, &mut raw)?;
            let text = std::str::from_utf8(&raw)
                .map_err(|e| anyhow!("journal: manifest is not UTF-8: {e}"))?;
            let mut lines = text.lines();
            let header = lines.next().unwrap_or("");
            let epoch: u64 = header
                .strip_prefix("epoch ")
                .and_then(|n| n.parse().ok())
                .ok_or_else(|| anyhow!("journal: bad manifest header '{header}'"))?;
            let roll_back = epoch > st.committed;
            for line in lines {
                if let Some(key) = line.strip_prefix("U ") {
                    let ukey = Self::undo_key(key);
                    if roll_back {
                        let mut prior = Vec::new();
                        self.inner.get(&ukey, &mut prior)?;
                        self.inner.put(key, &prior)?;
                    }
                    self.inner.delete(&ukey);
                } else if let Some(key) = line.strip_prefix("N ") {
                    if roll_back {
                        self.inner.delete(key);
                    }
                } else {
                    bail!("journal: bad manifest line '{line}'");
                }
            }
            self.inner.delete(Self::MANIFEST_KEY);
        }
        st.touched.clear();
        Ok(())
    }

    fn committed_epoch(&self) -> u64 {
        self.state.lock().unwrap().committed
    }
}

// ---------------------------------------------------------------------------
// PlannedStore
// ---------------------------------------------------------------------------

/// One concurrent transfer path of a [`PlannedStore`] plan, in fixed plan
/// order: DRAM (when capacity > 0), each NVMe device, then the remote tier
/// (when enabled).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathId {
    Dram,
    Nvme(usize),
    Remote,
}

/// Per-tier byte counters of a [`PlannedStore`] — the plan-level
/// attribution underneath the whole-object trait counters. The `traffic`
/// closed forms (`planned_read_bytes`) predict these exactly.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PathStats {
    pub dram_read: u64,
    pub dram_written: u64,
    /// Per-NVMe-device counters, in device order.
    pub nvme_read: Vec<u64>,
    pub nvme_written: Vec<u64>,
    pub remote_read: u64,
    pub remote_written: u64,
}

impl PathStats {
    pub fn total_read(&self) -> u64 {
        self.dram_read + self.nvme_read.iter().sum::<u64>() + self.remote_read
    }

    pub fn total_written(&self) -> u64 {
        self.dram_written + self.nvme_written.iter().sum::<u64>() + self.remote_written
    }
}

/// Configuration of a [`PlannedStore`]: one `(read_bps, write_bps)` pair
/// per NVMe device (heterogeneous rates allowed), the DRAM-path capacity
/// (0 disables the path) and modeled bandwidth (≤ 0 picks
/// [`PlannedStore::DRAM_BPS`]), and the simulated remote tier's bandwidth
/// (≤ 0 disables the path).
#[derive(Clone, Debug)]
pub struct PlannedConfig {
    pub nvme: Vec<(f64, f64)>,
    pub dram_capacity: u64,
    pub dram_bps: f64,
    pub remote_bps: f64,
}

/// Relative plan weight of a path from its bandwidth: ~MB/s, floored at 1
/// so every configured path participates in every plan. Unthrottled paths
/// get a large constant weight (they can absorb any share instantly).
pub fn path_weight(bps: f64) -> u64 {
    if bps.is_infinite() {
        4096
    } else {
        ((bps / 1e6).round() as u64).max(1)
    }
}

/// Split `len` bytes into per-path shares proportional to `weights`
/// (floor division in u128); the remainder goes whole to the first
/// maximum-weight path, so Σ shares == `len` exactly. Pure function —
/// the `traffic` closed forms reuse it to predict runtime counters.
pub fn plan_shares(len: u64, weights: &[u64]) -> Vec<u64> {
    let total: u128 = weights.iter().map(|&w| w as u128).sum();
    assert!(
        len == 0 || total > 0,
        "plan_shares: {len} bytes over all-zero weights {weights:?}"
    );
    if total == 0 {
        return vec![0; weights.len()];
    }
    let mut shares: Vec<u64> = weights
        .iter()
        .map(|&w| ((len as u128 * w as u128) / total) as u64)
        .collect();
    let assigned: u64 = shares.iter().sum();
    let rem = len - assigned;
    if rem > 0 {
        let mut imax = 0usize;
        for (i, &w) in weights.iter().enumerate() {
            if w > weights[imax] {
                imax = i;
            }
        }
        shares[imax] += rem;
    }
    shares
}

/// Where one object's bytes live: contiguous byte extents in plan (path)
/// order, recorded at put time so reads reassemble deterministically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransferPlan {
    pub len: u64,
    /// Extent length per path, parallel to [`PlannedStore::paths`];
    /// Σ extents == len.
    pub extents: Vec<u64>,
}

/// Per-path in-flight limit: a counting semaphore bounding how many
/// concurrent transfers may occupy one path at a time (the runtime twin
/// of the sim's per-resource queueing).
struct PathGate {
    limit: usize,
    in_flight: Mutex<usize>,
    cv: Condvar,
}

struct PathPermit<'g> {
    gate: &'g PathGate,
}

impl PathGate {
    fn new(limit: usize) -> Self {
        PathGate { limit: limit.max(1), in_flight: Mutex::new(0), cv: Condvar::new() }
    }

    fn acquire(&self) -> PathPermit<'_> {
        let mut n = self.in_flight.lock().unwrap();
        while *n >= self.limit {
            n = self.cv.wait(n).unwrap();
        }
        *n += 1;
        PathPermit { gate: self }
    }
}

impl Drop for PathPermit<'_> {
    fn drop(&mut self) {
        *self.gate.in_flight.lock().unwrap() -= 1;
        self.gate.cv.notify_one();
    }
}

struct RemotePath {
    objects: Mutex<HashMap<String, Vec<u8>>>,
    read: Throttle,
    write: Throttle,
}

struct PlanState {
    plans: HashMap<String, TransferPlan>,
    dram: HashMap<String, Vec<u8>>,
}

/// Multi-path transfer planner (`--planned`): every object is split into
/// contiguous extents served concurrently from the DRAM tier, each NVMe
/// device, and the optional remote tier — see the module docs for the
/// path model and the plan-equivalence contract.
pub struct PlannedStore {
    devices: Vec<SsdStorage>,
    /// DRAM-path capacity accounting (per-[`Category`] budgeted).
    tier: Tier,
    dram_throttle: Throttle,
    remote: Option<RemotePath>,
    /// Plans + DRAM-resident extents under ONE lock, so a plan's DRAM
    /// reservation is atomic with the free-capacity check that sized it.
    state: Mutex<PlanState>,
    /// Per-key RwLock: writers (put/delete) exclusive, readers shared —
    /// same generation-tearing defense as [`StripedStore`].
    locks: Mutex<HashMap<String, Arc<RwLock<()>>>>,
    paths: Vec<PathId>,
    weights: Vec<u64>,
    gates: Vec<PathGate>,
    reads: AtomicU64,
    writes: AtomicU64,
    dram_read: AtomicU64,
    dram_written: AtomicU64,
    /// Per-device attribution owned by the planner (NOT the devices' own
    /// counters): committed only after a whole extent set succeeds, so a
    /// failed put/get attributes nothing (see [`PlannedStore::put`]).
    nvme_read: Vec<AtomicU64>,
    nvme_written: Vec<AtomicU64>,
    remote_read: AtomicU64,
    remote_written: AtomicU64,
    /// Scope qualifier for this store's fault-site names (test isolation;
    /// see [`crate::util::fault::scoped`]). Empty in production.
    fault_scope: String,
}

impl PlannedStore {
    /// Modeled DRAM-path bandwidth when the config leaves it unset.
    pub const DRAM_BPS: f64 = 8e9;

    /// Per-path in-flight transfer limit (concurrency control).
    const PATH_DEPTH: usize = 4;

    /// Objects below this size move their extents sequentially — thread
    /// spawn overhead dominates (same reasoning as [`StripedStore`]).
    const PARALLEL_MIN: u64 = 32 * 1024;

    /// Create the planned store: backing files `{base}.d{i}` per NVMe
    /// device. The DRAM path participates when `cfg.dram_capacity > 0`,
    /// the remote path when `cfg.remote_bps > 0`.
    pub fn create<P: AsRef<Path>>(base: P, cfg: &PlannedConfig) -> Result<Self> {
        Self::create_profiled(base, cfg, None, None)
    }

    /// [`PlannedStore::create`] with a device model: `shape` supplies the
    /// curve shape (QD knee, size ramp, mix penalty, latency floor) that
    /// every NVMe device shares, re-rated per device to its `cfg.nvme`
    /// bandwidth pair ([`DeviceProfile::with_rates`](crate::memory::DeviceProfile::with_rates)),
    /// and `batch` is the per-device `--io-batch` submission window.
    /// `shape = None` (or a flat shape) without batching is exactly
    /// `create`.
    pub fn create_profiled<P: AsRef<Path>>(
        base: P,
        cfg: &PlannedConfig,
        shape: Option<&crate::memory::DeviceProfile>,
        batch: Option<crate::memory::BatchConfig>,
    ) -> Result<Self> {
        ensure!(!cfg.nvme.is_empty(), "planned store needs at least one NVMe device");
        let devices = cfg
            .nvme
            .iter()
            .enumerate()
            .map(|(i, &(r, w))| {
                let path = format!("{}.d{i}", base.as_ref().display());
                let profile = match shape {
                    Some(p) => p.with_rates(r, w),
                    None => crate::memory::DeviceProfile::flat(r, w),
                };
                SsdStorage::with_profile(path, profile, batch)
            })
            .collect::<Result<Vec<_>>>()?;
        let dram_bps = if cfg.dram_bps > 0.0 { cfg.dram_bps } else { Self::DRAM_BPS };
        let mut paths = Vec::new();
        let mut weights = Vec::new();
        if cfg.dram_capacity > 0 {
            paths.push(PathId::Dram);
            weights.push(path_weight(dram_bps));
        }
        for (i, &(r, _)) in cfg.nvme.iter().enumerate() {
            paths.push(PathId::Nvme(i));
            // plans are sized for the read path — the roofline the
            // planner targets; writes ride the same split
            weights.push(path_weight(r));
        }
        let remote = if cfg.remote_bps > 0.0 {
            paths.push(PathId::Remote);
            weights.push(path_weight(cfg.remote_bps));
            Some(RemotePath {
                objects: Mutex::new(HashMap::new()),
                read: Throttle::new(cfg.remote_bps),
                write: Throttle::new(cfg.remote_bps),
            })
        } else {
            None
        };
        let gates = paths.iter().map(|_| PathGate::new(Self::PATH_DEPTH)).collect();
        let n_dev = devices.len();
        Ok(PlannedStore {
            devices,
            tier: Tier::new("planned-dram", cfg.dram_capacity),
            dram_throttle: Throttle::new(dram_bps),
            remote,
            state: Mutex::new(PlanState { plans: HashMap::new(), dram: HashMap::new() }),
            locks: Mutex::new(HashMap::new()),
            paths,
            weights,
            gates,
            reads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            dram_read: AtomicU64::new(0),
            dram_written: AtomicU64::new(0),
            nvme_read: (0..n_dev).map(|_| AtomicU64::new(0)).collect(),
            nvme_written: (0..n_dev).map(|_| AtomicU64::new(0)).collect(),
            remote_read: AtomicU64::new(0),
            remote_written: AtomicU64::new(0),
            fault_scope: String::new(),
        })
    }

    /// Scope-qualify this store's fault-site names
    /// ([`crate::util::fault::scoped`]): a test arming
    /// `planned:write@{scope}` only fails extent writes through THIS
    /// store, not through every planned store a parallel test is using.
    pub fn with_fault_scope(mut self, scope: &str) -> Self {
        self.fault_scope = scope.to_string();
        self
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Bytes currently resident in the DRAM path.
    pub fn dram_bytes(&self) -> u64 {
        self.tier.used()
    }

    /// Path descriptors in plan (extent) order.
    pub fn paths(&self) -> &[PathId] {
        &self.paths
    }

    /// Per-path plan weights, parallel to [`PlannedStore::paths`].
    pub fn weights(&self) -> &[u64] {
        &self.weights
    }

    /// The current plan for `key`, if any (tests / benches).
    pub fn plan_of(&self, key: &str) -> Option<TransferPlan> {
        self.state.lock().unwrap().plans.get(key).cloned()
    }

    /// Per-path byte counters — the attribution the whole-object trait
    /// counters aggregate (`total_read() == bytes_read()` always, INCLUDING
    /// across failed operations: attribution commits only after a whole
    /// extent set succeeds, never partially).
    pub fn path_stats(&self) -> PathStats {
        PathStats {
            dram_read: self.dram_read.load(Ordering::Relaxed),
            dram_written: self.dram_written.load(Ordering::Relaxed),
            nvme_read: self.nvme_read.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            nvme_written: self.nvme_written.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            remote_read: self.remote_read.load(Ordering::Relaxed),
            remote_written: self.remote_written.load(Ordering::Relaxed),
        }
    }

    fn key_lock(&self, key: &str) -> Arc<RwLock<()>> {
        self.locks
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(RwLock::new(())))
            .clone()
    }

    fn dram_extent(&self, plan: &TransferPlan) -> u64 {
        if self.paths.first() == Some(&PathId::Dram) {
            plan.extents[0]
        } else {
            0
        }
    }

    /// Build the transfer plan for `len` bytes: proportional split over
    /// the path weights, DRAM extent capped at the tier's free capacity
    /// with the overflow re-split over the remaining paths.
    fn plan_for(&self, len: u64, dram_free: u64) -> TransferPlan {
        let mut extents = plan_shares(len, &self.weights);
        if self.paths.first() == Some(&PathId::Dram) && extents[0] > dram_free {
            let spill = extents[0] - dram_free;
            extents[0] = dram_free;
            let re = plan_shares(spill, &self.weights[1..]);
            for (e, r) in extents[1..].iter_mut().zip(re) {
                *e += r;
            }
        }
        TransferPlan { len, extents }
    }

    /// Move one extent. Byte attribution is NOT recorded here — the caller
    /// commits the whole plan's attribution after every extent succeeds,
    /// so a failed put never leaves partially-attributed counters.
    fn transfer_write(&self, key: &str, path_ix: usize, part: &[u8]) -> Result<()> {
        if crate::util::fault::any_armed()
            && crate::util::fault::should_fail(&crate::util::fault::scoped(
                "planned:write",
                &self.fault_scope,
            ))
        {
            bail!("injected fault: planned extent write ('{key}', path {path_ix})");
        }
        let _permit = self.gates[path_ix].acquire();
        match self.paths[path_ix] {
            PathId::Dram => {
                if part.is_empty() {
                    return Ok(());
                }
                self.dram_throttle.transfer(part.len() as u64);
                self.state.lock().unwrap().dram.insert(key.to_string(), part.to_vec());
            }
            PathId::Nvme(i) => {
                // even an empty share is written: it clears any stale
                // extent left by a previous generation of the key
                self.devices[i].put(key, part)?;
            }
            PathId::Remote => {
                if part.is_empty() {
                    return Ok(());
                }
                let r = self.remote.as_ref().expect("remote path configured");
                r.write.transfer(part.len() as u64);
                r.objects.lock().unwrap().insert(key.to_string(), part.to_vec());
            }
        }
        Ok(())
    }

    fn transfer_read(&self, key: &str, path_ix: usize, out: &mut [u8]) -> Result<()> {
        let _permit = self.gates[path_ix].acquire();
        match self.paths[path_ix] {
            PathId::Dram => {
                {
                    let st = self.state.lock().unwrap();
                    let data = st.dram.get(key).ok_or_else(|| {
                        anyhow!("planned store: DRAM extent of '{key}' missing")
                    })?;
                    ensure!(
                        data.len() == out.len(),
                        "planned store: DRAM extent of '{key}' is {} bytes, plan says {}",
                        data.len(),
                        out.len()
                    );
                    out.copy_from_slice(data);
                }
                self.dram_throttle.transfer(out.len() as u64);
            }
            PathId::Nvme(i) => {
                let mut buf = Vec::new();
                self.devices[i].get(key, &mut buf)?;
                ensure!(
                    buf.len() == out.len(),
                    "planned store: device {i} extent of '{key}' is {} bytes, plan says {}",
                    buf.len(),
                    out.len()
                );
                out.copy_from_slice(&buf);
            }
            PathId::Remote => {
                let r = self.remote.as_ref().expect("remote path configured");
                let data = r.objects.lock().unwrap().get(key).cloned().ok_or_else(|| {
                    anyhow!("planned store: remote extent of '{key}' missing")
                })?;
                ensure!(
                    data.len() == out.len(),
                    "planned store: remote extent of '{key}' is {} bytes, plan says {}",
                    data.len(),
                    out.len()
                );
                r.read.transfer(out.len() as u64);
                out.copy_from_slice(&data);
            }
        }
        Ok(())
    }

    /// Commit a whole plan's per-path byte attribution (called only after
    /// every extent of an operation succeeded).
    fn commit_attribution(&self, plan: &TransferPlan, write: bool) {
        for (i, &e) in plan.extents.iter().enumerate() {
            let (dram, nvme, remote) = if write {
                (&self.dram_written, &self.nvme_written, &self.remote_written)
            } else {
                (&self.dram_read, &self.nvme_read, &self.remote_read)
            };
            match self.paths[i] {
                PathId::Dram => dram.fetch_add(e, Ordering::Relaxed),
                PathId::Nvme(d) => nvme[d].fetch_add(e, Ordering::Relaxed),
                PathId::Remote => remote.fetch_add(e, Ordering::Relaxed),
            };
        }
    }

    /// Undo every trace of a failed `put`: the installed plan, the DRAM
    /// reservation sized from it, and any extents that landed before the
    /// failure. The key ends ABSENT — the old generation was already
    /// destroyed when the new plan replaced it, and resurrecting stale
    /// bytes would be worse than a clean miss (the [`JournalStore`] layer
    /// above is what restores pre-images). Caller holds the exclusive
    /// key lock.
    fn rollback_failed_put(&self, key: &str) {
        {
            let mut st = self.state.lock().unwrap();
            if let Some(plan) = st.plans.remove(key) {
                // release the reservation made at plan time — the DRAM
                // extent itself may or may not have landed
                let d = self.dram_extent(&plan);
                if d > 0 {
                    self.tier.release(d, category_of(key));
                }
            }
            st.dram.remove(key);
        }
        if let Some(r) = &self.remote {
            r.objects.lock().unwrap().remove(key);
        }
        for dev in &self.devices {
            dev.delete(key);
        }
    }
}

impl TensorStore for PlannedStore {
    /// Write an object across its plan's paths. **Failure contract:** if
    /// any extent transfer fails, the whole put rolls back — the plan,
    /// the DRAM reservation, and every landed extent are removed, no byte
    /// is attributed to any counter (trait-level or [`PathStats`]), and
    /// the key is left ABSENT (the previous generation was destroyed by
    /// the plan replacement; crash-consistent restoration is the
    /// [`JournalStore`] layer's job).
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let lock = self.key_lock(key);
        let _g = lock.write().unwrap();
        let len = data.len() as u64;
        let plan = {
            let mut st = self.state.lock().unwrap();
            if let Some(old) = st.dram.remove(key) {
                self.tier.release(old.len() as u64, category_of(key));
            }
            let plan = self.plan_for(len, self.tier.free_bytes());
            let d = self.dram_extent(&plan);
            if d > 0 {
                self.tier
                    .reserve(d, category_of(key))
                    .expect("extent sized under the state lock");
            }
            st.plans.insert(key.to_string(), plan.clone());
            plan
        };
        if let Some(r) = &self.remote {
            r.objects.lock().unwrap().remove(key);
        }
        // carve the contiguous extents in path order
        let mut parts: Vec<&[u8]> = Vec::with_capacity(self.paths.len());
        let mut rest = data;
        for &e in &plan.extents {
            let (a, b) = rest.split_at(e as usize);
            parts.push(a);
            rest = b;
        }
        let failed = if len < Self::PARALLEL_MIN {
            // sequential: stop at the first failing extent
            let mut failed = None;
            for (i, part) in parts.iter().enumerate() {
                if let Err(e) = self.transfer_write(key, i, part) {
                    failed = Some(e);
                    break;
                }
            }
            failed
        } else {
            let results: Vec<Result<()>> = std::thread::scope(|s| {
                let handles: Vec<_> = parts
                    .iter()
                    .enumerate()
                    .map(|(i, part)| s.spawn(move || self.transfer_write(key, i, part)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("planned put thread")).collect()
            });
            results.into_iter().find_map(|r| r.err())
        };
        if let Some(e) = failed {
            self.rollback_failed_put(key);
            return Err(e.context(format!(
                "planned store: put '{key}' failed; rolled back to absent"
            )));
        }
        // every extent landed: commit attribution as one unit
        self.commit_attribution(&plan, true);
        self.writes.fetch_add(len, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        let lock = self.key_lock(key);
        let _g = lock.read().unwrap();
        let plan = match self.state.lock().unwrap().plans.get(key) {
            Some(p) => p.clone(),
            None => bail!("planned store: no object '{key}'"),
        };
        out.clear();
        out.resize(plan.len as usize, 0);
        // carve disjoint &mut extent slices in path order
        let mut slices: Vec<(usize, &mut [u8])> = Vec::with_capacity(self.paths.len());
        let mut rest: &mut [u8] = out.as_mut_slice();
        for (i, &e) in plan.extents.iter().enumerate() {
            let (a, b) = std::mem::take(&mut rest).split_at_mut(e as usize);
            if !a.is_empty() {
                slices.push((i, a));
            }
            rest = b;
        }
        if plan.len < Self::PARALLEL_MIN {
            for (i, s) in slices.iter_mut() {
                self.transfer_read(key, *i, s)?;
            }
        } else {
            let results: Vec<Result<()>> = std::thread::scope(|sc| {
                let handles: Vec<_> = slices
                    .into_iter()
                    .map(|(i, s)| sc.spawn(move || self.transfer_read(key, i, s)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("planned get thread")).collect()
            });
            for r in results {
                r?;
            }
        }
        // all extents arrived: commit attribution as one unit (a failed
        // read attributes nothing, mirroring the put contract)
        self.commit_attribution(&plan, false);
        self.reads.fetch_add(plan.len, Ordering::Relaxed);
        Ok(())
    }

    fn delete(&self, key: &str) -> bool {
        let lock = self.key_lock(key);
        let _g = lock.write().unwrap();
        let existed = {
            let mut st = self.state.lock().unwrap();
            if let Some(old) = st.dram.remove(key) {
                self.tier.release(old.len() as u64, category_of(key));
            }
            st.plans.remove(key).is_some()
        };
        if let Some(r) = &self.remote {
            r.objects.lock().unwrap().remove(key);
        }
        let mut any = existed;
        for dev in &self.devices {
            any |= dev.delete(key);
        }
        any
    }

    fn contains(&self, key: &str) -> bool {
        self.state.lock().unwrap().plans.contains_key(key)
    }

    fn len_of(&self, key: &str) -> Option<u64> {
        self.state.lock().unwrap().plans.get(key).map(|p| p.len)
    }

    fn bytes_read(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    fn bytes_written(&self) -> u64 {
        self.writes.load(Ordering::Relaxed)
    }

    fn footprint(&self) -> u64 {
        let remote: u64 = self
            .remote
            .as_ref()
            .map(|r| r.objects.lock().unwrap().values().map(|v| v.len() as u64).sum())
            .unwrap_or(0);
        self.devices.iter().map(|d| d.footprint()).sum::<u64>() + self.tier.used() + remote
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gs_store_test_{name}_{}", std::process::id()))
    }

    fn striped(name: &str, n: usize) -> StripedStore {
        StripedStore::create(tmp(name), n, f64::INFINITY, f64::INFINITY).unwrap()
    }

    #[test]
    fn ssd_backend_roundtrips_through_trait_object() {
        let store: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("dyn")).unwrap());
        store.put("k", b"hello").unwrap();
        let mut out = Vec::new();
        store.get("k", &mut out).unwrap();
        assert_eq!(out, b"hello");
        assert_eq!(store.len_of("k"), Some(5));
        assert!(store.contains("k"));
        assert!(store.delete("k"));
        assert!(!store.contains("k"));
        assert_eq!(store.cache_stats().total, CacheCounters::default());
    }

    #[test]
    fn trait_get_f32_rejects_unaligned_length() {
        let store: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("align")).unwrap());
        store.put("bad", &[1u8, 2, 3, 4, 5]).unwrap();
        let mut out = Vec::new();
        let err = store.get_f32("bad", &mut out).unwrap_err().to_string();
        assert!(err.contains("f32-aligned"), "{err}");
        // clean lengths still round-trip
        store.put_f32("good", &[1.0, 2.5, -3.0]).unwrap();
        store.get_f32("good", &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn striped_roundtrip_various_sizes_and_devices() {
        for n in 1..=4usize {
            let s = striped(&format!("rt{n}"), n);
            for (i, len) in [0usize, 1, 2, 3, 63, 64, 65, 1000, 200_000].iter().enumerate() {
                let data: Vec<u8> = (0..*len).map(|b| (b * 7 + i + n) as u8).collect();
                let key = format!("k{i}");
                s.put(&key, &data).unwrap();
                let mut out = Vec::new();
                s.get(&key, &mut out).unwrap();
                assert_eq!(out, data, "n={n} len={len}");
                assert_eq!(s.len_of(&key), Some(*len as u64), "n={n} len={len}");
                assert!(s.contains(&key));
            }
            // overwrite with a different length
            s.put("k0", &[9u8; 777]).unwrap();
            let mut out = Vec::new();
            s.get("k0", &mut out).unwrap();
            assert_eq!(out, vec![9u8; 777]);
        }
    }

    #[test]
    fn striped_byte_accounting_matches_object_sizes() {
        let s = striped("acct", 3);
        s.put("a", &vec![1u8; 10_000]).unwrap();
        s.put("b", &vec![2u8; 5_000]).unwrap();
        assert_eq!(s.bytes_written(), 15_000);
        let mut out = Vec::new();
        s.get("a", &mut out).unwrap();
        assert_eq!(s.bytes_read(), 10_000);
        assert!(s.delete("a"));
        assert!(!s.contains("a"));
        assert!(!s.delete("a"));
    }

    #[test]
    fn striped_missing_key_errors() {
        let s = striped("miss", 2);
        let mut out = Vec::new();
        assert!(s.get("nope", &mut out).is_err());
        assert_eq!(s.len_of("nope"), None);
    }

    /// Two throttled devices move one object's halves in parallel, so the
    /// transfer takes ~half the single-device wall time.
    #[test]
    fn striped_write_runs_devices_in_parallel() {
        let one =
            StripedStore::create(tmp("par1"), 1, f64::INFINITY, 10_000_000.0).unwrap();
        let two =
            StripedStore::create(tmp("par2"), 2, f64::INFINITY, 10_000_000.0).unwrap();
        let data = vec![5u8; 600_000]; // 60 ms at 10 MB/s on one device
        let t0 = std::time::Instant::now();
        one.put("x", &data).unwrap();
        let t_one = t0.elapsed();
        let t0 = std::time::Instant::now();
        two.put("x", &data).unwrap();
        let t_two = t0.elapsed();
        assert!(
            t_two.as_secs_f64() < 0.75 * t_one.as_secs_f64(),
            "striped write {t_two:?} must undercut single-device {t_one:?}"
        );
    }

    fn planned(name: &str, cfg: &PlannedConfig) -> PlannedStore {
        PlannedStore::create(tmp(name), cfg).unwrap()
    }

    #[test]
    fn plan_shares_conserve_and_split_proportionally() {
        for len in [0u64, 1, 7, 1000, 65_536, 1_000_000] {
            for weights in
                [vec![1u64], vec![1, 1], vec![3, 1], vec![8000, 10, 10, 200]]
            {
                let shares = plan_shares(len, &weights);
                assert_eq!(shares.len(), weights.len());
                assert_eq!(shares.iter().sum::<u64>(), len, "len={len} w={weights:?}");
            }
        }
        // exact proportional split when the weights divide evenly
        assert_eq!(plan_shares(100, &[3, 1]), vec![75, 25]);
        // the remainder goes whole to the first maximum-weight path
        assert_eq!(plan_shares(10, &[1, 1, 1]), vec![4, 3, 3]);
        // throttled rates map to ~MB/s weights, floored at 1
        assert_eq!(path_weight(10_000_000.0), 10);
        assert_eq!(path_weight(1.0), 1);
        assert_eq!(path_weight(f64::INFINITY), 4096);
    }

    #[test]
    fn planned_roundtrip_across_path_mixes() {
        let mut cfgs = Vec::new();
        for n in 1..=3usize {
            for dram in [0u64, 1 << 20] {
                for remote in [0.0, 50e6] {
                    cfgs.push(PlannedConfig {
                        nvme: vec![(f64::INFINITY, f64::INFINITY); n],
                        dram_capacity: dram,
                        dram_bps: 0.0,
                        remote_bps: remote,
                    });
                }
            }
        }
        for (ci, cfg) in cfgs.iter().enumerate() {
            let s = planned(&format!("prt{ci}"), cfg);
            for (i, len) in [0usize, 1, 3, 1000, 40_000, 200_000].iter().enumerate() {
                let data: Vec<u8> = (0..*len).map(|b| (b * 11 + i + ci) as u8).collect();
                let key = format!("k{i}");
                s.put(&key, &data).unwrap();
                let mut out = Vec::new();
                s.get(&key, &mut out).unwrap();
                assert_eq!(out, data, "cfg={ci} len={len}");
                assert_eq!(s.len_of(&key), Some(*len as u64));
                assert!(s.contains(&key));
            }
            // overwrite with a different length, then delete
            s.put("k1", &vec![9u8; 777]).unwrap();
            let mut out = Vec::new();
            s.get("k1", &mut out).unwrap();
            assert_eq!(out, vec![9u8; 777]);
            assert!(s.delete("k1"));
            assert!(!s.delete("k1"));
            assert!(!s.contains("k1"));
            assert!(s.get("k1", &mut out).is_err());
        }
    }

    #[test]
    fn planned_path_accounting_conserves_object_bytes() {
        let cfg = PlannedConfig {
            nvme: vec![(f64::INFINITY, f64::INFINITY); 2],
            dram_capacity: 1 << 20,
            dram_bps: 0.0,
            remote_bps: 50e6,
        };
        let s = planned("acct_plan", &cfg);
        s.put("a", &vec![1u8; 100_000]).unwrap();
        s.put("b", &vec![2u8; 4_321]).unwrap();
        assert_eq!(s.bytes_written(), 104_321);
        let st = s.path_stats();
        assert_eq!(st.total_written(), 104_321, "{st:?}");
        let mut out = Vec::new();
        s.get("a", &mut out).unwrap();
        assert_eq!(s.bytes_read(), 100_000);
        let st = s.path_stats();
        assert_eq!(st.total_read(), 100_000, "{st:?}");
        // every configured path moved bytes for the large object
        assert!(st.dram_written > 0 && st.remote_written > 0, "{st:?}");
        assert!(st.nvme_written.iter().all(|&b| b > 0), "{st:?}");
        // the recorded plan is the split the counters saw
        let plan = s.plan_of("a").unwrap();
        assert_eq!(plan.extents.iter().sum::<u64>(), 100_000);
        assert_eq!(plan.extents.len(), s.paths().len());
        assert_eq!(plan.extents, plan_shares(100_000, s.weights()));
    }

    #[test]
    fn planned_dram_cap_spills_to_remaining_paths() {
        let cfg = PlannedConfig {
            nvme: vec![(f64::INFINITY, f64::INFINITY); 2],
            dram_capacity: 1000,
            dram_bps: 0.0,
            remote_bps: 0.0,
        };
        let s = planned("spill", &cfg);
        // the DRAM weight dominates, but only 1000 bytes fit: the rest
        // spills to the NVMe paths and the object still round-trips
        s.put("big", &vec![7u8; 50_000]).unwrap();
        let plan = s.plan_of("big").unwrap();
        assert_eq!(plan.extents[0], 1000, "DRAM extent capped at free capacity");
        assert_eq!(plan.extents.iter().sum::<u64>(), 50_000);
        assert_eq!(s.dram_bytes(), 1000);
        let mut out = Vec::new();
        s.get("big", &mut out).unwrap();
        assert_eq!(out, vec![7u8; 50_000]);
        // a second large object finds no DRAM capacity at all
        s.put("big2", &vec![8u8; 50_000]).unwrap();
        let plan2 = s.plan_of("big2").unwrap();
        assert_eq!(plan2.extents[0], 0);
        s.get("big2", &mut out).unwrap();
        assert_eq!(out, vec![8u8; 50_000]);
        // deleting returns the DRAM bytes
        assert!(s.delete("big"));
        assert_eq!(s.dram_bytes(), 0);
    }

    /// Two throttled NVMe paths serve one read concurrently — aggregate
    /// bandwidth approaches the sum of the paths (the multi-path law the
    /// fig16 bench pins end to end with a DRAM path on top).
    #[test]
    fn planned_read_runs_paths_in_parallel() {
        let single = PlannedConfig {
            nvme: vec![(10_000_000.0, f64::INFINITY)],
            dram_capacity: 0,
            dram_bps: 0.0,
            remote_bps: 0.0,
        };
        let multi = PlannedConfig {
            nvme: vec![(10_000_000.0, f64::INFINITY); 2],
            dram_capacity: 0,
            dram_bps: 0.0,
            remote_bps: 0.0,
        };
        let one = planned("mp1", &single);
        let two = planned("mp2", &multi);
        let data = vec![5u8; 600_000]; // 60 ms at 10 MB/s on one path
        one.put("x", &data).unwrap();
        two.put("x", &data).unwrap();
        let mut out = Vec::new();
        let t0 = std::time::Instant::now();
        one.get("x", &mut out).unwrap();
        let t_one = t0.elapsed();
        let t0 = std::time::Instant::now();
        two.get("x", &mut out).unwrap();
        let t_two = t0.elapsed();
        assert!(
            t_two.as_secs_f64() < 0.75 * t_one.as_secs_f64(),
            "planned read {t_two:?} must undercut single-path {t_one:?}"
        );
    }

    #[test]
    fn cached_store_absorbs_repeat_traffic() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_abs")).unwrap());
        let cache = CachedStore::new(Arc::clone(&inner), 1 << 20);
        cache.put("opt_m_l0_t0_e", &vec![1u8; 4096]).unwrap();
        let mut out = Vec::new();
        for _ in 0..10 {
            cache.get("opt_m_l0_t0_e", &mut out).unwrap();
            cache.put("opt_m_l0_t0_e", &out).unwrap();
        }
        // the backing store never saw a byte
        assert_eq!(cache.bytes_read(), 0);
        assert_eq!(cache.bytes_written(), 0);
        assert_eq!(inner.bytes_written(), 0);
        let stats = cache.cache_stats();
        assert_eq!(stats.total.hits, 10);
        assert_eq!(stats.total.misses, 0);
        assert_eq!(
            stats.by_cat.get(&Category::OptimizerStates).unwrap().hits,
            10
        );
        assert_eq!(cache.cached_bytes(), 4096);
    }

    #[test]
    fn cached_store_evicts_lru_with_write_back() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_lru")).unwrap());
        let cache = CachedStore::new(Arc::clone(&inner), 2048);
        cache.put("ilc_a", &vec![1u8; 1024]).unwrap();
        cache.put("ilc_b", &vec![2u8; 1024]).unwrap();
        // touch a so b is the LRU victim
        let mut out = Vec::new();
        cache.get("ilc_a", &mut out).unwrap();
        cache.put("ilc_c", &vec![3u8; 1024]).unwrap(); // evicts b (dirty)
        assert_eq!(inner.bytes_written(), 1024, "the evicted dirty entry wrote back");
        assert!(inner.contains("ilc_b"));
        assert!(!inner.contains("ilc_a"), "resident entries stay DRAM-only");
        // b still readable (re-faulted from the backing store: a miss)
        cache.get("ilc_b", &mut out).unwrap();
        assert_eq!(out, vec![2u8; 1024]);
        let stats = cache.cache_stats();
        assert_eq!(stats.total.evictions >= 1, true, "{stats:?}");
        assert!(stats.total.misses >= 1);
        assert_eq!(
            stats.by_cat.get(&Category::Checkpoints).unwrap().evictions,
            stats.total.evictions
        );
    }

    #[test]
    fn cached_store_delete_covers_dirty_only_entries() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_del")).unwrap());
        let cache = CachedStore::new(inner, 1 << 16);
        cache.put("k", b"abc").unwrap();
        assert!(cache.contains("k"));
        assert_eq!(cache.len_of("k"), Some(3));
        assert!(cache.delete("k"), "dirty-only entry must still report deleted");
        assert!(!cache.contains("k"));
        let mut out = Vec::new();
        assert!(cache.get("k", &mut out).is_err());
        assert!(!cache.delete("k"));
    }

    #[test]
    fn cached_store_write_through_for_oversized_objects() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_big")).unwrap());
        let cache = CachedStore::new(Arc::clone(&inner), 1024);
        cache.put("big", &vec![7u8; 4096]).unwrap();
        assert_eq!(inner.bytes_written(), 4096, "oversized objects write through");
        assert_eq!(cache.cached_bytes(), 0);
        let mut out = Vec::new();
        cache.get("big", &mut out).unwrap();
        assert_eq!(out, vec![7u8; 4096]);
    }

    #[test]
    fn cached_store_flush_writes_dirty_entries() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_flush")).unwrap());
        let cache = CachedStore::new(Arc::clone(&inner), 1 << 16);
        cache.put("opt_x", &vec![1u8; 100]).unwrap();
        assert!(!inner.contains("opt_x"));
        cache.flush().unwrap();
        assert!(inner.contains("opt_x"));
        // second flush is a no-op (entries now clean)
        cache.flush().unwrap();
        assert_eq!(inner.bytes_written(), 100);
    }

    /// Cross-backend ordering: `CachedStore::flush` + dirty write-back over
    /// a `StripedStore` inner — flush-then-read byte-identity through the
    /// inner store, plus exact per-device byte attribution.
    #[test]
    fn cached_flush_over_striped_inner_attributes_bytes_per_device() {
        let inner = Arc::new(striped("flush_str", 3));
        let cache = CachedStore::new(Arc::clone(&inner), 1 << 20);
        let objs: [(&str, usize, u8); 3] =
            [("opt_a", 10_000, 1), ("ilc_b", 5_000, 2), ("misc_c", 64, 3)];
        for (k, len, fill) in objs {
            cache.put(k, &vec![fill; len]).unwrap();
        }
        // everything still dirty in DRAM: the striped inner saw no bytes
        assert_eq!(inner.bytes_written(), 0);
        assert!(inner.per_device_bytes().iter().all(|&(r, w)| r == 0 && w == 0));
        cache.flush().unwrap();
        // write-back totals and their per-device split (the chunk layout
        // is a pure function of each object's length: 10000 splits
        // 3334/3334/3332, 5000 splits 1667/1667/1666, 64 splits 22/22/20)
        assert_eq!(inner.bytes_written(), 15_064);
        let per_dev: Vec<u64> = inner.per_device_bytes().iter().map(|&(_, w)| w).collect();
        assert_eq!(per_dev, vec![5_023, 5_023, 5_018]);
        // flushed bytes read back identical THROUGH THE INNER store
        for (k, len, fill) in objs {
            let mut out = Vec::new();
            inner.get(k, &mut out).unwrap();
            assert_eq!(out, vec![fill; len], "{k}");
        }
        // second flush is a no-op; a re-dirtied entry flushes again
        cache.flush().unwrap();
        assert_eq!(inner.bytes_written(), 15_064);
        cache.put("opt_a", &vec![9u8; 600]).unwrap();
        cache.flush().unwrap();
        assert_eq!(inner.bytes_written(), 15_064 + 600);
    }

    /// Same-key hammer through the trait object, across all four backends:
    /// concurrent puts and gets must never deadlock or hand a reader torn
    /// bytes (every writer writes a constant fill, so any successful read
    /// must be uniform).
    #[test]
    fn same_key_hammer_through_trait_object() {
        let ssd: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("ham_ssd")).unwrap());
        let str3: Arc<dyn TensorStore> = Arc::new(striped("ham_str", 3));
        let cached: Arc<dyn TensorStore> = Arc::new(CachedStore::new(
            Arc::new(SsdStorage::create_unthrottled(tmp("ham_c")).unwrap()),
            // small enough to force eviction churn mid-hammer
            2048,
        ));
        let plan_cfg = PlannedConfig {
            nvme: vec![(f64::INFINITY, f64::INFINITY); 2],
            // small enough that plans spill once hot objects accumulate
            dram_capacity: 4096,
            dram_bps: 0.0,
            remote_bps: 100e6,
        };
        let plan: Arc<dyn TensorStore> = Arc::new(planned("ham_plan", &plan_cfg));
        let backends =
            vec![("ssd", ssd), ("striped", str3), ("cached", cached), ("planned", plan)];
        for (name, store) in backends {
            store.put("hot", &[255u8; 64]).unwrap();
            let mut handles: Vec<_> = (0..6u8)
                .map(|t| {
                    let store = Arc::clone(&store);
                    std::thread::spawn(move || {
                        for i in 0..40usize {
                            let len = 128 + (t as usize * 37 + i * 13) % 512;
                            store.put("hot", &vec![t; len]).unwrap();
                            let own = format!("own{t}");
                            store.put(&own, &[t; 96]).unwrap();
                            let mut out = Vec::new();
                            store.get(&own, &mut out).unwrap();
                            assert_eq!(out, vec![t; 96], "private key torn");
                        }
                    })
                })
                .collect();
            for _ in 0..2 {
                let store = Arc::clone(&store);
                handles.push(std::thread::spawn(move || {
                    for _ in 0..80 {
                        let mut out = Vec::new();
                        store.get("hot", &mut out).unwrap();
                        assert!(
                            !out.is_empty() && out.iter().all(|&b| b == out[0]),
                            "torn read: {out:?}"
                        );
                    }
                }));
            }
            for h in handles {
                h.join().unwrap_or_else(|_| panic!("{name}: hammer thread panicked"));
            }
            let mut out = Vec::new();
            store.get("hot", &mut out).unwrap();
            assert!(!out.is_empty() && out.iter().all(|&b| b == out[0]), "{name}: {out:?}");
        }
    }

    #[test]
    fn category_classification_follows_key_prefixes() {
        assert_eq!(category_of("opt_m_l0_t1_e"), Category::OptimizerStates);
        assert_eq!(category_of("ilc_ckpt_l0_mb2"), Category::Checkpoints);
        assert_eq!(category_of("param_l3_w0"), Category::Parameters);
        assert_eq!(category_of("base_l2_t1"), Category::Parameters);
        assert_eq!(category_of("base_emb_0"), Category::Parameters);
        assert_eq!(category_of("adapter_3_l1_t0"), Category::Adapters);
        assert_eq!(category_of("misc"), Category::Working);
        // tenant ownership rides the adapter key structure only
        assert_eq!(tenant_of("adapter_3_l1_t0"), Some(3));
        assert_eq!(tenant_of("adapter_12_l0_t7"), Some(12));
        assert_eq!(tenant_of("base_l2_t1"), None);
        assert_eq!(tenant_of("opt_m_l0_t1_e"), None);
        assert_eq!(tenant_of("adapter_x_l0_t0"), None); // unparsable tenant id
    }

    /// Satellite regression: cache hit/miss/evict stats must attribute to
    /// the object's real category — params/base to `Parameters`, adapters
    /// to `Adapters` — instead of lumping every non-`opt_`/`ilc_` key into
    /// one `Working` bucket.
    #[test]
    fn cache_stats_attribute_param_and_adapter_categories() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_attr")).unwrap());
        let cache = CachedStore::new(Arc::clone(&inner), 1 << 16);
        for (key, n) in
            [("param_l0_w0", 64usize), ("base_l0_t0", 64), ("adapter_1_l0_t0", 8), ("misc", 16)]
        {
            cache.put(key, &vec![7u8; n]).unwrap();
            let mut out = Vec::new();
            cache.get(key, &mut out).unwrap(); // hit in DRAM
            assert_eq!(out.len(), n);
        }
        // a key the cache has never seen: one miss per category
        inner.put("adapter_2_l0_t0", &[1u8; 8]).unwrap();
        let mut out = Vec::new();
        cache.get("adapter_2_l0_t0", &mut out).unwrap();
        let stats = cache.cache_stats();
        let get = |cat: Category| stats.by_cat.get(&cat).copied().unwrap_or_default();
        assert_eq!(get(Category::Parameters).hits, 2, "param_ + base_ hits");
        assert_eq!(get(Category::Adapters).hits, 1);
        assert_eq!(get(Category::Adapters).misses, 1);
        assert_eq!(get(Category::Working).hits, 1);
        assert_eq!(get(Category::Working).misses, 0);
    }

    /// Per-tenant admission: under `CacheAdmission::PerTenant`, each
    /// tenant's resident adapter bytes stay within its budget (overflow
    /// writes through to the backing store without evicting anything),
    /// while shared `base_*` objects admit freely.
    #[test]
    fn cached_store_per_tenant_admission_budget() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_adm")).unwrap());
        let cache = CachedStore::with_admission(
            Arc::clone(&inner),
            1 << 16,
            CacheAdmission::PerTenant { per_tenant_bytes: 512 },
        );
        // base image: shared, always cacheable
        cache.put("base_l0_t0", &[2u8; 1024]).unwrap();
        // tenant 0: two 256 B adapters fit the 512 B budget exactly
        cache.put("adapter_0_l0_t0", &[3u8; 256]).unwrap();
        cache.put("adapter_0_l1_t0", &[4u8; 256]).unwrap();
        // the third overflows the budget -> write-through, not cached
        cache.put("adapter_0_l2_t0", &[5u8; 256]).unwrap();
        assert!(inner.contains("adapter_0_l2_t0"), "over-budget put must write through");
        // dirty in-budget entries have NOT been written back (still cached)
        assert!(!inner.contains("adapter_0_l0_t0"));
        // tenant 1 has its own budget
        cache.put("adapter_1_l0_t0", &[6u8; 256]).unwrap();
        assert!(!inner.contains("adapter_1_l0_t0"));
        // nothing was evicted to make the over-budget put "fit"
        assert_eq!(cache.cache_stats().total.evictions, 0);
        // a read of the written-through key must not fill the cache either:
        // the inner store's read counter grows on BOTH reads
        let mut out = Vec::new();
        let r0 = inner.bytes_read();
        cache.get("adapter_0_l2_t0", &mut out).unwrap();
        let r1 = inner.bytes_read();
        cache.get("adapter_0_l2_t0", &mut out).unwrap();
        let r2 = inner.bytes_read();
        assert!(r1 > r0 && r2 > r1, "over-budget reads must bypass the fill");
        // deleting an adapter returns its budget
        assert!(cache.delete("adapter_0_l0_t0"));
        cache.put("adapter_0_l3_t0", &[8u8; 256]).unwrap();
        assert!(!inner.contains("adapter_0_l3_t0"), "freed budget re-admits");
    }

    /// Satellite regression: a dirty entry deleted before any write-back
    /// must never be resurrected into the inner store by a later flush —
    /// and the concurrent shape (deleters racing miss-fills and flushers)
    /// must converge to the same answer.
    #[test]
    fn cached_store_deleted_dirty_entry_never_resurrects() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("cache_res")).unwrap());
        let cache = Arc::new(CachedStore::new(Arc::clone(&inner), 1 << 16));
        // deterministic single-threaded hammer: dirty put → delete → flush
        for i in 0..50usize {
            let k = format!("opt_res{i}");
            cache.put(&k, &vec![i as u8; 256]).unwrap();
            assert!(cache.delete(&k));
            cache.flush().unwrap();
            assert!(!inner.contains(&k), "flush resurrected deleted dirty '{k}'");
            assert!(!cache.contains(&k));
            let mut out = Vec::new();
            assert!(cache.get(&k, &mut out).is_err());
        }
        // concurrent hammer on one hot key: writers put+delete, readers
        // tolerate absence, a flusher runs throughout
        let mut handles: Vec<_> = (0..4u8)
            .map(|t| {
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || {
                    for i in 0..60usize {
                        cache.put("opt_hot", &vec![t; 64 + i % 32]).unwrap();
                        cache.delete("opt_hot");
                        if i % 8 == 0 {
                            cache.flush().unwrap();
                        }
                    }
                })
            })
            .collect();
        handles.push({
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || {
                for _ in 0..120 {
                    let mut out = Vec::new();
                    if cache.get("opt_hot", &mut out).is_ok() {
                        assert!(
                            !out.is_empty() && out.iter().all(|&b| b == out[0]),
                            "torn read: {out:?}"
                        );
                    }
                }
            })
        });
        for h in handles {
            h.join().expect("hammer thread");
        }
        // final state: delete + flush leaves the key absent EVERYWHERE
        cache.delete("opt_hot");
        cache.flush().unwrap();
        assert!(!cache.contains("opt_hot"));
        assert!(!inner.contains("opt_hot"), "delete-then-flush resurrected the key");
    }

    /// Satellite regression: a put that fails mid-extent-set must leave no
    /// trace — no partial byte attribution (trait counters or PathStats),
    /// no leaked DRAM reservation, no torn object — and a retry must land
    /// cleanly.
    #[test]
    fn planned_failed_put_rolls_back_completely() {
        let cfg = PlannedConfig {
            nvme: vec![(f64::INFINITY, f64::INFINITY); 2],
            dram_capacity: 1 << 20,
            dram_bps: 0.0,
            remote_bps: 50e6,
        };
        // 8 KB objects < PARALLEL_MIN → sequential extents → the n-th
        // armed hit picks a deterministic failing extent; the fault scope
        // keeps parallel PlannedStore users from absorbing the arms
        let s = planned("fail_put", &cfg).with_fault_scope("t_fail_put");
        let site = crate::util::fault::scoped("planned:write", "t_fail_put");
        s.put("a", &vec![1u8; 8_000]).unwrap();
        let written0 = s.bytes_written();
        let stats0 = s.path_stats();
        let dram0 = s.dram_bytes();
        // fail the SECOND extent (first NVMe device): the DRAM extent has
        // already landed and must be rolled back with its reservation
        crate::util::fault::arm(&site, 1);
        let err = s.put("b", &vec![2u8; 8_000]).unwrap_err().to_string();
        assert!(err.contains("injected fault"), "{err}");
        assert_eq!(s.bytes_written(), written0, "failed put attributed bytes");
        assert_eq!(s.path_stats(), stats0, "failed put left partial PathStats");
        assert_eq!(s.dram_bytes(), dram0, "failed put leaked a DRAM reservation");
        assert!(!s.contains("b"), "failed put left a plan behind");
        assert_eq!(s.len_of("b"), None);
        let mut out = Vec::new();
        assert!(s.get("b", &mut out).is_err());
        // the armed site is one-shot: the retry lands whole
        s.put("b", &vec![2u8; 8_000]).unwrap();
        s.get("b", &mut out).unwrap();
        assert_eq!(out, vec![2u8; 8_000]);
        assert_eq!(s.bytes_written(), written0 + 8_000);
        assert_eq!(s.path_stats().total_written(), written0 + 8_000);
        // overwrite failure rolls back to ABSENT (the old generation is
        // destroyed by the plan replacement — documented contract)
        crate::util::fault::arm(&site, 0);
        assert!(s.put("a", &vec![3u8; 100]).is_err());
        assert!(!s.contains("a"));
        // "a"'s DRAM extent reservation must also have been released:
        // only "b"'s extent remains resident
        assert_eq!(s.dram_bytes(), s.plan_of("b").map(|p| p.extents[0]).unwrap());
    }

    #[test]
    fn journal_commit_then_crash_rolls_back_to_epoch_boundary() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("jrnl")).unwrap());
        let j = JournalStore::new(Arc::clone(&inner)).unwrap();
        assert_eq!(j.committed_epoch(), 0);
        j.put("k1", b"v1").unwrap();
        j.put("k2", b"v2").unwrap();
        j.commit_epoch().unwrap();
        assert_eq!(j.committed_epoch(), 1);
        // epoch 2 in flight: overwrite k1, delete k2, create k3
        j.put("k1", b"V1B").unwrap();
        assert!(j.delete("k2"));
        j.put("k3", b"v3").unwrap();
        assert!(!j.contains("k2") && j.contains("k3"));
        // "crash" before commit → recover restores the epoch-1 image
        j.recover().unwrap();
        assert_eq!(j.committed_epoch(), 1);
        let mut out = Vec::new();
        j.get("k1", &mut out).unwrap();
        assert_eq!(out, b"v1");
        j.get("k2", &mut out).unwrap();
        assert_eq!(out, b"v2");
        assert!(!j.contains("k3"), "uncommitted new key survived recovery");
        // no journal residue
        assert!(!inner.contains("gsj_manifest"));
        assert!(!inner.contains("gsj_undo_k1"));
        assert!(!inner.contains("gsj_undo_k2"));
        // the redo commits cleanly, and recover after commit is a no-op
        j.put("k1", b"V1B").unwrap();
        j.delete("k2");
        j.commit_epoch().unwrap();
        assert_eq!(j.committed_epoch(), 2);
        j.recover().unwrap();
        assert_eq!(j.committed_epoch(), 2);
        j.get("k1", &mut out).unwrap();
        assert_eq!(out, b"V1B");
        assert!(!j.contains("k2"));
    }

    #[test]
    fn journal_torn_put_restores_prior_bytes() {
        let j = JournalStore::new(Arc::new(
            SsdStorage::create_unthrottled(tmp("jrnl_tear")).unwrap(),
        ))
        .unwrap()
        .with_fault_scope("t_tear");
        j.put("t", &[1u8; 100]).unwrap();
        j.commit_epoch().unwrap();
        crate::util::fault::arm(&crate::util::fault::scoped("store:tear_put", "t_tear"), 0);
        let err = j.put("t", &[2u8; 100]).unwrap_err().to_string();
        assert!(err.contains("torn put"), "{err}");
        // pre-recovery the torn half IS visible — that's the simulated
        // crash damage
        let mut out = Vec::new();
        j.get("t", &mut out).unwrap();
        assert_eq!(out, vec![2u8; 50]);
        j.recover().unwrap();
        j.get("t", &mut out).unwrap();
        assert_eq!(out, vec![1u8; 100], "recovery must restore the pre-image");
    }

    /// A new JournalStore over a store that already holds a committed
    /// epoch marker and a stale in-flight manifest adopts the marker and
    /// rolls the in-flight epoch back (the reopen-after-crash path).
    #[test]
    fn journal_reopen_adopts_marker_and_rolls_back() {
        let inner: Arc<dyn TensorStore> =
            Arc::new(SsdStorage::create_unthrottled(tmp("jrnl_reopen")).unwrap());
        {
            let j = JournalStore::new(Arc::clone(&inner)).unwrap();
            j.put("k", b"committed").unwrap();
            j.commit_epoch().unwrap();
            j.put("k", b"in-flight").unwrap();
            // dropped without commit: manifest + undo left in the inner
        }
        assert!(inner.contains("gsj_manifest"));
        let j2 = JournalStore::new(Arc::clone(&inner)).unwrap();
        assert_eq!(j2.committed_epoch(), 1);
        let mut out = Vec::new();
        j2.get("k", &mut out).unwrap();
        assert_eq!(out, b"committed");
        assert!(!inner.contains("gsj_manifest"));
    }
}
