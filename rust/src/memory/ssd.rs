//! File-backed SSD tier with independent read/write bandwidth throttles.
//!
//! Substitution for the paper's NVMe namespace (DESIGN.md): objects are
//! stored in one flat backing file managed with a free-list, I/O goes through
//! real `pread`/`pwrite`-style syscalls, and a [`Throttle`] caps the rates to
//! the paper's few-GB/s regime. The optimizer-state round trip that creates
//! the §3.1 I/O roofline therefore happens byte-for-byte.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::throttle::Throttle;

/// Key type for stored objects.
pub type Key = String;

#[derive(Debug, Clone, Copy)]
struct Extent {
    offset: u64,
    len: u64,
}

#[derive(Debug, Default)]
struct Layout {
    objects: HashMap<Key, Extent>,
    /// Sorted free extents (offset ascending), coalesced on free.
    free: Vec<Extent>,
    end: u64,
}

/// Flat-file object store with throttled read/write paths.
pub struct SsdStorage {
    file: Mutex<File>,
    layout: Mutex<Layout>,
    read_throttle: Throttle,
    write_throttle: Throttle,
    path: std::path::PathBuf,
}

impl SsdStorage {
    /// Create (truncating) a backing file at `path` with the given byte rates.
    pub fn create<P: AsRef<Path>>(path: P, read_bps: f64, write_bps: f64) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path.as_ref())
            .with_context(|| format!("open ssd backing file {:?}", path.as_ref()))?;
        Ok(SsdStorage {
            file: Mutex::new(file),
            layout: Mutex::new(Layout::default()),
            read_throttle: Throttle::new(read_bps),
            write_throttle: Throttle::new(write_bps),
            path: path.as_ref().to_path_buf(),
        })
    }

    /// Unthrottled store (tests, setup paths).
    pub fn create_unthrottled<P: AsRef<Path>>(path: P) -> Result<Self> {
        Self::create(path, f64::INFINITY, f64::INFINITY)
    }

    fn allocate(&self, len: u64) -> Extent {
        let mut l = self.layout.lock().unwrap();
        // best-fit over the free list
        let mut best: Option<usize> = None;
        for (i, e) in l.free.iter().enumerate() {
            if e.len >= len && best.is_none_or(|b| l.free[b].len > e.len) {
                best = Some(i);
            }
        }
        if let Some(i) = best {
            let e = l.free[i];
            if e.len == len {
                l.free.remove(i);
                return e;
            }
            l.free[i] = Extent { offset: e.offset + len, len: e.len - len };
            return Extent { offset: e.offset, len };
        }
        let e = Extent { offset: l.end, len };
        l.end += len;
        e
    }

    /// Write `data` under `key` (replacing any previous object).
    pub fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.delete(key); // frees old extent if present
        let extent = self.allocate(data.len() as u64);
        self.write_throttle.transfer(data.len() as u64);
        {
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(extent.offset))?;
            f.write_all(data)?;
        }
        self.layout.lock().unwrap().objects.insert(key.to_string(), extent);
        Ok(())
    }

    /// Read the object at `key` into `out` (resized to fit).
    pub fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        let extent = *self
            .layout
            .lock()
            .unwrap()
            .objects
            .get(key)
            .ok_or_else(|| anyhow!("ssd: no object '{key}'"))?;
        self.read_throttle.transfer(extent.len);
        out.resize(extent.len as usize, 0);
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(extent.offset))?;
        f.read_exact(out)?;
        Ok(())
    }

    /// Remove an object if present; its extent is coalesced into the free list.
    pub fn delete(&self, key: &str) -> bool {
        let mut l = self.layout.lock().unwrap();
        if let Some(e) = l.objects.remove(key) {
            let idx = l.free.partition_point(|f| f.offset < e.offset);
            l.free.insert(idx, e);
            // coalesce with neighbours
            if idx + 1 < l.free.len()
                && l.free[idx].offset + l.free[idx].len == l.free[idx + 1].offset
            {
                l.free[idx].len += l.free[idx + 1].len;
                l.free.remove(idx + 1);
            }
            if idx > 0 && l.free[idx - 1].offset + l.free[idx - 1].len == l.free[idx].offset {
                l.free[idx - 1].len += l.free[idx].len;
                l.free.remove(idx);
            }
            true
        } else {
            false
        }
    }

    pub fn contains(&self, key: &str) -> bool {
        self.layout.lock().unwrap().objects.contains_key(key)
    }

    pub fn len_of(&self, key: &str) -> Option<u64> {
        self.layout.lock().unwrap().objects.get(key).map(|e| e.len)
    }

    /// Total bytes moved through the read / write paths.
    pub fn bytes_read(&self) -> u64 {
        self.read_throttle.total_bytes()
    }

    pub fn bytes_written(&self) -> u64 {
        self.write_throttle.total_bytes()
    }

    /// Current backing-file high-water mark.
    pub fn footprint(&self) -> u64 {
        self.layout.lock().unwrap().end
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    // Typed helpers for the f32 tensors the trainer stores. ----------------

    pub fn put_f32(&self, key: &str, data: &[f32]) -> Result<()> {
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        self.put(key, bytes)
    }

    pub fn get_f32(&self, key: &str, out: &mut Vec<f32>) -> Result<()> {
        let mut raw = Vec::new();
        self.get(key, &mut raw)?;
        anyhow::ensure!(raw.len() % 4 == 0, "object '{key}' not f32-aligned");
        out.resize(raw.len() / 4, 0.0);
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, raw.len());
        }
        Ok(())
    }
}

impl Drop for SsdStorage {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gs_ssd_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn put_get_roundtrip() {
        let ssd = SsdStorage::create_unthrottled(tmp("rt")).unwrap();
        ssd.put("a", b"hello world").unwrap();
        let mut out = Vec::new();
        ssd.get("a", &mut out).unwrap();
        assert_eq!(out, b"hello world");
        assert_eq!(ssd.bytes_written(), 11);
        assert_eq!(ssd.bytes_read(), 11);
    }

    #[test]
    fn missing_key_errors() {
        let ssd = SsdStorage::create_unthrottled(tmp("miss")).unwrap();
        let mut out = Vec::new();
        assert!(ssd.get("nope", &mut out).is_err());
    }

    #[test]
    fn overwrite_replaces() {
        let ssd = SsdStorage::create_unthrottled(tmp("ow")).unwrap();
        ssd.put("k", b"short").unwrap();
        ssd.put("k", b"a considerably longer value").unwrap();
        let mut out = Vec::new();
        ssd.get("k", &mut out).unwrap();
        assert_eq!(out, b"a considerably longer value");
    }

    #[test]
    fn freed_space_is_reused() {
        let ssd = SsdStorage::create_unthrottled(tmp("reuse")).unwrap();
        ssd.put("a", &[0u8; 1000]).unwrap();
        ssd.put("b", &[1u8; 1000]).unwrap();
        let fp = ssd.footprint();
        ssd.delete("a");
        ssd.put("c", &[2u8; 900]).unwrap(); // fits in a's hole
        assert_eq!(ssd.footprint(), fp);
        let mut out = Vec::new();
        ssd.get("b", &mut out).unwrap();
        assert_eq!(out, vec![1u8; 1000]);
    }

    #[test]
    fn free_list_coalesces() {
        let ssd = SsdStorage::create_unthrottled(tmp("coal")).unwrap();
        for (k, v) in [("a", 100), ("b", 100), ("c", 100)] {
            ssd.put(k, &vec![0u8; v]).unwrap();
        }
        ssd.delete("a");
        ssd.delete("c");
        ssd.delete("b"); // middle join: one 300-byte extent
        ssd.put("big", &[7u8; 300]).unwrap();
        assert_eq!(ssd.footprint(), 300);
    }

    #[test]
    fn f32_roundtrip() {
        let ssd = SsdStorage::create_unthrottled(tmp("f32")).unwrap();
        let xs: Vec<f32> = (0..257).map(|i| i as f32 * 0.5).collect();
        ssd.put_f32("t", &xs).unwrap();
        let mut out = Vec::new();
        ssd.get_f32("t", &mut out).unwrap();
        assert_eq!(out, xs);
    }

    #[test]
    fn throttled_write_takes_time() {
        let ssd = SsdStorage::create(tmp("thr"), f64::INFINITY, 10_000_000.0).unwrap();
        let t0 = std::time::Instant::now();
        ssd.put("x", &vec![0u8; 500_000]).unwrap(); // 50 ms at 10 MB/s
        assert!(t0.elapsed() >= std::time::Duration::from_millis(45));
    }

    #[test]
    fn concurrent_puts_and_gets() {
        let ssd = std::sync::Arc::new(SsdStorage::create_unthrottled(tmp("conc")).unwrap());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let ssd = std::sync::Arc::clone(&ssd);
                std::thread::spawn(move || {
                    let data = vec![i as u8; 10_000];
                    let key = format!("k{i}");
                    ssd.put(&key, &data).unwrap();
                    let mut out = Vec::new();
                    ssd.get(&key, &mut out).unwrap();
                    assert_eq!(out, data);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
