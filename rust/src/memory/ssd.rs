//! File-backed SSD tier with a QD-aware device model on independent
//! read/write lanes.
//!
//! Substitution for the paper's NVMe namespace (DESIGN.md): objects are
//! stored in one flat backing file managed with a free-list, I/O goes through
//! real `pread`/`pwrite` positioned syscalls, and a [`DeviceThrottle`] caps
//! the rates to the paper's few-GB/s regime — flat by default
//! ([`SsdStorage::create`], exactly the old [`Throttle`](super::Throttle)
//! pair), or shaped by a full [`DeviceProfile`] (QD/size curves, mix
//! penalty, per-op latency floor) with optional io_uring-style submission
//! batching ([`SsdStorage::with_profile`]). The optimizer-state round trip
//! that creates the §3.1 I/O roofline therefore happens byte-for-byte, and
//! with a profiled device it is *priced* the way a real NVMe prices it.
//!
//! Concurrency: the layout (object table + free list) lives behind one short
//! mutex, but data transfer itself is lock-free — positioned I/O
//! (`FileExt::read_exact_at` / `write_all_at`) needs no shared seek cursor,
//! so the read and write lanes of [`crate::coordinator::io::IoPipeline`]
//! genuinely proceed in parallel even while both directions are throttled.
//! Object-table transitions are atomic: `put` installs the new extent and
//! frees the old one under a single lock acquisition, so concurrent puts to
//! the same key can never leak an extent or corrupt the free list
//! ([`SsdStorage::check_consistency`] verifies the invariant). Reads are
//! generation-validated: each `put` stamps the object, and `get` re-checks
//! the stamp after the unlocked transfer, retrying if the object was
//! replaced mid-read — so a racing same-key overwrite can never hand a
//! reader torn bytes.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, ensure, Context, Result};

use super::throttle::{BatchConfig, DeviceProfile, DeviceThrottle};

/// Key type for stored objects.
pub type Key = String;

#[derive(Debug, Clone, Copy)]
struct Extent {
    offset: u64,
    len: u64,
}

/// A stored object: its extent plus the generation stamp of the `put` that
/// wrote it (monotonic; lets `get` detect mid-read replacement).
#[derive(Debug, Clone, Copy)]
struct Obj {
    extent: Extent,
    gen: u64,
}

#[derive(Debug, Default)]
struct Layout {
    objects: HashMap<Key, Obj>,
    /// Sorted free extents (offset ascending), coalesced on free.
    free: Vec<Extent>,
    end: u64,
    next_gen: u64,
}

/// Flat-file object store with a profiled device model on the I/O paths.
pub struct SsdStorage {
    /// No mutex: positioned I/O takes `&File`, so reads and writes to
    /// disjoint extents run concurrently.
    file: File,
    layout: Mutex<Layout>,
    dev: DeviceThrottle,
    path: std::path::PathBuf,
}

impl SsdStorage {
    /// Create (truncating) a backing file at `path` with the given flat
    /// byte rates — exactly the pre-profile throttle semantics
    /// ([`DeviceProfile::flat`]), bit- and timing-identical to the old
    /// two-[`Throttle`](super::Throttle) store.
    pub fn create<P: AsRef<Path>>(path: P, read_bps: f64, write_bps: f64) -> Result<Self> {
        Self::with_profile(path, DeviceProfile::flat(read_bps, write_bps), None)
    }

    /// Create with a full device model: the profile's QD/size curves, mix
    /// penalty, and latency floor shape every transfer's timing, and
    /// `batch` (the `--io-batch` window) coalesces concurrent
    /// sub-saturating submissions io_uring-style. Only timing depends on
    /// `(profile, batch)` — stored bytes and the byte counters are
    /// invariant (the determinism contract the batching proptests pin).
    pub fn with_profile<P: AsRef<Path>>(
        path: P,
        profile: DeviceProfile,
        batch: Option<BatchConfig>,
    ) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path.as_ref())
            .with_context(|| format!("open ssd backing file {:?}", path.as_ref()))?;
        Ok(SsdStorage {
            file,
            layout: Mutex::new(Layout::default()),
            dev: DeviceThrottle::new(profile, batch),
            path: path.as_ref().to_path_buf(),
        })
    }

    /// Unthrottled store (tests, setup paths).
    pub fn create_unthrottled<P: AsRef<Path>>(path: P) -> Result<Self> {
        Self::create(path, f64::INFINITY, f64::INFINITY)
    }

    /// The device model enforcing this store's transfer timing.
    pub fn device(&self) -> &DeviceThrottle {
        &self.dev
    }

    fn allocate(&self, len: u64) -> Extent {
        if len == 0 {
            // canonical empty extent: offset 0, so it never pins the
            // high-water mark (which shrinks when tail extents free)
            return Extent { offset: 0, len: 0 };
        }
        let mut l = self.layout.lock().unwrap();
        // best-fit over the free list
        let mut best: Option<usize> = None;
        for (i, e) in l.free.iter().enumerate() {
            if e.len >= len && best.is_none_or(|b| l.free[b].len > e.len) {
                best = Some(i);
            }
        }
        if let Some(i) = best {
            let e = l.free[i];
            if e.len == len {
                l.free.remove(i);
                return e;
            }
            l.free[i] = Extent { offset: e.offset + len, len: e.len - len };
            return Extent { offset: e.offset, len };
        }
        let e = Extent { offset: l.end, len };
        l.end += len;
        e
    }

    /// Return an extent to the free list (coalescing with neighbours).
    /// Caller holds the layout lock.
    fn free_extent(l: &mut Layout, e: Extent) {
        if e.len == 0 {
            return;
        }
        let idx = l.free.partition_point(|f| f.offset < e.offset);
        l.free.insert(idx, e);
        // coalesce with neighbours
        if idx + 1 < l.free.len() && l.free[idx].offset + l.free[idx].len == l.free[idx + 1].offset
        {
            l.free[idx].len += l.free[idx + 1].len;
            l.free.remove(idx + 1);
        }
        if idx > 0 && l.free[idx - 1].offset + l.free[idx - 1].len == l.free[idx].offset {
            l.free[idx - 1].len += l.free[idx].len;
            l.free.remove(idx);
        }
        // A trailing free extent is reclaimable space, not footprint: shrink
        // the high-water mark back to the last live byte (the free list is
        // coalesced, so at most one extent can touch `end`). Without this,
        // `footprint()` only ever grew — churny delete/put workloads made
        // the backing file look permanently as large as its worst moment.
        if let Some(&last) = l.free.last() {
            if last.offset + last.len == l.end {
                l.end = last.offset;
                l.free.pop();
            }
        }
    }

    /// Write `data` under `key` (replacing any previous object).
    ///
    /// The layout transition is atomic: the new extent is installed and the
    /// old one freed under a single lock acquisition, so concurrent puts to
    /// the same key cannot leak an extent (the delete-then-allocate window
    /// of the previous implementation). The data transfer itself happens
    /// outside the layout lock on the write throttle.
    pub fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let extent = self.allocate(data.len() as u64);
        self.dev.write(data.len() as u64);
        if let Err(e) = self.file.write_all_at(data, extent.offset) {
            // do not leak the extent we failed to fill
            Self::free_extent(&mut self.layout.lock().unwrap(), extent);
            return Err(e).with_context(|| format!("ssd write '{key}'"));
        }
        let mut l = self.layout.lock().unwrap();
        let gen = l.next_gen;
        l.next_gen += 1;
        if let Some(old) = l.objects.insert(key.to_string(), Obj { extent, gen }) {
            Self::free_extent(&mut l, old.extent);
        }
        Ok(())
    }

    /// Read the object at `key` into `out` (resized to fit). Only the extent
    /// lookup takes the layout lock; the positioned read runs concurrently
    /// with any other transfer. The read is generation-validated: if a
    /// racing `put` replaced (or a `delete` removed) the object mid-read —
    /// its old extent may already be recycled — the transfer retries against
    /// the current layout instead of returning torn bytes.
    pub fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        loop {
            let obj = *self
                .layout
                .lock()
                .unwrap()
                .objects
                .get(key)
                .ok_or_else(|| anyhow!("ssd: no object '{key}'"))?;
            self.dev.read(obj.extent.len);
            out.resize(obj.extent.len as usize, 0);
            self.file
                .read_exact_at(out, obj.extent.offset)
                .with_context(|| format!("ssd read '{key}'"))?;
            let l = self.layout.lock().unwrap();
            if l.objects.get(key).is_some_and(|o| o.gen == obj.gen) {
                return Ok(());
            }
            // replaced mid-read: loop and read the new object (or surface
            // "no object" if it was deleted)
        }
    }

    /// Remove an object if present; its extent is coalesced into the free list.
    pub fn delete(&self, key: &str) -> bool {
        let mut l = self.layout.lock().unwrap();
        if let Some(o) = l.objects.remove(key) {
            Self::free_extent(&mut l, o.extent);
            true
        } else {
            false
        }
    }

    /// Verify the layout invariant: object extents and free extents tile
    /// `[0, end)` exactly — no gap (a leaked extent), no overlap (a
    /// double-booked one) — and the free list is sorted and coalesced.
    /// Meaningful at quiescent points (no put in flight).
    pub fn check_consistency(&self) -> Result<()> {
        let l = self.layout.lock().unwrap();
        let mut extents: Vec<(u64, u64)> =
            l.objects.values().map(|o| (o.extent.offset, o.extent.len)).collect();
        extents.extend(l.free.iter().map(|e| (e.offset, e.len)));
        extents.sort_unstable();
        let mut cursor = 0u64;
        for (off, len) in &extents {
            ensure!(
                *off == cursor,
                "extent at {off} but coverage cursor at {cursor} (leak or overlap)"
            );
            cursor = off + len;
        }
        ensure!(cursor == l.end, "extents cover [0, {cursor}) but file end is {}", l.end);
        for w in l.free.windows(2) {
            ensure!(
                w[0].offset + w[0].len < w[1].offset,
                "free list not sorted/coalesced at offset {}",
                w[1].offset
            );
        }
        Ok(())
    }

    /// Total bytes currently held by live objects.
    pub fn live_bytes(&self) -> u64 {
        self.layout.lock().unwrap().objects.values().map(|o| o.extent.len).sum()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.layout.lock().unwrap().objects.contains_key(key)
    }

    pub fn len_of(&self, key: &str) -> Option<u64> {
        self.layout.lock().unwrap().objects.get(key).map(|o| o.extent.len)
    }

    /// Total bytes moved through the read / write paths.
    pub fn bytes_read(&self) -> u64 {
        self.dev.bytes_read()
    }

    pub fn bytes_written(&self) -> u64 {
        self.dev.bytes_written()
    }

    /// Current backing-file high-water mark.
    pub fn footprint(&self) -> u64 {
        self.layout.lock().unwrap().end
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    // Typed helpers for the f32 tensors the trainer stores. ----------------

    pub fn put_f32(&self, key: &str, data: &[f32]) -> Result<()> {
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        self.put(key, bytes)
    }

    /// Delegates to the [`super::store::TensorStore`] default, which stages
    /// the raw bytes in a reusable per-thread scratch buffer instead of
    /// allocating a fresh `Vec` per call (the prefetch hot path).
    pub fn get_f32(&self, key: &str, out: &mut Vec<f32>) -> Result<()> {
        super::store::TensorStore::get_f32(self, key, out)
    }
}

impl Drop for SsdStorage {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gs_ssd_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn put_get_roundtrip() {
        let ssd = SsdStorage::create_unthrottled(tmp("rt")).unwrap();
        ssd.put("a", b"hello world").unwrap();
        let mut out = Vec::new();
        ssd.get("a", &mut out).unwrap();
        assert_eq!(out, b"hello world");
        assert_eq!(ssd.bytes_written(), 11);
        assert_eq!(ssd.bytes_read(), 11);
    }

    #[test]
    fn missing_key_errors() {
        let ssd = SsdStorage::create_unthrottled(tmp("miss")).unwrap();
        let mut out = Vec::new();
        assert!(ssd.get("nope", &mut out).is_err());
    }

    #[test]
    fn overwrite_replaces() {
        let ssd = SsdStorage::create_unthrottled(tmp("ow")).unwrap();
        ssd.put("k", b"short").unwrap();
        ssd.put("k", b"a considerably longer value").unwrap();
        let mut out = Vec::new();
        ssd.get("k", &mut out).unwrap();
        assert_eq!(out, b"a considerably longer value");
    }

    #[test]
    fn freed_space_is_reused() {
        let ssd = SsdStorage::create_unthrottled(tmp("reuse")).unwrap();
        ssd.put("a", &[0u8; 1000]).unwrap();
        ssd.put("b", &[1u8; 1000]).unwrap();
        let fp = ssd.footprint();
        ssd.delete("a");
        ssd.put("c", &[2u8; 900]).unwrap(); // fits in a's hole
        assert_eq!(ssd.footprint(), fp);
        let mut out = Vec::new();
        ssd.get("b", &mut out).unwrap();
        assert_eq!(out, vec![1u8; 1000]);
    }

    #[test]
    fn free_list_coalesces() {
        let ssd = SsdStorage::create_unthrottled(tmp("coal")).unwrap();
        for (k, v) in [("a", 100), ("b", 100), ("c", 100)] {
            ssd.put(k, &vec![0u8; v]).unwrap();
        }
        ssd.delete("a");
        ssd.delete("c");
        ssd.delete("b"); // middle join: one 300-byte extent
        ssd.put("big", &[7u8; 300]).unwrap();
        assert_eq!(ssd.footprint(), 300);
    }

    /// Regression: the high-water mark used to only ever grow — freeing a
    /// tail extent (via `delete` or a shrinking `put`) must give the space
    /// back, coalescing through interior holes that reach the end.
    #[test]
    fn footprint_shrinks_when_tail_extent_freed() {
        let ssd = SsdStorage::create_unthrottled(tmp("shrink")).unwrap();
        for (k, v) in [("a", 100), ("b", 100), ("c", 100)] {
            ssd.put(k, &vec![0u8; v]).unwrap();
        }
        assert_eq!(ssd.footprint(), 300);
        ssd.delete("c"); // tail extent: reclaimed immediately
        assert_eq!(ssd.footprint(), 200);
        ssd.delete("a"); // interior hole: footprint unchanged
        assert_eq!(ssd.footprint(), 200);
        ssd.delete("b"); // coalesces [0,100)+[100,200) through to the end
        assert_eq!(ssd.footprint(), 0);
        ssd.check_consistency().unwrap();
        // a put that frees the old tail extent (its new bytes land in an
        // interior hole) reclaims the tail too
        ssd.put("a", &[1u8; 100]).unwrap();
        ssd.put("t", &[2u8; 100]).unwrap();
        assert_eq!(ssd.footprint(), 200);
        ssd.delete("a"); // interior hole: footprint unchanged
        assert_eq!(ssd.footprint(), 200);
        ssd.put("t", &[3u8; 50]).unwrap(); // fits the hole; old tail freed
        assert_eq!(ssd.footprint(), 50, "put freeing the tail must shrink");
        ssd.check_consistency().unwrap();
        let mut out = Vec::new();
        ssd.get("t", &mut out).unwrap();
        assert_eq!(out, vec![3u8; 50]);
    }

    #[test]
    fn get_f32_rejects_unaligned_length() {
        let ssd = SsdStorage::create_unthrottled(tmp("unaligned")).unwrap();
        ssd.put("bad", &[1u8, 2, 3, 4, 5]).unwrap();
        let mut out = vec![9.0f32];
        let err = ssd.get_f32("bad", &mut out).unwrap_err().to_string();
        assert!(err.contains("f32-aligned"), "{err}");
    }

    #[test]
    fn f32_roundtrip() {
        let ssd = SsdStorage::create_unthrottled(tmp("f32")).unwrap();
        let xs: Vec<f32> = (0..257).map(|i| i as f32 * 0.5).collect();
        ssd.put_f32("t", &xs).unwrap();
        let mut out = Vec::new();
        ssd.get_f32("t", &mut out).unwrap();
        assert_eq!(out, xs);
    }

    #[test]
    fn throttled_write_takes_time() {
        let ssd = SsdStorage::create(tmp("thr"), f64::INFINITY, 10_000_000.0).unwrap();
        let t0 = std::time::Instant::now();
        ssd.put("x", &vec![0u8; 500_000]).unwrap(); // 50 ms at 10 MB/s
        assert!(t0.elapsed() >= std::time::Duration::from_millis(45));
    }

    /// Regression for the `delete`-then-`allocate` race: two concurrent puts
    /// to the same key used to leak the loser's extent (never freed, never
    /// reachable). Hammer the same key from many threads — with concurrent
    /// readers of that key, which the generation-validated `get` must never
    /// hand torn bytes — then verify the layout still tiles the file exactly.
    #[test]
    fn hammer_same_key_puts_never_leak_extents() {
        let ssd = std::sync::Arc::new(SsdStorage::create_unthrottled(tmp("hammer")).unwrap());
        ssd.put("hot", &[255u8; 64]).unwrap(); // readers always find the key
        let mut handles: Vec<_> = (0..8u8)
            .map(|t| {
                let ssd = std::sync::Arc::clone(&ssd);
                std::thread::spawn(move || {
                    for i in 0..50usize {
                        let len = 256 + (t as usize * 37 + i * 13) % 512;
                        ssd.put("hot", &vec![t; len]).unwrap();
                        let own = format!("own{t}");
                        ssd.put(&own, &[t; 128]).unwrap();
                        let mut out = Vec::new();
                        ssd.get(&own, &mut out).unwrap();
                        assert_eq!(out, vec![t; 128], "private key torn by a racer");
                    }
                })
            })
            .collect();
        for _ in 0..2 {
            let ssd = std::sync::Arc::clone(&ssd);
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    let mut out = Vec::new();
                    ssd.get("hot", &mut out).unwrap();
                    // every writer writes a constant fill, so any successful
                    // read must be uniform — torn reads would mix writers
                    assert!(
                        !out.is_empty() && out.iter().all(|&b| b == out[0]),
                        "torn read: {out:?}"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        ssd.check_consistency().unwrap();
        // the winner's payload is intact (one writer's constant bytes)
        let mut out = Vec::new();
        ssd.get("hot", &mut out).unwrap();
        assert!(!out.is_empty() && out.iter().all(|&b| b == out[0]), "{out:?}");
        // delete everything: the free list must cover the whole file again —
        // a leaked extent would leave a hole
        ssd.delete("hot");
        for t in 0..8u8 {
            ssd.delete(&format!("own{t}"));
        }
        ssd.check_consistency().unwrap();
        assert_eq!(ssd.live_bytes(), 0);
    }

    /// Positioned I/O: a throttled read and a throttled write overlap
    /// instead of serializing on a shared seek lock.
    #[test]
    fn read_and_write_paths_proceed_in_parallel() {
        let ssd = std::sync::Arc::new(
            SsdStorage::create(tmp("parallel"), 10_000_000.0, 10_000_000.0).unwrap(),
        );
        ssd.put("src", &vec![3u8; 500_000]).unwrap(); // pre-seed (50 ms write)
        let t0 = std::time::Instant::now();
        let reader = {
            let ssd = std::sync::Arc::clone(&ssd);
            std::thread::spawn(move || {
                let mut out = Vec::new();
                ssd.get("src", &mut out).unwrap(); // 50 ms at 10 MB/s
                assert_eq!(out.len(), 500_000);
            })
        };
        ssd.put("dst", &vec![4u8; 500_000]).unwrap(); // 50 ms at 10 MB/s
        reader.join().unwrap();
        let dt = t0.elapsed();
        // parallel: ~50 ms; serialized they would need ~100 ms
        assert!(dt < std::time::Duration::from_millis(95), "{dt:?}");
    }

    /// The `with_profile` satellite: a flat profile is bit-identical to the
    /// plain `create` store (same stored bytes, same counters) AND
    /// timing-equivalent within tolerance — every pre-profile suite keeps
    /// its meaning.
    #[test]
    fn flat_profile_side_by_side_with_create() {
        use super::super::throttle::DeviceProfile;
        let rate = 20_000_000.0; // 20 MB/s
        let plain = SsdStorage::create(tmp("flat_plain"), rate, rate).unwrap();
        let prof = SsdStorage::with_profile(
            tmp("flat_prof"),
            DeviceProfile::flat(rate, rate),
            None,
        )
        .unwrap();
        let blob: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        let time = |s: &SsdStorage| {
            let t0 = std::time::Instant::now();
            s.put("k", &blob).unwrap(); // 10 ms at 20 MB/s
            let mut out = Vec::new();
            s.get("k", &mut out).unwrap(); // 10 ms
            (t0.elapsed(), out)
        };
        let (dt_plain, out_plain) = time(&plain);
        let (dt_prof, out_prof) = time(&prof);
        // bit identity of the data plane and the counters
        assert_eq!(out_plain, out_prof);
        assert_eq!(out_prof, blob);
        assert_eq!(plain.bytes_read(), prof.bytes_read());
        assert_eq!(plain.bytes_written(), prof.bytes_written());
        assert_eq!(prof.device().batched_ops(), 0, "flat profiles never batch");
        // timing equivalence within tolerance (both should be ~20 ms)
        let (a, b) = (dt_plain.as_secs_f64(), dt_prof.as_secs_f64());
        assert!(a >= 0.018 && b >= 0.018, "{a} {b}");
        assert!((a - b).abs() < 0.5 * a.max(b), "flat timing diverged: {a}s vs {b}s");
    }

    /// A profiled + batched device stores the same bytes as the flat one;
    /// only wall time differs, and the batcher actually coalesces under
    /// concurrent small puts.
    #[test]
    fn profiled_batched_device_round_trips_and_coalesces() {
        use super::super::throttle::{BatchConfig, DeviceProfile};
        let profile = DeviceProfile {
            qd_knee: 4,
            sat_bytes: 1 << 20,
            mix_penalty: 0.1,
            op_latency_s: 5e-4,
            ..DeviceProfile::flat(f64::INFINITY, f64::INFINITY)
        };
        let ssd = std::sync::Arc::new(
            SsdStorage::with_profile(
                tmp("profbatch"),
                profile,
                Some(BatchConfig { max_bytes: 1 << 20, max_ops: 8 }),
            )
            .unwrap(),
        );
        let handles: Vec<_> = (0..4u8)
            .map(|t| {
                let ssd = std::sync::Arc::clone(&ssd);
                std::thread::spawn(move || {
                    for i in 0..8usize {
                        let key = format!("k{t}_{i}");
                        ssd.put(&key, &vec![t; 4096 + i]).unwrap();
                        let mut out = Vec::new();
                        ssd.get(&key, &mut out).unwrap();
                        assert_eq!(out, vec![t; 4096 + i]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        ssd.check_consistency().unwrap();
        assert!(ssd.device().batched_ops() > 0, "no submission ever joined a window");
        assert_eq!(ssd.bytes_read(), ssd.bytes_written());
    }

    #[test]
    fn consistency_check_passes_through_churn() {
        let ssd = SsdStorage::create_unthrottled(tmp("churn")).unwrap();
        for round in 0..5usize {
            for k in 0..10usize {
                ssd.put(&format!("k{k}"), &vec![k as u8; 100 + 77 * ((k + round) % 5)])
                    .unwrap();
            }
            for k in (0..10usize).step_by(2) {
                ssd.delete(&format!("k{k}"));
            }
            ssd.check_consistency().unwrap();
        }
    }

    #[test]
    fn concurrent_puts_and_gets() {
        let ssd = std::sync::Arc::new(SsdStorage::create_unthrottled(tmp("conc")).unwrap());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let ssd = std::sync::Arc::clone(&ssd);
                std::thread::spawn(move || {
                    let data = vec![i as u8; 10_000];
                    let key = format!("k{i}");
                    ssd.put(&key, &data).unwrap();
                    let mut out = Vec::new();
                    ssd.get(&key, &mut out).unwrap();
                    assert_eq!(out, data);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
