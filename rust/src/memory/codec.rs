//! Mixed-precision storage codecs under [`TensorStore`] — the encode/decode
//! layer that halves the SSD byte volume.
//!
//! Every object the coordinator persists used to hit the store as raw f32.
//! [`Codec`] adds the two half-precision wire formats (IEEE binary16 and
//! bfloat16, both round-to-nearest-even), and [`PrecisionPolicy`] maps each
//! data [`Category`] — derived from the structured key prefixes
//! (`opt_*`/`ilc_*`, see [`category_of`]) — to the codec it is stored with.
//! The default mixed policy follows MLP-Offload / SSDTrain: parameters and
//! activation checkpoints travel in half precision while master weights and
//! both Adam moments stay f32, and gradients are converted *delayed
//! in-place* during the per-shard optimizer update (see `coordinator::opt`)
//! rather than in a separate pass.
//!
//! [`CodecStore`] applies a policy transparently on top of ANY inner
//! [`TensorStore`] (single SSD, striped, DRAM-cached): the typed
//! `put_f32`/`get_f32` helpers encode/decode at the boundary, while the raw
//! byte API and every counter (`bytes_read`/`bytes_written`, footprint,
//! cache stats, `len_of`) speak *encoded* bytes — the traffic and capacity
//! that actually exist below the codec. Under the strict-f32 policy the
//! wrapper short-circuits to the inner typed helpers, byte-identical to not
//! wrapping at all (the bit-identity tier of the equivalence contract in
//! [`crate::memory::store`]).

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::store::{category_of, CacheStats, TensorStore};
use super::tier::Category;
use crate::util::{bf16, f16};

// ---------------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------------

/// Storage wire format for one f32 tensor object.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Codec {
    /// Raw little-endian f32 — the historical format, bit-exact.
    F32,
    /// IEEE 754 binary16: 10 significand bits, narrow range (max 65504),
    /// gradual underflow. Relative roundtrip error ≤ 2⁻¹¹ for in-range
    /// normals.
    F16,
    /// bfloat16: 7 explicit significand bits, full f32 exponent range.
    /// Relative roundtrip error ≤ 2⁻⁸; never overflows where f32 doesn't.
    BF16,
}

impl Codec {
    /// Stored bytes per f32 element.
    pub fn bytes_per_elem(self) -> u64 {
        match self {
            Codec::F32 => 4,
            Codec::F16 | Codec::BF16 => 2,
        }
    }

    /// Encoded byte length of an `n`-element f32 tensor (the length law:
    /// `encoded_len(n) = n * bytes_per_elem()`).
    pub fn encoded_len(self, n: usize) -> usize {
        n * self.bytes_per_elem() as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::F16 => "f16",
            Codec::BF16 => "bf16",
        }
    }

    /// Encode `src` into `out` (cleared first) as this codec's wire format.
    pub fn encode_into(self, src: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(self.encoded_len(src.len()));
        match self {
            Codec::F32 => {
                let bytes = unsafe {
                    std::slice::from_raw_parts(src.as_ptr() as *const u8, src.len() * 4)
                };
                out.extend_from_slice(bytes);
            }
            Codec::F16 => {
                for &x in src {
                    out.extend_from_slice(&f16::f32_to_f16(x).to_le_bytes());
                }
            }
            Codec::BF16 => {
                for &x in src {
                    out.extend_from_slice(&bf16::f32_to_bf16(x).to_le_bytes());
                }
            }
        }
    }

    /// Decode an encoded byte object back to f32s. Errors (instead of
    /// truncating) when the byte length is not a whole number of encoded
    /// elements — a corrupt or policy-mismatched object.
    pub fn decode_into(self, key: &str, src: &[u8], out: &mut Vec<f32>) -> Result<()> {
        let w = self.bytes_per_elem() as usize;
        ensure!(
            src.len() % w == 0,
            "object '{key}' not {}-aligned ({} bytes)",
            self.name(),
            src.len()
        );
        out.clear();
        out.reserve(src.len() / w);
        match self {
            Codec::F32 => {
                out.resize(src.len() / 4, 0.0);
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        src.as_ptr(),
                        out.as_mut_ptr() as *mut u8,
                        src.len(),
                    );
                }
            }
            Codec::F16 => {
                out.extend(src.chunks_exact(2).map(|c| {
                    f16::f16_to_f32(u16::from_le_bytes([c[0], c[1]]))
                }));
            }
            Codec::BF16 => {
                out.extend(src.chunks_exact(2).map(|c| {
                    bf16::bf16_to_f32(u16::from_le_bytes([c[0], c[1]]))
                }));
            }
        }
        Ok(())
    }

    /// Round every element through this codec in place — the delayed
    /// in-place conversion the optimizer applies to the gradient shard it is
    /// about to consume. A no-op at [`Codec::F32`].
    pub fn requantize(self, xs: &mut [f32]) {
        match self {
            Codec::F32 => {}
            Codec::F16 => {
                for x in xs {
                    *x = f16::f16_to_f32(f16::f32_to_f16(*x));
                }
            }
            Codec::BF16 => {
                for x in xs {
                    *x = bf16::bf16_to_f32(bf16::f32_to_bf16(*x));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PrecisionPolicy / Precision
// ---------------------------------------------------------------------------

/// Which codec each class of trainer data is stored (or requantized) with.
///
/// The store-visible classes map through [`category_of`]: `opt_*` moment
/// objects use `optimizer`, `ilc_*` checkpoints use `checkpoints`, anything
/// else uses `working`. `parameters` governs the low-precision parameter
/// stream the engine accounts per layer load, and `gradients` governs the
/// delayed in-place conversion inside the per-shard optimizer update —
/// neither touches the store directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrecisionPolicy {
    pub parameters: Codec,
    pub gradients: Codec,
    pub optimizer: Codec,
    pub checkpoints: Codec,
    pub working: Codec,
}

impl PrecisionPolicy {
    /// Everything raw f32 — the bit-identity baseline.
    pub const STRICT_F32: PrecisionPolicy = PrecisionPolicy {
        parameters: Codec::F32,
        gradients: Codec::F32,
        optimizer: Codec::F32,
        checkpoints: Codec::F32,
        working: Codec::F32,
    };

    /// The default mixed policy: parameters, gradients, and activation
    /// checkpoints in `half`; master weights and both Adam moments f32.
    pub fn mixed(half: Codec) -> PrecisionPolicy {
        PrecisionPolicy {
            parameters: half,
            gradients: half,
            optimizer: Codec::F32,
            checkpoints: half,
            working: Codec::F32,
        }
    }

    /// The codec storing objects of `cat`.
    pub fn codec_for(&self, cat: Category) -> Codec {
        match cat {
            Category::OptimizerStates => self.optimizer,
            Category::Checkpoints => self.checkpoints,
            _ => self.working,
        }
    }

    /// The codec storing the object at `key` (via its key-prefix category).
    pub fn codec_for_key(&self, key: &str) -> Codec {
        self.codec_for(category_of(key))
    }

    /// True iff every class is [`Codec::F32`] — the policy under which the
    /// codec layer is a byte-for-byte identity.
    pub fn is_strict_f32(&self) -> bool {
        *self == Self::STRICT_F32
    }
}

/// The `--precision` CLI axis: strict f32 or one of the two mixed policies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    #[default]
    F32,
    MixedF16,
    MixedBf16,
}

impl Precision {
    /// Parse a `--precision` / `GS_TEST_PRECISION` spelling. Accepts the
    /// full `mixed:` forms and the bare half names used by the CI matrix.
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "mixed:f16" | "f16" => Ok(Precision::MixedF16),
            "mixed:bf16" | "bf16" => Ok(Precision::MixedBf16),
            other => bail!("unknown precision '{other}' (expected f32 | mixed:f16 | mixed:bf16)"),
        }
    }

    pub fn policy(self) -> PrecisionPolicy {
        match self {
            Precision::F32 => PrecisionPolicy::STRICT_F32,
            Precision::MixedF16 => PrecisionPolicy::mixed(Codec::F16),
            Precision::MixedBf16 => PrecisionPolicy::mixed(Codec::BF16),
        }
    }

    /// The half-precision storage codec, if any.
    pub fn half_codec(self) -> Option<Codec> {
        match self {
            Precision::F32 => None,
            Precision::MixedF16 => Some(Codec::F16),
            Precision::MixedBf16 => Some(Codec::BF16),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::MixedF16 => "mixed:f16",
            Precision::MixedBf16 => "mixed:bf16",
        })
    }
}

// ---------------------------------------------------------------------------
// CodecStore
// ---------------------------------------------------------------------------

thread_local! {
    /// Reusable encode/decode staging buffer (one per thread, like the
    /// `get_f32` scratch in `store.rs`): the codec boundary is on the
    /// prefetch hot path, so it must not allocate per call.
    static CODEC_SCRATCH: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// A [`TensorStore`] adapter that applies a [`PrecisionPolicy`] at the typed
/// f32 boundary and passes everything else — raw byte API, counters,
/// capacity — through to the inner store in *encoded* bytes.
pub struct CodecStore {
    inner: Arc<dyn TensorStore>,
    policy: PrecisionPolicy,
}

impl CodecStore {
    pub fn new(inner: Arc<dyn TensorStore>, policy: PrecisionPolicy) -> Self {
        CodecStore { inner, policy }
    }

    pub fn policy(&self) -> &PrecisionPolicy {
        &self.policy
    }
}

impl TensorStore for CodecStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.inner.put(key, data)
    }

    fn get(&self, key: &str, out: &mut Vec<u8>) -> Result<()> {
        self.inner.get(key, out)
    }

    fn delete(&self, key: &str) -> bool {
        self.inner.delete(key)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }

    fn len_of(&self, key: &str) -> Option<u64> {
        self.inner.len_of(key)
    }

    fn bytes_read(&self) -> u64 {
        self.inner.bytes_read()
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn footprint(&self) -> u64 {
        self.inner.footprint()
    }

    fn cache_stats(&self) -> CacheStats {
        self.inner.cache_stats()
    }

    // epoch hooks forward to the (possibly journaling) layer below — the
    // journal's undo records therefore hold encoded at-rest bytes, and
    // rollback restores them byte-exactly under any policy
    fn commit_epoch(&self) -> Result<()> {
        self.inner.commit_epoch()
    }

    fn recover(&self) -> Result<()> {
        self.inner.recover()
    }

    fn committed_epoch(&self) -> u64 {
        self.inner.committed_epoch()
    }

    fn put_f32(&self, key: &str, data: &[f32]) -> Result<()> {
        let codec = self.policy.codec_for_key(key);
        if codec == Codec::F32 {
            return self.inner.put_f32(key, data);
        }
        let mut buf = CODEC_SCRATCH.with(|c| std::mem::take(&mut *c.borrow_mut()));
        codec.encode_into(data, &mut buf);
        let res = self.inner.put(key, &buf);
        CODEC_SCRATCH.with(|c| *c.borrow_mut() = buf);
        res
    }

    fn get_f32(&self, key: &str, out: &mut Vec<f32>) -> Result<()> {
        let codec = self.policy.codec_for_key(key);
        if codec == Codec::F32 {
            return self.inner.get_f32(key, out);
        }
        let mut buf = CODEC_SCRATCH.with(|c| std::mem::take(&mut *c.borrow_mut()));
        let res = self
            .inner
            .get(key, &mut buf)
            .and_then(|()| codec.decode_into(key, &buf, out));
        CODEC_SCRATCH.with(|c| *c.borrow_mut() = buf);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::store::{CachedStore, StripedStore};
    use crate::memory::SsdStorage;
    use crate::util::prng::Prng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gs_codec_test_{name}_{}", std::process::id()))
    }

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n).map(|_| (p.next_f64() as f32 - 0.5) * 8.0).collect()
    }

    #[test]
    fn encoded_length_laws() {
        let xs = sample(1000, 1);
        let mut buf = Vec::new();
        for codec in [Codec::F32, Codec::F16, Codec::BF16] {
            codec.encode_into(&xs, &mut buf);
            assert_eq!(buf.len(), codec.encoded_len(xs.len()));
            assert_eq!(buf.len() as u64, xs.len() as u64 * codec.bytes_per_elem());
        }
    }

    #[test]
    fn decode_rejects_misaligned_lengths() {
        let mut out = Vec::new();
        for codec in [Codec::F16, Codec::BF16] {
            let err = codec.decode_into("k", &[1u8, 2, 3], &mut out).unwrap_err();
            assert!(err.to_string().contains("aligned"), "{err}");
        }
        let err = Codec::F32.decode_into("k", &[1u8, 2, 3], &mut out).unwrap_err();
        assert!(err.to_string().contains("aligned"), "{err}");
    }

    #[test]
    fn roundtrip_matches_requantize() {
        // decode(encode(x)) must equal the in-place requantize of x — the
        // optimizer's delayed conversion sees the same values the store
        // would have handed back.
        let xs = sample(4096, 2);
        let mut buf = Vec::new();
        let mut back = Vec::new();
        for codec in [Codec::F32, Codec::F16, Codec::BF16] {
            codec.encode_into(&xs, &mut buf);
            codec.decode_into("k", &buf, &mut back).unwrap();
            let mut req = xs.clone();
            codec.requantize(&mut req);
            for (a, b) in back.iter().zip(&req) {
                assert_eq!(a.to_bits(), b.to_bits(), "{codec:?}");
            }
        }
    }

    #[test]
    fn policy_maps_categories_and_prefixes() {
        let p = PrecisionPolicy::mixed(Codec::F16);
        assert_eq!(p.codec_for(Category::OptimizerStates), Codec::F32);
        assert_eq!(p.codec_for(Category::Checkpoints), Codec::F16);
        assert_eq!(p.codec_for_key("opt_m_l0_t0_e"), Codec::F32);
        assert_eq!(p.codec_for_key("ilc_ckpt_l0_mb2"), Codec::F16);
        assert_eq!(p.codec_for_key("misc"), Codec::F32);
        assert!(!p.is_strict_f32());
        assert!(PrecisionPolicy::STRICT_F32.is_strict_f32());
        assert!(Precision::F32.policy().is_strict_f32());
    }

    #[test]
    fn precision_parse_and_display() {
        for (s, p) in [
            ("f32", Precision::F32),
            ("mixed:f16", Precision::MixedF16),
            ("f16", Precision::MixedF16),
            ("mixed:bf16", Precision::MixedBf16),
            ("bf16", Precision::MixedBf16),
        ] {
            assert_eq!(Precision::parse(s).unwrap(), p, "{s}");
        }
        assert!(Precision::parse("fp8").is_err());
        assert_eq!(Precision::MixedF16.to_string(), "mixed:f16");
        assert_eq!(Precision::parse(&Precision::MixedBf16.to_string()).unwrap(),
            Precision::MixedBf16);
    }

    #[test]
    fn strict_f32_codec_store_is_byte_identical_to_bare_store() {
        let bare = SsdStorage::create_unthrottled(tmp("id_bare")).unwrap();
        let wrapped = CodecStore::new(
            Arc::new(SsdStorage::create_unthrottled(tmp("id_wrap")).unwrap()),
            PrecisionPolicy::STRICT_F32,
        );
        let xs = sample(777, 3);
        bare.put_f32("ilc_x", &xs).unwrap();
        wrapped.put_f32("ilc_x", &xs).unwrap();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        TensorStore::get(&bare, "ilc_x", &mut a).unwrap();
        wrapped.get("ilc_x", &mut b).unwrap();
        assert_eq!(a, b);
        assert_eq!(wrapped.bytes_written(), bare.bytes_written());
        let mut back = Vec::new();
        wrapped.get_f32("ilc_x", &mut back).unwrap();
        for (x, y) in xs.iter().zip(&back) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// The headline byte halving, measured at the store counters: an f16
    /// checkpoint working set moves exactly 0.5× the bytes of its f32 twin
    /// (param + checkpoint traffic ≤ 0.55× is the PR's acceptance bound).
    #[test]
    fn mixed_f16_halves_checkpoint_store_traffic() {
        let strict = CodecStore::new(
            Arc::new(SsdStorage::create_unthrottled(tmp("ratio_f32")).unwrap()),
            Precision::F32.policy(),
        );
        let mixed = CodecStore::new(
            Arc::new(SsdStorage::create_unthrottled(tmp("ratio_f16")).unwrap()),
            Precision::MixedF16.policy(),
        );
        let xs = sample(8192, 4);
        let mut out = Vec::new();
        for store in [&strict, &mixed] {
            for i in 0..8 {
                store.put_f32(&format!("ilc_ckpt_l{i}"), &xs).unwrap();
                store.get_f32(&format!("ilc_ckpt_l{i}"), &mut out).unwrap();
            }
        }
        let strict_traffic = strict.bytes_read() + strict.bytes_written();
        let mixed_traffic = mixed.bytes_read() + mixed.bytes_written();
        assert_eq!(mixed_traffic * 2, strict_traffic);
        assert_eq!(mixed.len_of("ilc_ckpt_l0"), Some(8192 * 2));
        // moments stay f32 under the mixed policy
        mixed.put_f32("opt_m_l0_t0_e", &xs).unwrap();
        assert_eq!(mixed.len_of("opt_m_l0_t0_e"), Some(8192 * 4));
    }

    /// Satellite: a half-precision working set fits in a cache its f32 twin
    /// overflows — the `Tier` reserve/release accounting runs on encoded
    /// bytes because the codec sits ABOVE the cache.
    #[test]
    fn cached_store_accounts_encoded_bytes() {
        let n = 1024usize; // 4 KiB raw, 2 KiB encoded per object
        let objs = 8usize;
        let capacity = (objs * n * 2) as u64; // fits encoded, not raw
        let build = |name: &str, prec: Precision| {
            let inner: Arc<dyn TensorStore> =
                Arc::new(SsdStorage::create_unthrottled(tmp(name)).unwrap());
            let cached: Arc<dyn TensorStore> = Arc::new(CachedStore::new(inner, capacity));
            CodecStore::new(cached, prec.policy())
        };
        let xs = sample(n, 5);
        let mut out = Vec::new();
        for (prec, name) in [(Precision::MixedF16, "enc_f16"), (Precision::F32, "enc_f32")] {
            let store = build(name, prec);
            for round in 0..3 {
                for i in 0..objs {
                    let key = format!("ilc_ws_{i}");
                    if round == 0 {
                        store.put_f32(&key, &xs).unwrap();
                    }
                    store.get_f32(&key, &mut out).unwrap();
                }
            }
            let stats = store.cache_stats();
            match prec {
                Precision::MixedF16 => {
                    assert_eq!(stats.total.evictions, 0, "f16 working set must fit");
                    assert_eq!(stats.total.misses, 0);
                    assert_eq!(store.bytes_read() + store.bytes_written(), 0);
                }
                _ => {
                    assert!(stats.total.evictions > 0, "f32 twin must overflow: {stats:?}");
                    assert!(store.bytes_written() > 0);
                }
            }
        }
    }

    /// Satellite: `ssd` ≡ `striped` ≡ `cached` byte-for-byte under every
    /// codec — backends still only change where encoded bytes live.
    #[test]
    fn backends_byte_identical_under_every_codec() {
        let xs = sample(5000, 6);
        for (ci, codec) in [Codec::F32, Codec::F16, Codec::BF16].iter().enumerate() {
            let policy = PrecisionPolicy {
                parameters: *codec,
                gradients: *codec,
                optimizer: *codec,
                checkpoints: *codec,
                working: *codec,
            };
            let ssd: Arc<dyn TensorStore> = Arc::new(
                SsdStorage::create_unthrottled(tmp(&format!("xb_ssd{ci}"))).unwrap(),
            );
            let striped: Arc<dyn TensorStore> = Arc::new(
                StripedStore::create(tmp(&format!("xb_str{ci}")), 3, f64::INFINITY, f64::INFINITY)
                    .unwrap(),
            );
            let cached: Arc<dyn TensorStore> = Arc::new(CachedStore::new(
                Arc::new(SsdStorage::create_unthrottled(tmp(&format!("xb_cin{ci}"))).unwrap()),
                4096, // small: forces eviction churn through the backing store
            ));
            let stores: Vec<CodecStore> = [ssd, striped, cached]
                .into_iter()
                .map(|inner| CodecStore::new(inner, policy))
                .collect();
            for (k, key) in ["opt_m_l0_t0_e", "ilc_ckpt_l1_mb0", "scratch"].iter().enumerate() {
                let data = &xs[k * 1000..k * 1000 + 1000];
                let mut raw: Vec<Vec<u8>> = Vec::new();
                let mut dec: Vec<Vec<f32>> = Vec::new();
                for s in &stores {
                    s.put_f32(key, data).unwrap();
                    let mut bytes = Vec::new();
                    s.get(key, &mut bytes).unwrap();
                    let mut vals = Vec::new();
                    s.get_f32(key, &mut vals).unwrap();
                    assert_eq!(bytes.len(), codec.encoded_len(data.len()), "{key}");
                    raw.push(bytes);
                    dec.push(vals);
                }
                assert_eq!(raw[0], raw[1], "{codec:?}/{key}: ssd vs striped");
                assert_eq!(raw[0], raw[2], "{codec:?}/{key}: ssd vs cached");
                for d in &dec[1..] {
                    for (a, b) in dec[0].iter().zip(d) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
            }
        }
    }
}
