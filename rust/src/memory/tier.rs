//! Capacity-accounted memory tier (models GPU memory / CPU DRAM).
//!
//! The real allocations live in ordinary process memory; the tier enforces a
//! *budget* so schedules that would not fit on the paper's hardware fail here
//! too, with per-category accounting (parameters, checkpoints, gradients,
//! optimizer states, working buffers) mirroring the LP constraints of §4.5.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

/// Data categories tracked by a tier (the LP's variables, plus the serve
/// path's per-tenant adapter deltas).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Category {
    Parameters,
    Checkpoints,
    Gradients,
    OptimizerStates,
    /// Per-tenant fine-tuning deltas (`adapter_*` store keys) — small
    /// objects riding the shared base image in the multi-tenant serve path.
    Adapters,
    Working,
}

impl Category {
    pub const ALL: [Category; 6] = [
        Category::Parameters,
        Category::Checkpoints,
        Category::Gradients,
        Category::OptimizerStates,
        Category::Adapters,
        Category::Working,
    ];
}

#[derive(Default, Debug)]
struct Usage {
    used: u64,
    peak: u64,
    by_cat: BTreeMap<Category, u64>,
}

/// A named, capacity-limited memory tier.
#[derive(Debug)]
pub struct Tier {
    name: String,
    capacity: u64,
    usage: Mutex<Usage>,
}

/// RAII allocation ticket; returns its bytes to the tier on drop.
pub struct Allocation<'t> {
    tier: &'t Tier,
    bytes: u64,
    cat: Category,
}

impl Tier {
    pub fn new(name: &str, capacity_bytes: u64) -> Self {
        Tier { name: name.to_string(), capacity: capacity_bytes, usage: Mutex::new(Usage::default()) }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Reserve `bytes` under `cat`; fails if it would exceed capacity.
    pub fn alloc(&self, bytes: u64, cat: Category) -> Result<Allocation<'_>> {
        self.reserve(bytes, cat)?;
        Ok(Allocation { tier: self, bytes, cat })
    }

    /// Reserve `bytes` under `cat` WITHOUT an RAII ticket — for long-lived
    /// holders (the [`crate::memory::store::CachedStore`] cache entries)
    /// that pair every reservation with an explicit [`Tier::release`].
    pub fn reserve(&self, bytes: u64, cat: Category) -> Result<()> {
        let mut u = self.usage.lock().unwrap();
        if u.used + bytes > self.capacity {
            bail!(
                "{}: out of memory — requested {} with {}/{} used (would need {})",
                self.name,
                crate::util::stats::fmt_bytes(bytes as f64),
                crate::util::stats::fmt_bytes(u.used as f64),
                crate::util::stats::fmt_bytes(self.capacity as f64),
                crate::util::stats::fmt_bytes((u.used + bytes) as f64),
            );
        }
        u.used += bytes;
        u.peak = u.peak.max(u.used);
        *u.by_cat.entry(cat).or_default() += bytes;
        Ok(())
    }

    pub fn used(&self) -> u64 {
        self.usage.lock().unwrap().used
    }

    pub fn peak(&self) -> u64 {
        self.usage.lock().unwrap().peak
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used()
    }

    pub fn used_by(&self, cat: Category) -> u64 {
        self.usage.lock().unwrap().by_cat.get(&cat).copied().unwrap_or(0)
    }

    /// Return `bytes` reserved under `cat` (the pair of [`Tier::reserve`];
    /// [`Allocation`] calls this on drop).
    pub fn release(&self, bytes: u64, cat: Category) {
        let mut u = self.usage.lock().unwrap();
        u.used -= bytes;
        if let Some(c) = u.by_cat.get_mut(&cat) {
            *c -= bytes;
        }
    }
}

impl Allocation<'_> {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Shrink the allocation in place (checkpoint memory reclaimed for
    /// delayed-step gradients, §4.4).
    pub fn shrink_to(&mut self, new_bytes: u64) {
        assert!(new_bytes <= self.bytes);
        self.tier.release(self.bytes - new_bytes, self.cat);
        self.bytes = new_bytes;
    }
}

impl Drop for Allocation<'_> {
    fn drop(&mut self) {
        self.tier.release(self.bytes, self.cat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let t = Tier::new("gpu", 1000);
        {
            let a = t.alloc(600, Category::Parameters).unwrap();
            assert_eq!(t.used(), 600);
            assert_eq!(a.bytes(), 600);
        }
        assert_eq!(t.used(), 0);
        assert_eq!(t.peak(), 600);
    }

    #[test]
    fn oom_rejected() {
        let t = Tier::new("gpu", 100);
        let _a = t.alloc(80, Category::Working).unwrap();
        assert!(t.alloc(30, Category::Working).is_err());
        assert_eq!(t.used(), 80); // failed alloc must not leak accounting
    }

    #[test]
    fn per_category_accounting() {
        let t = Tier::new("cpu", 1000);
        let _p = t.alloc(100, Category::Parameters).unwrap();
        let _c = t.alloc(200, Category::Checkpoints).unwrap();
        assert_eq!(t.used_by(Category::Parameters), 100);
        assert_eq!(t.used_by(Category::Checkpoints), 200);
        assert_eq!(t.used_by(Category::Gradients), 0);
    }

    #[test]
    fn shrink_reclaims() {
        let t = Tier::new("cpu", 1000);
        let mut a = t.alloc(500, Category::Checkpoints).unwrap();
        a.shrink_to(100);
        assert_eq!(t.used(), 100);
        assert_eq!(t.free_bytes(), 900);
    }

    #[test]
    fn owned_reserve_release_roundtrip() {
        let t = Tier::new("cache", 1000);
        t.reserve(600, Category::OptimizerStates).unwrap();
        assert_eq!(t.used(), 600);
        assert!(t.reserve(500, Category::OptimizerStates).is_err());
        t.release(600, Category::OptimizerStates);
        assert_eq!(t.used(), 0);
        assert_eq!(t.peak(), 600);
    }

    #[test]
    fn peak_tracks_high_water() {
        let t = Tier::new("gpu", 1000);
        {
            let _a = t.alloc(700, Category::Working).unwrap();
        }
        let _b = t.alloc(100, Category::Working).unwrap();
        assert_eq!(t.peak(), 700);
    }
}
