//! Token-bucket bandwidth throttle and the QD-aware NVMe device model.
//!
//! The SSD tier and the simulated PCIe links use [`Throttle`] to reproduce
//! the paper's bandwidth regimes (a few GB/s host↔SSD) on hardware where the
//! backing file may actually be much faster. The throttle *adds* delay to
//! reach the target rate; it never makes a slow medium faster.
//!
//! [`DeviceProfile`] generalizes the flat throttle into a real NVMe device
//! model — queue-depth ramp, request-size ramp, read/write mix penalty, and
//! a per-op latency floor — and [`DeviceThrottle`] enforces it at runtime
//! with an io_uring-style submission-batching window ([`BatchConfig`]) that
//! amortizes the latency floor across concurrent sub-saturating
//! submissions. A flat profile degenerates EXACTLY to two [`Throttle`]s
//! (one per direction), which is how every pre-profile suite keeps its
//! meaning. See the [`crate::memory`] module docs for the profile JSON
//! format and the curve semantics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// Enforces an average byte rate over a sliding window.
#[derive(Debug)]
pub struct Throttle {
    bytes_per_sec: f64,
    state: Mutex<ThrottleState>,
}

#[derive(Debug)]
struct ThrottleState {
    /// Time before which the link is already committed.
    busy_until: Instant,
    total_bytes: u64,
    total_wait: Duration,
}

impl Throttle {
    /// `bytes_per_sec == f64::INFINITY` disables throttling.
    pub fn new(bytes_per_sec: f64) -> Self {
        assert!(bytes_per_sec > 0.0);
        Throttle {
            bytes_per_sec,
            state: Mutex::new(ThrottleState {
                busy_until: Instant::now(),
                total_bytes: 0,
                total_wait: Duration::ZERO,
            }),
        }
    }

    pub fn rate(&self) -> f64 {
        self.bytes_per_sec
    }

    /// Account a transfer of `bytes` and sleep until the link would have
    /// finished it. Serializes concurrent callers (one link = one resource).
    pub fn transfer(&self, bytes: u64) {
        if self.bytes_per_sec.is_infinite() {
            self.state.lock().unwrap().total_bytes += bytes;
            return;
        }
        let dur = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        let wake = {
            let mut st = self.state.lock().unwrap();
            let now = Instant::now();
            let start = st.busy_until.max(now);
            st.busy_until = start + dur;
            st.total_bytes += bytes;
            // Each transfer charges its own service time exactly once, so
            // Σ total_wait == Σ bytes/rate regardless of how callers
            // overlap. (The old code charged the full queue delay to every
            // concurrent caller — N overlapping transfers recorded
            // ~N(N+1)/2 × dur instead of N × dur, so reported wait could
            // exceed wall-clock × callers.)
            st.total_wait += dur;
            st.busy_until
        };
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.state.lock().unwrap().total_bytes
    }

    pub fn total_wait(&self) -> Duration {
        self.state.lock().unwrap().total_wait
    }
}

/// Per-device NVMe throughput model (the "Breaking the Memory Wall" curve
/// family): a direction-split peak bandwidth shaped by three effects real
/// flat throttles ignore —
///
/// * **queue-depth ramp** — delivered bandwidth scales `min(1, QD/qd_knee)`:
///   a device with `qd_knee = 8` needs 8 outstanding requests to saturate,
///   so a synchronous (QD 1) caller sees 1/8 of peak;
/// * **request-size ramp** — scales `min(1, size/sat_bytes)`: requests
///   below the saturating size `sat_bytes` waste the parallelism of the
///   flash channels (0 disables the ramp);
/// * **read/write mix penalty** — concurrent traffic in the other
///   direction multiplies the rate by `1 − mix_penalty`;
/// * **per-op latency floor** — every submission pays `op_latency_s`
///   before its bytes move, which dominates small requests and is exactly
///   what the [`BatchConfig`] submission window amortizes.
///
/// `flat(r, w)` — knee 1, no size ramp, no mix penalty, zero latency — is
/// bit- and timing-identical to two plain [`Throttle`]s, which keeps every
/// pre-profile suite meaningful ([`DeviceProfile::is_flat`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Peak read bandwidth, bytes/s (`f64::INFINITY` = unthrottled).
    pub read_bps: f64,
    /// Peak write bandwidth, bytes/s.
    pub write_bps: f64,
    /// Queue depth at which the device saturates (≥ 1).
    pub qd_knee: u32,
    /// Request size (bytes) at which the device saturates; 0 = no ramp.
    pub sat_bytes: u64,
    /// Bandwidth fraction LOST while the other direction is active ∈ [0, 1).
    pub mix_penalty: f64,
    /// Fixed per-submission latency, seconds (0 = none).
    pub op_latency_s: f64,
}

impl DeviceProfile {
    /// The degenerate profile: a flat bandwidth pair, exactly today's
    /// [`Throttle`] semantics.
    pub fn flat(read_bps: f64, write_bps: f64) -> DeviceProfile {
        DeviceProfile {
            read_bps,
            write_bps,
            qd_knee: 1,
            sat_bytes: 0,
            mix_penalty: 0.0,
            op_latency_s: 0.0,
        }
    }

    /// True when every curve effect is disabled and the profile is
    /// equivalent to two flat [`Throttle`]s.
    pub fn is_flat(&self) -> bool {
        self.qd_knee <= 1
            && self.sat_bytes == 0
            && self.mix_penalty == 0.0
            && self.op_latency_s == 0.0
    }

    /// Same curve shape, re-rated peaks (the striped/planned stores re-rate
    /// one measured profile per device).
    pub fn with_rates(&self, read_bps: f64, write_bps: f64) -> DeviceProfile {
        DeviceProfile { read_bps, write_bps, ..*self }
    }

    /// Parse one device object from the hardware-profile JSON (see the
    /// [`crate::memory`] module docs): `read_gbps`/`write_gbps` required,
    /// `qd_knee`, `sat_kib`, `mix_penalty`, `op_latency_us` optional
    /// (defaulting to the flat profile's values).
    pub fn from_json(v: &Json) -> Result<DeviceProfile> {
        let gbps = |key: &str| -> Result<f64> {
            v.get(key)?.as_f64().with_context(|| format!("device profile field '{key}'"))
        };
        let opt = |key: &str, default: f64| -> Result<f64> {
            match v.get(key) {
                Ok(x) => x.as_f64().with_context(|| format!("device profile field '{key}'")),
                Err(_) => Ok(default),
            }
        };
        let p = DeviceProfile {
            read_bps: gbps("read_gbps")? * 1e9,
            write_bps: gbps("write_gbps")? * 1e9,
            qd_knee: opt("qd_knee", 1.0)? as u32,
            sat_bytes: (opt("sat_kib", 0.0)? * 1024.0) as u64,
            mix_penalty: opt("mix_penalty", 0.0)?,
            op_latency_s: opt("op_latency_us", 0.0)? * 1e-6,
        };
        ensure!(p.read_bps > 0.0 && p.write_bps > 0.0, "device rates must be positive");
        ensure!(p.qd_knee >= 1, "qd_knee must be >= 1");
        ensure!((0.0..1.0).contains(&p.mix_penalty), "mix_penalty must be in [0, 1)");
        ensure!(p.op_latency_s >= 0.0, "op_latency_us must be >= 0");
        Ok(p)
    }

    /// Queue-depth bandwidth fraction: `min(1, qd/qd_knee)`.
    pub fn qd_frac(&self, qd: usize) -> f64 {
        (qd.max(1) as f64 / self.qd_knee.max(1) as f64).min(1.0)
    }

    /// Request-size bandwidth fraction: `min(1, bytes/sat_bytes)` (1 when
    /// the ramp is disabled or the request is empty-but-free).
    pub fn size_frac(&self, bytes: u64) -> f64 {
        if self.sat_bytes == 0 {
            1.0
        } else {
            (bytes as f64 / self.sat_bytes as f64).min(1.0)
        }
    }

    /// Bandwidth fraction retained under mixed read/write traffic.
    pub fn mix_frac(&self) -> f64 {
        1.0 - self.mix_penalty
    }

    /// Closed-form effective bandwidth for a steady stream of
    /// `req_bytes`-sized requests at queue depth `qd` with `batch_ops`
    /// submissions coalesced per ring window (1 = unbatched) — what the
    /// simulator and the autotuner price I/O with. Each window moves
    /// `req_bytes × batch_ops` at the curve rate (the window is what the
    /// device sees, so the size ramp applies to the window) and pays the
    /// latency floor once:
    ///
    /// ```text
    /// eff = window_bytes / (op_latency + window_bytes / stream_rate)
    /// stream_rate = peak × size_frac(window_bytes) × qd_frac(qd)
    /// ```
    ///
    /// A flat profile returns the peak rate exactly, for every
    /// `(req_bytes, qd, batch_ops)` — the sim identity the pin tests hold.
    pub fn eff_bps(&self, write: bool, req_bytes: u64, qd: usize, batch_ops: u64) -> f64 {
        let peak = if write { self.write_bps } else { self.read_bps };
        let k = batch_ops.max(1);
        let window = (req_bytes.max(1)).saturating_mul(k);
        let stream = peak * self.size_frac(window) * self.qd_frac(qd);
        if self.op_latency_s == 0.0 {
            // No latency floor: the stream rate IS the effective rate. This
            // short-circuit keeps the flat identity exact (×1.0 is exact in
            // f64; `w / (w / peak)` is not).
            return stream;
        }
        let service = if stream.is_infinite() { 0.0 } else { window as f64 / stream };
        window as f64 / (self.op_latency_s + service)
    }
}

/// The `--io-batch` submission window: concurrent sub-saturating
/// submissions that arrive while the device is still busy coalesce into one
/// ring submission of at most `max_ops` requests / `max_bytes` bytes, and
/// only the window's FIRST request pays the profile's latency floor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchConfig {
    pub max_bytes: u64,
    pub max_ops: u64,
}

impl Default for BatchConfig {
    /// One typical ring: 1 MiB / 32 submissions per window.
    fn default() -> Self {
        BatchConfig { max_bytes: 1 << 20, max_ops: 32 }
    }
}

impl BatchConfig {
    /// Parse the `--io-batch BYTES[:OPS]` CLI form.
    pub fn parse(s: &str) -> Result<BatchConfig> {
        let (bytes, ops) = match s.split_once(':') {
            Some((b, o)) => (b, Some(o)),
            None => (s, None),
        };
        let max_bytes: u64 =
            bytes.trim().parse().with_context(|| format!("io-batch bytes in '{s}'"))?;
        let max_ops: u64 = match ops {
            Some(o) => o.trim().parse().with_context(|| format!("io-batch ops in '{s}'"))?,
            None => BatchConfig::default().max_ops,
        };
        ensure!(max_bytes >= 1 && max_ops >= 1, "io-batch window must be at least 1:1");
        Ok(BatchConfig { max_bytes, max_ops })
    }
}

/// Per-direction device state. The window counters track the open ring
/// submission window: ops that join it skip the latency floor.
#[derive(Debug)]
struct DirState {
    busy_until: Instant,
    total_bytes: u64,
    total_wait: Duration,
    total_ops: u64,
    batched_ops: u64,
    window_ops: u64,
    window_bytes: u64,
}

impl DirState {
    fn new() -> DirState {
        DirState {
            busy_until: Instant::now(),
            total_bytes: 0,
            total_wait: Duration::ZERO,
            total_ops: 0,
            batched_ops: 0,
            window_ops: 0,
            window_bytes: 0,
        }
    }
}

/// Runtime enforcement of a [`DeviceProfile`]: one device, two directions
/// (independent read/write lanes, like the flat throttle pair it replaces),
/// with queue depth sampled from the actually-outstanding transfers and an
/// optional [`BatchConfig`] submission window. Only *timing* depends on the
/// profile — byte movement and counters are identical for every profile,
/// which is the batching determinism contract.
#[derive(Debug)]
pub struct DeviceThrottle {
    profile: DeviceProfile,
    batch: Option<BatchConfig>,
    read: Mutex<DirState>,
    write: Mutex<DirState>,
    inflight_read: AtomicU64,
    inflight_write: AtomicU64,
}

impl DeviceThrottle {
    pub fn new(profile: DeviceProfile, batch: Option<BatchConfig>) -> Self {
        assert!(profile.read_bps > 0.0 && profile.write_bps > 0.0);
        DeviceThrottle {
            profile,
            batch,
            read: Mutex::new(DirState::new()),
            write: Mutex::new(DirState::new()),
            inflight_read: AtomicU64::new(0),
            inflight_write: AtomicU64::new(0),
        }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    pub fn batch(&self) -> Option<BatchConfig> {
        self.batch
    }

    /// Account + delay a read of `bytes`.
    pub fn read(&self, bytes: u64) {
        self.transfer(false, bytes)
    }

    /// Account + delay a write of `bytes`.
    pub fn write(&self, bytes: u64) {
        self.transfer(true, bytes)
    }

    fn transfer(&self, write: bool, bytes: u64) {
        let peak = if write { self.profile.write_bps } else { self.profile.read_bps };
        let dir = if write { &self.write } else { &self.read };
        // Unthrottled with no latency floor — or an empty transfer, which
        // moves nothing and submits nothing: count only (the flat
        // throttle's infinite-rate fast path).
        if bytes == 0 || (peak.is_infinite() && self.profile.op_latency_s == 0.0) {
            let mut st = dir.lock().unwrap();
            st.total_bytes += bytes;
            st.total_ops += 1;
            return;
        }
        let (own, other) = if write {
            (&self.inflight_write, &self.inflight_read)
        } else {
            (&self.inflight_read, &self.inflight_write)
        };
        // Queue depth is sampled at submission: this transfer plus every
        // other one still outstanding in the same direction.
        let qd = own.fetch_add(1, Ordering::SeqCst) as usize + 1;
        let mixed = other.load(Ordering::SeqCst) > 0;
        let mut rate = peak * self.profile.size_frac(bytes) * self.profile.qd_frac(qd);
        if mixed {
            rate *= self.profile.mix_frac();
        }
        let service = if rate.is_infinite() { 0.0 } else { bytes as f64 / rate };
        let wake = {
            let mut st = dir.lock().unwrap();
            let now = Instant::now();
            // io_uring-style coalescing: a sub-saturating submission that
            // arrives while the device is busy joins the open ring window
            // (if the window has room) and skips the latency floor — one
            // doorbell per window, not per op.
            let sub_sat = self.profile.sat_bytes == 0 || bytes < self.profile.sat_bytes;
            let joined = match self.batch {
                Some(b) => {
                    self.profile.op_latency_s > 0.0
                        && sub_sat
                        && now < st.busy_until
                        && st.window_ops > 0
                        && st.window_ops < b.max_ops
                        && st.window_bytes + bytes <= b.max_bytes
                }
                None => false,
            };
            let dur = if joined {
                st.window_ops += 1;
                st.window_bytes += bytes;
                st.batched_ops += 1;
                Duration::from_secs_f64(service)
            } else {
                st.window_ops = 1;
                st.window_bytes = bytes;
                Duration::from_secs_f64(self.profile.op_latency_s + service)
            };
            let start = st.busy_until.max(now);
            st.busy_until = start + dur;
            st.total_bytes += bytes;
            // per-transfer service (+ latency) time, charged exactly once
            // (the same accounting law as `Throttle::transfer`)
            st.total_wait += dur;
            st.total_ops += 1;
            st.busy_until
        };
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
        own.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn bytes_read(&self) -> u64 {
        self.read.lock().unwrap().total_bytes
    }

    pub fn bytes_written(&self) -> u64 {
        self.write.lock().unwrap().total_bytes
    }

    /// Total submissions, both directions.
    pub fn total_ops(&self) -> u64 {
        self.read.lock().unwrap().total_ops + self.write.lock().unwrap().total_ops
    }

    /// Submissions that joined an open ring window (skipped the latency
    /// floor) — the batcher's effectiveness counter.
    pub fn batched_ops(&self) -> u64 {
        self.read.lock().unwrap().batched_ops + self.write.lock().unwrap().batched_ops
    }

    /// Modeled device-busy time charged so far, both directions.
    pub fn total_wait(&self) -> Duration {
        self.read.lock().unwrap().total_wait + self.write.lock().unwrap().total_wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unthrottled_is_instant() {
        let t = Throttle::new(f64::INFINITY);
        let t0 = Instant::now();
        t.transfer(1 << 30);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn enforces_rate() {
        let t = Throttle::new(10_000_000.0); // 10 MB/s
        let t0 = Instant::now();
        t.transfer(500_000); // 50 ms at 10 MB/s
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(45), "{dt:?}");
        assert!(dt < Duration::from_millis(500), "{dt:?}");
    }

    #[test]
    fn serializes_concurrent_transfers() {
        let t = std::sync::Arc::new(Throttle::new(10_000_000.0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let t = std::sync::Arc::clone(&t);
                std::thread::spawn(move || t.transfer(250_000)) // 25ms each
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 4 × 25 ms on one link ≈ 100 ms total, not 25.
        assert!(t0.elapsed() >= Duration::from_millis(90), "{:?}", t0.elapsed());
    }

    #[test]
    fn stats_accumulate() {
        let t = Throttle::new(1e9);
        t.transfer(1000);
        t.transfer(2000);
        assert_eq!(t.total_bytes(), 3000);
    }

    /// Regression for the `total_wait` over-count: N overlapping transfers
    /// used to each record the full queue delay (Σ ≈ N(N+1)/2 × dur);
    /// per-transfer service time must be recorded once, so the sum pins to
    /// Σ bytes/rate and can never exceed the concurrent elapsed wall clock
    /// times the caller count.
    #[test]
    fn total_wait_records_service_time_once() {
        let rate = 10_000_000.0; // 10 MB/s
        let t = std::sync::Arc::new(Throttle::new(rate));
        let per = 250_000u64; // 25 ms each
        let n = 4u64;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let t = std::sync::Arc::clone(&t);
                std::thread::spawn(move || t.transfer(per))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let elapsed = t0.elapsed();
        let expect = Duration::from_secs_f64((n * per) as f64 / rate); // 100 ms
        let wait = t.total_wait();
        assert_eq!(wait, expect, "Σ total_wait must equal Σ bytes/rate exactly");
        // the old over-count would have recorded ~(1+2+3+4)×25 = 250 ms here
        assert!(
            wait <= elapsed + Duration::from_millis(5),
            "recorded wait {wait:?} exceeds elapsed {elapsed:?}"
        );
    }

    #[test]
    fn flat_profile_is_flat_and_degenerate() {
        let p = DeviceProfile::flat(3.2e9, 2.8e9);
        assert!(p.is_flat());
        for (req, qd, k) in [(1u64, 1usize, 1u64), (4096, 8, 16), (1 << 20, 64, 1)] {
            assert_eq!(p.eff_bps(false, req, qd, k), 3.2e9);
            assert_eq!(p.eff_bps(true, req, qd, k), 2.8e9);
        }
        assert!(!DeviceProfile { qd_knee: 8, ..p }.is_flat());
        assert!(!DeviceProfile { op_latency_s: 1e-4, ..p }.is_flat());
    }

    #[test]
    fn curves_are_monotone_in_qd_size_and_batch() {
        let p = DeviceProfile {
            read_bps: 3.2e9,
            write_bps: 2.8e9,
            qd_knee: 8,
            sat_bytes: 256 * 1024,
            mix_penalty: 0.2,
            op_latency_s: 80e-6,
        };
        // QD ramp up to the knee, then flat
        let e1 = p.eff_bps(false, 64 * 1024, 1, 1);
        let e4 = p.eff_bps(false, 64 * 1024, 4, 1);
        let e8 = p.eff_bps(false, 64 * 1024, 8, 1);
        let e16 = p.eff_bps(false, 64 * 1024, 16, 1);
        assert!(e1 < e4 && e4 < e8, "{e1} {e4} {e8}");
        assert_eq!(e8, e16, "flat past the knee");
        // size ramp toward sat_bytes
        let s4k = p.eff_bps(false, 4 * 1024, 8, 1);
        let s64k = p.eff_bps(false, 64 * 1024, 8, 1);
        let s1m = p.eff_bps(false, 1 << 20, 8, 1);
        assert!(s4k < s64k && s64k < s1m, "{s4k} {s64k} {s1m}");
        // saturated requests approach (but never exceed) peak
        assert!(s1m <= 3.2e9 && s1m > 0.9 * 3.2e9, "{s1m}");
        // batching amortizes the latency floor for small requests
        let b1 = p.eff_bps(false, 16 * 1024, 8, 1);
        let b8 = p.eff_bps(false, 16 * 1024, 8, 8);
        assert!(b8 > 1.5 * b1, "batched {b8} vs unbatched {b1}");
        // mix penalty
        assert_eq!(p.mix_frac(), 0.8);
    }

    #[test]
    fn profile_json_roundtrip_and_defaults() {
        let full = Json::parse(
            r#"{"read_gbps": 3.2, "write_gbps": 2.8, "qd_knee": 8,
                "sat_kib": 256, "mix_penalty": 0.15, "op_latency_us": 80}"#,
        )
        .unwrap();
        let p = DeviceProfile::from_json(&full).unwrap();
        assert_eq!(p.read_bps, 3.2e9);
        assert_eq!(p.write_bps, 2.8e9);
        assert_eq!(p.qd_knee, 8);
        assert_eq!(p.sat_bytes, 256 * 1024);
        assert_eq!(p.mix_penalty, 0.15);
        assert!((p.op_latency_s - 80e-6).abs() < 1e-12);
        // omitted curve fields default to the flat profile
        let min = Json::parse(r#"{"read_gbps": 1.0, "write_gbps": 1.0}"#).unwrap();
        assert!(DeviceProfile::from_json(&min).unwrap().is_flat());
        // missing rates are an error
        let bad = Json::parse(r#"{"read_gbps": 1.0}"#).unwrap();
        assert!(DeviceProfile::from_json(&bad).is_err());
    }

    #[test]
    fn io_batch_cli_parse() {
        assert_eq!(
            BatchConfig::parse("1048576:16").unwrap(),
            BatchConfig { max_bytes: 1 << 20, max_ops: 16 }
        );
        assert_eq!(BatchConfig::parse("65536").unwrap().max_bytes, 65536);
        assert_eq!(BatchConfig::parse("65536").unwrap().max_ops, 32);
        assert!(BatchConfig::parse("0:4").is_err());
        assert!(BatchConfig::parse("nope").is_err());
    }

    /// Flat-profile timing compatibility: the device throttle at a flat
    /// profile enforces the same rate as the plain throttle it replaces.
    #[test]
    fn flat_device_throttle_enforces_rate() {
        let d = DeviceThrottle::new(DeviceProfile::flat(f64::INFINITY, 10_000_000.0), None);
        let t0 = Instant::now();
        d.write(500_000); // 50 ms at 10 MB/s
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(45), "{dt:?}");
        assert!(dt < Duration::from_millis(500), "{dt:?}");
        // reads are unthrottled and instant
        let t0 = Instant::now();
        d.read(1 << 30);
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert_eq!(d.bytes_read(), 1 << 30);
        assert_eq!(d.bytes_written(), 500_000);
        assert_eq!(d.batched_ops(), 0);
    }

    /// The latency floor is real: ops pay it unbatched, and the submission
    /// window amortizes it — same bytes, far less wall time.
    #[test]
    fn batch_window_amortizes_latency_floor() {
        let profile = DeviceProfile {
            op_latency_s: 2e-3,
            sat_bytes: 1 << 20,
            ..DeviceProfile::flat(f64::INFINITY, f64::INFINITY)
        };
        let run = |batch: Option<BatchConfig>| {
            let d = std::sync::Arc::new(DeviceThrottle::new(profile, batch));
            let t0 = Instant::now();
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let d = std::sync::Arc::clone(&d);
                    std::thread::spawn(move || {
                        for _ in 0..10 {
                            d.write(4096);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            (t0.elapsed(), d.total_ops(), d.batched_ops())
        };
        let (un, un_ops, un_batched) = run(None);
        let (ba, ba_ops, ba_batched) = run(Some(BatchConfig { max_bytes: 1 << 20, max_ops: 8 }));
        assert_eq!((un_ops, ba_ops), (40, 40));
        assert_eq!(un_batched, 0);
        assert!(ba_batched > 0, "window never coalesced");
        // 40 × 2 ms unbatched ≈ 80 ms; batched pays one floor per window
        assert!(un >= Duration::from_millis(70), "{un:?}");
        assert!(
            ba.as_secs_f64() < 0.6 * un.as_secs_f64(),
            "batched {ba:?} vs unbatched {un:?}"
        );
    }

    /// Byte counters are profile- and batch-invariant (the determinism
    /// contract: only timing may change).
    #[test]
    fn counters_invariant_across_profiles() {
        let flat = DeviceThrottle::new(DeviceProfile::flat(f64::INFINITY, f64::INFINITY), None);
        let curved = DeviceThrottle::new(
            DeviceProfile {
                qd_knee: 4,
                sat_bytes: 64 * 1024,
                op_latency_s: 1e-5,
                ..DeviceProfile::flat(1e12, 1e12)
            },
            Some(BatchConfig::default()),
        );
        for d in [&flat, &curved] {
            d.write(1000);
            d.write(2000);
            d.read(500);
        }
        assert_eq!(flat.bytes_written(), curved.bytes_written());
        assert_eq!(flat.bytes_read(), curved.bytes_read());
        assert_eq!(flat.total_ops(), curved.total_ops());
    }
}
