//! Token-bucket bandwidth throttle.
//!
//! The SSD tier and the simulated PCIe links use this to reproduce the
//! paper's bandwidth regimes (a few GB/s host↔SSD) on hardware where the
//! backing file may actually be much faster. The throttle *adds* delay to
//! reach the target rate; it never makes a slow medium faster.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Enforces an average byte rate over a sliding window.
#[derive(Debug)]
pub struct Throttle {
    bytes_per_sec: f64,
    state: Mutex<ThrottleState>,
}

#[derive(Debug)]
struct ThrottleState {
    /// Time before which the link is already committed.
    busy_until: Instant,
    total_bytes: u64,
    total_wait: Duration,
}

impl Throttle {
    /// `bytes_per_sec == f64::INFINITY` disables throttling.
    pub fn new(bytes_per_sec: f64) -> Self {
        assert!(bytes_per_sec > 0.0);
        Throttle {
            bytes_per_sec,
            state: Mutex::new(ThrottleState {
                busy_until: Instant::now(),
                total_bytes: 0,
                total_wait: Duration::ZERO,
            }),
        }
    }

    pub fn rate(&self) -> f64 {
        self.bytes_per_sec
    }

    /// Account a transfer of `bytes` and sleep until the link would have
    /// finished it. Serializes concurrent callers (one link = one resource).
    pub fn transfer(&self, bytes: u64) {
        if self.bytes_per_sec.is_infinite() {
            self.state.lock().unwrap().total_bytes += bytes;
            return;
        }
        let dur = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        let wake = {
            let mut st = self.state.lock().unwrap();
            let now = Instant::now();
            let start = st.busy_until.max(now);
            st.busy_until = start + dur;
            st.total_bytes += bytes;
            let wait = st.busy_until.saturating_duration_since(now);
            st.total_wait += wait;
            st.busy_until
        };
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.state.lock().unwrap().total_bytes
    }

    pub fn total_wait(&self) -> Duration {
        self.state.lock().unwrap().total_wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unthrottled_is_instant() {
        let t = Throttle::new(f64::INFINITY);
        let t0 = Instant::now();
        t.transfer(1 << 30);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn enforces_rate() {
        let t = Throttle::new(10_000_000.0); // 10 MB/s
        let t0 = Instant::now();
        t.transfer(500_000); // 50 ms at 10 MB/s
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(45), "{dt:?}");
        assert!(dt < Duration::from_millis(500), "{dt:?}");
    }

    #[test]
    fn serializes_concurrent_transfers() {
        let t = std::sync::Arc::new(Throttle::new(10_000_000.0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let t = std::sync::Arc::clone(&t);
                std::thread::spawn(move || t.transfer(250_000)) // 25ms each
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 4 × 25 ms on one link ≈ 100 ms total, not 25.
        assert!(t0.elapsed() >= Duration::from_millis(90), "{:?}", t0.elapsed());
    }

    #[test]
    fn stats_accumulate() {
        let t = Throttle::new(1e9);
        t.transfer(1000);
        t.transfer(2000);
        assert_eq!(t.total_bytes(), 3000);
    }
}
