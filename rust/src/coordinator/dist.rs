//! Data-parallel multi-worker engine (`--workers W`) with a deterministic
//! chunked ring all-reduce.
//!
//! The paper's headline results are multi-GPU (1.93× over ZeRO-Infinity on
//! 4 GPUs for GPT-65B); this module adds that dimension to the runtime: a
//! [`DataParallelEngine`] partitions each step's M micro-batches
//! *contiguously* across W worker [`StepEngine`]s — each with its own
//! checkpoint coordinator and I/O-pipeline lanes, all over the ONE shared
//! [`TensorStore`](crate::memory::store::TensorStore) tier (single SSD,
//! striped multi-SSD, or DRAM-cached — `--ssds`/`--cpu-cache-mb`), whose
//! throttle layer
//! arbitrates the contended tier exactly as it does for a single worker's
//! concurrent lanes — and combines the per-layer gradients with a
//! deterministic chunked ring all-reduce before the eager/delayed optimizer
//! split runs once on rank 0 through the shared
//! [`OptimizerStepCoordinator`].
//!
//! ## Determinism contract
//!
//! `--workers W` is bit-identical to `--workers 1` (today's single
//! [`StepEngine::step`]) for every W, schedule, and io-depth. Three things
//! make that true:
//!
//! 1. **Per-visit gradients.** Workers do NOT pre-accumulate across the
//!    worker boundary: [`StepEngine::partial_step`] returns one gradient
//!    contribution per `(layer, micro-batch)` backward visit. f32 addition
//!    is not associative, so summing pre-reduced worker partials would
//!    diverge from the sequential engine in the last bits.
//! 2. **Fixed reduction order.** The all-reduce sorts each layer's
//!    contributions into the *canonical* order — the order the layer's
//!    visits appear in the schedule's full backward order — and left-folds
//!    them. That is literally the same sequence of f32 additions
//!    [`StepEngine::step`] performs into its resident accumulation buffer,
//!    on the same values (micro-batches are independent through forward and
//!    backward), so the result is bit-identical — and, because the sort key
//!    is the canonical position, invariant to worker completion order
//!    (property-tested in `rust/tests/proptests.rs`).
//! 3. **Ring chunking is element-local.** [`RingReduce`] splits each tensor
//!    into chunks that circulate the ring independently (that is where a
//!    real ring gets its bandwidth), but addition is element-wise, so the
//!    chunk split cannot change a single bit. A real ring staggers each
//!    chunk's start rank and thereby reduces in rank-rotation order; we pin
//!    the fold to the canonical order instead — the price of W-invariance.
//!
//! Losses and head/embedding gradients reduce the same way (ascending
//! micro-batch, head contributions before embedding contributions for
//! `wte` — the single-engine accumulation order); the optimizer then runs
//! once, submitting layers in descending order exactly as the single
//! engine does, so clip accounting, gradient norms, and the α-split moment
//! round trips are unchanged.
//!
//! ## Sharded optimizer states (`--shard-optimizer`)
//!
//! With [`TrainerConfig::shard_optimizer`](super::state::TrainerConfig),
//! the rank-0 optimizer becomes ZeRO-style: the gradients *reduce-scatter*
//! (each of the W ranks keeps only its contiguous element shard of the
//! reduced gradient), each rank runs the eager/delayed Adam update on its
//! own parameter shard through the shared
//! [`OptimizerStepCoordinator`] (α split applied per shard, per-rank moment
//! SSD objects — so CPU-optimizer work and per-rank optimizer SSD round
//! trips shrink ~1/W), and the updated parameter shards *all-gather* before
//! the next iteration's parameter prefetch
//! ([`IoPipeline::prefetch_params`](super::io::IoPipeline) waits out the
//! pending shard updates through the shared coordinator exactly as it waits
//! the rank-0 update). The determinism contract is unchanged: the
//! reduce-scatter reuses the SAME canonical-order left-fold per shard
//! ([`RingReduce`] chunking is element-local), and the fused Adam update is
//! partition-invariant, so `--shard-optimizer --workers W` stays
//! bit-identical to `--workers 1` — including the `Σx²` parameter/moment
//! digests — for every schedule, io-depth, and α. The clip scale is a
//! **per-step barrier value**: each step freezes the speculative scale it
//! saw at eager submission and its delayed-α tail re-uses that frozen
//! value at dispatch time (`LayerPending::held_scale` in
//! [`super::opt`]), so a violation landing between a step's eager
//! submission and its delayed dispatch cannot change which elements see
//! the corrective scale — bit-identity holds for *finite* `clip_norm`
//! too, not just the `∞` default (pinned by
//! `clip_scale_is_a_per_step_barrier` in `opt.rs`). Sharding partitions
//! optimizer state across ALL configured ranks (the process group), not
//! just the ranks that own micro-batches, so the reduce-scatter/all-gather
//! byte accounting uses the group size W while the unsharded all-reduce
//! counts active workers.
//!
//! ## Persistence-sharded parameters (`--param-persist`)
//!
//! With [`TrainerConfig::param_persist`](super::state::TrainerConfig) (+
//! `--opt-on-ssd`), the *parameter persistence* shards too: each rank owns
//! per-rank parameter shard objects (`param_l{l}_t{t}_r{r}_{e|d}`, and
//! `param_emb_t{t}_r{r}` for the embedding/head group) and the per-shard
//! update round-trips ONLY that rank's ~1/W of the parameter bytes through
//! the store — read shard, Adam, write shard — instead of every rank
//! re-materializing the full parameter set. The embedding/head group's
//! update fans out over the same rank partition. Per-rank SSD parameter
//! bytes are counted by `ParamShardCounters` (surfaced in `RunLog`), and
//! the ~1/W closed forms live in [`crate::traffic::Workload`] /
//! [`crate::sim::simulate_dist`]. Updates stay bit-identical: Adam is
//! elementwise, so the store round trip at f32 cannot change a bit.
//!
//! ## Elastic re-shard + crash recovery
//!
//! `reshard_store(W→W′)` ([`super::opt::reshard_store`]) deterministically
//! repartitions every persisted shard object (moments, parameter shards,
//! embed shards) from a W-rank layout to a W′-rank layout at a **drained
//! boundary** ([`OptimizerStepCoordinator::drain_delayed`] — no α-tail
//! outstanding). Because the update is partition-invariant, a run resumed
//! at W′ is *bit-identical* to a fresh run at W′ from the same state —
//! the Σx² digest suites in `opt.rs`/`tests/integration.rs` pin this.
//! Crash consistency comes from the layer below: with `--journal` the
//! store wraps in a [`crate::memory::store::JournalStore`] and the trainer
//! commits an epoch per step (see `trainer`), so a worker killed mid-step
//! (fault-injection sites `engine:forward`, `dist:post-reduce`,
//! `opt:delayed`, `lane:*`, `store:tear_put`) replays from the last
//! committed boundary with an unchanged loss curve.
//!
//! ## What is modeled vs real
//!
//! Worker *compute* is serialized on the one PJRT stream (PJRT handles are
//! not `Send`); each worker's I/O lanes still overlap its own compute, and
//! all workers' SSD traffic is arbitrated by the shared throttle. Shared-
//! tier *contention* between concurrently-computing workers is the
//! discrete-event simulator's job ([`crate::sim::simulate_dist`]: per-worker
//! compute resources, one shared `ssd-read`/`ssd-write` pair); the runtime
//! engine's job is the determinism contract above. Per-worker stall and
//! all-reduce time are reported through [`DistStepStats`].

use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::memory::store::TensorStore;
use crate::runtime::tensor::{HostTensor, TokenTensor};
use crate::runtime::Runtime;

use super::engine::{StepEngine, StepStats};
use super::opt::OptimizerStepCoordinator;
use super::schedule::{validate_order, Schedule};
use super::state::ModelState;

/// Contiguous micro-batch partition: worker `w` gets `out[w]`, the first
/// `m % workers` workers get one extra micro-batch, and the ranges cover
/// `0..m` in order (workers beyond `m` get empty ranges).
pub fn partition(m: usize, workers: usize) -> Vec<Range<usize>> {
    let w = workers.max(1);
    let base = m / w;
    let extra = m % w;
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// The deterministic chunked ring all-reduce's arithmetic core: left-fold
/// already-canonically-ordered contributions, chunk by chunk. See the
/// module docs for why chunking cannot change bits.
#[derive(Clone, Copy, Debug)]
pub struct RingReduce {
    /// Elements per ring chunk (the granularity at which a real ring
    /// pipelines its sends; ≥ 1).
    pub chunk_elems: usize,
}

impl Default for RingReduce {
    fn default() -> Self {
        RingReduce { chunk_elems: 1 << 16 }
    }
}

impl RingReduce {
    /// Elementwise sum of `parts` (all the same length), folded left to
    /// right — the fixed reduction order — one chunk at a time.
    pub fn reduce(&self, parts: &[&[f32]]) -> Vec<f32> {
        assert!(!parts.is_empty(), "ring reduce needs at least one contribution");
        let n = parts[0].len();
        let mut out = parts[0].to_vec();
        let chunk = self.chunk_elems.max(1);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + chunk).min(n);
            for p in &parts[1..] {
                debug_assert_eq!(p.len(), n, "contribution length mismatch");
                for i in lo..hi {
                    out[i] += p[i];
                }
            }
            lo = hi;
        }
        out
    }

    /// Reduce parallel lists of tensors: `contribs[k][t]` is contribution
    /// k's tensor t; contributions must already be in canonical order.
    fn reduce_tensors(&self, contribs: &[&Vec<HostTensor>]) -> Vec<HostTensor> {
        assert!(!contribs.is_empty());
        (0..contribs[0].len())
            .map(|t| {
                let parts: Vec<&[f32]> =
                    contribs.iter().map(|c| c[t].data.as_slice()).collect();
                HostTensor { shape: contribs[0][t].shape.clone(), data: self.reduce(&parts) }
            })
            .collect()
    }
}

/// Tensor-`t` data slices of a sorted contribution list (reduction inputs).
fn pick<'t>(list: &'t [GradContrib], t: usize) -> Vec<&'t [f32]> {
    list.iter().map(|(_, g)| g[t].data.as_slice()).collect()
}

/// Total bytes a W-rank ring moves to all-reduce a `payload`-byte tensor:
/// each rank sends 2·(W−1)/W·payload (reduce-scatter + all-gather), so the
/// ring total is 2·(W−1)·payload. 0 for a single rank.
///
/// This is the single source of truth for ring byte accounting: the
/// runtime engine, the discrete-event simulator
/// ([`crate::sim::simulate_dist`]), and the analytic traffic model
/// ([`crate::traffic::Workload`]) all derive their ring totals from this
/// function and its two halves below, so the closed forms and the measured
/// counters can never drift apart.
pub fn ring_traffic_bytes(ranks: usize, payload: u64) -> u64 {
    ring_reduce_scatter_bytes(ranks, payload) + ring_allgather_bytes(ranks, payload)
}

/// Total bytes a W-rank ring reduce-scatter moves: each rank sends
/// (W−1)/W·payload, so the ring total is (W−1)·payload. 0 for one rank.
pub fn ring_reduce_scatter_bytes(ranks: usize, payload: u64) -> u64 {
    if ranks <= 1 {
        0
    } else {
        (ranks as u64 - 1) * payload
    }
}

/// Total bytes a W-rank ring all-gather moves: same (W−1)·payload as the
/// reduce-scatter half (each rank receives the other W−1 shards).
pub fn ring_allgather_bytes(ranks: usize, payload: u64) -> u64 {
    ring_reduce_scatter_bytes(ranks, payload)
}

/// Fraction of a payload EACH rank's ring leg moves in one reduce-scatter
/// (equally, one all-gather): (W−1)/W — the discrete-event simulator sizes
/// its per-worker interconnect ops with this, so the sim's modeled ring
/// traffic and the byte helpers above agree by construction
/// (`ranks · frac · payload = (W−1) · payload`). A full all-reduce leg is
/// twice this. 0 for a single rank.
pub fn ring_leg_frac(ranks: usize) -> f64 {
    if ranks <= 1 {
        0.0
    } else {
        (ranks - 1) as f64 / ranks as f64
    }
}

/// One per-visit gradient contribution: the GLOBAL micro-batch index it
/// came from, and the per-tensor gradients of that visit.
pub type GradContrib = (usize, Vec<HostTensor>);

/// One worker's share of a step ([`StepEngine::partial_step`]): per-visit
/// gradient contributions tagged with their GLOBAL micro-batch index, plus
/// the worker's data-path counters.
pub struct WorkerPartial {
    /// `(global micro-batch, loss)` for each owned micro-batch.
    pub losses: Vec<(usize, f64)>,
    /// `layer_grads[l]` = this worker's backward visits of layer `l`, in
    /// visit order.
    pub layer_grads: Vec<Vec<GradContrib>>,
    /// Head contributions per owned micro-batch: `[dlnf_w, dlnf_b, dwte]`.
    pub head_grads: Vec<GradContrib>,
    /// Embedding-backward contributions per owned micro-batch:
    /// `[dwte, dwpe]`.
    pub embed_grads: Vec<GradContrib>,
    /// Layer-parameter bytes this worker uploaded.
    pub param_bytes: u64,
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
    /// Seconds this worker's compute thread stalled on I/O.
    pub io_stall_s: f64,
}

/// [`StepStats`] plus the per-worker breakdown the aggregate hides.
#[derive(Clone, Debug)]
pub struct DistStepStats {
    /// Aggregated step metrics (loss averaged over all M micro-batches,
    /// SSD/param bytes and stalls summed across workers, plus the
    /// all-reduce time/traffic fields).
    pub stats: StepStats,
    /// Per-worker compute-thread I/O stall seconds this step, one entry per
    /// ACTIVE worker in rank order. Workers with an empty micro-batch
    /// partition (W > M) do no work and get NO entry — reporting them as
    /// genuine 0-stall workers would dilute per-worker averages.
    pub worker_stall_s: Vec<f64>,
}

/// The data-parallel engine: W worker [`StepEngine`]s over one
/// [`ModelState`] + shared SSD, a deterministic chunked ring all-reduce,
/// and the rank-0 optimizer. See the module docs for the determinism
/// contract.
pub struct DataParallelEngine<'a> {
    state: &'a ModelState,
    rt: &'a Runtime,
    /// The one optimizer coordinator all workers share (rank 0's — or, with
    /// `--shard-optimizer`, the coordinator that fans each update out over
    /// the W per-rank shards).
    pub opt: Arc<OptimizerStepCoordinator>,
    workers: Vec<StepEngine<'a>>,
    ring: RingReduce,
    /// ZeRO-style sharded optimizer states (see the module docs).
    shard: bool,
    step: u64,
}

impl<'a> DataParallelEngine<'a> {
    /// Build `workers` worker engines sharing one optimizer coordinator.
    /// `workers == 1` is the degenerate case used to cross-check the
    /// determinism contract against [`StepEngine::step`]. The sharded
    /// optimizer path is taken when `state.cfg.shard_optimizer` is set and
    /// `workers > 1`.
    pub fn new(state: &'a ModelState, rt: &'a Runtime, workers: usize) -> Result<Self> {
        let workers = workers.max(1);
        if state.cfg.shard_optimizer && workers != state.cfg.workers.max(1) {
            // the coordinator's shard layout (and the moment digest) derive
            // from cfg.workers; a mismatched engine worker count would ring
            // over one group size while updating another's shards
            bail!(
                "--shard-optimizer: engine worker count {workers} must equal \
                 TrainerConfig.workers {}",
                state.cfg.workers.max(1)
            );
        }
        let opt = OptimizerStepCoordinator::new(state);
        opt.seed_ssd(state)?;
        let opt = Arc::new(opt);
        let engines = (0..workers)
            .map(|_| StepEngine::with_coordinator(state, rt, Arc::clone(&opt)))
            .collect();
        Ok(DataParallelEngine {
            state,
            rt,
            opt,
            workers: engines,
            ring: RingReduce::default(),
            shard: state.cfg.shard_optimizer && workers > 1,
            step: 0,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Iterations executed so far.
    pub fn steps_done(&self) -> u64 {
        self.step
    }

    /// Resume the iteration counter after a crash-recovery rebuild — see
    /// [`StepEngine::set_steps_done`]: Adam's bias correction and the
    /// delayed-dispatch step tags must continue from the committed count.
    pub fn set_steps_done(&mut self, n: u64) {
        self.step = n;
        for w in &mut self.workers {
            w.set_steps_done(n);
        }
    }

    /// One data-parallel training iteration over `m` micro-batches. The
    /// phase structure mirrors [`StepEngine::step`] exactly: delayed-α
    /// dispatch first (overlapping the forward), workers' compute, the
    /// deterministic reduce, then optimizer submission in descending layer
    /// order and the embedding update.
    pub fn step(
        &mut self,
        schedule: &dyn Schedule,
        tokens: &[TokenTensor],
        targets: &[TokenTensor],
    ) -> Result<DistStepStats> {
        let m = tokens.len();
        assert_eq!(m, targets.len());
        assert!(m > 0, "a step needs at least one micro-batch");
        let nl = self.state.manifest.config.n_layers;
        if self.state.cfg.alpha > 0.0 && !schedule.supports_delay() {
            bail!(
                "schedule '{}' has no delayed-step support (α must be 0, got {})",
                schedule.name(),
                self.state.cfg.alpha
            );
        }
        self.step += 1;
        let read0 = self.state.store.bytes_read();
        let written0 = self.state.store.bytes_written();
        let cache0 = self.state.store.cache_stats().total;

        // Delayed α updates from the previous iteration overlap this
        // forward; every worker's first visit of a layer waits on them
        // through the shared coordinator.
        if schedule.supports_delay() {
            self.opt.dispatch_delayed(
                self.state,
                Some(self.rt),
                self.step.saturating_sub(1).max(1),
            )?;
        }
        self.opt.wait_embed();

        // The canonical backward order defines each layer's reduction
        // order; validate the full orders once up front (workers validate
        // their restrictions again).
        let fwd_full = schedule.forward_order(nl, m);
        validate_order(&fwd_full, nl, m, false)
            .with_context(|| format!("schedule '{}' forward order", schedule.name()))?;
        let bwd_full = schedule.backward_order(nl, m);
        validate_order(&bwd_full, nl, m, true)
            .with_context(|| format!("schedule '{}' backward order", schedule.name()))?;
        // canonical_pos[l][j] = rank of micro-batch j among layer l's
        // backward visits in the FULL order.
        let mut canonical_pos: Vec<Vec<usize>> = vec![vec![0; m]; nl];
        let mut seen: Vec<usize> = vec![0; nl];
        for &(l, j) in &bwd_full {
            canonical_pos[l][j] = seen[l];
            seen[l] += 1;
        }

        // ---------------- worker compute ----------------
        // Serialized on the one PJRT stream (see module docs); each worker
        // keeps its own I/O lanes and stall clock.
        let parts = partition(m, self.workers.len());
        let mut partials: Vec<WorkerPartial> = Vec::new();
        for (w, range) in parts.iter().enumerate() {
            if range.is_empty() {
                continue;
            }
            let p = self.workers[w].partial_step(schedule, tokens, targets, range.clone())?;
            partials.push(p);
        }
        let active = partials.len();
        // per-ACTIVE-worker stall shares, rank order (idle ranks get none)
        let worker_stall_s: Vec<f64> = partials.iter().map(|p| p.io_stall_s).collect();

        // Ring byte accounting: the unsharded all-reduce runs among the
        // ACTIVE workers (idle ranks contribute nothing and receive
        // nothing); the sharded reduce-scatter spans the whole group — every
        // configured rank owns an optimizer shard and must receive its slice
        // of the reduced gradient.
        let shard = self.shard;
        let group = self.workers.len();
        let grad_ring_bytes = |payload: u64| {
            if shard {
                ring_reduce_scatter_bytes(group, payload)
            } else {
                ring_traffic_bytes(active, payload)
            }
        };

        // ---------------- deterministic chunked ring reduce ----------------
        // All-reduce on the rank-0 path; reduce-scatter under
        // `--shard-optimizer` (same canonical-order left-fold — each rank
        // simply keeps only its shard of the result, which cannot change a
        // bit of it).
        let t_red = Instant::now();
        let mut allreduce_bytes = 0u64;
        // loss: left-fold in ascending micro-batch order (the single
        // engine's head-loop accumulation order)
        let mut losses: Vec<(usize, f64)> = Vec::with_capacity(m);
        for p in &partials {
            losses.extend(p.losses.iter().copied());
        }
        losses.sort_by_key(|&(j, _)| j);
        let mut loss_sum = 0.0f64;
        for &(_, l) in &losses {
            loss_sum += l;
        }

        // per-layer gradients, canonical order per layer
        let mut reduced: Vec<Option<Vec<HostTensor>>> = Vec::new();
        reduced.resize_with(nl, || None);
        for l in 0..nl {
            let mut contribs: Vec<GradContrib> = Vec::with_capacity(m);
            for p in &mut partials {
                contribs.append(&mut p.layer_grads[l]);
            }
            // the sort key is the canonical position, so worker completion
            // order cannot matter
            contribs.sort_by_key(|&(j, _)| canonical_pos[l][j]);
            if contribs.len() != m {
                bail!("layer {l}: {} gradient contributions for {m} micro-batches", contribs.len());
            }
            let lists: Vec<&Vec<HostTensor>> = contribs.iter().map(|(_, g)| g).collect();
            let grads = self.ring.reduce_tensors(&lists);
            for g in &grads {
                allreduce_bytes += grad_ring_bytes(g.bytes());
            }
            reduced[l] = Some(grads);
        }

        // head/embedding gradients: ascending micro-batch, head before
        // embedding for wte — the single engine's accumulation order
        let mut head: Vec<GradContrib> = Vec::with_capacity(m);
        let mut emb: Vec<GradContrib> = Vec::with_capacity(m);
        for p in &mut partials {
            head.append(&mut p.head_grads);
            emb.append(&mut p.embed_grads);
        }
        head.sort_by_key(|&(j, _)| j);
        emb.sort_by_key(|&(j, _)| j);
        if head.len() != m || emb.len() != m {
            bail!("head/embed contributions incomplete: {}/{} of {m}", head.len(), emb.len());
        }
        let dlnf_w = {
            let parts = pick(&head, 0);
            HostTensor { shape: head[0].1[0].shape.clone(), data: self.ring.reduce(&parts) }
        };
        let dlnf_b = {
            let parts = pick(&head, 1);
            HostTensor { shape: head[0].1[1].shape.clone(), data: self.ring.reduce(&parts) }
        };
        let dwte = {
            let mut parts = pick(&head, 2);
            parts.extend(pick(&emb, 0));
            HostTensor { shape: head[0].1[2].shape.clone(), data: self.ring.reduce(&parts) }
        };
        let dwpe = {
            let parts = pick(&emb, 1);
            HostTensor { shape: emb[0].1[1].shape.clone(), data: self.ring.reduce(&parts) }
        };
        let embed_bytes: u64 = [&dlnf_w, &dlnf_b, &dwte, &dwpe].iter().map(|t| t.bytes()).sum();
        // the embedding/head group's update fans out over the rank
        // partition in shard mode (see `submit_embed`), so its gradients
        // reduce-scatter across the group there; unsharded they all-reduce
        // among the active workers
        allreduce_bytes += grad_ring_bytes(embed_bytes);
        let allreduce_s = t_red.elapsed().as_secs_f64();

        // Fault site: a worker dropping right after the reduce-scatter —
        // gradients are combined but no optimizer state has advanced. The
        // journaled trainer must replay the whole step.
        if crate::util::fault::any_armed()
            && crate::util::fault::should_fail(&crate::util::fault::scoped(
                "dist:post-reduce",
                &self.state.cfg.fault_scope,
            ))
        {
            bail!("injected fault: worker lost after reduce-scatter (step {})", self.step);
        }

        // ---------------- optimizer (rank-0 or per-rank sharded) -----------
        // Descending layer order — exactly the order the single engine's
        // eager (and deferred) submissions retire in — then the embedding
        // group, so clip accounting and the gradient norm are unchanged.
        // Under `--shard-optimizer` the shared coordinator fans each
        // submission out over the W per-rank shards (α split per shard).
        for l in (0..nl).rev() {
            let grads = reduced[l].take().expect("reduced gradients");
            self.opt.submit_eager(self.state, Some(self.rt), l, grads, self.step)?;
        }
        self.opt.submit_embed(self.state, vec![dwte, dwpe, dlnf_w, dlnf_b], self.step)?;
        if schedule.end_of_step_barrier() {
            for l in 0..nl {
                self.opt.wait_layer(l);
            }
            self.opt.wait_embed();
        }
        let grad_norm = self.opt.finish_iter();

        // Sharded mode: the updated parameter shards all-gather so every
        // rank holds the full updated model before the next iteration's
        // parameter prefetch (the IoPipeline's `param-upload` lane waits out
        // the pending shard updates through the shared coordinator, so the
        // gather is ordered after them). The embedding/head group's shards
        // gather the same way — its update fans out over the rank partition
        // too. Accounted to the step that produced the shards; params are
        // f32 on this substrate.
        let allgather_bytes = if shard {
            let layer_params = nl as u64 * (self.state.manifest.layer_numel() * 4) as u64;
            // embed/head param bytes == embed/head grad bytes (same tensors)
            ring_allgather_bytes(group, layer_params + embed_bytes)
        } else {
            0
        };

        let cache1 = self.state.store.cache_stats().total;
        let mut stats = StepStats {
            loss: loss_sum / m as f64,
            grad_norm,
            ssd_bytes_read: self.state.store.bytes_read() - read0,
            ssd_bytes_written: self.state.store.bytes_written() - written0,
            param_bytes_loaded: 0,
            prefetch_hits: 0,
            prefetch_misses: 0,
            io_stall_s: 0.0,
            allreduce_s,
            allreduce_bytes,
            allgather_bytes,
            cache_hits: cache1.hits - cache0.hits,
            cache_misses: cache1.misses - cache0.misses,
            cache_evictions: cache1.evictions - cache0.evictions,
        };
        for p in &partials {
            stats.param_bytes_loaded += p.param_bytes;
            stats.prefetch_hits += p.prefetch_hits;
            stats.prefetch_misses += p.prefetch_misses;
            stats.io_stall_s += p.io_stall_s;
        }
        Ok(DistStepStats { stats, worker_stall_s })
    }

    /// Drain all outstanding I/O and optimizer work (end of training):
    /// flush every worker's lanes, then drive the one shared coordinator
    /// the way [`StepEngine::drain`] does.
    pub fn drain(&mut self) -> Result<()> {
        for w in &mut self.workers {
            w.flush_io()?;
        }
        self.opt.dispatch_delayed(self.state, Some(self.rt), self.step.max(1))?;
        for l in 0..self.state.manifest.config.n_layers {
            self.opt.wait_layer(l);
        }
        self.opt.wait_embed();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_contiguous_and_balanced() {
        for m in [0usize, 1, 3, 4, 7, 16] {
            for w in [1usize, 2, 3, 4, 8] {
                let parts = partition(m, w);
                assert_eq!(parts.len(), w);
                let mut next = 0;
                for r in &parts {
                    assert_eq!(r.start, next, "m={m} w={w}");
                    next = r.end;
                }
                assert_eq!(next, m, "ranges must cover 0..{m}");
                let sizes: Vec<usize> = parts.iter().map(|r| r.len()).collect();
                let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "m={m} w={w}: {sizes:?}");
            }
        }
    }

    #[test]
    fn ring_reduce_is_left_fold_sum() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![10.0f32, 20.0, 30.0];
        let c = vec![100.0f32, 200.0, 300.0];
        for chunk in [1usize, 2, 3, 64] {
            let ring = RingReduce { chunk_elems: chunk };
            let got = ring.reduce(&[a.as_slice(), b.as_slice(), c.as_slice()]);
            assert_eq!(got, vec![111.0, 222.0, 333.0], "chunk={chunk}");
        }
        // single contribution is the identity
        let ring = RingReduce::default();
        assert_eq!(ring.reduce(&[a.as_slice()]), a);
    }

    /// Chunk splits cannot change bits: addition is element-local.
    #[test]
    fn ring_reduce_chunking_is_bit_invariant() {
        let mut rng = crate::util::prng::Prng::new(0xD157);
        let n = 257;
        let parts: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..n).map(|_| (rng.next_f32() - 0.5) * 3.0).collect())
            .collect();
        let refs: Vec<&[f32]> = parts.iter().map(|v| v.as_slice()).collect();
        let base = RingReduce { chunk_elems: 1 }.reduce(&refs);
        for chunk in [2usize, 7, 64, 1000] {
            let got = RingReduce { chunk_elems: chunk }.reduce(&refs);
            assert!(
                got.iter().zip(&base).all(|(x, y)| x.to_bits() == y.to_bits()),
                "chunk={chunk} changed bits"
            );
        }
    }

    #[test]
    fn ring_traffic_matches_2w_minus_1_formula() {
        assert_eq!(ring_traffic_bytes(1, 1000), 0);
        assert_eq!(ring_traffic_bytes(2, 1000), 2000);
        assert_eq!(ring_traffic_bytes(4, 1000), 6000);
    }

    /// The all-reduce is exactly reduce-scatter + all-gather, for every rank
    /// count — the identity the sharded byte accounting rests on.
    #[test]
    fn ring_halves_sum_to_all_reduce() {
        for ranks in 0..10usize {
            for payload in [0u64, 1, 777, 1 << 20] {
                assert_eq!(
                    ring_reduce_scatter_bytes(ranks, payload)
                        + ring_allgather_bytes(ranks, payload),
                    ring_traffic_bytes(ranks, payload),
                    "ranks={ranks} payload={payload}"
                );
            }
        }
        assert_eq!(ring_reduce_scatter_bytes(4, 1000), 3000);
        assert_eq!(ring_allgather_bytes(4, 1000), 3000);
        assert_eq!(ring_reduce_scatter_bytes(1, 1000), 0);
    }
}
