//! Model/optimizer state and the parameter-residency coordinator.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::memory::codec::{CodecStore, Precision};
use crate::memory::store::{
    CachedStore, JournalStore, PlannedConfig, PlannedStore, StripedStore, TensorStore,
};
use crate::memory::{BatchConfig, DeviceProfile, SsdStorage};
use crate::optimizer::{AdamParams, AdamState};
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::HostTensor;
use crate::util::prng::Prng;

/// Run-level configuration for the real trainer.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// Delay ratio α ∈ [0, 0.5]: tail fraction of every layer's parameters
    /// whose optimizer update runs during the next iteration's forward.
    pub alpha: f64,
    /// Keep optimizer states (m, v) on the throttled SSD tier (paper
    /// default) instead of CPU-resident.
    pub opt_on_ssd: bool,
    /// Spill activation checkpoints to SSD as well (the Figure-12
    /// 100 %-offload stress mode).
    pub ckpt_on_ssd: bool,
    /// Run Adam through the AOT Pallas kernel (inline on the coordinator
    /// thread — PJRT handles are not Send) instead of the fused Rust loop
    /// on the overlap worker.
    pub use_hlo_adam: bool,
    /// Overlap optimizer steps with GPU compute on a worker thread.
    pub overlap: bool,
    /// Schedule-lookahead depth K of the async I/O pipeline
    /// (`coordinator::io::IoPipeline`): the engine issues the next K visits'
    /// parameter loads and checkpoint reads while the current visit
    /// computes, and checkpoint stores become write-behind. 0 = fully
    /// synchronous I/O on the compute thread (bit-identical to the
    /// pre-pipeline engine).
    pub io_depth: usize,
    /// Data-parallel worker count W (`--workers`). Each step's micro-batches
    /// are partitioned contiguously across W model replicas
    /// ([`crate::coordinator::dist::DataParallelEngine`]), each with its own
    /// I/O pipeline over the one shared SSD, and per-layer gradients are
    /// combined with a deterministic chunked ring all-reduce before the
    /// optimizer runs once on rank 0 — bit-identical to `workers == 1`
    /// (today's single [`crate::coordinator::StepEngine`]) for every W.
    pub workers: usize,
    /// ZeRO-style sharded optimizer states (`--shard-optimizer`): with
    /// `workers > 1`, each rank owns a contiguous element shard of every
    /// layer tensor's optimizer state and updates only that shard (α-split
    /// applied per shard), so CPU-optimizer work and per-rank optimizer SSD
    /// round trips shrink ~1/W. Gradients reduce-scatter instead of
    /// all-reducing and the updated parameter shards all-gather before the
    /// next iteration's prefetch. Still bit-identical to `workers == 1`
    /// (the Adam update is partition-invariant; see
    /// [`crate::coordinator::dist`]'s determinism contract). No effect at
    /// `workers == 1`.
    pub shard_optimizer: bool,
    pub adam: AdamParams,
    /// Global gradient-norm clip threshold (speculative; f64::INFINITY off).
    pub clip_norm: f64,
    /// SSD backing file and simulated bandwidths.
    pub ssd_path: std::path::PathBuf,
    pub ssd_read_bps: f64,
    pub ssd_write_bps: f64,
    /// NVMe device-curve shape (`--nvme-profile`): QD knee, saturating
    /// request size, read/write mix penalty, and per-op latency floor
    /// applied to every backing device, re-rated to
    /// `ssd_read_bps`/`ssd_write_bps` ([`DeviceProfile::with_rates`]).
    /// `None` (the default) keeps the flat pre-profile throttle —
    /// bit-identical AND timing-identical to the seed engine. Profiles
    /// change timing only: losses and Σx² digests stay bit-identical.
    pub nvme: Option<DeviceProfile>,
    /// io_uring-style submission-batching window (`--io-batch BYTES[:OPS]`)
    /// on every backing device: concurrent sub-saturating submissions
    /// coalesce into one ring submission and amortize the profile's
    /// latency floor. `None` = unbatched. Never changes results — only
    /// wall time (the batching determinism contract).
    pub io_batch: Option<BatchConfig>,
    /// Number of independent SSD devices to stripe the store across
    /// (`--ssds`; the runtime twin of the sim flag). 1 = the single-device
    /// [`SsdStorage`] path; N > 1 = [`StripedStore`] — each object's
    /// extents round-robin over N backing files (`{ssd_path}.d{i}`), each
    /// with its OWN read/write throttle, so one object's transfer proceeds
    /// over N parallel paths. Bit-identical to `ssds = 1`.
    pub ssds: usize,
    /// Bounded CPU-DRAM write-back cache in front of the store, MiB
    /// (`--cpu-cache-mb`; 0 = off). Hot objects (moments, checkpoints) are
    /// served from DRAM — absorbed traffic never reaches the SSD tier —
    /// with LRU eviction + dirty write-back when the budget
    /// ([`crate::memory::Tier`]-accounted) runs out. Bit-identical to the
    /// uncached path.
    pub cpu_cache_mb: usize,
    /// Use the multi-path [`PlannedStore`] planner (`--planned`) instead of
    /// the static cache-then-stripe nesting: every object gets a transfer
    /// plan splitting its bytes into extents served concurrently from the
    /// DRAM tier (`cpu_cache_mb` capacity), each of the `ssds` NVMe devices
    /// (per-device throttles at `ssd_read_bps`/`ssd_write_bps`), and the
    /// optional remote path (`remote_mbps`). Bit-identical to every other
    /// backend at strict f32 (the plan-equivalence contract in
    /// `memory::store`).
    pub planned: bool,
    /// Simulated remote/object-store path bandwidth in MB/s for the
    /// planned store (`--remote-mbps`; 0 = no remote path).
    pub remote_mbps: f64,
    /// Storage precision (`--precision {f32,mixed:f16,mixed:bf16}`).
    /// `f32` (default) keeps every stored object raw f32 — the bit-identity
    /// baseline. The mixed policies interpose a
    /// [`crate::memory::codec::CodecStore`] over the whole backend stack:
    /// activation checkpoints (`ilc_*`) are encoded in half precision and
    /// gradients are requantized delayed in-place during the per-shard
    /// optimizer update, while master weights and both Adam moments
    /// (`opt_*`) stay f32. Mixed runs are pinned to the f32 baseline by the
    /// tolerance-equivalence suite (see `memory::store`'s two-tier
    /// contract), not by bit identity.
    pub precision: Precision,
    /// Shard parameter *persistence* on the SSD tier (`--param-persist`):
    /// master parameters also live on the store as per-(rank, part) shard
    /// objects (`param_l{l}_t{t}[_r{r}]_{e|d}`, plus `param_emb_t{t}[_r{r}]`
    /// for the embedding/head group), and every optimizer visit round-trips
    /// its shard — read before the update, written back after — so each
    /// rank moves ~1/W of the parameter bytes per iteration (the finished
    /// ZeRO-Infinity picture; today's default re-reads nothing because
    /// params stay host-resident only). Parameter shards are always stored
    /// f32 (they are master weights), so this is bit-identical to the
    /// host-resident path at every precision. Requires `opt_on_ssd`.
    pub param_persist: bool,
    /// Crash-consistent write-behind journal (`--journal`): wrap the store
    /// in a [`crate::memory::store::JournalStore`] that undo-logs the first
    /// write to each key per step and commits an epoch marker at every step
    /// boundary, and make the trainer retry a failed step from the last
    /// committed boundary (store rollback + host-state restore) with the
    /// SAME batch — so a worker killed mid-step replays with a provably
    /// unchanged loss curve. Recovery of host state requires
    /// `param_persist` (+ `opt_on_ssd`), which make the store the single
    /// source of truth for params and moments.
    pub journal: bool,
    /// Scope tag appended to the fault-injection site names this config's
    /// runtime objects check (`site@scope`, see
    /// [`crate::util::fault::scoped`]). The fault registry is
    /// process-global, so parallel tests exercising the same production
    /// code path would otherwise consume each other's armed sites; tests
    /// arm scoped names instead. Empty (the production default) checks the
    /// bare site names.
    pub fault_scope: String,
    /// Seed for parameter init and the synthetic corpus.
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            alpha: 0.25,
            opt_on_ssd: true,
            ckpt_on_ssd: false,
            use_hlo_adam: false,
            overlap: true,
            io_depth: 2,
            workers: 1,
            shard_optimizer: false,
            adam: AdamParams { lr: 3e-4, weight_decay: 0.01, ..Default::default() },
            clip_norm: f64::INFINITY,
            ssd_path: std::env::temp_dir()
                .join(format!("greedysnake_ssd_{}", std::process::id())),
            ssd_read_bps: f64::INFINITY,
            ssd_write_bps: f64::INFINITY,
            nvme: None,
            io_batch: None,
            ssds: 1,
            cpu_cache_mb: 0,
            planned: false,
            remote_mbps: 0.0,
            precision: Precision::F32,
            param_persist: false,
            journal: false,
            fault_scope: String::new(),
            seed: 42,
        }
    }
}

impl TrainerConfig {
    /// Canonical test fixture: the deterministic small-run baseline (α = 0,
    /// CPU-resident moments, no overlap worker — the settings every ad-hoc
    /// test fixture used to duplicate) with a process- AND instance-unique
    /// temp `ssd_path`, so concurrent tests — in particular the multi-worker
    /// suites, which open the backing file from several engines — can never
    /// collide on an SSD file. Override individual fields with struct-update
    /// syntax: `TrainerConfig { opt_on_ssd: true, ..TrainerConfig::for_test("t") }`.
    pub fn for_test(tag: &str) -> TrainerConfig {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let uniq = NEXT.fetch_add(1, Ordering::Relaxed);
        TrainerConfig {
            alpha: 0.0,
            opt_on_ssd: false,
            overlap: false,
            ssd_path: std::env::temp_dir().join(format!(
                "gs_test_{tag}_{}_{uniq}",
                std::process::id()
            )),
            fault_scope: tag.to_string(),
            ..Default::default()
        }
    }
}

/// Parameter groups outside the transformer stack, updated with the layers.
pub const EMBED_TENSORS: [&str; 4] = ["wte", "wpe", "lnf_w", "lnf_b"];

/// All trainable state. Parameters live behind per-layer mutexes so the
/// overlap worker can update a layer while the coordinator computes another
/// — the locking discipline *is* the paper's "update layer i before its
/// forward" dependency, enforced by [`ModelState::wait_layer_ready`].
pub struct ModelState {
    pub manifest: Manifest,
    /// `layers[l][t]` = tensor t of layer l (manifest order).
    pub layers: Vec<Arc<Mutex<Vec<HostTensor>>>>,
    /// wte, wpe, lnf_w, lnf_b.
    pub embed: Arc<Mutex<Vec<HostTensor>>>,
    /// CPU-resident moments (empty when `opt_on_ssd`).
    pub layer_opt: Vec<Arc<Mutex<Vec<AdamState>>>>,
    pub embed_opt: Arc<Mutex<Vec<AdamState>>>,
    /// The pluggable storage tier holding offloaded optimizer state and
    /// spilled checkpoints — single SSD, striped multi-SSD, DRAM-cached,
    /// or the multi-path planner per [`TrainerConfig::ssds`] /
    /// [`TrainerConfig::cpu_cache_mb`] / [`TrainerConfig::planned`],
    /// optionally under a mixed-precision codec layer per
    /// [`TrainerConfig::precision`]. At `--precision f32` every backend is
    /// bit-identical (see `memory::store`); the mixed policies store
    /// checkpoints encoded in half precision and are tolerance-pinned
    /// instead. Only byte placement, byte width, and wall time differ.
    pub store: Arc<dyn TensorStore>,
    pub cfg: TrainerConfig,
}

/// Build the configured [`TensorStore`] backend stack for `cfg`:
/// `CodecStore?` → `JournalStore?` → `CachedStore?` →
/// `StripedStore | SsdStorage`, or with `cfg.planned` the flat multi-path
/// stack `CodecStore?` → `JournalStore?` → `PlannedStore`
/// (DRAM + N NVMe + remote as concurrent paths — the planner replaces the
/// cache-then-stripe nesting, so `cpu_cache_mb` becomes the DRAM *path*
/// capacity and `remote_mbps` enables the remote path). The codec sits on
/// TOP so every layer below it — including the cache's `Tier` capacity
/// accounting and the SSD byte counters — sees encoded bytes; at strict
/// f32 the wrapper is omitted entirely (bit-identity by construction).
/// The journal sits directly under the codec so its undo records hold the
/// encoded at-rest bytes (rollback restores them verbatim, codec or not)
/// and its epoch commit/recover calls reach it through the codec's
/// pass-through delegation.
pub(crate) fn build_store(cfg: &TrainerConfig) -> Result<Arc<dyn TensorStore>> {
    build_store_with_admission(cfg, crate::memory::CacheAdmission::All)
}

/// [`build_store`] with an explicit cache-admission policy — the serve path
/// (`coordinator::serve`) reuses the whole training store stack (striping,
/// DRAM cache, journal, codec) but runs the cache tier under its
/// multi-tenant [`CacheAdmission`](crate::memory::CacheAdmission) policy.
/// Training always passes `All`, so this split changes nothing there.
pub(crate) fn build_store_with_admission(
    cfg: &TrainerConfig,
    admission: crate::memory::CacheAdmission,
) -> Result<Arc<dyn TensorStore>> {
    let base: Arc<dyn TensorStore> = if cfg.planned {
        let pc = PlannedConfig {
            nvme: vec![(cfg.ssd_read_bps, cfg.ssd_write_bps); cfg.ssds.max(1)],
            dram_capacity: (cfg.cpu_cache_mb as u64) << 20,
            dram_bps: 0.0, // PlannedStore::DRAM_BPS
            remote_bps: cfg.remote_mbps * 1e6,
        };
        Arc::new(
            PlannedStore::create_profiled(&cfg.ssd_path, &pc, cfg.nvme.as_ref(), cfg.io_batch)?
                .with_fault_scope(&cfg.fault_scope),
        )
    } else {
        // Re-rate the configured curve shape (if any) to the configured
        // bandwidth pair; flat otherwise — identical to the seed engine.
        let profile = match cfg.nvme {
            Some(p) => p.with_rates(cfg.ssd_read_bps, cfg.ssd_write_bps),
            None => DeviceProfile::flat(cfg.ssd_read_bps, cfg.ssd_write_bps),
        };
        let dev: Arc<dyn TensorStore> = if cfg.ssds > 1 {
            Arc::new(StripedStore::create_profiled(
                &cfg.ssd_path,
                cfg.ssds,
                profile,
                cfg.io_batch,
                StripedStore::DEFAULT_STRIPE,
            )?)
        } else {
            Arc::new(SsdStorage::with_profile(&cfg.ssd_path, profile, cfg.io_batch)?)
        };
        if cfg.cpu_cache_mb > 0 {
            Arc::new(CachedStore::with_admission(
                dev,
                (cfg.cpu_cache_mb as u64) << 20,
                admission,
            ))
        } else {
            dev
        }
    };
    let journaled: Arc<dyn TensorStore> = if cfg.journal {
        Arc::new(JournalStore::new(base)?.with_fault_scope(&cfg.fault_scope))
    } else {
        base
    };
    let policy = cfg.precision.policy();
    let store: Arc<dyn TensorStore> = if policy.is_strict_f32() {
        journaled
    } else {
        Arc::new(CodecStore::new(journaled, policy))
    };
    Ok(store)
}

impl ModelState {
    /// Initialize from the manifest (deterministic given `cfg.seed`) and
    /// seed the SSD tier with the zero-initialized moments.
    pub fn init(manifest: Manifest, cfg: TrainerConfig) -> Result<ModelState> {
        let mut rng = Prng::new(cfg.seed);
        let nl = manifest.config.n_layers;
        let mut layers = Vec::with_capacity(nl);
        let mut layer_opt = Vec::with_capacity(nl);
        let store = build_store(&cfg)?;

        for _l in 0..nl {
            let params: Vec<HostTensor> = manifest
                .layer_params
                .iter()
                .map(|s| HostTensor::init(s, nl, &mut rng))
                .collect();
            let mut opts = Vec::new();
            if !cfg.opt_on_ssd {
                for spec in manifest.layer_params.iter() {
                    opts.push(AdamState::zeros(spec.numel));
                }
            }
            // (SSD-resident moments are seeded by
            // OptimizerStepCoordinator::seed_ssd with the α-split layout.)
            layers.push(Arc::new(Mutex::new(params)));
            layer_opt.push(Arc::new(Mutex::new(opts)));
        }

        let embed: Vec<HostTensor> = manifest
            .embed_params
            .iter()
            .chain(manifest.head_params.iter())
            .map(|s| HostTensor::init(s, nl, &mut rng))
            .collect();
        let embed_opt: Vec<AdamState> =
            embed.iter().map(|t| AdamState::zeros(t.numel())).collect();

        Ok(ModelState {
            manifest,
            layers,
            embed: Arc::new(Mutex::new(embed)),
            layer_opt,
            embed_opt: Arc::new(Mutex::new(embed_opt)),
            store,
            cfg,
        })
    }

    /// Snapshot a layer's parameters as PJRT literals (copy under the lock;
    /// the overlap worker may be updating another layer concurrently).
    pub fn layer_literals(&self, l: usize) -> Result<Vec<xla::Literal>> {
        let guard = self.layers[l].lock().unwrap();
        guard.iter().map(|t| t.to_literal()).collect()
    }

    /// Sum of squares over ALL optimizer moments (m and v), wherever they
    /// live — CPU-resident buffers or the α-split SSD objects (global or
    /// per-rank sharded layout). The digest is layout-canonical: each
    /// tensor's moment vector is first reassembled into ONE buffer in
    /// ascending element order (eager-then-delayed; rank-major in the
    /// sharded layout — the parts tile `0..n` contiguously either way) and
    /// squared with a single flat fold, so the f64 addition sequence — and
    /// therefore the exact bits — cannot depend on how the α split or the
    /// `--shard-optimizer` sharding grouped the storage. The
    /// gradient-equivalence suite uses exact bit equality of this digest to
    /// pin W-worker (and sharded-optimizer) training to the W = 1 baseline.
    pub fn moment_sq_norm(&self) -> Result<f64> {
        use super::opt::{part_key, shard_part_key, Part};
        let sq = |xs: &[f32]| xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        let shards = if self.cfg.shard_optimizer { self.cfg.workers.max(1) } else { 1 };
        let mut s = 0.0;
        if self.cfg.opt_on_ssd {
            let mut buf = Vec::new();
            let mut full = Vec::new();
            for l in 0..self.manifest.config.n_layers {
                for t in 0..self.manifest.layer_params.len() {
                    for kind in ['m', 'v'] {
                        full.clear();
                        for r in 0..shards {
                            for part in [Part::Eager, Part::Delayed] {
                                let key = if shards > 1 {
                                    shard_part_key(l, t, kind, r, part)
                                } else {
                                    part_key(l, t, kind, part)
                                };
                                if self.store.contains(&key) {
                                    self.store.get_f32(&key, &mut buf)?;
                                    full.extend_from_slice(&buf);
                                }
                            }
                        }
                        s += sq(&full);
                    }
                }
            }
        } else {
            for lo in &self.layer_opt {
                for st in lo.lock().unwrap().iter() {
                    s += sq(&st.m) + sq(&st.v);
                }
            }
        }
        for st in self.embed_opt.lock().unwrap().iter() {
            s += sq(&st.m) + sq(&st.v);
        }
        Ok(s)
    }

    /// Re-synchronize the host parameter replicas from the
    /// persistence-sharded store objects (an "all-gather from SSD") —
    /// the host-state half of crash recovery: after
    /// [`TensorStore::recover`] rolls the store back to the last committed
    /// epoch boundary, the rolled-back `param_*` shard objects are the
    /// source of truth and the host tensors are refreshed from them.
    /// Requires `cfg.param_persist` (otherwise there are no shard objects
    /// to gather; returns an error so callers can't silently resume from
    /// torn host state).
    pub fn load_params_from_shards(&self) -> Result<()> {
        use super::opt::{embed_param_key, param_key, shard_part_range, Part};
        anyhow::ensure!(
            self.cfg.param_persist,
            "load_params_from_shards requires cfg.param_persist"
        );
        let shards =
            if self.cfg.shard_optimizer { self.cfg.workers.max(1) } else { 1 };
        let mut buf = Vec::new();
        for l in 0..self.manifest.config.n_layers {
            let mut guard = self.layers[l].lock().unwrap();
            for (t, spec) in self.manifest.layer_params.iter().enumerate() {
                for r in 0..shards {
                    for part in [Part::Eager, Part::Delayed] {
                        let (lo, hi) =
                            shard_part_range(spec.numel, self.cfg.alpha, r, shards, part);
                        if lo == hi {
                            continue;
                        }
                        self.store.get_f32(&param_key(l, t, r, shards, part), &mut buf)?;
                        anyhow::ensure!(
                            buf.len() == hi - lo,
                            "param shard l{l} t{t} r{r} has {} elems, want {}",
                            buf.len(),
                            hi - lo
                        );
                        guard[t].data[lo..hi].copy_from_slice(&buf);
                    }
                }
            }
        }
        let mut guard = self.embed.lock().unwrap();
        for t in 0..guard.len() {
            let n = guard[t].numel();
            for r in 0..shards {
                let (lo, hi) = shard_part_range(n, 0.0, r, shards, Part::Eager);
                if lo == hi {
                    continue;
                }
                self.store.get_f32(&embed_param_key(t, r, shards), &mut buf)?;
                anyhow::ensure!(
                    buf.len() == hi - lo,
                    "embed param shard t{t} r{r} has {} elems, want {}",
                    buf.len(),
                    hi - lo
                );
                guard[t].data[lo..hi].copy_from_slice(&buf);
            }
        }
        Ok(())
    }

    /// Loss-bearing scalar state summary (debug/observability).
    pub fn param_sq_norm(&self) -> f64 {
        let mut s = 0.0;
        for l in &self.layers {
            for t in l.lock().unwrap().iter() {
                s += t.sq_sum();
            }
        }
        for t in self.embed.lock().unwrap().iter() {
            s += t.sq_sum();
        }
        s
    }
}

/// SSD key for a layer tensor's moment vector.
pub fn opt_key(layer: usize, tensor: usize, kind: char) -> String {
    format!("opt_{kind}_l{layer}_t{tensor}")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `None` (skip) when the AOT artifacts were never built.
    fn tiny_state(opt_on_ssd: bool) -> Option<ModelState> {
        let m = Manifest::load_if_built("artifacts/tiny")?;
        let cfg = TrainerConfig {
            opt_on_ssd,
            ..TrainerConfig::for_test(&format!("state_{opt_on_ssd}"))
        };
        Some(ModelState::init(m, cfg).unwrap())
    }

    /// Two fixtures with the SAME tag must still get distinct SSD paths —
    /// this is what keeps the multi-worker suites from colliding on a
    /// backing file (the bug class `for_test` exists to kill).
    #[test]
    fn for_test_paths_are_unique_even_for_equal_tags() {
        let a = TrainerConfig::for_test("same");
        let b = TrainerConfig::for_test("same");
        assert_ne!(a.ssd_path, b.ssd_path);
        assert_eq!(a.alpha, 0.0);
        assert!(!a.opt_on_ssd && !a.overlap);
        assert_eq!(a.workers, 1);
        assert!(!a.shard_optimizer);
    }

    /// `build_store` assembles the configured backend stack; every backend
    /// must round-trip bytes identically (the bit-identity contract).
    #[test]
    fn store_backend_selection_round_trips() {
        let configs = [
            TrainerConfig::for_test("store_ssd"),
            TrainerConfig { ssds: 2, ..TrainerConfig::for_test("store_striped") },
            TrainerConfig { cpu_cache_mb: 4, ..TrainerConfig::for_test("store_cached") },
            TrainerConfig {
                ssds: 3,
                cpu_cache_mb: 4,
                ..TrainerConfig::for_test("store_both")
            },
            TrainerConfig {
                planned: true,
                ssds: 2,
                cpu_cache_mb: 4,
                remote_mbps: 100.0,
                ..TrainerConfig::for_test("store_planned")
            },
        ];
        for cfg in configs {
            let store = super::build_store(&cfg).unwrap();
            let xs: Vec<f32> = (0..513).map(|i| i as f32 * 0.25).collect();
            store.put_f32("opt_m_l0_t0_e", &xs).unwrap();
            let mut out = Vec::new();
            store.get_f32("opt_m_l0_t0_e", &mut out).unwrap();
            assert_eq!(out, xs, "ssds={} cache={}", cfg.ssds, cfg.cpu_cache_mb);
            assert!(store.contains("opt_m_l0_t0_e"));
            assert_eq!(store.len_of("opt_m_l0_t0_e"), Some(513 * 4));
        }
    }

    /// Mixed precision wraps the same backend stack in a `CodecStore`:
    /// checkpoints land encoded (half the bytes), moments stay f32, and
    /// the decoded values obey the codec's rounding — while strict f32
    /// builds the identical stack as before (no wrapper at all).
    #[test]
    fn store_backend_selection_applies_precision_policy() {
        for (prec, name) in
            [(Precision::MixedF16, "prec_f16"), (Precision::MixedBf16, "prec_bf16")]
        {
            let cfg = TrainerConfig { precision: prec, ..TrainerConfig::for_test(name) };
            let store = super::build_store(&cfg).unwrap();
            // (i % 128) * 0.5 needs at most 7 significand bits — exactly
            // representable in f16 AND bf16, so the roundtrip is lossless
            let xs: Vec<f32> = (0..513).map(|i| (i % 128) as f32 * 0.5).collect();
            store.put_f32("ilc_ckpt_l0", &xs).unwrap();
            store.put_f32("opt_m_l0_t0_e", &xs).unwrap();
            assert_eq!(store.len_of("ilc_ckpt_l0"), Some(513 * 2), "{prec}");
            assert_eq!(store.len_of("opt_m_l0_t0_e"), Some(513 * 4), "{prec}");
            let mut out = Vec::new();
            store.get_f32("ilc_ckpt_l0", &mut out).unwrap();
            assert_eq!(out, xs, "{prec}");
            store.get_f32("opt_m_l0_t0_e", &mut out).unwrap();
            assert_eq!(out, xs, "{prec}");
        }
    }

    #[test]
    fn init_is_deterministic() {
        let Some(a) = tiny_state(false) else { return };
        let b = tiny_state(false).expect("gated above");
        assert_eq!(a.param_sq_norm(), b.param_sq_norm());
        assert!(a.param_sq_norm() > 0.0);
    }

    #[test]
    fn ssd_mode_defers_moments_to_coordinator() {
        let Some(s) = tiny_state(true) else { return };
        assert!(s.layer_opt[0].lock().unwrap().is_empty());
    }

    #[test]
    fn cpu_mode_keeps_moments_resident() {
        let Some(s) = tiny_state(false) else { return };
        assert_eq!(s.layer_opt[0].lock().unwrap().len(), 12);
    }

    #[test]
    fn layer_literals_have_right_arity() {
        let Some(s) = tiny_state(false) else { return };
        assert_eq!(s.layer_literals(0).unwrap().len(), 12);
    }
}
