//! The horizontal baseline scheduler (ZeRO-Infinity's order, §3.3): run each
//! micro-batch through ALL layers before the next, accumulate gradients in
//! per-layer buffers across micro-batches, and run the whole optimizer step
//! after the last micro-batch's backward pass.
//!
//! Numerically this computes the same gradients as the vertical scheduler
//! (Figure 13's equivalence), while moving parameters 2·M times instead of
//! twice — the traffic difference is measured by the integration tests via
//! the runtime's stage-call counters and the SSD byte counters.

use anyhow::Result;

use crate::runtime::tensor::{HostTensor, TokenTensor};
use crate::runtime::{Runtime, Stage};

use super::ckpt::{ckpt_key, InterLayerCoordinator};
use super::opt::OptimizerStepCoordinator;
use super::state::ModelState;
use super::vertical::{accumulate, StepStats};

/// The baseline scheduler.
pub struct HorizontalScheduler<'a> {
    pub state: &'a ModelState,
    pub rt: &'a Runtime,
    pub ilc: InterLayerCoordinator,
    pub opt: OptimizerStepCoordinator,
    step: u64,
}

impl<'a> HorizontalScheduler<'a> {
    pub fn new(state: &'a ModelState, rt: &'a Runtime) -> Result<Self> {
        assert!(
            state.cfg.alpha == 0.0,
            "horizontal schedule has no delayed-step support (α must be 0)"
        );
        let opt = OptimizerStepCoordinator::new(state);
        opt.seed_ssd(state)?;
        Ok(HorizontalScheduler {
            state,
            rt,
            ilc: InterLayerCoordinator::new(
                std::sync::Arc::clone(&state.ssd),
                state.cfg.ckpt_on_ssd,
            ),
            opt,
            step: 0,
        })
    }

    /// One iteration: M sequential forward-backward passes, then the
    /// optimizer (the only overlap is the final micro-batch's backward).
    pub fn step(&mut self, tokens: &[TokenTensor], targets: &[TokenTensor]) -> Result<StepStats> {
        let m = tokens.len();
        let c = self.state.manifest.config;
        let nl = c.n_layers;
        self.step += 1;
        let read0 = self.state.ssd.bytes_read();
        let written0 = self.state.ssd.bytes_written();
        self.opt.wait_embed();

        let mut loss_sum = 0.0f64;
        let mut grad_acc: Vec<Option<Vec<HostTensor>>> = vec![None; nl];
        let mut dwte: Option<HostTensor> = None;
        let mut dwpe: Option<HostTensor> = None;
        let mut dlnf_w: Option<HostTensor> = None;
        let mut dlnf_b: Option<HostTensor> = None;

        for j in 0..m {
            // ---- forward of micro-batch j through all layers ----
            let (wte_lit, wpe_lit) = {
                let guard = self.state.embed.lock().unwrap();
                (guard[0].to_literal()?, guard[1].to_literal()?)
            };
            let out = self.rt.execute(
                Stage::EmbedFwd,
                &[tokens[j].to_literal()?, wte_lit, wpe_lit],
            )?;
            let mut act = HostTensor::from_literal(&out[0])?;
            for l in 0..nl {
                // horizontal reloads the layer's parameters for EVERY
                // micro-batch — the traffic the paper eliminates
                let params = self.state.layer_literals(l)?;
                self.ilc.put(&ckpt_key(l, j), act.clone())?;
                let x_lit = act.to_literal()?;
                let mut inputs: Vec<&xla::Literal> = vec![&x_lit];
                inputs.extend(params.iter());
                let out = self.rt.execute(Stage::LayerFwd, &inputs)?;
                act = HostTensor::from_literal(&out[0])?;
            }

            // ---- head ----
            let mut dx = {
                let guard = self.state.embed.lock().unwrap();
                let (wte, lnf_w, lnf_b) = (&guard[0], &guard[2], &guard[3]);
                let out = self.rt.execute(
                    Stage::HeadLoss,
                    &[
                        act.to_literal()?,
                        lnf_w.to_literal()?,
                        lnf_b.to_literal()?,
                        wte.to_literal()?,
                        targets[j].to_literal()?,
                    ],
                )?;
                loss_sum += out[0].to_vec::<f32>()?[0] as f64;
                accumulate(&mut dlnf_w, HostTensor::from_literal(&out[2])?);
                accumulate(&mut dlnf_b, HostTensor::from_literal(&out[3])?);
                accumulate(&mut dwte, HostTensor::from_literal(&out[4])?);
                HostTensor::from_literal(&out[1])?
            };

            // ---- backward of micro-batch j, accumulating into buffers ----
            for l in (0..nl).rev() {
                let params = self.state.layer_literals(l)?;
                let x_ckpt = self.ilc.take(&ckpt_key(l, j))?;
                let (x_lit, dy_lit) = (x_ckpt.to_literal()?, dx.to_literal()?);
                let mut inputs: Vec<&xla::Literal> = vec![&x_lit, &dy_lit];
                inputs.extend(params.iter());
                let out = self.rt.execute(Stage::LayerBwd, &inputs)?;
                dx = HostTensor::from_literal(&out[0])?;
                match &mut grad_acc[l] {
                    None => {
                        grad_acc[l] = Some(
                            out[1..]
                                .iter()
                                .map(HostTensor::from_literal)
                                .collect::<Result<_>>()?,
                        )
                    }
                    Some(acc) => {
                        for (a, lit) in acc.iter_mut().zip(&out[1..]) {
                            a.add_assign(&HostTensor::from_literal(lit)?);
                        }
                    }
                }
            }
            let out = self
                .rt
                .execute(Stage::EmbedBwd, &[tokens[j].to_literal()?, dx.to_literal()?])?;
            accumulate(&mut dwte, HostTensor::from_literal(&out[0])?);
            accumulate(&mut dwpe, HostTensor::from_literal(&out[1])?);
        }

        // ---- optimizer step for all layers, only now (§3.3) ----
        for l in (0..nl).rev() {
            self.opt
                .submit_eager(self.state, Some(self.rt), l, grad_acc[l].take().unwrap(), self.step)?;
        }
        self.opt.submit_embed(
            self.state,
            vec![dwte.unwrap(), dwpe.unwrap(), dlnf_w.unwrap(), dlnf_b.unwrap()],
            self.step,
        )?;
        // the model must be fully updated before the next iteration starts
        for l in 0..nl {
            self.opt.wait_layer(l);
        }
        self.opt.wait_embed();

        let grad_norm = self.opt.finish_iter();
        Ok(StepStats {
            loss: loss_sum / m as f64,
            grad_norm,
            ssd_bytes_read: self.state.ssd.bytes_read() - read0,
            ssd_bytes_written: self.state.ssd.bytes_written() - written0,
        })
    }

    pub fn steps_done(&self) -> u64 {
        self.step
    }
}
