//! The horizontal baseline scheduler (ZeRO-Infinity's order, §3.3): a thin
//! [`HorizontalSchedule`] policy over the shared [`StepEngine`] — run each
//! micro-batch through ALL layers before the next, accumulate gradients in
//! per-layer buffers across micro-batches, and run the whole optimizer step
//! after the last micro-batch's backward pass.
//!
//! Numerically this computes the same gradients as the vertical scheduler
//! (Figure 13's equivalence), while moving parameters 2·M times instead of
//! twice — measured directly by [`StepStats::param_bytes_loaded`] and
//! property-tested in `tests/integration.rs`.

use anyhow::Result;

use crate::runtime::tensor::TokenTensor;
use crate::runtime::Runtime;

use super::engine::{StepEngine, StepStats};
use super::schedule::HorizontalSchedule;
use super::state::ModelState;

/// The baseline scheduler: [`StepEngine`] driven by [`HorizontalSchedule`].
pub struct HorizontalScheduler<'a> {
    pub engine: StepEngine<'a>,
    policy: HorizontalSchedule,
}

impl<'a> HorizontalScheduler<'a> {
    pub fn new(state: &'a ModelState, rt: &'a Runtime) -> Result<Self> {
        assert!(
            state.cfg.alpha == 0.0,
            "horizontal schedule has no delayed-step support (α must be 0)"
        );
        Ok(HorizontalScheduler { engine: StepEngine::new(state, rt)?, policy: HorizontalSchedule })
    }

    /// One iteration in the horizontal traversal order: every micro-batch
    /// sweeps the full stack before the next (parameters reload per
    /// micro-batch), the optimizer is deferred until the whole backward
    /// pass finishes, and the step barriers on all updates before
    /// returning — no overlap into the next iteration.
    pub fn step(&mut self, tokens: &[TokenTensor], targets: &[TokenTensor]) -> Result<StepStats> {
        self.engine.step(&self.policy, tokens, targets)
    }

    /// Drain outstanding optimizer work. The horizontal schedule barriers
    /// at the end of every step, so this is a no-op in practice — but the
    /// uniform interface lets `trainer::train` treat all schedules alike.
    pub fn drain(&mut self) -> Result<()> {
        self.engine.drain()
    }

    pub fn steps_done(&self) -> u64 {
        self.engine.steps_done()
    }
}
