//! The phase-generic layer-streaming core shared by training and serving.
//!
//! [`StepEngine`](super::engine::StepEngine) (training) and
//! [`ServeEngine`](super::serve::ServeEngine) (forward-only token
//! generation) execute the same inner loop: walk a
//! [`Schedule`](super::schedule::Schedule)'s `(layer, micro-batch)` visit
//! order, keep a one-layer parameter-literal cache resident on the device,
//! and look ahead `--io-depth K` visits through the [`IoPipeline`] so the
//! next layer's parameter stream overlaps the current visit's compute.
//! [`LayerStreamer`] is that loop's substrate, extracted so a forward-only
//! workload reuses the schedule/prefetch machinery without inheriting any
//! training policy:
//!
//! * **what** a parameter load *is* stays with the phase — the caller hands
//!   [`LayerStreamer::ensure_params`] a synchronous loader closure (training:
//!   wait out the layer's pending optimizer updates, then snapshot host
//!   tensors; serving: read base weights from the `TensorStore` and apply the
//!   tenant's adapter delta), and hands [`LayerStreamer::lookahead`] the
//!   matching per-layer / per-visit prefetch issuers;
//! * **when** loads happen — cache-hit suppression, prefetch claim vs
//!   synchronous fallback, the depth-K lookahead window walk, stall-clock
//!   charging, and per-layer byte accounting — lives here exactly once.
//!
//! Bit-identity contract: for any fixed sequence of `ensure_params` /
//! `lookahead` calls this type performs the same [`IoPipeline`] operations
//! in the same order and charges the same stall clock as the pre-refactor
//! engine-private code did — the training engine's gradient-equivalence
//! suites (`rust/tests/integration.rs`) pin that down across schedules ×
//! io-depth × workers × store backends.

use std::time::Instant;

use anyhow::Result;

use super::io::{IoPipeline, IoStats};

/// One-layer parameter-literal cache (the resident layer on the device).
pub struct ParamCache {
    pub layer: Option<usize>,
    pub literals: Vec<xla::Literal>,
}

impl ParamCache {
    pub fn empty() -> Self {
        ParamCache { layer: None, literals: Vec::new() }
    }
}

/// Schedule-driven parameter streaming: the one-layer residency model, the
/// depth-K lookahead window, and the per-layer byte meter. Phase policy
/// (training vs serving) is injected through closures.
pub struct LayerStreamer {
    io: IoPipeline,
    /// Bytes one layer's parameter stream moves per load (at the precision
    /// policy's parameter width for training; f32 base + adapter width for
    /// serving — the caller fixes the constant).
    layer_bytes: u64,
    param_bytes_loaded: u64,
}

impl LayerStreamer {
    pub fn new(io_depth: usize, layer_bytes: u64) -> Self {
        LayerStreamer {
            io: IoPipeline::new(io_depth),
            layer_bytes,
            param_bytes_loaded: 0,
        }
    }

    /// The lookahead window size K (0 = fully synchronous).
    pub fn depth(&self) -> usize {
        self.io.depth()
    }

    /// Direct pipeline access for the phase's non-parameter traffic
    /// (checkpoint put/take/prefetch in training; custom prefetch issuers).
    pub fn io_mut(&mut self) -> &mut IoPipeline {
        &mut self.io
    }

    /// Cumulative pipeline counters (snapshot at step boundaries).
    pub fn stats(&self) -> IoStats {
        self.io.stats()
    }

    /// Cumulative parameter bytes uploaded across all passes.
    pub fn param_bytes_loaded(&self) -> u64 {
        self.param_bytes_loaded
    }

    /// Pass boundary: discard stale parameter prefetches (passes may differ
    /// in load semantics — e.g. training's forward waits for optimizer
    /// updates, its backward does not).
    pub fn begin_pass(&mut self) -> Result<()> {
        self.io.begin_pass()
    }

    /// Step/request boundary: retire all in-flight lane work; lane failures
    /// surface here as errors.
    pub fn flush(&mut self) -> Result<()> {
        self.io.flush()
    }

    /// Ensure `cache` holds layer `l`'s parameter literals. A prefetched
    /// snapshot (issued by [`Self::lookahead`]) is claimed when available;
    /// otherwise `sync_load` runs on the compute thread with its wall time
    /// charged to the stall clock — the same blocking set the prefetched
    /// path performs on the `param-upload` lane, so depth-0 and depth-K
    /// runs stay comparable. Every cache miss meters `layer_bytes`.
    pub fn ensure_params(
        &mut self,
        cache: &mut ParamCache,
        l: usize,
        sync_load: impl FnOnce() -> Result<Vec<xla::Literal>>,
    ) -> Result<()> {
        if cache.layer == Some(l) {
            return Ok(());
        }
        match self.io.take_params(l)? {
            Some(snapshot) => {
                // the lane already performed the phase's load; only the
                // host→device conversion remains here
                cache.literals =
                    snapshot.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
            }
            None => {
                let t0 = Instant::now();
                cache.literals = sync_load()?;
                self.io.note_sync_stall(t0.elapsed());
            }
        }
        cache.layer = Some(l);
        self.param_bytes_loaded += self.layer_bytes;
        Ok(())
    }

    /// Walk the next `depth` visits after `idx` in `order`, issuing
    /// `on_layer` at every upcoming layer transition (deduped against the
    /// currently resident layer; the pipeline additionally tracks in-flight
    /// layers) and `on_visit` for every scanned visit (training's backward
    /// pass prefetches checkpoint reads here; phases without per-visit
    /// traffic pass a no-op).
    pub fn lookahead(
        &mut self,
        order: &[(usize, usize)],
        idx: usize,
        mut on_layer: impl FnMut(&mut IoPipeline, usize),
        mut on_visit: impl FnMut(&mut IoPipeline, usize, usize),
    ) {
        let depth = self.io.depth();
        if depth == 0 {
            return;
        }
        // the cache will hold the current visit's layer while the window runs
        let mut resident = order[idx].0;
        for &(l, j) in order.iter().skip(idx + 1).take(depth) {
            if l != resident {
                on_layer(&mut self.io, l);
                resident = l;
            }
            on_visit(&mut self.io, l, j);
        }
    }
}
