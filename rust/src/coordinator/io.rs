//! The asynchronous I/O pipeline: schedule-lookahead parameter prefetch and
//! checkpoint write-behind over the [`LaneExecutor`].
//!
//! The paper's speedups come from overlapping SSD traffic with GPU compute
//! (Figs. 6–8). A [`Schedule`](super::schedule::Schedule) yields the full
//! `(layer, micro-batch)` visit order up front, so the
//! [`StepEngine`](super::engine::StepEngine) can look ahead `K` visits and
//! issue the *next* visits' parameter loads and checkpoint reads while the
//! current visit computes. This type is that pipeline: three dedicated
//! serial lanes —
//!
//! * `ssd-read`   — checkpoint prefetch (the backward pass's `take`s),
//! * `ssd-write`  — checkpoint write-behind (the forward pass's `put`s),
//! * `param-upload` — parameter staging (wait for a layer's pending
//!   optimizer updates, then snapshot its tensors for upload),
//!
//! with dependency tracking between them (a prefetched read of a key waits
//! for that key's in-flight write, never for unrelated traffic). `K = 0`
//! disables the executor entirely and reproduces the synchronous engine
//! bit-for-bit; the pipeline then only times the compute thread's I/O
//! stalls, so the two modes are directly comparable through
//! [`IoStats::stall_seconds`].
//!
//! The pipeline is storage-agnostic: it moves tensors through the
//! [`InterLayerCoordinator`], which itself writes whatever
//! [`TensorStore`](crate::memory::store::TensorStore) backend the run
//! configured — a single SSD, a striped multi-SSD set, or the DRAM-cached
//! tier, any of them under the mixed-precision codec layer
//! (`--precision`), which halves the checkpoint bytes each lane op moves —
//! so lookahead depth, backend, and storage precision compose freely.
//! When the run carries an NVMe device curve (`--nvme-profile`) with a
//! submission window (`--io-batch`), these lanes are also what *feeds* the
//! per-device batcher ([`crate::memory::DeviceThrottle`]): lookahead keeps
//! several sub-saturating transfers in flight on the same device at once,
//! which is exactly the concurrency the io_uring-style window coalesces to
//! amortize the per-op latency floor. Batching changes wall time only —
//! lane ordering, stored bytes, and results stay bit-identical.
//!
//! Lane-op failures (I/O errors *and* panics) surface as `anyhow` errors at
//! this boundary — a panicked op poisons the executor
//! ([`LaneExecutor::try_wait`]) instead of unwinding or deadlocking the
//! compute thread.
//!
//! Under `--shard-optimizer`
//! ([`super::dist`]), the `param-upload` lane is also where the parameter
//! *all-gather* ordering lives: a prefetched load waits out the layer's
//! pending optimizer updates through the shared coordinator, and in sharded
//! mode those pending handles cover every rank's shard update — so by the
//! time the snapshot is taken, the per-rank updated shards have been
//! republished into the full parameter tensor (host memory plays the
//! gathered copy; [`crate::coordinator::StepStats::allgather_bytes`]
//! accounts the ring traffic a real multi-GPU gather would move).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::exec::lanes::{LaneExecutor, OpId};
use crate::runtime::tensor::HostTensor;

use super::ckpt::InterLayerCoordinator;
use super::opt::OptimizerStepCoordinator;

/// Lane names (one serial worker each; the rows of the Fig. 6–8 diagrams).
pub const LANE_SSD_READ: &str = "ssd-read";
pub const LANE_SSD_WRITE: &str = "ssd-write";
pub const LANE_PARAM_UPLOAD: &str = "param-upload";

/// Cumulative pipeline counters. `stall_seconds` is wall time the *compute*
/// thread spent blocked on I/O — synchronous transfers at depth 0, waits on
/// not-yet-finished prefetches at depth ≥ 1 — which is exactly the quantity
/// the overlap is supposed to shrink.
#[derive(Clone, Copy, Debug, Default)]
pub struct IoStats {
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
    pub stall_seconds: f64,
}

/// Result slot filled by a lane op (errors stringified — closures cross a
/// panic boundary and must stay `Send`).
type OpResult<T> = std::result::Result<T, String>;
type Slot<T> = Arc<Mutex<Option<OpResult<T>>>>;
/// An in-flight prefetch: the lane op to wait on plus its result slot.
type InFlight<T> = (OpId, Slot<T>);

/// Time `f` and charge the elapsed wall time to `stats.stall_seconds`.
fn timed<R>(stats: &mut IoStats, f: impl FnOnce() -> R) -> R {
    let t0 = Instant::now();
    let r = f();
    stats.stall_seconds += t0.elapsed().as_secs_f64();
    r
}

/// The engine-facing pipeline. Owned exclusively by one engine; all methods
/// take `&mut self`, the shared state lives in the coordinators the lane
/// closures capture by `Arc`.
pub struct IoPipeline {
    /// `None` at depth 0: every call degrades to the synchronous path.
    ex: Option<LaneExecutor>,
    depth: usize,
    /// key → last write-behind op (completion tracking for `take`).
    pending_writes: HashMap<String, OpId>,
    /// key → in-flight prefetched checkpoint read.
    pending_takes: HashMap<String, InFlight<HostTensor>>,
    /// layer → in-flight parameter snapshot.
    pending_params: HashMap<usize, InFlight<Vec<HostTensor>>>,
    /// I/O errors from write-behind ops, reported at the next take/flush.
    write_errors: Arc<Mutex<Vec<String>>>,
    stats: IoStats,
}

impl IoPipeline {
    /// `depth` is the schedule-lookahead K: 0 = fully synchronous (no lanes,
    /// bit-identical to the pre-pipeline engine), K ≥ 1 = prefetch the next
    /// K visits' loads while the current visit computes.
    pub fn new(depth: usize) -> Self {
        let ex = if depth > 0 {
            Some(LaneExecutor::new(&[LANE_SSD_READ, LANE_SSD_WRITE, LANE_PARAM_UPLOAD]))
        } else {
            None
        };
        IoPipeline {
            ex,
            depth,
            pending_writes: HashMap::new(),
            pending_takes: HashMap::new(),
            pending_params: HashMap::new(),
            write_errors: Arc::new(Mutex::new(Vec::new())),
            stats: IoStats::default(),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn is_async(&self) -> bool {
        self.ex.is_some()
    }

    /// Cumulative counters (snapshot at step boundaries for per-step deltas).
    pub fn stats(&self) -> IoStats {
        self.stats
    }

    /// Charge synchronous I/O done outside the pipeline (the engine's own
    /// blocking loads) to the stall clock, keeping depth-0 and depth-K runs
    /// comparable.
    pub fn note_sync_stall(&mut self, d: Duration) {
        self.stats.stall_seconds += d.as_secs_f64();
    }

    /// Store a checkpoint. Depth 0: synchronous. Otherwise write-behind on
    /// the `ssd-write` lane with completion tracking, so the engine returns
    /// to compute immediately and `take_ckpt` only waits if this write is
    /// still in flight.
    pub fn put_ckpt(
        &mut self,
        ilc: &Arc<InterLayerCoordinator>,
        key: &str,
        t: HostTensor,
    ) -> Result<()> {
        if self.ex.is_none() {
            return timed(&mut self.stats, || ilc.put(key, t));
        }
        // serialize with any previous in-flight write to the same key
        let deps: Vec<OpId> = self.pending_writes.get(key).copied().into_iter().collect();
        let ilc2 = Arc::clone(ilc);
        let key2 = key.to_string();
        let errs = Arc::clone(&self.write_errors);
        let id = self.ex.as_mut().unwrap().submit_on(LANE_SSD_WRITE, &deps, move || {
            if let Err(e) = ilc2.put(&key2, t) {
                errs.lock().unwrap().push(format!("ckpt write '{key2}': {e}"));
            }
        });
        self.pending_writes.insert(key.to_string(), id);
        Ok(())
    }

    /// Issue the checkpoint read for a *future* visit on the `ssd-read`
    /// lane. No-op at depth 0 or when already in flight. The read depends on
    /// the key's pending write-behind, if any.
    #[allow(clippy::map_entry)] // the insert needs &mut self.ex in between
    pub fn prefetch_take(&mut self, ilc: &Arc<InterLayerCoordinator>, key: &str) {
        if self.ex.is_none() || self.pending_takes.contains_key(key) {
            return;
        }
        let deps: Vec<OpId> = self.pending_writes.get(key).copied().into_iter().collect();
        let slot: Slot<HostTensor> = Arc::new(Mutex::new(None));
        let s2 = Arc::clone(&slot);
        let ilc2 = Arc::clone(ilc);
        let key2 = key.to_string();
        let id = self.ex.as_mut().unwrap().submit_on(LANE_SSD_READ, &deps, move || {
            let r = ilc2.take(&key2).map_err(|e| e.to_string());
            *s2.lock().unwrap() = Some(r);
        });
        self.pending_takes.insert(key.to_string(), (id, slot));
    }

    /// Fetch (and remove) a checkpoint. Prefetched: wait only if the read is
    /// still in flight (a *hit*). Not prefetched: wait out any write-behind
    /// for the key, then read synchronously (a *miss* in async mode).
    pub fn take_ckpt(
        &mut self,
        ilc: &Arc<InterLayerCoordinator>,
        key: &str,
    ) -> Result<HostTensor> {
        if let Some((id, slot)) = self.pending_takes.remove(key) {
            self.pending_writes.remove(key); // the read already waited on it
            let ex = self.ex.as_ref().expect("prefetched take implies async mode");
            timed(&mut self.stats, || ex.try_wait(id))
                .map_err(|m| anyhow!("ckpt prefetch lane op panicked: {m}"))?;
            self.stats.prefetch_hits += 1;
            let res = slot
                .lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow!("ckpt prefetch '{key}' finished without a result"))?;
            return res.map_err(|e| anyhow!("ckpt prefetch '{key}': {e}"));
        }
        if let Some(id) = self.pending_writes.remove(key) {
            let ex = self.ex.as_ref().expect("write-behind implies async mode");
            timed(&mut self.stats, || ex.try_wait(id))
                .map_err(|m| anyhow!("ckpt write-behind lane op panicked: {m}"))?;
        }
        if self.is_async() {
            self.stats.prefetch_misses += 1;
        }
        self.check_write_errors()?;
        timed(&mut self.stats, || ilc.take(key))
    }

    /// Issue a *future* visit's parameter load on the `param-upload` lane:
    /// wait for the layer's pending optimizer updates (forward passes only —
    /// the Fig. 8 "update layer i before its forward" dependency), then
    /// snapshot its tensors for upload. No-op at depth 0 / already in flight.
    pub fn prefetch_params(
        &mut self,
        opt: &Arc<OptimizerStepCoordinator>,
        layer: usize,
        params: &Arc<Mutex<Vec<HostTensor>>>,
        wait_updates: bool,
    ) {
        let opt2 = Arc::clone(opt);
        let p2 = Arc::clone(params);
        self.prefetch_with(layer, move || {
            if wait_updates {
                opt2.wait_layer(layer); // params fully updated before use
            }
            Ok(p2.lock().unwrap().clone())
        });
    }

    /// Phase-generic form of [`IoPipeline::prefetch_params`]: run an
    /// arbitrary loader on the `param-upload` lane and stage its tensors
    /// for `layer`. The training engine's optimizer-wait snapshot and the
    /// serve engine's store-streamed weight read are both instances of
    /// this. No-op at depth 0 / already in flight.
    #[allow(clippy::map_entry)] // the insert needs &mut self.ex in between
    pub fn prefetch_with(
        &mut self,
        layer: usize,
        load: impl FnOnce() -> OpResult<Vec<HostTensor>> + Send + 'static,
    ) {
        if self.ex.is_none() || self.pending_params.contains_key(&layer) {
            return;
        }
        let slot: Slot<Vec<HostTensor>> = Arc::new(Mutex::new(None));
        let s2 = Arc::clone(&slot);
        let id = self.ex.as_mut().unwrap().submit_on(LANE_PARAM_UPLOAD, &[], move || {
            *s2.lock().unwrap() = Some(load());
        });
        self.pending_params.insert(layer, (id, slot));
    }

    /// Claim a prefetched parameter snapshot for `layer`. `Ok(None)` means
    /// no prefetch is in flight (a miss in async mode): the caller loads
    /// synchronously.
    pub fn take_params(&mut self, layer: usize) -> Result<Option<Vec<HostTensor>>> {
        let Some((id, slot)) = self.pending_params.remove(&layer) else {
            if self.is_async() {
                self.stats.prefetch_misses += 1;
            }
            return Ok(None);
        };
        let ex = self.ex.as_ref().expect("prefetched params imply async mode");
        timed(&mut self.stats, || ex.try_wait(id))
            .map_err(|m| anyhow!("param prefetch lane op panicked: {m}"))?;
        self.stats.prefetch_hits += 1;
        let res = slot
            .lock()
            .unwrap()
            .take()
            .ok_or_else(|| anyhow!("param prefetch l{layer} finished without a result"))?;
        let snap = res.map_err(|e| anyhow!("param prefetch l{layer}: {e}"))?;
        Ok(Some(snap))
    }

    /// Pass boundary: discard stale parameter prefetches (the forward and
    /// backward passes have different wait-for-update semantics). Normally a
    /// no-op — every in-pass prefetch is consumed by its layer transition.
    pub fn begin_pass(&mut self) -> Result<()> {
        let stale: Vec<usize> = self.pending_params.keys().copied().collect();
        for l in stale {
            if let Some((id, _slot)) = self.pending_params.remove(&l) {
                if let Some(ex) = self.ex.as_ref() {
                    ex.try_wait(id)
                        .map_err(|m| anyhow!("stale param prefetch lane op panicked: {m}"))?;
                }
            }
        }
        Ok(())
    }

    /// Step boundary: wait out all in-flight lane work and report any
    /// write-behind failure or lane panic as an error. After `flush` the SSD
    /// byte counters are step-accurate again.
    pub fn flush(&mut self) -> Result<()> {
        if let Some(ex) = self.ex.as_ref() {
            timed(&mut self.stats, || ex.try_wait_all())
                .map_err(|m| anyhow!("i/o lane op panicked: {m}"))?;
        }
        self.pending_writes.clear();
        self.pending_takes.clear();
        self.pending_params.clear();
        self.check_write_errors()
    }

    fn check_write_errors(&self) -> Result<()> {
        let mut errs = self.write_errors.lock().unwrap();
        if errs.is_empty() {
            Ok(())
        } else {
            let msg = errs.join("; ");
            errs.clear();
            Err(anyhow!("checkpoint write-behind failed: {msg}"))
        }
    }

    /// Test hook: make a lane op panic, to exercise the error boundary.
    #[cfg(test)]
    fn inject_panic_for_test(&mut self, msg: &'static str) {
        if let Some(ex) = self.ex.as_mut() {
            ex.submit_on(LANE_SSD_WRITE, &[], move || panic!("{msg}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::SsdStorage;

    fn ssd_ilc(tag: &str, read_bps: f64, write_bps: f64) -> Arc<InterLayerCoordinator> {
        let path = std::env::temp_dir().join(format!("gs_io_test_{tag}_{}", std::process::id()));
        let ssd = Arc::new(SsdStorage::create(path, read_bps, write_bps).unwrap());
        Arc::new(InterLayerCoordinator::new(ssd, true))
    }

    fn tensor(seed: usize, n: usize) -> HostTensor {
        HostTensor::from_vec(&[n], (0..n).map(|i| (i + seed) as f32).collect()).unwrap()
    }

    #[test]
    fn depth_zero_is_synchronous_passthrough() {
        let ilc = ssd_ilc("sync", f64::INFINITY, f64::INFINITY);
        let mut io = IoPipeline::new(0);
        assert!(!io.is_async());
        let t = tensor(7, 64);
        io.put_ckpt(&ilc, "k", t.clone()).unwrap();
        // synchronous: the checkpoint is live immediately
        assert_eq!(ilc.live_count(), 1);
        let back = io.take_ckpt(&ilc, "k").unwrap();
        assert_eq!(back, t);
        let s = io.stats();
        assert_eq!((s.prefetch_hits, s.prefetch_misses), (0, 0));
        io.flush().unwrap();
    }

    #[test]
    fn write_behind_then_prefetched_take_roundtrips() {
        let ilc = ssd_ilc("wb", f64::INFINITY, f64::INFINITY);
        let mut io = IoPipeline::new(2);
        let tensors: Vec<HostTensor> = (0..6).map(|i| tensor(i, 128)).collect();
        for (i, t) in tensors.iter().enumerate() {
            io.put_ckpt(&ilc, &format!("k{i}"), t.clone()).unwrap();
        }
        // prefetch half, take all — prefetched keys count as hits
        for i in 0..3 {
            io.prefetch_take(&ilc, &format!("k{i}"));
        }
        for (i, t) in tensors.iter().enumerate() {
            let back = io.take_ckpt(&ilc, &format!("k{i}")).unwrap();
            assert_eq!(&back, t, "k{i}");
        }
        let s = io.stats();
        assert_eq!(s.prefetch_hits, 3);
        assert_eq!(s.prefetch_misses, 3);
        io.flush().unwrap();
        assert_eq!(ilc.live_count(), 0);
    }

    #[test]
    fn take_waits_for_in_flight_write() {
        // slow writes: take must block on the write-behind, not read garbage
        let ilc = ssd_ilc("wait", f64::INFINITY, 10_000_000.0);
        let mut io = IoPipeline::new(1);
        let t = tensor(3, 100_000); // 400 KB -> 40 ms at 10 MB/s
        io.put_ckpt(&ilc, "slow", t.clone()).unwrap();
        let back = io.take_ckpt(&ilc, "slow").unwrap();
        assert_eq!(back, t);
        io.flush().unwrap();
    }

    #[test]
    fn missing_key_is_error_not_panic() {
        let ilc = ssd_ilc("miss", f64::INFINITY, f64::INFINITY);
        let mut io = IoPipeline::new(2);
        assert!(io.take_ckpt(&ilc, "nope").is_err());
        io.prefetch_take(&ilc, "ghost");
        assert!(io.take_ckpt(&ilc, "ghost").is_err());
        io.flush().unwrap();
    }

    /// Regression (engine boundary): a panicked lane op becomes an `anyhow`
    /// error from `flush`, not an unwind or a hang on the compute thread.
    #[test]
    fn lane_panic_surfaces_as_anyhow_error() {
        let ilc = ssd_ilc("panic", f64::INFINITY, f64::INFINITY);
        let mut io = IoPipeline::new(1);
        io.put_ckpt(&ilc, "fine", tensor(1, 16)).unwrap();
        io.inject_panic_for_test("lane exploded");
        let err = io.flush().unwrap_err().to_string();
        assert!(err.contains("lane exploded"), "{err}");
    }

    /// The headline property: under a throttled SSD, depth-K prefetch +
    /// write-behind strictly reduces the compute thread's I/O stall versus
    /// the synchronous depth-0 path, with every take a prefetch hit.
    #[test]
    fn prefetch_reduces_stall_under_throttle() {
        let n = 50_000; // 200 KB/tensor -> 40 ms per transfer at 5 MB/s
        let keys = 5usize;
        let compute = std::time::Duration::from_millis(50);

        let run = |depth: usize, tag: &str| -> IoStats {
            let ilc = ssd_ilc(tag, 5_000_000.0, 5_000_000.0);
            let mut io = IoPipeline::new(depth);
            for i in 0..keys {
                io.put_ckpt(&ilc, &format!("k{i}"), tensor(i, n)).unwrap();
                std::thread::sleep(compute); // the GPU work writes overlap
            }
            for i in 0..keys {
                io.prefetch_take(&ilc, &format!("k{i}"));
            }
            for i in 0..keys {
                std::thread::sleep(compute); // the GPU work reads overlap
                io.take_ckpt(&ilc, &format!("k{i}")).unwrap();
            }
            io.flush().unwrap();
            io.stats()
        };

        let sync = run(0, "stall0");
        let asyn = run(3, "stall3");
        assert_eq!(asyn.prefetch_hits, keys as u64);
        assert!(
            asyn.stall_seconds < 0.5 * sync.stall_seconds,
            "async stall {:.3}s vs sync {:.3}s",
            asyn.stall_seconds,
            sync.stall_seconds
        );
    }
}
