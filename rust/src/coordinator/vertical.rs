//! The GreedySnake vertical scheduler (§3.4, §4): execute every layer across
//! ALL micro-batches before advancing, accumulate parameter gradients in
//! resident buffers, overlap the (1-α) optimizer share with the backward
//! pass and the α share with the next iteration's forward.

use anyhow::{Context, Result};

use crate::runtime::tensor::{HostTensor, TokenTensor};
use crate::runtime::{Runtime, Stage};

use super::ckpt::{ckpt_key, InterLayerCoordinator};
use super::opt::OptimizerStepCoordinator;
use super::state::ModelState;

/// Per-step metrics.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f64,
    pub grad_norm: f64,
    pub ssd_bytes_read: u64,
    pub ssd_bytes_written: u64,
}

/// The vertical scheduler. Owns the inter-layer and optimizer coordinators;
/// the [`ModelState`] plays the parameter coordinator.
pub struct VerticalScheduler<'a> {
    pub state: &'a ModelState,
    pub rt: &'a Runtime,
    pub ilc: InterLayerCoordinator,
    pub opt: OptimizerStepCoordinator,
    step: u64,
}

impl<'a> VerticalScheduler<'a> {
    pub fn new(state: &'a ModelState, rt: &'a Runtime) -> Result<Self> {
        let opt = OptimizerStepCoordinator::new(state);
        opt.seed_ssd(state)?;
        Ok(VerticalScheduler {
            state,
            rt,
            ilc: InterLayerCoordinator::new(
                std::sync::Arc::clone(&state.ssd),
                state.cfg.ckpt_on_ssd,
            ),
            opt,
            step: 0,
        })
    }

    /// Micro-batch execution order for a layer: consecutive layers alternate
    /// so the boundary micro-batch's activation stays in GPU memory (§4.2).
    pub fn mb_order(layer: usize, m: usize) -> Vec<usize> {
        if layer % 2 == 0 {
            (0..m).collect()
        } else {
            (0..m).rev().collect()
        }
    }

    /// One training iteration over `m` micro-batches.
    /// `tokens[j]` / `targets[j]`: micro-batch j, shaped (B, T).
    pub fn step(&mut self, tokens: &[TokenTensor], targets: &[TokenTensor]) -> Result<StepStats> {
        let m = tokens.len();
        assert_eq!(m, targets.len());
        let c = self.state.manifest.config;
        let nl = c.n_layers;
        self.step += 1;
        let read0 = self.state.ssd.bytes_read();
        let written0 = self.state.ssd.bytes_written();

        // Kick off the delayed α updates from the previous iteration — they
        // overlap this forward pass; each layer waits before computing.
        self.opt.dispatch_delayed(
            self.state,
            Some(self.rt),
            self.step.saturating_sub(1).max(1),
        )?;
        self.opt.wait_embed();

        // ---------------- forward ----------------
        // Embedding (the boundary stage).
        let embed_lits = {
            let guard = self.state.embed.lock().unwrap();
            (guard[0].to_literal()?, guard[1].to_literal()?)
        };
        let mut acts: Vec<HostTensor> = Vec::with_capacity(m);
        for tok in tokens {
            let out = self.rt.execute(
                Stage::EmbedFwd,
                &[tok.to_literal()?, embed_lits.0.clone(), embed_lits.1.clone()],
            )?;
            acts.push(HostTensor::from_literal(&out[0])?);
        }

        for l in 0..nl {
            self.opt.wait_layer(l); // params must be fully updated (Fig. 8)
            let params = self.state.layer_literals(l)?;
            for &j in &Self::mb_order(l, m) {
                // the layer's INPUT activation is its backward checkpoint
                self.ilc
                    .put(&ckpt_key(l, j), acts[j].clone())
                    .with_context(|| format!("ckpt store l{l} mb{j}"))?;
                let x_lit = acts[j].to_literal()?;
                let mut inputs: Vec<&xla::Literal> = vec![&x_lit];
                inputs.extend(params.iter());
                let out = self.rt.execute(Stage::LayerFwd, &inputs)?;
                acts[j] = HostTensor::from_literal(&out[0])?;
            }
        }

        // ---------------- head: loss + dx + head/wte grads ----------------
        let mut loss_sum = 0.0f64;
        let mut dxs: Vec<HostTensor> = Vec::with_capacity(m);
        let mut dwte: Option<HostTensor> = None;
        let mut dlnf_w: Option<HostTensor> = None;
        let mut dlnf_b: Option<HostTensor> = None;
        {
            // Upload the (large) head parameters ONCE per step, not per
            // micro-batch — wte is V×D and dominated head-stage dispatch
            // before this caching (§Perf, EXPERIMENTS.md).
            let (wte_lit, lnf_w_lit, lnf_b_lit) = {
                let guard = self.state.embed.lock().unwrap();
                (guard[0].to_literal()?, guard[2].to_literal()?, guard[3].to_literal()?)
            };
            for j in 0..m {
                let out = self.rt.execute(
                    Stage::HeadLoss,
                    &[
                        &acts[j].to_literal()?,
                        &lnf_w_lit,
                        &lnf_b_lit,
                        &wte_lit,
                        &targets[j].to_literal()?,
                    ],
                )?;
                loss_sum += out[0].to_vec::<f32>()?[0] as f64;
                dxs.push(HostTensor::from_literal(&out[1])?);
                accumulate(&mut dlnf_w, HostTensor::from_literal(&out[2])?);
                accumulate(&mut dlnf_b, HostTensor::from_literal(&out[3])?);
                accumulate(&mut dwte, HostTensor::from_literal(&out[4])?);
            }
        }

        // ---------------- backward (vertical) + eager optimizer -----------
        for l in (0..nl).rev() {
            let params = self.state.layer_literals(l)?;
            let mut grad_acc: Option<Vec<HostTensor>> = None; // resident buffer
            for &j in &Self::mb_order(l, m) {
                let x_ckpt = self.ilc.take(&ckpt_key(l, j))?;
                let (x_lit, dy_lit) = (x_ckpt.to_literal()?, dxs[j].to_literal()?);
                let mut inputs: Vec<&xla::Literal> = vec![&x_lit, &dy_lit];
                inputs.extend(params.iter());
                let out = self.rt.execute(Stage::LayerBwd, &inputs)?;
                dxs[j] = HostTensor::from_literal(&out[0])?;
                // accumulate parameter gradients in the resident buffer
                match &mut grad_acc {
                    None => {
                        grad_acc = Some(
                            out[1..]
                                .iter()
                                .map(HostTensor::from_literal)
                                .collect::<Result<_>>()?,
                        );
                    }
                    Some(acc) => {
                        for (a, lit) in acc.iter_mut().zip(&out[1..]) {
                            a.add_assign(&HostTensor::from_literal(lit)?);
                        }
                    }
                }
            }
            // fully-accumulated gradients leave "GPU memory" exactly once
            self.opt
                .submit_eager(self.state, Some(self.rt), l, grad_acc.unwrap(), self.step)?;
        }

        // ---------------- embedding backward ------------------------------
        let mut dwpe: Option<HostTensor> = None;
        for j in 0..m {
            let out = self
                .rt
                .execute(Stage::EmbedBwd, &[tokens[j].to_literal()?, dxs[j].to_literal()?])?;
            accumulate(&mut dwte, HostTensor::from_literal(&out[0])?);
            accumulate(&mut dwpe, HostTensor::from_literal(&out[1])?);
        }
        self.opt.submit_embed(
            self.state,
            vec![dwte.unwrap(), dwpe.unwrap(), dlnf_w.unwrap(), dlnf_b.unwrap()],
            self.step,
        )?;

        let grad_norm = self.opt.finish_iter();
        Ok(StepStats {
            loss: loss_sum / m as f64,
            grad_norm,
            ssd_bytes_read: self.state.ssd.bytes_read() - read0,
            ssd_bytes_written: self.state.ssd.bytes_written() - written0,
        })
    }

    /// Drain all outstanding optimizer work (end of training).
    pub fn drain(&mut self) -> Result<()> {
        self.opt.dispatch_delayed(self.state, Some(self.rt), self.step.max(1))?;
        for l in 0..self.state.manifest.config.n_layers {
            self.opt.wait_layer(l);
        }
        self.opt.wait_embed();
        Ok(())
    }

    pub fn steps_done(&self) -> u64 {
        self.step
    }
}

/// Accumulate into an optional buffer.
pub fn accumulate(acc: &mut Option<HostTensor>, t: HostTensor) {
    match acc {
        None => *acc = Some(t),
        Some(a) => a.add_assign(&t),
    }
}
