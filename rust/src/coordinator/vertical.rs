//! The GreedySnake vertical scheduler (§3.4, §4): a thin
//! [`VerticalSchedule`] policy over the shared [`StepEngine`] — execute
//! every layer across ALL micro-batches before advancing, accumulate
//! parameter gradients in resident buffers, overlap the (1-α) optimizer
//! share with the backward pass and the α share with the next iteration's
//! forward. All execution machinery lives in [`super::engine`]; this type
//! exists as the named entry point for the paper's system.

use anyhow::Result;

use crate::runtime::tensor::TokenTensor;
use crate::runtime::Runtime;

// Compatibility re-exports: `StepStats` and `accumulate` predate the
// engine/schedule split and were defined here.
pub use super::engine::{accumulate, StepEngine, StepStats};
use super::schedule::{self, VerticalSchedule};
use super::state::ModelState;

/// The vertical scheduler: [`StepEngine`] driven by [`VerticalSchedule`].
pub struct VerticalScheduler<'a> {
    pub engine: StepEngine<'a>,
    policy: VerticalSchedule,
}

impl<'a> VerticalScheduler<'a> {
    pub fn new(state: &'a ModelState, rt: &'a Runtime) -> Result<Self> {
        Ok(VerticalScheduler { engine: StepEngine::new(state, rt)?, policy: VerticalSchedule })
    }

    /// Micro-batch execution order for a layer: consecutive layers alternate
    /// so the boundary micro-batch's activation stays in GPU memory (§4.2).
    pub fn mb_order(layer: usize, m: usize) -> Vec<usize> {
        schedule::mb_order(layer, m)
    }

    /// One training iteration over `m` micro-batches.
    /// `tokens[j]` / `targets[j]`: micro-batch j, shaped (B, T).
    pub fn step(&mut self, tokens: &[TokenTensor], targets: &[TokenTensor]) -> Result<StepStats> {
        self.engine.step(&self.policy, tokens, targets)
    }

    /// Drain all outstanding optimizer work (end of training).
    pub fn drain(&mut self) -> Result<()> {
        self.engine.drain()
    }

    pub fn steps_done(&self) -> u64 {
        self.engine.steps_done()
    }
}
