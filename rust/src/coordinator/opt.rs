//! Optimizer Step Coordinator: gradient offload, optimizer-state SSD round
//! trips, CPU Adam execution (worker-overlapped Rust path or inline AOT
//! Pallas kernel), and the §4.4 delay-α split.
//!
//! Optimizer state for each (layer, tensor) is stored as two objects on
//! the pluggable [`TensorStore`](crate::memory::store::TensorStore) tier,
//! split at the α boundary — the *eager* part `[0, split)` updates during
//! the backward pass (Fig. 7), the *delayed* part `[split, n)` during the
//! next iteration's forward (Fig. 8) — so each part round-trips exactly its
//! own bytes, like the paper's partial-state transfers.
//!
//! ## ZeRO-style sharding (`--shard-optimizer`)
//!
//! With [`TrainerConfig::shard_optimizer`] and `workers > 1`, every tensor's
//! element space is partitioned contiguously across the W ranks (the same
//! [`partition`](super::dist::partition) policy the micro-batches use), each
//! rank owns and updates only its shard, and the α split applies *per
//! shard* — rank r's eager part is the first (1−α) of r's shard, its
//! delayed tail the rest, so every rank keeps an optimizer/forward overlap
//! share (guaranteed non-empty by [`delay_split`]'s ceil rounding). The SSD
//! layout becomes one (rank, part) object per moment vector
//! ([`shard_part_key`]), so a rank's round trip moves ~1/W of the bytes the
//! rank-0 path moves. [`submit_eager`](OptimizerStepCoordinator::submit_eager)
//! and [`dispatch_delayed`](OptimizerStepCoordinator::dispatch_delayed) keep
//! their signatures: callers hand over full reduced gradients and the
//! coordinator fans the update out over the ranks internally. Because the
//! fused Adam expression is partition-invariant (§6.5; property-tested in
//! `optimizer::tests`), the sharded update is element-for-element
//! bit-identical to the unsharded one.
//!
//! ## Delayed gradient conversion (`--precision mixed:*`)
//!
//! Under a mixed [`TrainerConfig::precision`] policy, gradients arrive as
//! f32 and are requantized through the half-precision gradient codec
//! *delayed in-place*, MLP-Offload style: each (rank, part) visit rounds
//! exactly the shard range it is about to consume, inside the update,
//! instead of a separate whole-tensor conversion pass. The clip monitor
//! still accumulates the f32 norms on arrival (bookkeeping is not part of
//! the storage precision). The embedding/head group is master-weight
//! territory and always updates in f32. At `--precision f32` the gradient
//! codec is the identity and this path is bit-for-bit the historical one.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::exec::pool::{TaskHandle, ThreadPool};
use crate::memory::codec::Codec;
use crate::memory::store::TensorStore;
use crate::optimizer::{adam_step_hlo, adam_step_rust, delay_split, AdamParams, AdamState, ClipMonitor};
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;

use super::state::{ModelState, TrainerConfig};

/// Which half of the α split an update covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Part {
    Eager,
    Delayed,
}

/// SSD key for a split moment object.
pub fn part_key(layer: usize, tensor: usize, kind: char, part: Part) -> String {
    let suffix = match part {
        Part::Eager => "e",
        Part::Delayed => "d",
    };
    format!("opt_{kind}_l{layer}_t{tensor}_{suffix}")
}

/// SSD key for rank `rank`'s split moment object in the sharded
/// (`--shard-optimizer`) layout.
pub fn shard_part_key(
    layer: usize,
    tensor: usize,
    kind: char,
    rank: usize,
    part: Part,
) -> String {
    let suffix = match part {
        Part::Eager => "e",
        Part::Delayed => "d",
    };
    format!("opt_{kind}_l{layer}_t{tensor}_r{rank}_{suffix}")
}

/// Pending update handles for one layer.
#[derive(Default)]
struct LayerPending {
    eager: Option<TaskHandle<()>>,
    delayed: Option<TaskHandle<()>>,
    /// Gradients retained for the delayed part (§4.4's reclaimed memory).
    held_grads: Option<Arc<Vec<HostTensor>>>,
}

/// The coordinator.
pub struct OptimizerStepCoordinator {
    pool: ThreadPool,
    pending: Vec<Mutex<LayerPending>>,
    embed_pending: Mutex<Option<TaskHandle<()>>>,
    pub clip: Mutex<ClipMonitor>,
    cfg: TrainerConfig,
    /// Optimizer-state shard count: `cfg.workers` under `--shard-optimizer`
    /// (every rank owns a contiguous element shard of each tensor), else 1
    /// (the rank-0 path — one whole-tensor update).
    shards: usize,
}

impl OptimizerStepCoordinator {
    pub fn new(state: &ModelState) -> Self {
        let nl = state.manifest.config.n_layers;
        let shards = if state.cfg.shard_optimizer { state.cfg.workers.max(1) } else { 1 };
        OptimizerStepCoordinator {
            pool: ThreadPool::new(1), // one CPU-optimizer lane, like cpu_adam
            pending: (0..nl).map(|_| Mutex::new(LayerPending::default())).collect(),
            embed_pending: Mutex::new(None),
            clip: Mutex::new(ClipMonitor::new(state.cfg.clip_norm)),
            cfg: state.cfg.clone(),
            shards,
        }
    }

    /// Optimizer-state shard count (1 on the unsharded rank-0 path).
    pub fn n_shards(&self) -> usize {
        self.shards
    }

    /// Seed the split SSD objects for all layers (called once at startup
    /// when `opt_on_ssd`): one (eager, delayed) object pair per tensor, or
    /// one pair per (rank, tensor) in the sharded layout. Only non-empty
    /// parts get an object — exactly the parts
    /// [`shard_part_range`] reports non-empty, so the update paths never
    /// read a missing key.
    pub fn seed_ssd(&self, state: &ModelState) -> Result<()> {
        if !self.cfg.opt_on_ssd {
            return Ok(());
        }
        for l in 0..state.manifest.config.n_layers {
            for (t, spec) in state.manifest.layer_params.iter().enumerate() {
                for r in 0..self.shards {
                    for part in [Part::Eager, Part::Delayed] {
                        let (lo, hi) =
                            shard_part_range(spec.numel, self.cfg.alpha, r, self.shards, part);
                        if lo == hi {
                            continue;
                        }
                        for kind in ['m', 'v'] {
                            let key = if self.shards > 1 {
                                shard_part_key(l, t, kind, r, part)
                            } else {
                                part_key(l, t, kind, part)
                            };
                            state.store.put_f32(&key, &vec![0.0; hi - lo])?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Submit the eager (1-α) update for layer `l` with its freshly
    /// accumulated gradients. Overlaps on the worker unless configured
    /// inline. The gradients are retained for the delayed part. In sharded
    /// mode the update fans out over the W per-rank shards (disjoint element
    /// ranges of the same tensors — partition-invariant, so results match
    /// the whole-tensor update bit for bit).
    pub fn submit_eager(
        &self,
        state: &ModelState,
        rt: Option<&Runtime>,
        l: usize,
        grads: Vec<HostTensor>,
        step: u64,
    ) -> Result<()> {
        // speculative-clip accounting happens as gradients arrive — once per
        // tensor, sharded or not (the global-norm bookkeeping is unsharded)
        {
            let mut clip = self.clip.lock().unwrap();
            for g in &grads {
                clip.accumulate(g.sq_sum());
            }
        }
        let scale = self.clip.lock().unwrap().speculative_scale();
        let grads = Arc::new(grads);
        let mut pend = self.pending[l].lock().unwrap();
        pend.held_grads = Some(Arc::clone(&grads));
        let shards = self.shards;

        if self.cfg.use_hlo_adam {
            // PJRT is not Send: run inline through the AOT kernel.
            let rt = rt.expect("use_hlo_adam requires a Runtime");
            apply_update_hlo(state, rt, l, &grads, step, scale, shards, Part::Eager, &self.cfg)?;
            pend.eager = None;
        } else if self.cfg.overlap {
            let params = Arc::clone(&state.layers[l]);
            let opts = Arc::clone(&state.layer_opt[l]);
            let store = Arc::clone(&state.store);
            let cfg = self.cfg.clone();
            let g2 = Arc::clone(&grads);
            pend.eager = Some(self.pool.submit(move || {
                apply_update_rust(
                    &params, &opts, &store, l, &g2, step, scale, shards, Part::Eager, &cfg,
                )
                .expect("eager optimizer update");
            }));
        } else {
            apply_update_rust(
                &state.layers[l],
                &state.layer_opt[l],
                &state.store,
                l,
                &grads,
                step,
                scale,
                shards,
                Part::Eager,
                &self.cfg,
            )?;
            pend.eager = None;
        }
        Ok(())
    }

    /// Dispatch all delayed (α) updates — called at the start of the next
    /// iteration so they overlap its forward pass (Fig. 8). Sharded mode
    /// dispatches every rank's delayed tail (each rank delays the α-fraction
    /// of its own shard).
    pub fn dispatch_delayed(
        &self,
        state: &ModelState,
        rt: Option<&Runtime>,
        step: u64,
    ) -> Result<()> {
        if self.cfg.alpha <= 0.0 {
            return Ok(());
        }
        let shards = self.shards;
        for l in 0..state.manifest.config.n_layers {
            let mut pend = self.pending[l].lock().unwrap();
            let Some(grads) = pend.held_grads.take() else {
                continue; // first iteration: nothing accumulated yet
            };
            let scale = self.clip.lock().unwrap().speculative_scale();
            if self.cfg.use_hlo_adam {
                let rt = rt.expect("use_hlo_adam requires a Runtime");
                apply_update_hlo(
                    state, rt, l, &grads, step, scale, shards, Part::Delayed, &self.cfg,
                )?;
            } else if self.cfg.overlap {
                let params = Arc::clone(&state.layers[l]);
                let opts = Arc::clone(&state.layer_opt[l]);
                let store = Arc::clone(&state.store);
                let cfg = self.cfg.clone();
                pend.delayed = Some(self.pool.submit(move || {
                    apply_update_rust(
                        &params, &opts, &store, l, &grads, step, scale, shards, Part::Delayed,
                        &cfg,
                    )
                    .expect("delayed optimizer update");
                }));
            } else {
                apply_update_rust(
                    &state.layers[l],
                    &state.layer_opt[l],
                    &state.store,
                    l,
                    &grads,
                    step,
                    scale,
                    shards,
                    Part::Delayed,
                    &self.cfg,
                )?;
            }
        }
        Ok(())
    }

    /// Block until layer `l`'s parameters are fully updated — the
    /// "get the right data at the right time" dependency before its forward.
    pub fn wait_layer(&self, l: usize) {
        let (e, d) = {
            let mut pend = self.pending[l].lock().unwrap();
            (pend.eager.take(), pend.delayed.take())
        };
        if let Some(h) = e {
            h.wait();
        }
        if let Some(h) = d {
            h.wait();
        }
    }

    /// Update the embedding/head group (no α split; runs like a layer).
    pub fn submit_embed(
        &self,
        state: &ModelState,
        grads: Vec<HostTensor>,
        step: u64,
    ) -> Result<()> {
        {
            let mut clip = self.clip.lock().unwrap();
            for g in &grads {
                clip.accumulate(g.sq_sum());
            }
        }
        let scale = self.clip.lock().unwrap().speculative_scale();
        let hp = self.cfg.adam;
        let embed = Arc::clone(&state.embed);
        let opts = Arc::clone(&state.embed_opt);
        let job = move || {
            let mut params = embed.lock().unwrap();
            let mut opt = opts.lock().unwrap();
            for (t, g) in grads.iter().enumerate() {
                let n = g.numel();
                adam_step_rust(
                    &mut params[t].data,
                    &mut opt[t],
                    &g.data,
                    &hp,
                    step,
                    scale,
                    0,
                    n,
                );
            }
        };
        if self.cfg.overlap && !self.cfg.use_hlo_adam {
            *self.embed_pending.lock().unwrap() = Some(self.pool.submit(job));
        } else {
            job();
        }
        Ok(())
    }

    pub fn wait_embed(&self) {
        if let Some(h) = self.embed_pending.lock().unwrap().take() {
            h.wait();
        }
    }

    /// Finish the iteration's clip bookkeeping; returns the global norm.
    pub fn finish_iter(&self) -> f64 {
        self.clip.lock().unwrap().finish_iter()
    }
}

/// Element range covered by rank `rank`'s `part` for a tensor of `n`
/// elements sharded `shards` ways: the tensor partitions contiguously
/// across ranks — the same balanced split
/// [`partition`](super::dist::partition) produces, computed in closed form
/// here because this sits on the per-(layer, tensor, rank, part) optimizer
/// hot path (equality with `partition` is unit-tested) — and the α split
/// applies within each rank's shard. At
/// `shards == 1` this is exactly the historical global α split
/// (`Eager = [0, split)`, `Delayed = [split, n)`).
pub fn shard_part_range(
    n: usize,
    alpha: f64,
    rank: usize,
    shards: usize,
    part: Part,
) -> (usize, usize) {
    let w = shards.max(1);
    let (base, extra) = (n / w, n % w);
    let start = rank * base + rank.min(extra);
    let end = start + base + usize::from(rank < extra);
    let split = start + delay_split(end - start, alpha);
    match part {
        Part::Eager => (start, split),
        Part::Delayed => (split, end),
    }
}

/// SSD key for the (rank, part) moment object — the sharded layout when
/// `shards > 1`, the historical global layout otherwise.
fn moment_key(l: usize, t: usize, kind: char, rank: usize, shards: usize, part: Part) -> String {
    if shards > 1 {
        shard_part_key(l, t, kind, rank, part)
    } else {
        part_key(l, t, kind, part)
    }
}

/// The Send-safe Rust update path (runs on the worker). Covers `part` of
/// every tensor across ALL `shards` rank shards (the rank fan-out lives
/// here, so every call site updates the whole tensor's share of `part`;
/// `shards = 1` is the whole-tensor rank-0 path).
#[allow(clippy::too_many_arguments)]
fn apply_update_rust(
    params: &Arc<Mutex<Vec<HostTensor>>>,
    opts: &Arc<Mutex<Vec<AdamState>>>,
    store: &Arc<dyn TensorStore>,
    l: usize,
    grads: &Arc<Vec<HostTensor>>,
    step: u64,
    scale: f32,
    shards: usize,
    part: Part,
    cfg: &TrainerConfig,
) -> Result<()> {
    let hp: AdamParams = cfg.adam;
    let shards = shards.max(1);
    let gcodec = cfg.precision.policy().gradients;
    let mut pguard = params.lock().unwrap();
    for (t, g) in grads.iter().enumerate() {
        let n = g.numel();
        // Delayed in-place gradient conversion: the f32 gradient stays
        // untouched until a (rank, part) visit requantizes exactly the
        // shard range it consumes (no separate conversion pass). The
        // staging copy exists only under a half-precision gradient codec.
        let mut gq: Vec<f32> = Vec::new();
        for rank in 0..shards {
            let (lo, hi) = shard_part_range(n, cfg.alpha, rank, shards, part);
            if lo == hi {
                continue;
            }
            let gdata: &[f32] = if gcodec == Codec::F32 {
                &g.data
            } else {
                if gq.is_empty() {
                    gq.extend_from_slice(&g.data);
                }
                gcodec.requantize(&mut gq[lo..hi]);
                &gq
            };
            if cfg.opt_on_ssd {
                // round-trip exactly this part's bytes through the throttled
                // SSD (~1/W of the tensor per rank in sharded mode)
                let key_m = moment_key(l, t, 'm', rank, shards, part);
                let key_v = moment_key(l, t, 'v', rank, shards, part);
                let mut m = Vec::new();
                let mut v = Vec::new();
                store.get_f32(&key_m, &mut m)?;
                store.get_f32(&key_v, &mut v)?;
                let mut st = AdamState { m, v };
                adam_step_rust(
                    &mut pguard[t].data[lo..hi],
                    &mut st,
                    &gdata[lo..hi],
                    &hp,
                    step,
                    scale,
                    0,
                    hi - lo,
                );
                store.put_f32(&key_m, &st.m)?;
                store.put_f32(&key_v, &st.v)?;
            } else {
                let mut oguard = opts.lock().unwrap();
                adam_step_rust(
                    &mut pguard[t].data,
                    &mut oguard[t],
                    gdata,
                    &hp,
                    step,
                    scale,
                    lo,
                    hi,
                );
            }
        }
    }
    Ok(())
}

/// The inline AOT-kernel path (PJRT not Send). Same part coverage and rank
/// fan-out as [`apply_update_rust`].
#[allow(clippy::too_many_arguments)]
fn apply_update_hlo(
    state: &ModelState,
    rt: &Runtime,
    l: usize,
    grads: &Arc<Vec<HostTensor>>,
    step: u64,
    scale: f32,
    shards: usize,
    part: Part,
    cfg: &TrainerConfig,
) -> Result<()> {
    let chunk = state.manifest.config.adam_chunk;
    let shards = shards.max(1);
    let gcodec = cfg.precision.policy().gradients;
    let mut pguard = state.layers[l].lock().unwrap();
    for (t, g) in grads.iter().enumerate() {
        let n = g.numel();
        // same delayed in-place conversion as the Rust path
        let mut gq: Vec<f32> = Vec::new();
        for rank in 0..shards {
            let (lo, hi) = shard_part_range(n, cfg.alpha, rank, shards, part);
            if lo == hi {
                continue;
            }
            let gdata: &[f32] = if gcodec == Codec::F32 {
                &g.data
            } else {
                if gq.is_empty() {
                    gq.extend_from_slice(&g.data);
                }
                gcodec.requantize(&mut gq[lo..hi]);
                &gq
            };
            if cfg.opt_on_ssd {
                let key_m = moment_key(l, t, 'm', rank, shards, part);
                let key_v = moment_key(l, t, 'v', rank, shards, part);
                let mut m = Vec::new();
                let mut v = Vec::new();
                state.store.get_f32(&key_m, &mut m)?;
                state.store.get_f32(&key_v, &mut v)?;
                let mut st = AdamState { m, v };
                let len = hi - lo;
                adam_step_hlo(
                    rt,
                    chunk,
                    &mut pguard[t].data[lo..hi],
                    &mut st,
                    &gdata[lo..hi],
                    &cfg.adam,
                    step,
                    scale,
                    0,
                    len,
                )?;
                state.store.put_f32(&key_m, &st.m)?;
                state.store.put_f32(&key_v, &st.v)?;
            } else {
                let mut oguard = state.layer_opt[l].lock().unwrap();
                adam_step_hlo(
                    rt,
                    chunk,
                    &mut pguard[t].data,
                    &mut oguard[t],
                    gdata,
                    &cfg.adam,
                    step,
                    scale,
                    lo,
                    hi,
                )?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    /// `None` (skip) when the AOT artifacts were never built; these tests
    /// exercise the pure-Rust optimizer paths and only need the manifest.
    fn mk_state(alpha: f64, opt_on_ssd: bool, overlap: bool) -> Option<ModelState> {
        let m = Manifest::load_if_built("artifacts/tiny")?;
        let cfg = TrainerConfig {
            alpha,
            opt_on_ssd,
            overlap,
            ..TrainerConfig::for_test(&format!("opt_{alpha}_{opt_on_ssd}_{overlap}"))
        };
        Some(ModelState::init(m, cfg).unwrap())
    }

    fn fake_grads(state: &ModelState, seed: u64) -> Vec<HostTensor> {
        let mut rng = crate::util::prng::Prng::new(seed);
        state
            .manifest
            .layer_params
            .iter()
            .map(|s| {
                let mut t = HostTensor::zeros(&s.shape);
                rng.fill_normal(&mut t.data, 0.01);
                t
            })
            .collect()
    }

    /// Eager+delayed across all storage/overlap modes must equal one plain
    /// full-range Adam step.
    #[test]
    fn all_paths_agree_with_plain_adam() {
        let reference = {
            let Some(state) = mk_state(0.0, false, false) else { return };
            let coord = OptimizerStepCoordinator::new(&state);
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.wait_layer(0);
            let snapshot = state.layers[0].lock().unwrap().clone();
            snapshot
        };
        for (alpha, on_ssd, overlap) in
            [(0.3, false, false), (0.3, true, false), (0.3, true, true), (0.5, false, true)]
        {
            let state = mk_state(alpha, on_ssd, overlap).expect("gated above");
            let coord = OptimizerStepCoordinator::new(&state);
            coord.seed_ssd(&state).unwrap();
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.dispatch_delayed(&state, None, 1).unwrap();
            coord.wait_layer(0);
            let got = state.layers[0].lock().unwrap().clone();
            for (a, b) in reference.iter().zip(&got) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert!(
                        (x - y).abs() <= 1e-6,
                        "alpha={alpha} ssd={on_ssd} ov={overlap}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn delayed_part_not_applied_until_dispatch() {
        let Some(state) = mk_state(0.5, false, false) else { return };
        let coord = OptimizerStepCoordinator::new(&state);
        let before = state.layers[0].lock().unwrap().clone();
        let grads = fake_grads(&state, 2);
        coord.submit_eager(&state, None, 0, grads, 1).unwrap();
        coord.wait_layer(0);
        // w_fc2 (index 10) is large: its tail half must still be untouched
        let mid = state.layers[0].lock().unwrap().clone();
        let t = 10;
        let n = mid[t].numel();
        let split = delay_split(n, 0.5);
        assert_ne!(before[t].data[..split], mid[t].data[..split]);
        assert_eq!(before[t].data[split..], mid[t].data[split..]);
        coord.dispatch_delayed(&state, None, 1).unwrap();
        coord.wait_layer(0);
        let after = state.layers[0].lock().unwrap().clone();
        assert_ne!(mid[t].data[split..], after[t].data[split..]);
    }

    /// `shard_part_range` pure-function invariants: for any (n, α, W), the
    /// rank × part ranges are disjoint, ascending, cover `[0, n)` exactly,
    /// and tile the SAME rank boundaries as `dist::partition` (the closed
    /// form exists only to avoid the hot-path Vec allocation); at W = 1
    /// they reproduce the historical global α split.
    #[test]
    fn shard_part_range_partitions_exactly() {
        for n in [0usize, 1, 2, 3, 7, 64, 1000] {
            for alpha in [0.0, 0.25, 0.5] {
                for shards in [1usize, 2, 3, 4, 8] {
                    let ranges = crate::coordinator::dist::partition(n, shards);
                    let mut next = 0;
                    for r in 0..shards {
                        for part in [Part::Eager, Part::Delayed] {
                            let (lo, hi) = shard_part_range(n, alpha, r, shards, part);
                            assert!(lo <= hi, "n={n} α={alpha} W={shards} r={r}");
                            assert_eq!(lo, next, "gap at n={n} α={alpha} W={shards} r={r}");
                            next = hi;
                        }
                        // rank boundaries match dist::partition exactly
                        let (elo, _) = shard_part_range(n, alpha, r, shards, Part::Eager);
                        let (dlo, dhi) = shard_part_range(n, alpha, r, shards, Part::Delayed);
                        assert_eq!(elo, ranges[r].start, "n={n} W={shards} r={r}");
                        assert_eq!(dhi, ranges[r].end, "n={n} W={shards} r={r}");
                        // every non-empty shard keeps a delayed tail at α > 0
                        if alpha > 0.0 && dhi > elo {
                            assert!(dhi > dlo, "n={n} α={alpha} W={shards} r={r}: no delay");
                        }
                    }
                    assert_eq!(next, n, "n={n} α={alpha} W={shards}: not covered");
                }
                // W = 1 is the global split
                let split = delay_split(n, alpha);
                assert_eq!(shard_part_range(n, alpha, 0, 1, Part::Eager), (0, split));
                assert_eq!(shard_part_range(n, alpha, 0, 1, Part::Delayed), (split, n));
            }
        }
    }

    /// The sharded (ZeRO-style) update must equal one plain full-range Adam
    /// step bit-for-bit across storage/overlap modes and α values — the
    /// partition-invariance that makes `--shard-optimizer` bit-identical to
    /// the rank-0 path.
    #[test]
    fn sharded_update_matches_unsharded() {
        let reference = {
            let Some(state) = mk_state(0.0, false, false) else { return };
            let coord = OptimizerStepCoordinator::new(&state);
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.wait_layer(0);
            let snapshot = state.layers[0].lock().unwrap().clone();
            snapshot
        };
        for (alpha, on_ssd, overlap, workers) in [
            (0.0, false, false, 2),
            (0.25, false, false, 3),
            (0.25, true, false, 2),
            (0.25, true, true, 4),
            (0.5, true, false, 2),
        ] {
            let m = Manifest::load_if_built("artifacts/tiny").expect("gated above");
            let cfg = TrainerConfig {
                alpha,
                opt_on_ssd: on_ssd,
                overlap,
                workers,
                shard_optimizer: true,
                ..TrainerConfig::for_test(&format!("optsh_{alpha}_{on_ssd}_{overlap}_{workers}"))
            };
            let state = ModelState::init(m, cfg).unwrap();
            let coord = OptimizerStepCoordinator::new(&state);
            assert_eq!(coord.n_shards(), workers);
            coord.seed_ssd(&state).unwrap();
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.dispatch_delayed(&state, None, 1).unwrap();
            coord.wait_layer(0);
            let got = state.layers[0].lock().unwrap().clone();
            for (a, b) in reference.iter().zip(&got) {
                for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "alpha={alpha} ssd={on_ssd} ov={overlap} W={workers} i={i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn clip_monitor_counts_violations() {
        let Some(m) = Manifest::load_if_built("artifacts/tiny") else { return };
        let cfg = TrainerConfig {
            clip_norm: 1e-9, // everything violates
            ..TrainerConfig::for_test("opt_clip")
        };
        let state = ModelState::init(m, cfg).unwrap();
        let coord = OptimizerStepCoordinator::new(&state);
        let grads = fake_grads(&state, 3);
        coord.submit_eager(&state, None, 0, grads, 1).unwrap();
        let norm = coord.finish_iter();
        assert!(norm > 0.0);
        assert_eq!(coord.clip.lock().unwrap().violations, 1);
        assert!(coord.clip.lock().unwrap().speculative_scale() < 1.0);
    }
}
