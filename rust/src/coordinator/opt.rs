//! Optimizer Step Coordinator: gradient offload, optimizer-state SSD round
//! trips, CPU Adam execution (worker-overlapped Rust path or inline AOT
//! Pallas kernel), and the §4.4 delay-α split.
//!
//! Optimizer state for each (layer, tensor) is stored as two objects on
//! the pluggable [`TensorStore`](crate::memory::store::TensorStore) tier,
//! split at the α boundary — the *eager* part `[0, split)` updates during
//! the backward pass (Fig. 7), the *delayed* part `[split, n)` during the
//! next iteration's forward (Fig. 8) — so each part round-trips exactly its
//! own bytes, like the paper's partial-state transfers.
//!
//! ## ZeRO-style sharding (`--shard-optimizer`)
//!
//! With [`TrainerConfig::shard_optimizer`] and `workers > 1`, every tensor's
//! element space is partitioned contiguously across the W ranks (the same
//! [`partition`](super::dist::partition) policy the micro-batches use), each
//! rank owns and updates only its shard, and the α split applies *per
//! shard* — rank r's eager part is the first (1−α) of r's shard, its
//! delayed tail the rest, so every rank keeps an optimizer/forward overlap
//! share (guaranteed non-empty by [`delay_split`]'s ceil rounding). The SSD
//! layout becomes one (rank, part) object per moment vector
//! ([`shard_part_key`]), so a rank's round trip moves ~1/W of the bytes the
//! rank-0 path moves. [`submit_eager`](OptimizerStepCoordinator::submit_eager)
//! and [`dispatch_delayed`](OptimizerStepCoordinator::dispatch_delayed) keep
//! their signatures: callers hand over full reduced gradients and the
//! coordinator fans the update out over the ranks internally. Because the
//! fused Adam expression is partition-invariant (§6.5; property-tested in
//! `optimizer::tests`), the sharded update is element-for-element
//! bit-identical to the unsharded one.
//!
//! ## Delayed gradient conversion (`--precision mixed:*`)
//!
//! Under a mixed [`TrainerConfig::precision`] policy, gradients arrive as
//! f32 and are requantized through the half-precision gradient codec
//! *delayed in-place*, MLP-Offload style: each (rank, part) visit rounds
//! exactly the shard range it is about to consume, inside the update,
//! instead of a separate whole-tensor conversion pass. The clip monitor
//! still accumulates the f32 norms on arrival (bookkeeping is not part of
//! the storage precision). The embedding/head group is master-weight
//! territory and always updates in f32. At `--precision f32` the gradient
//! codec is the identity and this path is bit-for-bit the historical one.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::exec::pool::{TaskHandle, ThreadPool};
use crate::memory::codec::Codec;
use crate::memory::store::TensorStore;
use crate::optimizer::{adam_step_hlo, adam_step_rust, delay_split, AdamParams, AdamState, ClipMonitor};
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;

use super::state::{ModelState, TrainerConfig};

/// Which half of the α split an update covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Part {
    Eager,
    Delayed,
}

/// SSD key for a split moment object.
pub fn part_key(layer: usize, tensor: usize, kind: char, part: Part) -> String {
    let suffix = match part {
        Part::Eager => "e",
        Part::Delayed => "d",
    };
    format!("opt_{kind}_l{layer}_t{tensor}_{suffix}")
}

/// SSD key for rank `rank`'s split moment object in the sharded
/// (`--shard-optimizer`) layout.
pub fn shard_part_key(
    layer: usize,
    tensor: usize,
    kind: char,
    rank: usize,
    part: Part,
) -> String {
    let suffix = match part {
        Part::Eager => "e",
        Part::Delayed => "d",
    };
    format!("opt_{kind}_l{layer}_t{tensor}_r{rank}_{suffix}")
}

/// Store key for a persistence-sharded master-parameter object
/// (`--param-persist`): rank `rank`'s `part` of layer tensor `(layer,
/// tensor)` — the sharded `param_l{l}_t{t}_r{r}_{e|d}` layout when
/// `shards > 1`, the global `param_l{l}_t{t}_{e|d}` layout otherwise.
/// `param_*` keys are [`crate::memory::tier::Category::Working`] objects,
/// so every precision policy stores them f32 (master weights).
pub fn param_key(layer: usize, tensor: usize, rank: usize, shards: usize, part: Part) -> String {
    let suffix = match part {
        Part::Eager => "e",
        Part::Delayed => "d",
    };
    if shards > 1 {
        format!("param_l{layer}_t{tensor}_r{rank}_{suffix}")
    } else {
        format!("param_l{layer}_t{tensor}_{suffix}")
    }
}

/// Store key for a persistence-sharded embedding/head-group parameter
/// object (`--param-persist`). The embed group has no α split, so the key
/// carries only the rank: `param_emb_t{t}_r{r}` (or `param_emb_t{t}` in
/// the unsharded layout).
pub fn embed_param_key(tensor: usize, rank: usize, shards: usize) -> String {
    if shards > 1 {
        format!("param_emb_t{tensor}_r{rank}")
    } else {
        format!("param_emb_t{tensor}")
    }
}

/// Per-rank store byte counters for the persistence-sharded parameter
/// objects — the runtime evidence (fig17) that each rank round-trips
/// ~1/W of the parameter bytes per iteration under `--param-persist`.
/// Counts the decoded f32 bytes each (rank, part) visit moved (param
/// shards are stored f32 under every policy, so decoded == at-rest).
#[derive(Debug, Default)]
pub struct ParamShardCounters {
    read: Vec<AtomicU64>,
    written: Vec<AtomicU64>,
}

impl ParamShardCounters {
    fn new(shards: usize) -> Self {
        ParamShardCounters {
            read: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            written: (0..shards).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn add(&self, rank: usize, read: u64, written: u64) {
        self.read[rank].fetch_add(read, Ordering::Relaxed);
        self.written[rank].fetch_add(written, Ordering::Relaxed);
    }

    /// Parameter-shard bytes read from the store, by rank.
    pub fn read_by_rank(&self) -> Vec<u64> {
        self.read.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Parameter-shard bytes written to the store, by rank.
    pub fn written_by_rank(&self) -> Vec<u64> {
        self.written.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// Pending update handles for one layer.
#[derive(Default)]
struct LayerPending {
    eager: Option<TaskHandle<()>>,
    delayed: Option<TaskHandle<()>>,
    /// Gradients retained for the delayed part (§4.4's reclaimed memory).
    held_grads: Option<Arc<Vec<HostTensor>>>,
    /// The speculative clip scale captured when THIS step's eager part was
    /// submitted. The delayed part — dispatched after the intervening
    /// `finish_iter` may have changed the monitor's pending scale — must
    /// reuse it, so the clip decision is a per-step barrier value shared by
    /// every (rank, part, eager/delayed) submission of the step. Only
    /// meaningful while `held_grads` is `Some` (they are set together).
    held_scale: f32,
}

/// The coordinator.
pub struct OptimizerStepCoordinator {
    pool: ThreadPool,
    pending: Vec<Mutex<LayerPending>>,
    embed_pending: Mutex<Option<TaskHandle<()>>>,
    pub clip: Mutex<ClipMonitor>,
    cfg: TrainerConfig,
    /// Optimizer-state shard count: `cfg.workers` under `--shard-optimizer`
    /// (every rank owns a contiguous element shard of each tensor), else 1
    /// (the rank-0 path — one whole-tensor update).
    shards: usize,
    /// Per-rank byte counters for `--param-persist` shard round trips.
    pub param_counters: Arc<ParamShardCounters>,
}

impl OptimizerStepCoordinator {
    pub fn new(state: &ModelState) -> Self {
        let nl = state.manifest.config.n_layers;
        let shards = if state.cfg.shard_optimizer { state.cfg.workers.max(1) } else { 1 };
        OptimizerStepCoordinator {
            pool: ThreadPool::new(1), // one CPU-optimizer lane, like cpu_adam
            pending: (0..nl).map(|_| Mutex::new(LayerPending::default())).collect(),
            embed_pending: Mutex::new(None),
            clip: Mutex::new(ClipMonitor::new(state.cfg.clip_norm)),
            cfg: state.cfg.clone(),
            shards,
            param_counters: Arc::new(ParamShardCounters::new(shards)),
        }
    }

    /// Optimizer-state shard count (1 on the unsharded rank-0 path).
    pub fn n_shards(&self) -> usize {
        self.shards
    }

    /// Seed the split store objects for all layers (called at startup):
    /// one (eager, delayed) moment-object pair per tensor when
    /// `opt_on_ssd` — or one pair per (rank, tensor) in the sharded
    /// layout — plus the persistence-sharded `param_*` objects (seeded
    /// from the freshly initialized host parameters) when `param_persist`.
    /// Only non-empty parts get an object — exactly the parts
    /// [`shard_part_range`] reports non-empty, so the update paths never
    /// read a missing key.
    ///
    /// Idempotent: existing objects are left untouched (`contains` guard),
    /// so a coordinator rebuilt over a live store — crash recovery, or a
    /// resume after [`reshard_store`] — never clobbers evolved moments or
    /// parameter shards. A fresh store takes the historical seeding path
    /// bit for bit.
    pub fn seed_ssd(&self, state: &ModelState) -> Result<()> {
        if !self.cfg.opt_on_ssd && !self.cfg.param_persist {
            return Ok(());
        }
        for l in 0..state.manifest.config.n_layers {
            let params = state.layers[l].lock().unwrap();
            for (t, spec) in state.manifest.layer_params.iter().enumerate() {
                for r in 0..self.shards {
                    for part in [Part::Eager, Part::Delayed] {
                        let (lo, hi) =
                            shard_part_range(spec.numel, self.cfg.alpha, r, self.shards, part);
                        if lo == hi {
                            continue;
                        }
                        if self.cfg.opt_on_ssd {
                            for kind in ['m', 'v'] {
                                let key = if self.shards > 1 {
                                    shard_part_key(l, t, kind, r, part)
                                } else {
                                    part_key(l, t, kind, part)
                                };
                                if !state.store.contains(&key) {
                                    state.store.put_f32(&key, &vec![0.0; hi - lo])?;
                                }
                            }
                        }
                        if self.cfg.param_persist {
                            let key = param_key(l, t, r, self.shards, part);
                            if !state.store.contains(&key) {
                                state.store.put_f32(&key, &params[t].data[lo..hi])?;
                            }
                        }
                    }
                }
            }
        }
        if self.cfg.param_persist {
            let embed = state.embed.lock().unwrap();
            for (t, p) in embed.iter().enumerate() {
                for r in 0..self.shards {
                    let (lo, hi) =
                        shard_part_range(p.numel(), 0.0, r, self.shards, Part::Eager);
                    if lo == hi {
                        continue;
                    }
                    let key = embed_param_key(t, r, self.shards);
                    if !state.store.contains(&key) {
                        state.store.put_f32(&key, &p.data[lo..hi])?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Submit the eager (1-α) update for layer `l` with its freshly
    /// accumulated gradients. Overlaps on the worker unless configured
    /// inline. The gradients are retained for the delayed part. In sharded
    /// mode the update fans out over the W per-rank shards (disjoint element
    /// ranges of the same tensors — partition-invariant, so results match
    /// the whole-tensor update bit for bit).
    pub fn submit_eager(
        &self,
        state: &ModelState,
        rt: Option<&Runtime>,
        l: usize,
        grads: Vec<HostTensor>,
        step: u64,
    ) -> Result<()> {
        // speculative-clip accounting happens as gradients arrive — once per
        // tensor, sharded or not (the global-norm bookkeeping is unsharded)
        {
            let mut clip = self.clip.lock().unwrap();
            for g in &grads {
                clip.accumulate(g.sq_sum());
            }
        }
        let scale = self.clip.lock().unwrap().speculative_scale();
        let grads = Arc::new(grads);
        let mut pend = self.pending[l].lock().unwrap();
        pend.held_grads = Some(Arc::clone(&grads));
        // freeze the per-step clip decision: the delayed part of THIS step
        // reuses this scale even though it dispatches after finish_iter
        pend.held_scale = scale;
        let shards = self.shards;

        if self.cfg.use_hlo_adam {
            // PJRT is not Send: run inline through the AOT kernel.
            let rt = rt.expect("use_hlo_adam requires a Runtime");
            apply_update_hlo(
                state,
                rt,
                l,
                &grads,
                step,
                scale,
                shards,
                Part::Eager,
                &self.cfg,
                &self.param_counters,
            )?;
            pend.eager = None;
        } else if self.cfg.overlap {
            let params = Arc::clone(&state.layers[l]);
            let opts = Arc::clone(&state.layer_opt[l]);
            let store = Arc::clone(&state.store);
            let cfg = self.cfg.clone();
            let g2 = Arc::clone(&grads);
            let pctr = Arc::clone(&self.param_counters);
            pend.eager = Some(self.pool.submit(move || {
                apply_update_rust(
                    &params, &opts, &store, l, &g2, step, scale, shards, Part::Eager, &cfg,
                    &pctr,
                )
                .expect("eager optimizer update");
            }));
        } else {
            apply_update_rust(
                &state.layers[l],
                &state.layer_opt[l],
                &state.store,
                l,
                &grads,
                step,
                scale,
                shards,
                Part::Eager,
                &self.cfg,
                &self.param_counters,
            )?;
            pend.eager = None;
        }
        Ok(())
    }

    /// Dispatch all delayed (α) updates — called at the start of the next
    /// iteration so they overlap its forward pass (Fig. 8). Sharded mode
    /// dispatches every rank's delayed tail (each rank delays the α-fraction
    /// of its own shard).
    pub fn dispatch_delayed(
        &self,
        state: &ModelState,
        rt: Option<&Runtime>,
        step: u64,
    ) -> Result<()> {
        if crate::util::fault::any_armed()
            && crate::util::fault::should_fail(&crate::util::fault::scoped(
                "opt:delayed",
                &self.cfg.fault_scope,
            ))
        {
            anyhow::bail!("injected fault: delayed optimizer dispatch");
        }
        if self.cfg.alpha <= 0.0 {
            return Ok(());
        }
        let shards = self.shards;
        for l in 0..state.manifest.config.n_layers {
            let mut pend = self.pending[l].lock().unwrap();
            let Some(grads) = pend.held_grads.take() else {
                continue; // first iteration: nothing accumulated yet
            };
            // the per-step barrier scale frozen at submit_eager time — NOT
            // the monitor's current pending scale, which finish_iter may
            // have changed between this step's eager and delayed halves
            // (the finite-clip_norm drift documented in dist.rs)
            let scale = pend.held_scale;
            if self.cfg.use_hlo_adam {
                let rt = rt.expect("use_hlo_adam requires a Runtime");
                apply_update_hlo(
                    state,
                    rt,
                    l,
                    &grads,
                    step,
                    scale,
                    shards,
                    Part::Delayed,
                    &self.cfg,
                    &self.param_counters,
                )?;
            } else if self.cfg.overlap {
                let params = Arc::clone(&state.layers[l]);
                let opts = Arc::clone(&state.layer_opt[l]);
                let store = Arc::clone(&state.store);
                let cfg = self.cfg.clone();
                let pctr = Arc::clone(&self.param_counters);
                pend.delayed = Some(self.pool.submit(move || {
                    apply_update_rust(
                        &params, &opts, &store, l, &grads, step, scale, shards, Part::Delayed,
                        &cfg, &pctr,
                    )
                    .expect("delayed optimizer update");
                }));
            } else {
                apply_update_rust(
                    &state.layers[l],
                    &state.layer_opt[l],
                    &state.store,
                    l,
                    &grads,
                    step,
                    scale,
                    shards,
                    Part::Delayed,
                    &self.cfg,
                    &self.param_counters,
                )?;
            }
        }
        Ok(())
    }

    /// Block until layer `l`'s parameters are fully updated — the
    /// "get the right data at the right time" dependency before its forward.
    pub fn wait_layer(&self, l: usize) {
        let (e, d) = {
            let mut pend = self.pending[l].lock().unwrap();
            (pend.eager.take(), pend.delayed.take())
        };
        if let Some(h) = e {
            h.wait();
        }
        if let Some(h) = d {
            h.wait();
        }
    }

    /// Update the embedding/head group (no α split). In sharded mode the
    /// update fans out over the W contiguous rank ranges of each tensor —
    /// partition-invariant, so it is bit-identical to the historical
    /// full-range update — and under `--param-persist` each rank
    /// round-trips its own `param_emb_*` shard object through the store
    /// (~1/W of the group's parameter bytes per rank), mirroring the layer
    /// path.
    pub fn submit_embed(
        &self,
        state: &ModelState,
        grads: Vec<HostTensor>,
        step: u64,
    ) -> Result<()> {
        {
            let mut clip = self.clip.lock().unwrap();
            for g in &grads {
                clip.accumulate(g.sq_sum());
            }
        }
        let scale = self.clip.lock().unwrap().speculative_scale();
        let hp = self.cfg.adam;
        let embed = Arc::clone(&state.embed);
        let opts = Arc::clone(&state.embed_opt);
        let store = Arc::clone(&state.store);
        let shards = self.shards;
        let param_persist = self.cfg.param_persist && self.cfg.opt_on_ssd;
        let pctr = Arc::clone(&self.param_counters);
        let job = move || -> Result<()> {
            let mut params = embed.lock().unwrap();
            let mut opt = opts.lock().unwrap();
            for (t, g) in grads.iter().enumerate() {
                let n = g.numel();
                for rank in 0..shards {
                    let (lo, hi) = shard_part_range(n, 0.0, rank, shards, Part::Eager);
                    if lo == hi {
                        continue;
                    }
                    if param_persist {
                        let key = embed_param_key(t, rank, shards);
                        let mut pshard = Vec::new();
                        store.get_f32(&key, &mut pshard)?;
                        anyhow::ensure!(
                            pshard.len() == hi - lo,
                            "embed shard {key}: {} elems, want {}",
                            pshard.len(),
                            hi - lo
                        );
                        let mut st = AdamState {
                            m: opt[t].m[lo..hi].to_vec(),
                            v: opt[t].v[lo..hi].to_vec(),
                        };
                        adam_step_rust(
                            &mut pshard,
                            &mut st,
                            &g.data[lo..hi],
                            &hp,
                            step,
                            scale,
                            0,
                            hi - lo,
                        );
                        store.put_f32(&key, &pshard)?;
                        pctr.add(rank, 4 * (hi - lo) as u64, 4 * (hi - lo) as u64);
                        params[t].data[lo..hi].copy_from_slice(&pshard);
                        opt[t].m[lo..hi].copy_from_slice(&st.m);
                        opt[t].v[lo..hi].copy_from_slice(&st.v);
                    } else {
                        adam_step_rust(
                            &mut params[t].data,
                            &mut opt[t],
                            &g.data,
                            &hp,
                            step,
                            scale,
                            lo,
                            hi,
                        );
                    }
                }
            }
            Ok(())
        };
        if self.cfg.overlap && !self.cfg.use_hlo_adam {
            *self.embed_pending.lock().unwrap() =
                Some(self.pool.submit(move || job().expect("embed optimizer update")));
        } else {
            job()?;
        }
        Ok(())
    }

    pub fn wait_embed(&self) {
        if let Some(h) = self.embed_pending.lock().unwrap().take() {
            h.wait();
        }
    }

    /// Finish the iteration's clip bookkeeping; returns the global norm.
    pub fn finish_iter(&self) -> f64 {
        self.clip.lock().unwrap().finish_iter()
    }

    /// Wait out every in-flight optimizer task (eager/delayed pool handles
    /// and the embed update) WITHOUT consuming held delayed gradients — the
    /// pre-commit barrier the crash-consistent journal needs: after
    /// `quiesce` returns, all of this step's optimizer store writes have
    /// completed, so the epoch the trainer commits next is a consistent
    /// boundary.
    pub fn quiesce(&self) {
        for l in 0..self.pending.len() {
            self.wait_layer(l);
        }
        self.wait_embed();
    }

    /// Dispatch and complete every outstanding delayed (α-tail) update —
    /// the full-consistency barrier an elastic re-shard requires: after
    /// this, the optimizer state is exactly "`step` full steps applied",
    /// with no element range still owed its α share, so [`reshard_store`]
    /// may re-partition element space without splitting a half-applied
    /// step across two different shard layouts.
    pub fn drain_delayed(
        &self,
        state: &ModelState,
        rt: Option<&Runtime>,
        step: u64,
    ) -> Result<()> {
        self.dispatch_delayed(state, rt, step)?;
        self.quiesce();
        Ok(())
    }

    /// Persist the coordinator state a crash-recovery resume cannot
    /// reconstruct from the sharded objects alone: the clip monitor's
    /// boundary snapshot (`gs_clip`), each layer's held delayed gradients
    /// with their frozen per-step scale (`gs_held_*`), and the
    /// embedding/head group's DRAM-resident params + moments
    /// (`gs_emb_*`). Called by the trainer right before each epoch commit
    /// (after [`Self::quiesce`]); all keys are `Working`-category objects,
    /// stored f32 under every precision policy, so the restore is exact.
    pub fn persist_resume_state(&self, state: &ModelState) -> Result<()> {
        let store = &state.store;
        {
            let (scale, violations) = self.clip.lock().unwrap().snapshot();
            store.put_f32("gs_clip", &[scale, violations as f32])?;
        }
        for (l, pend) in self.pending.iter().enumerate() {
            let pend = pend.lock().unwrap();
            if let Some(grads) = &pend.held_grads {
                store.put_f32(&format!("gs_held_s_l{l}"), &[pend.held_scale])?;
                for (t, g) in grads.iter().enumerate() {
                    store.put_f32(&format!("gs_held_l{l}_t{t}"), &g.data)?;
                }
            }
        }
        let embed = state.embed.lock().unwrap();
        let opt = state.embed_opt.lock().unwrap();
        for (t, p) in embed.iter().enumerate() {
            store.put_f32(&format!("gs_emb_p_t{t}"), &p.data)?;
            store.put_f32(&format!("gs_emb_m_t{t}"), &opt[t].m)?;
            store.put_f32(&format!("gs_emb_v_t{t}"), &opt[t].v)?;
        }
        Ok(())
    }

    /// Restore the [`Self::persist_resume_state`] snapshot into a freshly
    /// built coordinator + model state — the host half of crash recovery,
    /// run after the store rolled back to the last committed boundary.
    /// Missing keys are treated as "nothing was pending" (a crash before
    /// the first commit restores the initial state).
    pub fn restore_resume_state(&self, state: &ModelState) -> Result<()> {
        let store = &state.store;
        let mut buf = Vec::new();
        if store.contains("gs_clip") {
            store.get_f32("gs_clip", &mut buf)?;
            anyhow::ensure!(buf.len() == 2, "gs_clip has {} elems", buf.len());
            self.clip.lock().unwrap().restore(buf[0], buf[1] as u64);
        }
        for (l, pend) in self.pending.iter().enumerate() {
            let key_s = format!("gs_held_s_l{l}");
            let mut pend = pend.lock().unwrap();
            pend.eager = None;
            pend.delayed = None;
            if store.contains(&key_s) {
                store.get_f32(&key_s, &mut buf)?;
                anyhow::ensure!(buf.len() == 1, "{key_s} has {} elems", buf.len());
                pend.held_scale = buf[0];
                let mut grads = Vec::with_capacity(state.manifest.layer_params.len());
                for (t, spec) in state.manifest.layer_params.iter().enumerate() {
                    let mut g = HostTensor::zeros(&spec.shape);
                    store.get_f32(&format!("gs_held_l{l}_t{t}"), &mut buf)?;
                    anyhow::ensure!(
                        buf.len() == g.numel(),
                        "gs_held_l{l}_t{t} has {} elems, want {}",
                        buf.len(),
                        g.numel()
                    );
                    g.data.copy_from_slice(&buf);
                    grads.push(g);
                }
                pend.held_grads = Some(Arc::new(grads));
            } else {
                pend.held_grads = None;
            }
        }
        let mut embed = state.embed.lock().unwrap();
        let mut opt = state.embed_opt.lock().unwrap();
        for t in 0..embed.len() {
            if !store.contains(&format!("gs_emb_p_t{t}")) {
                continue;
            }
            let mut restore_into = |suffix: &str, dst: &mut [f32]| -> Result<()> {
                store.get_f32(&format!("gs_emb_{suffix}_t{t}"), &mut buf)?;
                anyhow::ensure!(
                    buf.len() == dst.len(),
                    "gs_emb_{suffix}_t{t} has {} elems, want {}",
                    buf.len(),
                    dst.len()
                );
                dst.copy_from_slice(&buf);
                Ok(())
            };
            restore_into("p", &mut embed[t].data)?;
            restore_into("m", &mut opt[t].m)?;
            restore_into("v", &mut opt[t].v)?;
        }
        Ok(())
    }
}

/// Element range covered by rank `rank`'s `part` for a tensor of `n`
/// elements sharded `shards` ways: the tensor partitions contiguously
/// across ranks — the same balanced split
/// [`partition`](super::dist::partition) produces, computed in closed form
/// here because this sits on the per-(layer, tensor, rank, part) optimizer
/// hot path (equality with `partition` is unit-tested) — and the α split
/// applies within each rank's shard. At
/// `shards == 1` this is exactly the historical global α split
/// (`Eager = [0, split)`, `Delayed = [split, n)`).
pub fn shard_part_range(
    n: usize,
    alpha: f64,
    rank: usize,
    shards: usize,
    part: Part,
) -> (usize, usize) {
    let w = shards.max(1);
    let (base, extra) = (n / w, n % w);
    let start = rank * base + rank.min(extra);
    let end = start + base + usize::from(rank < extra);
    let split = start + delay_split(end - start, alpha);
    match part {
        Part::Eager => (start, split),
        Part::Delayed => (split, end),
    }
}

/// SSD key for the (rank, part) moment object — the sharded layout when
/// `shards > 1`, the historical global layout otherwise.
fn moment_key(l: usize, t: usize, kind: char, rank: usize, shards: usize, part: Part) -> String {
    if shards > 1 {
        shard_part_key(l, t, kind, rank, part)
    } else {
        part_key(l, t, kind, part)
    }
}

/// Re-partition one logical vector's per-(rank, part) store objects from an
/// `old_w`-way layout to a `new_w`-way layout: reassemble the full vector
/// in ascending element order (rank-major, eager-then-delayed — the same
/// canonical order `ModelState::moment_sq_norm` folds in), delete the old
/// objects, and write the new layout's objects. Because
/// [`shard_part_range`] is a pure closed form of `(n, α, rank, W, part)`,
/// the new objects are byte-identical to what a fresh `new_w`-way run
/// would hold at the same point.
fn repartition(
    store: &Arc<dyn TensorStore>,
    n: usize,
    alpha: f64,
    old_w: usize,
    new_w: usize,
    key: impl Fn(usize, usize, Part) -> String,
) -> Result<()> {
    let mut full: Vec<f32> = Vec::with_capacity(n);
    let mut buf = Vec::new();
    for r in 0..old_w {
        for part in [Part::Eager, Part::Delayed] {
            let (lo, hi) = shard_part_range(n, alpha, r, old_w, part);
            if lo == hi {
                continue;
            }
            let k = key(r, old_w, part);
            store.get_f32(&k, &mut buf)?;
            anyhow::ensure!(
                buf.len() == hi - lo,
                "reshard: {k} has {} elems, want {}",
                buf.len(),
                hi - lo
            );
            full.extend_from_slice(&buf);
        }
    }
    for r in 0..old_w {
        for part in [Part::Eager, Part::Delayed] {
            let (lo, hi) = shard_part_range(n, alpha, r, old_w, part);
            if lo != hi {
                store.delete(&key(r, old_w, part));
            }
        }
    }
    for r in 0..new_w {
        for part in [Part::Eager, Part::Delayed] {
            let (lo, hi) = shard_part_range(n, alpha, r, new_w, part);
            if lo == hi {
                continue;
            }
            store.put_f32(&key(r, new_w, part), &full[lo..hi])?;
        }
    }
    Ok(())
}

/// Deterministic elastic re-shard: re-partition EVERY per-rank store object
/// — the α-split moment objects and, under `--param-persist`, the
/// `param_*` shard objects (layer tensors and the embedding/head group) —
/// from an `old_shards`-way layout to a `new_shards`-way layout.
///
/// Determinism contract: a run that trains k steps at W, re-shards W→W′,
/// and continues at W′ is bit-identical to a fresh run that trained all
/// steps at W′ (pinned by the Σx² digest suites). This holds because (a)
/// [`shard_part_range`] partitions element space as a pure closed form, so
/// the re-written objects equal what the W′ run would hold, and (b) the
/// fused Adam update is partition-invariant, so element values never
/// depended on the old grouping in the first place.
///
/// MUST be called at a *drained* boundary — after
/// [`OptimizerStepCoordinator::drain_delayed`] (no outstanding α-tail
/// work) and outside any in-flight journal epoch — otherwise a
/// half-applied step would be split across two shard layouts. The caller
/// then updates `cfg.workers` and rebuilds the coordinator; its idempotent
/// [`OptimizerStepCoordinator::seed_ssd`] leaves the re-sharded objects
/// untouched.
pub fn reshard_store(state: &ModelState, old_shards: usize, new_shards: usize) -> Result<()> {
    let old_w = old_shards.max(1);
    let new_w = new_shards.max(1);
    if old_w == new_w {
        return Ok(());
    }
    let alpha = state.cfg.alpha;
    for l in 0..state.manifest.config.n_layers {
        for (t, spec) in state.manifest.layer_params.iter().enumerate() {
            if state.cfg.opt_on_ssd {
                for kind in ['m', 'v'] {
                    repartition(&state.store, spec.numel, alpha, old_w, new_w, |r, w, part| {
                        moment_key(l, t, kind, r, w, part)
                    })?;
                }
            }
            if state.cfg.param_persist {
                repartition(&state.store, spec.numel, alpha, old_w, new_w, |r, w, part| {
                    param_key(l, t, r, w, part)
                })?;
            }
        }
    }
    if state.cfg.param_persist {
        let sizes: Vec<usize> = {
            let embed = state.embed.lock().unwrap();
            embed.iter().map(|p| p.numel()).collect()
        };
        for (t, n) in sizes.into_iter().enumerate() {
            // the embed group has no α split (α = 0 keeps Delayed empty)
            repartition(&state.store, n, 0.0, old_w, new_w, |r, w, _part| {
                embed_param_key(t, r, w)
            })?;
        }
    }
    Ok(())
}

/// The Send-safe Rust update path (runs on the worker). Covers `part` of
/// every tensor across ALL `shards` rank shards (the rank fan-out lives
/// here, so every call site updates the whole tensor's share of `part`;
/// `shards = 1` is the whole-tensor rank-0 path).
#[allow(clippy::too_many_arguments)]
fn apply_update_rust(
    params: &Arc<Mutex<Vec<HostTensor>>>,
    opts: &Arc<Mutex<Vec<AdamState>>>,
    store: &Arc<dyn TensorStore>,
    l: usize,
    grads: &Arc<Vec<HostTensor>>,
    step: u64,
    scale: f32,
    shards: usize,
    part: Part,
    cfg: &TrainerConfig,
    pctr: &ParamShardCounters,
) -> Result<()> {
    let hp: AdamParams = cfg.adam;
    let shards = shards.max(1);
    let gcodec = cfg.precision.policy().gradients;
    let mut pguard = params.lock().unwrap();
    for (t, g) in grads.iter().enumerate() {
        let n = g.numel();
        // Delayed in-place gradient conversion: the f32 gradient stays
        // untouched until a (rank, part) visit requantizes exactly the
        // shard range it consumes (no separate conversion pass). The
        // staging copy exists only under a half-precision gradient codec.
        let mut gq: Vec<f32> = Vec::new();
        for rank in 0..shards {
            let (lo, hi) = shard_part_range(n, cfg.alpha, rank, shards, part);
            if lo == hi {
                continue;
            }
            let gdata: &[f32] = if gcodec == Codec::F32 {
                &g.data
            } else {
                if gq.is_empty() {
                    gq.extend_from_slice(&g.data);
                }
                gcodec.requantize(&mut gq[lo..hi]);
                &gq
            };
            if cfg.opt_on_ssd {
                // round-trip exactly this part's bytes through the throttled
                // SSD (~1/W of the tensor per rank in sharded mode)
                let key_m = moment_key(l, t, 'm', rank, shards, part);
                let key_v = moment_key(l, t, 'v', rank, shards, part);
                let mut m = Vec::new();
                let mut v = Vec::new();
                store.get_f32(&key_m, &mut m)?;
                store.get_f32(&key_v, &mut v)?;
                let mut st = AdamState { m, v };
                if cfg.param_persist {
                    // the finished ZeRO-Infinity picture: the rank's master
                    // parameter shard round-trips the store with its
                    // moments, and the host replica is refreshed from the
                    // updated shard (the all-gather stand-in). Param shards
                    // store f32 under every policy, so the round trip is
                    // lossless and this stays bit-identical to the in-place
                    // host update.
                    let key_p = param_key(l, t, rank, shards, part);
                    let mut pshard = Vec::new();
                    store.get_f32(&key_p, &mut pshard)?;
                    anyhow::ensure!(
                        pshard.len() == hi - lo,
                        "param shard {key_p}: {} elems, want {}",
                        pshard.len(),
                        hi - lo
                    );
                    adam_step_rust(
                        &mut pshard,
                        &mut st,
                        &gdata[lo..hi],
                        &hp,
                        step,
                        scale,
                        0,
                        hi - lo,
                    );
                    store.put_f32(&key_p, &pshard)?;
                    pctr.add(rank, 4 * (hi - lo) as u64, 4 * (hi - lo) as u64);
                    pguard[t].data[lo..hi].copy_from_slice(&pshard);
                } else {
                    adam_step_rust(
                        &mut pguard[t].data[lo..hi],
                        &mut st,
                        &gdata[lo..hi],
                        &hp,
                        step,
                        scale,
                        0,
                        hi - lo,
                    );
                }
                store.put_f32(&key_m, &st.m)?;
                store.put_f32(&key_v, &st.v)?;
            } else {
                let mut oguard = opts.lock().unwrap();
                adam_step_rust(
                    &mut pguard[t].data,
                    &mut oguard[t],
                    gdata,
                    &hp,
                    step,
                    scale,
                    lo,
                    hi,
                );
            }
        }
    }
    Ok(())
}

/// The inline AOT-kernel path (PJRT not Send). Same part coverage and rank
/// fan-out as [`apply_update_rust`].
#[allow(clippy::too_many_arguments)]
fn apply_update_hlo(
    state: &ModelState,
    rt: &Runtime,
    l: usize,
    grads: &Arc<Vec<HostTensor>>,
    step: u64,
    scale: f32,
    shards: usize,
    part: Part,
    cfg: &TrainerConfig,
    pctr: &ParamShardCounters,
) -> Result<()> {
    let chunk = state.manifest.config.adam_chunk;
    let shards = shards.max(1);
    let gcodec = cfg.precision.policy().gradients;
    let mut pguard = state.layers[l].lock().unwrap();
    for (t, g) in grads.iter().enumerate() {
        let n = g.numel();
        // same delayed in-place conversion as the Rust path
        let mut gq: Vec<f32> = Vec::new();
        for rank in 0..shards {
            let (lo, hi) = shard_part_range(n, cfg.alpha, rank, shards, part);
            if lo == hi {
                continue;
            }
            let gdata: &[f32] = if gcodec == Codec::F32 {
                &g.data
            } else {
                if gq.is_empty() {
                    gq.extend_from_slice(&g.data);
                }
                gcodec.requantize(&mut gq[lo..hi]);
                &gq
            };
            if cfg.opt_on_ssd {
                let key_m = moment_key(l, t, 'm', rank, shards, part);
                let key_v = moment_key(l, t, 'v', rank, shards, part);
                let mut m = Vec::new();
                let mut v = Vec::new();
                state.store.get_f32(&key_m, &mut m)?;
                state.store.get_f32(&key_v, &mut v)?;
                let mut st = AdamState { m, v };
                let len = hi - lo;
                if cfg.param_persist {
                    // same store round trip of the rank's param shard as
                    // the Rust path (see apply_update_rust)
                    let key_p = param_key(l, t, rank, shards, part);
                    let mut pshard = Vec::new();
                    state.store.get_f32(&key_p, &mut pshard)?;
                    anyhow::ensure!(
                        pshard.len() == len,
                        "param shard {key_p}: {} elems, want {len}",
                        pshard.len()
                    );
                    adam_step_hlo(
                        rt,
                        chunk,
                        &mut pshard,
                        &mut st,
                        &gdata[lo..hi],
                        &cfg.adam,
                        step,
                        scale,
                        0,
                        len,
                    )?;
                    state.store.put_f32(&key_p, &pshard)?;
                    pctr.add(rank, 4 * len as u64, 4 * len as u64);
                    pguard[t].data[lo..hi].copy_from_slice(&pshard);
                } else {
                    adam_step_hlo(
                        rt,
                        chunk,
                        &mut pguard[t].data[lo..hi],
                        &mut st,
                        &gdata[lo..hi],
                        &cfg.adam,
                        step,
                        scale,
                        0,
                        len,
                    )?;
                }
                state.store.put_f32(&key_m, &st.m)?;
                state.store.put_f32(&key_v, &st.v)?;
            } else {
                let mut oguard = state.layer_opt[l].lock().unwrap();
                adam_step_hlo(
                    rt,
                    chunk,
                    &mut pguard[t].data,
                    &mut oguard[t],
                    gdata,
                    &cfg.adam,
                    step,
                    scale,
                    lo,
                    hi,
                )?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    /// `None` (skip) when the AOT artifacts were never built; these tests
    /// exercise the pure-Rust optimizer paths and only need the manifest.
    fn mk_state(alpha: f64, opt_on_ssd: bool, overlap: bool) -> Option<ModelState> {
        let m = Manifest::load_if_built("artifacts/tiny")?;
        let cfg = TrainerConfig {
            alpha,
            opt_on_ssd,
            overlap,
            ..TrainerConfig::for_test(&format!("opt_{alpha}_{opt_on_ssd}_{overlap}"))
        };
        Some(ModelState::init(m, cfg).unwrap())
    }

    fn fake_grads(state: &ModelState, seed: u64) -> Vec<HostTensor> {
        let mut rng = crate::util::prng::Prng::new(seed);
        state
            .manifest
            .layer_params
            .iter()
            .map(|s| {
                let mut t = HostTensor::zeros(&s.shape);
                rng.fill_normal(&mut t.data, 0.01);
                t
            })
            .collect()
    }

    /// Eager+delayed across all storage/overlap modes must equal one plain
    /// full-range Adam step.
    #[test]
    fn all_paths_agree_with_plain_adam() {
        let reference = {
            let Some(state) = mk_state(0.0, false, false) else { return };
            let coord = OptimizerStepCoordinator::new(&state);
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.wait_layer(0);
            let snapshot = state.layers[0].lock().unwrap().clone();
            snapshot
        };
        for (alpha, on_ssd, overlap) in
            [(0.3, false, false), (0.3, true, false), (0.3, true, true), (0.5, false, true)]
        {
            let state = mk_state(alpha, on_ssd, overlap).expect("gated above");
            let coord = OptimizerStepCoordinator::new(&state);
            coord.seed_ssd(&state).unwrap();
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.dispatch_delayed(&state, None, 1).unwrap();
            coord.wait_layer(0);
            let got = state.layers[0].lock().unwrap().clone();
            for (a, b) in reference.iter().zip(&got) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert!(
                        (x - y).abs() <= 1e-6,
                        "alpha={alpha} ssd={on_ssd} ov={overlap}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn delayed_part_not_applied_until_dispatch() {
        let Some(state) = mk_state(0.5, false, false) else { return };
        let coord = OptimizerStepCoordinator::new(&state);
        let before = state.layers[0].lock().unwrap().clone();
        let grads = fake_grads(&state, 2);
        coord.submit_eager(&state, None, 0, grads, 1).unwrap();
        coord.wait_layer(0);
        // w_fc2 (index 10) is large: its tail half must still be untouched
        let mid = state.layers[0].lock().unwrap().clone();
        let t = 10;
        let n = mid[t].numel();
        let split = delay_split(n, 0.5);
        assert_ne!(before[t].data[..split], mid[t].data[..split]);
        assert_eq!(before[t].data[split..], mid[t].data[split..]);
        coord.dispatch_delayed(&state, None, 1).unwrap();
        coord.wait_layer(0);
        let after = state.layers[0].lock().unwrap().clone();
        assert_ne!(mid[t].data[split..], after[t].data[split..]);
    }

    /// `shard_part_range` pure-function invariants: for any (n, α, W), the
    /// rank × part ranges are disjoint, ascending, cover `[0, n)` exactly,
    /// and tile the SAME rank boundaries as `dist::partition` (the closed
    /// form exists only to avoid the hot-path Vec allocation); at W = 1
    /// they reproduce the historical global α split.
    #[test]
    fn shard_part_range_partitions_exactly() {
        for n in [0usize, 1, 2, 3, 7, 64, 1000] {
            for alpha in [0.0, 0.25, 0.5] {
                for shards in [1usize, 2, 3, 4, 8] {
                    let ranges = crate::coordinator::dist::partition(n, shards);
                    let mut next = 0;
                    for r in 0..shards {
                        for part in [Part::Eager, Part::Delayed] {
                            let (lo, hi) = shard_part_range(n, alpha, r, shards, part);
                            assert!(lo <= hi, "n={n} α={alpha} W={shards} r={r}");
                            assert_eq!(lo, next, "gap at n={n} α={alpha} W={shards} r={r}");
                            next = hi;
                        }
                        // rank boundaries match dist::partition exactly
                        let (elo, _) = shard_part_range(n, alpha, r, shards, Part::Eager);
                        let (dlo, dhi) = shard_part_range(n, alpha, r, shards, Part::Delayed);
                        assert_eq!(elo, ranges[r].start, "n={n} W={shards} r={r}");
                        assert_eq!(dhi, ranges[r].end, "n={n} W={shards} r={r}");
                        // every non-empty shard keeps a delayed tail at α > 0
                        if alpha > 0.0 && dhi > elo {
                            assert!(dhi > dlo, "n={n} α={alpha} W={shards} r={r}: no delay");
                        }
                    }
                    assert_eq!(next, n, "n={n} α={alpha} W={shards}: not covered");
                }
                // W = 1 is the global split
                let split = delay_split(n, alpha);
                assert_eq!(shard_part_range(n, alpha, 0, 1, Part::Eager), (0, split));
                assert_eq!(shard_part_range(n, alpha, 0, 1, Part::Delayed), (split, n));
            }
        }
    }

    /// The sharded (ZeRO-style) update must equal one plain full-range Adam
    /// step bit-for-bit across storage/overlap modes and α values — the
    /// partition-invariance that makes `--shard-optimizer` bit-identical to
    /// the rank-0 path.
    #[test]
    fn sharded_update_matches_unsharded() {
        let reference = {
            let Some(state) = mk_state(0.0, false, false) else { return };
            let coord = OptimizerStepCoordinator::new(&state);
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.wait_layer(0);
            let snapshot = state.layers[0].lock().unwrap().clone();
            snapshot
        };
        for (alpha, on_ssd, overlap, workers) in [
            (0.0, false, false, 2),
            (0.25, false, false, 3),
            (0.25, true, false, 2),
            (0.25, true, true, 4),
            (0.5, true, false, 2),
        ] {
            let m = Manifest::load_if_built("artifacts/tiny").expect("gated above");
            let cfg = TrainerConfig {
                alpha,
                opt_on_ssd: on_ssd,
                overlap,
                workers,
                shard_optimizer: true,
                ..TrainerConfig::for_test(&format!("optsh_{alpha}_{on_ssd}_{overlap}_{workers}"))
            };
            let state = ModelState::init(m, cfg).unwrap();
            let coord = OptimizerStepCoordinator::new(&state);
            assert_eq!(coord.n_shards(), workers);
            coord.seed_ssd(&state).unwrap();
            let grads = fake_grads(&state, 1);
            coord.submit_eager(&state, None, 0, grads, 1).unwrap();
            coord.dispatch_delayed(&state, None, 1).unwrap();
            coord.wait_layer(0);
            let got = state.layers[0].lock().unwrap().clone();
            for (a, b) in reference.iter().zip(&got) {
                for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "alpha={alpha} ssd={on_ssd} ov={overlap} W={workers} i={i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn clip_monitor_counts_violations() {
        let Some(m) = Manifest::load_if_built("artifacts/tiny") else { return };
        let cfg = TrainerConfig {
            clip_norm: 1e-9, // everything violates
            ..TrainerConfig::for_test("opt_clip")
        };
        let state = ModelState::init(m, cfg).unwrap();
        let coord = OptimizerStepCoordinator::new(&state);
        let grads = fake_grads(&state, 3);
        coord.submit_eager(&state, None, 0, grads, 1).unwrap();
        let norm = coord.finish_iter();
        assert!(norm > 0.0);
        assert_eq!(coord.clip.lock().unwrap().violations, 1);
        assert!(coord.clip.lock().unwrap().speculative_scale() < 1.0);
    }

    fn fake_embed_grads(state: &ModelState, seed: u64) -> Vec<HostTensor> {
        let mut rng = crate::util::prng::Prng::new(seed);
        let shapes: Vec<Vec<usize>> =
            state.embed.lock().unwrap().iter().map(|p| p.shape.clone()).collect();
        shapes
            .into_iter()
            .map(|s| {
                let mut t = HostTensor::zeros(&s);
                rng.fill_normal(&mut t.data, 0.01);
                t
            })
            .collect()
    }

    fn assert_bits_eq(a: &[HostTensor], b: &[HostTensor], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: tensor count");
        for (t, (x, y)) in a.iter().zip(b).enumerate() {
            for (i, (p, q)) in x.data.iter().zip(&y.data).enumerate() {
                assert_eq!(p.to_bits(), q.to_bits(), "{ctx}: tensor {t} elem {i}: {p} vs {q}");
            }
        }
    }

    /// Regression: the clip decision is a PER-STEP barrier value. The
    /// delayed (α) half of step s must reuse the scale frozen when s's
    /// eager half was submitted — `finish_iter` runs between the two halves
    /// and changes the monitor's pending scale, and dispatching the delayed
    /// half with that fresher scale silently de-synchronizes it from the
    /// eager half (the finite-`clip_norm` drift). A finite-clip α > 0 run,
    /// sharded or not, must stay bit-identical to the α = 0
    /// single-submission reference.
    #[test]
    fn clip_scale_is_a_per_step_barrier() {
        const STEPS: u64 = 3;
        let run = |alpha: f64, workers: usize| -> Option<(Vec<HostTensor>, u64)> {
            let m = Manifest::load_if_built("artifacts/tiny")?;
            let cfg = TrainerConfig {
                alpha,
                // small enough that every fake_grads step violates, so the
                // pending scale varies from step to step
                clip_norm: 0.05,
                workers,
                shard_optimizer: workers > 1,
                ..TrainerConfig::for_test(&format!("opt_clipbar_{alpha}_{workers}"))
            };
            let state = ModelState::init(m, cfg).unwrap();
            let coord = OptimizerStepCoordinator::new(&state);
            coord.seed_ssd(&state).unwrap();
            for s in 1..=STEPS {
                if s > 1 {
                    coord.dispatch_delayed(&state, None, s - 1).unwrap();
                }
                coord.submit_eager(&state, None, 0, fake_grads(&state, s), s).unwrap();
                // the drift trigger: the monitor's pending scale changes
                // between this step's eager and delayed submissions
                coord.finish_iter();
            }
            coord.dispatch_delayed(&state, None, STEPS).unwrap();
            coord.wait_layer(0);
            let snap = state.layers[0].lock().unwrap().clone();
            let violations = coord.clip.lock().unwrap().violations;
            Some((snap, violations))
        };
        let Some((reference, viol)) = run(0.0, 1) else { return };
        // sanity: the clip actually engages, or this test pins nothing
        assert_eq!(viol, STEPS, "clip_norm=0.05 should violate every step");
        for workers in [1usize, 2] {
            let (got, viol) = run(0.25, workers).expect("gated above");
            assert_eq!(viol, STEPS);
            assert_bits_eq(&reference, &got, &format!("alpha=0.25 W={workers}"));
        }
    }

    /// `--param-persist` must be bit-identical to the host-resident update
    /// (the shard round trip is f32, Adam is partition-invariant), and its
    /// per-rank counters must show each rank moving ~1/W of the 4·Σnumel
    /// parameter bytes per full step, read and written.
    #[test]
    fn param_persist_matches_host_resident() {
        const STEPS: u64 = 2;
        let Some(man) = Manifest::load_if_built("artifacts/tiny") else { return };
        let total_numel: u64 = man.layer_params.iter().map(|s| s.numel as u64).sum();
        let n_tensors = man.layer_params.len() as u64;
        let run = |persist: bool, workers: usize| -> (Vec<HostTensor>, Vec<u64>, Vec<u64>) {
            let m = Manifest::load_if_built("artifacts/tiny").expect("gated above");
            let cfg = TrainerConfig {
                alpha: 0.25,
                opt_on_ssd: true,
                param_persist: persist,
                workers,
                shard_optimizer: workers > 1,
                ..TrainerConfig::for_test(&format!("opt_pp_{persist}_{workers}"))
            };
            let state = ModelState::init(m, cfg).unwrap();
            let coord = OptimizerStepCoordinator::new(&state);
            coord.seed_ssd(&state).unwrap();
            for s in 1..=STEPS {
                if s > 1 {
                    coord.dispatch_delayed(&state, None, s - 1).unwrap();
                }
                coord.submit_eager(&state, None, 0, fake_grads(&state, s), s).unwrap();
            }
            coord.dispatch_delayed(&state, None, STEPS).unwrap();
            coord.wait_layer(0);
            let snap = state.layers[0].lock().unwrap().clone();
            (
                snap,
                coord.param_counters.read_by_rank(),
                coord.param_counters.written_by_rank(),
            )
        };
        let (reference, rd0, wr0) = run(false, 1);
        assert_eq!(rd0.iter().sum::<u64>(), 0, "no param traffic without --param-persist");
        assert_eq!(wr0.iter().sum::<u64>(), 0);
        let expect_total = STEPS * 4 * total_numel;
        for workers in [1usize, 3] {
            let (got, rd, wr) = run(true, workers);
            assert_bits_eq(&reference, &got, &format!("param-persist W={workers}"));
            assert_eq!(rd.len(), workers);
            assert_eq!(rd.iter().sum::<u64>(), expect_total, "W={workers} reads");
            assert_eq!(wr.iter().sum::<u64>(), expect_total, "W={workers} writes");
            // ~1/W per rank: contiguous partitioning keeps every rank's
            // shard of each tensor within one element of n/W
            let slack = 4 * STEPS * n_tensors;
            let fair = expect_total / workers as u64;
            for (r, &b) in rd.iter().enumerate() {
                assert!(
                    b <= fair + slack && b + slack >= fair,
                    "W={workers} rank {r}: {b} bytes vs fair share {fair}"
                );
            }
        }
    }

    /// The sharded embedding/head update (rank fan-out + per-rank
    /// `param_emb_*` store round trips) must equal the historical
    /// full-range in-place update bit for bit.
    #[test]
    fn sharded_embed_update_matches_unsharded() {
        const STEPS: u64 = 2;
        let run = |workers: usize, persist: bool| -> Option<Vec<HostTensor>> {
            let m = Manifest::load_if_built("artifacts/tiny")?;
            let cfg = TrainerConfig {
                opt_on_ssd: persist,
                param_persist: persist,
                workers,
                shard_optimizer: workers > 1,
                ..TrainerConfig::for_test(&format!("opt_emb_{workers}_{persist}"))
            };
            let state = ModelState::init(m, cfg).unwrap();
            let coord = OptimizerStepCoordinator::new(&state);
            coord.seed_ssd(&state).unwrap();
            for s in 1..=STEPS {
                coord.submit_embed(&state, fake_embed_grads(&state, s), s).unwrap();
                coord.wait_embed();
            }
            let snap = state.embed.lock().unwrap().clone();
            Some(snap)
        };
        let Some(reference) = run(1, false) else { return };
        for (workers, persist) in [(2, false), (2, true), (3, true)] {
            let got = run(workers, persist).expect("gated above");
            assert_bits_eq(&reference, &got, &format!("embed W={workers} persist={persist}"));
        }
    }

    /// `seed_ssd` over a live store (what a crash-recovery rebuild does)
    /// must not clobber evolved state: after a full step, re-seeding leaves
    /// param shards and moment objects bit-identical.
    #[test]
    fn seed_ssd_is_idempotent_over_live_store() {
        let Some(m) = Manifest::load_if_built("artifacts/tiny") else { return };
        let cfg = TrainerConfig {
            alpha: 0.25,
            opt_on_ssd: true,
            param_persist: true,
            workers: 2,
            shard_optimizer: true,
            ..TrainerConfig::for_test("opt_seed_idem")
        };
        let state = ModelState::init(m, cfg).unwrap();
        let coord = OptimizerStepCoordinator::new(&state);
        coord.seed_ssd(&state).unwrap();
        coord.submit_eager(&state, None, 0, fake_grads(&state, 7), 1).unwrap();
        coord.dispatch_delayed(&state, None, 1).unwrap();
        coord.wait_layer(0);
        let key_p = param_key(0, 0, 0, 2, Part::Eager);
        let key_m = shard_part_key(0, 0, 'm', 1, Part::Delayed);
        let (mut before_p, mut before_m) = (Vec::new(), Vec::new());
        state.store.get_f32(&key_p, &mut before_p).unwrap();
        state.store.get_f32(&key_m, &mut before_m).unwrap();
        // the step must have moved the moments off their zero seed, or the
        // re-seed below could "pass" by rewriting identical bytes
        assert!(before_m.iter().any(|&x| x != 0.0));
        OptimizerStepCoordinator::new(&state).seed_ssd(&state).unwrap();
        let (mut after_p, mut after_m) = (Vec::new(), Vec::new());
        state.store.get_f32(&key_p, &mut after_p).unwrap();
        state.store.get_f32(&key_m, &mut after_m).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&before_p), bits(&after_p), "param shard clobbered by re-seed");
        assert_eq!(bits(&before_m), bits(&after_m), "moment shard clobbered by re-seed");
    }

    /// Elastic re-shard determinism: train 2 steps at W=2, drain the α
    /// tail, `reshard_store(2→3)`, continue 1 step at W=3 — parameters,
    /// embed group, and the moment digest must be bit-identical to a fresh
    /// 3-step run at W=3.
    #[test]
    fn reshard_resume_matches_fresh_run() {
        let mk = |workers: usize, tag: &str| -> Option<ModelState> {
            let m = Manifest::load_if_built("artifacts/tiny")?;
            let cfg = TrainerConfig {
                alpha: 0.25,
                opt_on_ssd: true,
                param_persist: true,
                workers,
                shard_optimizer: true,
                ..TrainerConfig::for_test(tag)
            };
            Some(ModelState::init(m, cfg).unwrap())
        };
        let step = |state: &ModelState, coord: &OptimizerStepCoordinator, s: u64| {
            if s > 1 {
                coord.dispatch_delayed(state, None, s - 1).unwrap();
            }
            coord.submit_eager(state, None, 0, fake_grads(state, s), s).unwrap();
            coord.submit_embed(state, fake_embed_grads(state, 100 + s), s).unwrap();
            coord.finish_iter();
        };

        // resumed path: 2 steps at W=2, drained re-shard to W=3, 1 more step
        let Some(mut state_a) = mk(2, "opt_reshard_a") else { return };
        {
            let coord = OptimizerStepCoordinator::new(&state_a);
            coord.seed_ssd(&state_a).unwrap();
            step(&state_a, &coord, 1);
            step(&state_a, &coord, 2);
            coord.drain_delayed(&state_a, None, 2).unwrap();
        }
        reshard_store(&state_a, 2, 3).unwrap();
        state_a.cfg.workers = 3;
        let coord_a = OptimizerStepCoordinator::new(&state_a);
        assert_eq!(coord_a.n_shards(), 3);
        coord_a.seed_ssd(&state_a).unwrap(); // idempotent over the re-sharded store
        step(&state_a, &coord_a, 3);
        coord_a.drain_delayed(&state_a, None, 3).unwrap();

        // fresh path: all 3 steps at W=3
        let state_b = mk(3, "opt_reshard_b").expect("gated above");
        let coord_b = OptimizerStepCoordinator::new(&state_b);
        coord_b.seed_ssd(&state_b).unwrap();
        for s in 1..=3 {
            step(&state_b, &coord_b, s);
        }
        coord_b.drain_delayed(&state_b, None, 3).unwrap();

        assert_bits_eq(
            &state_a.layers[0].lock().unwrap(),
            &state_b.layers[0].lock().unwrap(),
            "resumed vs fresh layer params",
        );
        assert_bits_eq(
            &state_a.embed.lock().unwrap(),
            &state_b.embed.lock().unwrap(),
            "resumed vs fresh embed params",
        );
        let (da, db) =
            (state_a.moment_sq_norm().unwrap(), state_b.moment_sq_norm().unwrap());
        assert_eq!(da.to_bits(), db.to_bits(), "moment digest: {da} vs {db}");
    }
}
