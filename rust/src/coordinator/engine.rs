//! The schedule-agnostic training step engine — the TRAINING policy over
//! the phase-generic [`LayerStreamer`](super::streamer::LayerStreamer)
//! core (which owns the one-layer residency model, the depth-K lookahead
//! window, and the parameter byte meter; `coordinator::serve` builds its
//! forward-only token engine on the same core).
//!
//! Everything the vertical and horizontal schedulers used to duplicate
//! lives here exactly once: stage dispatch (EmbedFwd / LayerFwd / HeadLoss /
//! LayerBwd / EmbedBwd), activation checkpoint put/take through the
//! [`InterLayerCoordinator`], resident gradient accumulation, eager / embed
//! optimizer submission through the [`OptimizerStepCoordinator`], delayed-α
//! dispatch, and SSD + parameter-upload byte accounting. A
//! [`Schedule`](super::schedule::Schedule) contributes only the traversal
//! order and three policy knobs; the engine is the single place that knows
//! how to *execute* a visit.
//!
//! Parameter residency is modeled by a one-layer literal cache: a visit to a
//! layer other than the cached one re-uploads that layer's parameters (and,
//! in the forward pass, first waits for its pending optimizer updates — the
//! "update layer i before its forward" dependency, Fig. 8). The cache-miss
//! count is exactly the schedule-dependent parameter traffic the paper
//! analyzes: one load per layer per pass under the vertical order, one per
//! (layer, micro-batch) under the horizontal order, one per (layer, chunk)
//! in between.
//!
//! I/O is asynchronous: since the schedule hands over the full visit order
//! up front, the engine looks ahead `cfg.io_depth` visits through the
//! [`IoPipeline`](super::io::IoPipeline) — issuing the *next* visits'
//! parameter loads (and, in the
//! backward pass, checkpoint reads) while the current visit computes, and
//! turning checkpoint stores into write-behind with completion tracking.
//! Depth 0 reproduces the synchronous engine bit-for-bit; either way the
//! [`StepStats`] report prefetch hits/misses and the compute thread's I/O
//! stall seconds.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::memory::store::TensorStore;
use crate::runtime::tensor::{HostTensor, TokenTensor};
use crate::runtime::{Runtime, Stage};

use super::ckpt::{ckpt_key, InterLayerCoordinator};
use super::io::IoStats;
use super::opt::OptimizerStepCoordinator;
use super::schedule::{validate_order, Schedule};
use super::state::ModelState;
use super::streamer::{LayerStreamer, ParamCache};

/// Per-step metrics.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f64,
    pub grad_norm: f64,
    pub ssd_bytes_read: u64,
    pub ssd_bytes_written: u64,
    /// Bytes of layer parameters uploaded to the device this step — the
    /// schedule-dependent share of host↔GPU traffic (§3.3 vs §3.4).
    pub param_bytes_loaded: u64,
    /// Lookahead loads that were already in flight when needed (0 when
    /// `io_depth == 0`).
    pub prefetch_hits: u64,
    /// Loads the engine had to perform synchronously in async mode.
    pub prefetch_misses: u64,
    /// Seconds the compute thread spent blocked in the parameter/checkpoint
    /// data path this step — synchronous transfers at depth 0, residual
    /// waits on in-flight prefetches at depth ≥ 1. Deliberately *includes*
    /// waiting out a layer's pending optimizer updates before its load (the
    /// Fig. 8 dependency) on both paths — at depth ≥ 1 that wait runs on the
    /// `param-upload` lane, which is part of the overlap win — so depth-0
    /// and depth-K runs measure the same blocking set and stay comparable.
    pub io_stall_s: f64,
    /// Wall seconds spent in the deterministic ring all-reduce combining the
    /// workers' gradients ([`super::dist::DataParallelEngine`]). 0 on the
    /// single-worker engine.
    pub allreduce_s: f64,
    /// Ring traffic the all-reduce moved this step, summed across ranks
    /// (2·(W−1)·payload for W active workers). Under `--shard-optimizer`
    /// this counts the gradient *reduce-scatter* ((W−1)·payload over the
    /// whole group) instead. 0 on the single-worker engine.
    pub allreduce_bytes: u64,
    /// Ring traffic of the parameter all-gather that republishes the
    /// per-rank updated shards under `--shard-optimizer` ((W−1)·param
    /// payload, performed before the next iteration's prefetch). 0 on the
    /// single-worker engine and the rank-0 (unsharded) optimizer path.
    pub allgather_bytes: u64,
    /// DRAM cache-tier hits this step (0 without `--cpu-cache-mb` — see
    /// [`crate::memory::CachedStore`]). A hit is a `get` served from DRAM
    /// without touching the SSD tier.
    pub cache_hits: u64,
    /// Cache-tier misses this step (reads that fell through to the SSD).
    pub cache_misses: u64,
    /// Cache-tier LRU evictions this step (dirty victims write back).
    pub cache_evictions: u64,
}

/// Accumulate into an optional buffer.
pub fn accumulate(acc: &mut Option<HostTensor>, t: HostTensor) {
    match acc {
        None => *acc = Some(t),
        Some(a) => a.add_assign(&t),
    }
}

/// The training policy over the phase-generic [`LayerStreamer`] core: owns
/// the inter-layer and optimizer coordinators (shared with the I/O lanes
/// via `Arc`) and layers grad/ckpt/optimizer logic on the core's
/// schedule-driven visit iteration; the [`ModelState`] plays the parameter
/// coordinator.
pub struct StepEngine<'a> {
    pub state: &'a ModelState,
    pub rt: &'a Runtime,
    pub ilc: Arc<InterLayerCoordinator>,
    pub opt: Arc<OptimizerStepCoordinator>,
    core: LayerStreamer,
    step: u64,
}

impl<'a> StepEngine<'a> {
    pub fn new(state: &'a ModelState, rt: &'a Runtime) -> Result<Self> {
        let opt = OptimizerStepCoordinator::new(state);
        opt.seed_ssd(state)?;
        Ok(Self::with_coordinator(state, rt, Arc::new(opt)))
    }

    /// Build an engine sharing an externally owned optimizer coordinator —
    /// how [`super::dist::DataParallelEngine`] gives its W workers one
    /// coordinator, so every worker's forward waits on the same pending
    /// (eager and delayed) updates (the Fig. 8 dependency) while each keeps
    /// its own checkpoint coordinator and I/O-pipeline lanes. The caller is
    /// responsible for having seeded the SSD moments once.
    pub fn with_coordinator(
        state: &'a ModelState,
        rt: &'a Runtime,
        opt: Arc<OptimizerStepCoordinator>,
    ) -> Self {
        // Bytes one layer's parameter stream moves per load, at the
        // precision policy's parameter width — half under
        // `--precision mixed:*` (the low-precision parameter copy is what
        // streams), 4 B/elem at strict f32.
        let bpe = state.cfg.precision.policy().parameters.bytes_per_elem();
        let layer_bytes = state.manifest.layer_numel() as u64 * bpe;
        StepEngine {
            state,
            rt,
            ilc: Arc::new(InterLayerCoordinator::new(
                Arc::clone(&state.store),
                state.cfg.ckpt_on_ssd,
            )),
            opt,
            core: LayerStreamer::new(state.cfg.io_depth, layer_bytes),
            step: 0,
        }
    }

    /// Iterations executed so far.
    pub fn steps_done(&self) -> u64 {
        self.step
    }

    /// Resume the iteration counter — crash recovery rebuilds the engine
    /// after rolling the store back to the last committed epoch, and Adam's
    /// bias correction (plus the delayed-dispatch step tags) must continue
    /// from the committed step count, not restart at 0.
    pub fn set_steps_done(&mut self, n: u64) {
        self.step = n;
    }

    /// Cumulative parameter bytes uploaded across all steps.
    pub fn param_bytes_loaded(&self) -> u64 {
        self.core.param_bytes_loaded()
    }

    /// Cumulative I/O-pipeline counters across all steps.
    pub fn io_stats(&self) -> IoStats {
        self.core.stats()
    }

    /// Training's parameter-load policy over the core: ensure `cache` holds
    /// layer `l`'s literals, claiming a prefetched snapshot (issued by
    /// [`Self::lookahead`]) when available; otherwise the load runs
    /// synchronously — optionally waiting for the layer's pending optimizer
    /// updates first (forward passes must; backward passes reuse the
    /// forward's params), with the wait on the stall clock (the prefetched
    /// path performs the same wait on the lane, so both modes charge the
    /// same blocking set — see [`StepStats::io_stall_s`]).
    fn ensure_params(&mut self, cache: &mut ParamCache, l: usize, wait: bool) -> Result<()> {
        if cache.layer == Some(l) {
            return Ok(());
        }
        if wait
            && crate::util::fault::any_armed()
            && crate::util::fault::should_fail(&crate::util::fault::scoped(
                "engine:forward",
                &self.state.cfg.fault_scope,
            ))
        {
            bail!("injected fault: forward parameter load (layer {l})");
        }
        let state = self.state;
        let opt = Arc::clone(&self.opt);
        self.core.ensure_params(cache, l, move || {
            if wait {
                opt.wait_layer(l); // params fully updated before use (Fig. 8)
            }
            state.layer_literals(l)
        })
    }

    /// Issue the async loads for the next `io_depth` visits after `idx` in
    /// `order`: parameter snapshots at every upcoming layer transition
    /// (deduped — the pipeline tracks in-flight layers) and, in the backward
    /// pass, the upcoming visits' checkpoint reads.
    fn lookahead(&mut self, order: &[(usize, usize)], idx: usize, forward: bool) {
        let state = self.state;
        let opt = Arc::clone(&self.opt);
        let ilc = Arc::clone(&self.ilc);
        self.core.lookahead(
            order,
            idx,
            |io, l| io.prefetch_params(&opt, l, &state.layers[l], forward),
            |io, l, j| {
                if !forward {
                    io.prefetch_take(&ilc, &ckpt_key(l, j));
                }
            },
        );
    }

    /// One training iteration over `m` micro-batches under `schedule`.
    /// `tokens[j]` / `targets[j]`: micro-batch j, shaped (B, T).
    ///
    /// KEEP IN SYNC with [`Self::partial_step`]: the data-parallel path
    /// re-implements this stage dispatch with per-visit gradient retention
    /// (it cannot share the resident-accumulation control flow without
    /// losing the eager-optimizer/backward overlap), and the bit-equality
    /// contract between the two is what the gradient-equivalence suite in
    /// `rust/tests/integration.rs` pins down. Any change to stage inputs,
    /// checkpoint keying, or I/O sequencing here must be mirrored there.
    pub fn step(
        &mut self,
        schedule: &dyn Schedule,
        tokens: &[TokenTensor],
        targets: &[TokenTensor],
    ) -> Result<StepStats> {
        let m = tokens.len();
        assert_eq!(m, targets.len());
        assert!(m > 0, "a step needs at least one micro-batch");
        let nl = self.state.manifest.config.n_layers;
        if self.state.cfg.alpha > 0.0 && !schedule.supports_delay() {
            bail!(
                "schedule '{}' has no delayed-step support (α must be 0, got {})",
                schedule.name(),
                self.state.cfg.alpha
            );
        }
        self.step += 1;
        let read0 = self.state.store.bytes_read();
        let written0 = self.state.store.bytes_written();
        let cache0 = self.state.store.cache_stats().total;
        let loaded0 = self.core.param_bytes_loaded();
        let io0 = self.core.stats();

        // Kick off the delayed α updates from the previous iteration — they
        // overlap this forward pass; each layer's first forward visit waits.
        if schedule.supports_delay() {
            self.opt.dispatch_delayed(
                self.state,
                Some(self.rt),
                self.step.saturating_sub(1).max(1),
            )?;
        }
        self.opt.wait_embed();

        // ---------------- forward ----------------
        // Embedding (the boundary stage); upload wte/wpe once per step.
        let embed_lits = {
            let guard = self.state.embed.lock().unwrap();
            (guard[0].to_literal()?, guard[1].to_literal()?)
        };
        let mut acts: Vec<HostTensor> = Vec::with_capacity(m);
        for tok in tokens {
            let out = self.rt.execute(
                Stage::EmbedFwd,
                &[tok.to_literal()?, embed_lits.0.clone(), embed_lits.1.clone()],
            )?;
            acts.push(HostTensor::from_literal(&out[0])?);
        }
        drop(embed_lits);

        let fwd = schedule.forward_order(nl, m);
        validate_order(&fwd, nl, m, false)
            .with_context(|| format!("schedule '{}' forward order", schedule.name()))?;
        self.core.begin_pass()?;
        let mut cache = ParamCache::empty();
        for (idx, &(l, j)) in fwd.iter().enumerate() {
            self.ensure_params(&mut cache, l, true)?;
            self.lookahead(&fwd, idx, true);
            // the layer's INPUT activation is its backward checkpoint
            // (write-behind: the store overlaps this visit's compute)
            self.core
                .io_mut()
                .put_ckpt(&self.ilc, &ckpt_key(l, j), acts[j].clone())
                .with_context(|| format!("ckpt store l{l} mb{j}"))?;
            let x_lit = acts[j].to_literal()?;
            let mut inputs: Vec<&xla::Literal> = vec![&x_lit];
            inputs.extend(cache.literals.iter());
            let out = self.rt.execute(Stage::LayerFwd, &inputs)?;
            acts[j] = HostTensor::from_literal(&out[0])?;
        }

        // ---------------- head: loss + dx + head/wte grads ----------------
        let mut loss_sum = 0.0f64;
        let mut dxs: Vec<HostTensor> = Vec::with_capacity(m);
        let mut dwte: Option<HostTensor> = None;
        let mut dlnf_w: Option<HostTensor> = None;
        let mut dlnf_b: Option<HostTensor> = None;
        {
            // Upload the (large) head parameters ONCE per step, not per
            // micro-batch — wte is V×D and dominated head-stage dispatch
            // before this caching (§Perf, EXPERIMENTS.md).
            let (wte_lit, lnf_w_lit, lnf_b_lit) = {
                let guard = self.state.embed.lock().unwrap();
                (guard[0].to_literal()?, guard[2].to_literal()?, guard[3].to_literal()?)
            };
            for j in 0..m {
                let out = self.rt.execute(
                    Stage::HeadLoss,
                    &[
                        &acts[j].to_literal()?,
                        &lnf_w_lit,
                        &lnf_b_lit,
                        &wte_lit,
                        &targets[j].to_literal()?,
                    ],
                )?;
                loss_sum += out[0].to_vec::<f32>()?[0] as f64;
                dxs.push(HostTensor::from_literal(&out[1])?);
                accumulate(&mut dlnf_w, HostTensor::from_literal(&out[2])?);
                accumulate(&mut dlnf_b, HostTensor::from_literal(&out[3])?);
                accumulate(&mut dwte, HostTensor::from_literal(&out[4])?);
            }
        }

        // ---------------- backward + optimizer ----------------------------
        let bwd = schedule.backward_order(nl, m);
        validate_order(&bwd, nl, m, true)
            .with_context(|| format!("schedule '{}' backward order", schedule.name()))?;
        self.core.begin_pass()?;
        // Resident gradient-accumulation buffers. Under the vertical order
        // at most one is live at a time; interleaving orders keep up to one
        // per layer (ZeRO-Infinity's CPU gradient buffers).
        let mut grad_acc: Vec<Option<Vec<HostTensor>>> = Vec::new();
        grad_acc.resize_with(nl, || None);
        let mut remaining: Vec<usize> = vec![m; nl];
        let mut cache = ParamCache::empty();
        for (idx, &(l, j)) in bwd.iter().enumerate() {
            self.ensure_params(&mut cache, l, false)?;
            self.lookahead(&bwd, idx, false);
            let x_ckpt = self.core.io_mut().take_ckpt(&self.ilc, &ckpt_key(l, j))?;
            let (x_lit, dy_lit) = (x_ckpt.to_literal()?, dxs[j].to_literal()?);
            let mut inputs: Vec<&xla::Literal> = vec![&x_lit, &dy_lit];
            inputs.extend(cache.literals.iter());
            let out = self.rt.execute(Stage::LayerBwd, &inputs)?;
            dxs[j] = HostTensor::from_literal(&out[0])?;
            // accumulate parameter gradients in the resident buffer
            match &mut grad_acc[l] {
                None => {
                    grad_acc[l] = Some(
                        out[1..]
                            .iter()
                            .map(HostTensor::from_literal)
                            .collect::<Result<_>>()?,
                    );
                }
                Some(acc) => {
                    for (a, lit) in acc.iter_mut().zip(&out[1..]) {
                        a.add_assign(&HostTensor::from_literal(lit)?);
                    }
                }
            }
            remaining[l] -= 1;
            if remaining[l] == 0 && schedule.eager_optimizer() {
                // fully-accumulated gradients leave "GPU memory" exactly
                // once; the optimizer share overlaps the rest of backward
                let grads = grad_acc[l].take().expect("accumulated gradients");
                self.opt.submit_eager(self.state, Some(self.rt), l, grads, self.step)?;
            }
        }

        // ---------------- embedding backward ------------------------------
        let mut dwpe: Option<HostTensor> = None;
        for j in 0..m {
            let out = self
                .rt
                .execute(Stage::EmbedBwd, &[tokens[j].to_literal()?, dxs[j].to_literal()?])?;
            accumulate(&mut dwte, HostTensor::from_literal(&out[0])?);
            accumulate(&mut dwpe, HostTensor::from_literal(&out[1])?);
        }

        // Deferred optimizer flush (§3.3): all layers only after the full
        // backward pass.
        if !schedule.eager_optimizer() {
            for l in (0..nl).rev() {
                let grads = grad_acc[l].take().expect("accumulated gradients");
                self.opt.submit_eager(self.state, Some(self.rt), l, grads, self.step)?;
            }
        }
        self.opt.submit_embed(
            self.state,
            vec![dwte.unwrap(), dwpe.unwrap(), dlnf_w.unwrap(), dlnf_b.unwrap()],
            self.step,
        )?;
        if schedule.end_of_step_barrier() {
            // the model must be fully updated before the step returns
            for l in 0..nl {
                self.opt.wait_layer(l);
            }
            self.opt.wait_embed();
        }

        // Retire all in-flight lane I/O (normally a no-op: every write was
        // awaited by its take) so the per-step SSD byte deltas are exact and
        // any lane failure surfaces here as an error, not later or as a
        // panic.
        self.core.flush()?;
        let io1 = self.core.stats();

        let grad_norm = self.opt.finish_iter();
        let cache1 = self.state.store.cache_stats().total;
        Ok(StepStats {
            loss: loss_sum / m as f64,
            grad_norm,
            ssd_bytes_read: self.state.store.bytes_read() - read0,
            ssd_bytes_written: self.state.store.bytes_written() - written0,
            param_bytes_loaded: self.core.param_bytes_loaded() - loaded0,
            prefetch_hits: io1.prefetch_hits - io0.prefetch_hits,
            prefetch_misses: io1.prefetch_misses - io0.prefetch_misses,
            io_stall_s: io1.stall_seconds - io0.stall_seconds,
            allreduce_s: 0.0,
            allreduce_bytes: 0,
            allgather_bytes: 0,
            cache_hits: cache1.hits - cache0.hits,
            cache_misses: cache1.misses - cache0.misses,
            cache_evictions: cache1.evictions - cache0.evictions,
        })
    }

    /// One worker's share of a data-parallel step: forward, head-loss, and
    /// backward over the micro-batches in `mbs` (a contiguous slice of the
    /// GLOBAL 0..M index space; `tokens`/`targets` are the full global
    /// arrays), with NO optimizer work. Gradients come back at per-visit
    /// granularity — one entry per `(layer, micro-batch)` backward visit, in
    /// this worker's visit order — so [`super::dist::DataParallelEngine`]
    /// can replay the canonical schedule accumulation order exactly and stay
    /// bit-identical to [`Self::step`] at W = 1. Checkpoint keys carry the
    /// global micro-batch index, so W workers sharing one SSD never collide.
    ///
    /// The visit orders are the schedule's full orders filtered to `mbs`:
    /// restriction preserves legality (validated), and it preserves each
    /// layer's relative visit order, which the reduction depends on.
    pub fn partial_step(
        &mut self,
        schedule: &dyn Schedule,
        tokens: &[TokenTensor],
        targets: &[TokenTensor],
        mbs: std::ops::Range<usize>,
    ) -> Result<super::dist::WorkerPartial> {
        let m = tokens.len();
        assert_eq!(m, targets.len());
        assert!(!mbs.is_empty() && mbs.end <= m, "worker range {mbs:?} outside 0..{m}");
        let nl = self.state.manifest.config.n_layers;
        self.step += 1;
        let loaded0 = self.core.param_bytes_loaded();
        let io0 = self.core.stats();

        // ---------------- forward ----------------
        let embed_lits = {
            let guard = self.state.embed.lock().unwrap();
            (guard[0].to_literal()?, guard[1].to_literal()?)
        };
        let mut acts: Vec<Option<HostTensor>> = (0..m).map(|_| None).collect();
        for j in mbs.clone() {
            let out = self.rt.execute(
                Stage::EmbedFwd,
                &[tokens[j].to_literal()?, embed_lits.0.clone(), embed_lits.1.clone()],
            )?;
            acts[j] = Some(HostTensor::from_literal(&out[0])?);
        }
        drop(embed_lits);

        let fwd: Vec<(usize, usize)> = schedule
            .forward_order(nl, m)
            .into_iter()
            .filter(|&(_, j)| mbs.contains(&j))
            .collect();
        let local: Vec<(usize, usize)> = fwd.iter().map(|&(l, j)| (l, j - mbs.start)).collect();
        validate_order(&local, nl, mbs.len(), false)
            .with_context(|| format!("schedule '{}' restricted forward order", schedule.name()))?;
        self.core.begin_pass()?;
        let mut cache = ParamCache::empty();
        for (idx, &(l, j)) in fwd.iter().enumerate() {
            self.ensure_params(&mut cache, l, true)?;
            self.lookahead(&fwd, idx, true);
            let x_prev = acts[j].as_ref().expect("activation for owned micro-batch");
            self.core
                .io_mut()
                .put_ckpt(&self.ilc, &ckpt_key(l, j), x_prev.clone())
                .with_context(|| format!("ckpt store l{l} mb{j}"))?;
            let x_lit = x_prev.to_literal()?;
            let mut inputs: Vec<&xla::Literal> = vec![&x_lit];
            inputs.extend(cache.literals.iter());
            let out = self.rt.execute(Stage::LayerFwd, &inputs)?;
            acts[j] = Some(HostTensor::from_literal(&out[0])?);
        }

        // ---------------- head: per-micro-batch loss + grads --------------
        let mut losses: Vec<(usize, f64)> = Vec::with_capacity(mbs.len());
        let mut dxs: Vec<Option<HostTensor>> = (0..m).map(|_| None).collect();
        let mut head_grads: Vec<super::dist::GradContrib> = Vec::with_capacity(mbs.len());
        {
            let (wte_lit, lnf_w_lit, lnf_b_lit) = {
                let guard = self.state.embed.lock().unwrap();
                (guard[0].to_literal()?, guard[2].to_literal()?, guard[3].to_literal()?)
            };
            for j in mbs.clone() {
                let out = self.rt.execute(
                    Stage::HeadLoss,
                    &[
                        &acts[j].as_ref().expect("forward output").to_literal()?,
                        &lnf_w_lit,
                        &lnf_b_lit,
                        &wte_lit,
                        &targets[j].to_literal()?,
                    ],
                )?;
                losses.push((j, out[0].to_vec::<f32>()?[0] as f64));
                dxs[j] = Some(HostTensor::from_literal(&out[1])?);
                // [dlnf_w, dlnf_b, dwte] — the head's contribution order
                head_grads.push((
                    j,
                    vec![
                        HostTensor::from_literal(&out[2])?,
                        HostTensor::from_literal(&out[3])?,
                        HostTensor::from_literal(&out[4])?,
                    ],
                ));
            }
        }

        // ---------------- backward (grads retained per visit) -------------
        let bwd: Vec<(usize, usize)> = schedule
            .backward_order(nl, m)
            .into_iter()
            .filter(|&(_, j)| mbs.contains(&j))
            .collect();
        let local: Vec<(usize, usize)> = bwd.iter().map(|&(l, j)| (l, j - mbs.start)).collect();
        validate_order(&local, nl, mbs.len(), true)
            .with_context(|| format!("schedule '{}' restricted backward order", schedule.name()))?;
        self.core.begin_pass()?;
        let mut layer_grads: Vec<Vec<super::dist::GradContrib>> = Vec::new();
        layer_grads.resize_with(nl, Vec::new);
        let mut cache = ParamCache::empty();
        for (idx, &(l, j)) in bwd.iter().enumerate() {
            self.ensure_params(&mut cache, l, false)?;
            self.lookahead(&bwd, idx, false);
            let x_ckpt = self.core.io_mut().take_ckpt(&self.ilc, &ckpt_key(l, j))?;
            let (x_lit, dy_lit) =
                (x_ckpt.to_literal()?, dxs[j].as_ref().expect("head dx").to_literal()?);
            let mut inputs: Vec<&xla::Literal> = vec![&x_lit, &dy_lit];
            inputs.extend(cache.literals.iter());
            let out = self.rt.execute(Stage::LayerBwd, &inputs)?;
            dxs[j] = Some(HostTensor::from_literal(&out[0])?);
            layer_grads[l].push((
                j,
                out[1..].iter().map(HostTensor::from_literal).collect::<Result<_>>()?,
            ));
        }

        // ---------------- embedding backward ------------------------------
        let mut embed_grads: Vec<super::dist::GradContrib> = Vec::with_capacity(mbs.len());
        for j in mbs.clone() {
            let out = self.rt.execute(
                Stage::EmbedBwd,
                &[tokens[j].to_literal()?, dxs[j].as_ref().expect("bwd dx").to_literal()?],
            )?;
            // [dwte, dwpe] — the embedding's contribution order
            embed_grads.push((
                j,
                vec![HostTensor::from_literal(&out[0])?, HostTensor::from_literal(&out[1])?],
            ));
        }

        // retire all lane I/O before the reduce (exact SSD byte accounting,
        // lane failures surface here)
        self.core.flush()?;
        let io1 = self.core.stats();
        Ok(super::dist::WorkerPartial {
            losses,
            layer_grads,
            head_grads,
            embed_grads,
            param_bytes: self.core.param_bytes_loaded() - loaded0,
            prefetch_hits: io1.prefetch_hits - io0.prefetch_hits,
            prefetch_misses: io1.prefetch_misses - io0.prefetch_misses,
            io_stall_s: io1.stall_seconds - io0.stall_seconds,
        })
    }

    /// Retire all in-flight lane I/O without touching optimizer state —
    /// [`super::dist::DataParallelEngine::drain`] flushes every worker's
    /// lanes, then drives the one shared optimizer coordinator itself.
    pub fn flush_io(&mut self) -> Result<()> {
        self.core.flush()
    }

    /// Drain all outstanding optimizer and I/O work (end of training). Safe
    /// under every schedule: delayed dispatch is a no-op at α = 0 and the
    /// waits are no-ops when a barrier already ran.
    pub fn drain(&mut self) -> Result<()> {
        self.core.flush()?;
        self.opt.dispatch_delayed(self.state, Some(self.rt), self.step.max(1))?;
        for l in 0..self.state.manifest.config.n_layers {
            self.opt.wait_layer(l);
        }
        self.opt.wait_embed();
        Ok(())
    }
}
