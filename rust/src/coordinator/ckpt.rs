//! Inter-layer Tensor Coordinator: activation checkpoints (forward) and
//! inter-layer gradients (backward) share one store with CPU-or-SSD
//! placement — the two data types have the same access pattern (§5).
//!
//! The offloaded path goes through the pluggable
//! [`TensorStore`](crate::memory::store::TensorStore), so checkpoints ride
//! whatever backend the run configured (single SSD, striped multi-SSD, or
//! the DRAM-cached tier — optionally under the mixed-precision codec
//! layer, which stores `ilc_*` objects in half precision). `ssd_bytes`
//! reports *encoded* bytes — the traffic that actually crossed the store
//! boundary — so the counter halves under `--precision mixed:*` exactly
//! like the store's own `bytes_read`/`bytes_written`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::memory::store::TensorStore;
use crate::runtime::tensor::HostTensor;

/// Keyed activation/gradient store.
pub struct InterLayerCoordinator {
    cpu: Mutex<HashMap<String, HostTensor>>,
    ssd: Arc<dyn TensorStore>,
    to_ssd: bool,
    /// Stats: bytes moved through each path.
    pub cpu_bytes: std::sync::atomic::AtomicU64,
    pub ssd_bytes: std::sync::atomic::AtomicU64,
}

/// Key for a (layer, micro-batch) checkpoint.
pub fn ckpt_key(layer: usize, mb: usize) -> String {
    format!("ckpt_l{layer}_mb{mb}")
}

impl InterLayerCoordinator {
    pub fn new(ssd: Arc<dyn TensorStore>, to_ssd: bool) -> Self {
        InterLayerCoordinator {
            cpu: Mutex::new(HashMap::new()),
            ssd,
            to_ssd,
            cpu_bytes: Default::default(),
            ssd_bytes: Default::default(),
        }
    }

    /// Store a tensor (consumes it; the GPU-side buffer is released).
    pub fn put(&self, key: &str, t: HostTensor) -> Result<()> {
        use std::sync::atomic::Ordering::Relaxed;
        if self.to_ssd {
            let skey = format!("ilc_{key}");
            self.ssd.put_f32(&skey, &t.data)?;
            // account the bytes as stored (encoded under a mixed-precision
            // policy), not the logical f32 size
            self.ssd_bytes.fetch_add(self.ssd.len_of(&skey).unwrap_or(t.bytes()), Relaxed);
            // shape needed for reconstruction
            self.cpu.lock().unwrap().insert(
                format!("{key}__shape"),
                HostTensor::from_vec(
                    &[t.shape.len()],
                    t.shape.iter().map(|&d| d as f32).collect(),
                )?,
            );
        } else {
            self.cpu_bytes.fetch_add(t.bytes(), Relaxed);
            self.cpu.lock().unwrap().insert(key.to_string(), t);
        }
        Ok(())
    }

    /// Fetch (and remove) a tensor.
    pub fn take(&self, key: &str) -> Result<HostTensor> {
        use std::sync::atomic::Ordering::Relaxed;
        if self.to_ssd {
            let shape_t = self
                .cpu
                .lock()
                .unwrap()
                .remove(&format!("{key}__shape"))
                .ok_or_else(|| anyhow!("no checkpoint '{key}'"))?;
            let shape: Vec<usize> = shape_t.data.iter().map(|&d| d as usize).collect();
            let skey = format!("ilc_{key}");
            let stored = self.ssd.len_of(&skey);
            let mut data = Vec::new();
            self.ssd.get_f32(&skey, &mut data)?;
            self.ssd.delete(&skey);
            let t = HostTensor::from_vec(&shape, data)?;
            self.ssd_bytes.fetch_add(stored.unwrap_or(t.bytes()), Relaxed);
            Ok(t)
        } else {
            self.cpu
                .lock()
                .unwrap()
                .remove(key)
                .ok_or_else(|| anyhow!("no checkpoint '{key}'"))
        }
    }

    /// Non-destructive read (backward recompute needs the checkpoint that
    /// forward stored, and it is consumed exactly once — `take` — but tests
    /// and the horizontal schedule use peeks).
    pub fn peek(&self, key: &str) -> Option<HostTensor> {
        if self.to_ssd {
            let shape: Vec<usize> = self
                .cpu
                .lock()
                .unwrap()
                .get(&format!("{key}__shape"))?
                .data
                .iter()
                .map(|&d| d as usize)
                .collect();
            let mut data = Vec::new();
            self.ssd.get_f32(&format!("ilc_{key}"), &mut data).ok()?;
            HostTensor::from_vec(&shape, data).ok()
        } else {
            self.cpu.lock().unwrap().get(key).cloned()
        }
    }

    pub fn live_count(&self) -> usize {
        let m = self.cpu.lock().unwrap();
        if self.to_ssd {
            m.keys().filter(|k| k.ends_with("__shape")).count()
        } else {
            m.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ssd() -> Arc<dyn TensorStore> {
        Arc::new(
            crate::memory::SsdStorage::create_unthrottled(
                std::env::temp_dir().join(format!("gs_ckpt_test_{}", std::process::id())),
            )
            .unwrap(),
        )
    }

    #[test]
    fn cpu_roundtrip() {
        let c = InterLayerCoordinator::new(ssd(), false);
        let t = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        c.put(&ckpt_key(0, 1), t.clone()).unwrap();
        assert_eq!(c.live_count(), 1);
        let back = c.take(&ckpt_key(0, 1)).unwrap();
        assert_eq!(back, t);
        assert_eq!(c.live_count(), 0);
        assert!(c.take(&ckpt_key(0, 1)).is_err());
    }

    #[test]
    fn ssd_roundtrip_preserves_shape() {
        let c = InterLayerCoordinator::new(ssd(), true);
        let t = HostTensor::from_vec(&[2, 3, 4], (0..24).map(|i| i as f32).collect()).unwrap();
        c.put("k", t.clone()).unwrap();
        let back = c.take("k").unwrap();
        assert_eq!(back, t);
        assert!(c.ssd_bytes.load(std::sync::atomic::Ordering::Relaxed) >= 2 * t.bytes());
    }

    /// Under the mixed codec layer the ILC accounts encoded bytes: a full
    /// put+take round trip of an n-element checkpoint counts 2·2n bytes,
    /// half the f32 path's 2·4n.
    #[test]
    fn ssd_bytes_count_encoded_bytes_under_mixed_precision() {
        use crate::memory::codec::{CodecStore, Precision};
        let inner: Arc<dyn TensorStore> = Arc::new(
            crate::memory::SsdStorage::create_unthrottled(
                std::env::temp_dir().join(format!("gs_ckpt_enc_test_{}", std::process::id())),
            )
            .unwrap(),
        );
        let store: Arc<dyn TensorStore> =
            Arc::new(CodecStore::new(inner, Precision::MixedF16.policy()));
        let c = InterLayerCoordinator::new(store, true);
        let t = HostTensor::from_vec(&[2, 3, 4], (0..24).map(|i| i as f32).collect()).unwrap();
        c.put("k", t.clone()).unwrap();
        let back = c.take("k").unwrap();
        // 0..24 are small integers: exactly representable in f16
        assert_eq!(back, t);
        let counted = c.ssd_bytes.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(counted, t.bytes(), "put+take at 2 B/elem == one f32 pass");
    }

    #[test]
    fn peek_does_not_consume() {
        let c = InterLayerCoordinator::new(ssd(), false);
        let t = HostTensor::zeros(&[4]);
        c.put("k", t.clone()).unwrap();
        assert_eq!(c.peek("k").unwrap(), t);
        assert_eq!(c.peek("k").unwrap(), t);
        c.take("k").unwrap();
        assert!(c.peek("k").is_none());
    }
}
