//! The GreedySnake coordinator layer — the paper's system contribution,
//! running for real over PJRT-executed AOT artifacts.
//!
//! §5 structures the system as three coordinators over a pipelined
//! resource-time space; here they are:
//!
//! * [`ckpt::InterLayerCoordinator`] — activation checkpoints in the forward
//!   pass and inter-layer gradients in the backward pass (same access
//!   pattern, same store);
//! * [`state::ParameterCoordinator`] (embedded in [`state::ModelState`]) —
//!   parameter residency and update ordering: a layer's forward may not
//!   start until its pending (eager and delayed) optimizer updates land;
//! * [`opt::OptimizerStepCoordinator`] — gradient offload, optimizer-state
//!   SSD round trips, the CPU Adam step (Rust fused loop on the overlap
//!   worker, or the AOT Pallas kernel inline), and the delay-α split.
//!
//! Two schedulers drive them: [`vertical::VerticalScheduler`] (GreedySnake)
//! and [`horizontal::HorizontalScheduler`] (the ZeRO-Infinity baseline).
//! Both compute *identical* gradients (property-tested), so Figure 13's
//! loss-equivalence experiment runs on this exact code.

pub mod ckpt;
pub mod horizontal;
pub mod opt;
pub mod state;
pub mod vertical;

pub use ckpt::InterLayerCoordinator;
pub use horizontal::HorizontalScheduler;
pub use opt::OptimizerStepCoordinator;
pub use state::{ModelState, TrainerConfig};
pub use vertical::VerticalScheduler;
