//! The GreedySnake coordinator layer — the paper's system contribution,
//! running for real over PJRT-executed AOT artifacts.
//!
//! §5 structures the system as three coordinators over a pipelined
//! resource-time space; here they are:
//!
//! * [`ckpt::InterLayerCoordinator`] — activation checkpoints in the forward
//!   pass and inter-layer gradients in the backward pass (same access
//!   pattern, same store);
//! * [`state::ParameterCoordinator`] (embedded in [`state::ModelState`]) —
//!   parameter residency and update ordering: a layer's forward may not
//!   start until its pending (eager and delayed) optimizer updates land;
//! * [`opt::OptimizerStepCoordinator`] — gradient offload, optimizer-state
//!   SSD round trips, the CPU Adam step (Rust fused loop on the overlap
//!   worker, or the AOT Pallas kernel inline), and the delay-α split.
//!
//! Since the engine/schedule split, *one* execution engine drives them:
//! [`engine::StepEngine`] owns all stage dispatch, checkpoint put/take,
//! resident gradient accumulation, and optimizer submission, while a
//! pluggable [`schedule::Schedule`] contributes only the traversal order
//! over the (layer × micro-batch) grid plus flush/delay/barrier policy.
//! The phase-generic inner loop — one-layer parameter residency, depth-K
//! lookahead through the pipeline, per-layer byte metering — lives in
//! [`streamer::LayerStreamer`], shared by the training engine and the
//! forward-only multi-tenant serving engine ([`serve::ServeEngine`]:
//! schedule-driven decode passes streaming a shared base image plus
//! per-tenant adapter deltas from the same `TensorStore` tier).
//! Three policies ship today: [`schedule::VerticalSchedule`] (GreedySnake,
//! §3.4), [`schedule::HorizontalSchedule`] (the ZeRO-Infinity baseline,
//! §3.3), and [`schedule::ChunkedVerticalSchedule`] (`chunked:G` — vertical
//! sweeps over chunks of G micro-batches, interpolating between the two).
//! All policies compute *identical* gradients modulo accumulation-order
//! rounding (property-tested), so Figure 13's loss-equivalence experiment
//! runs on this exact code. [`vertical::VerticalScheduler`] and
//! [`horizontal::HorizontalScheduler`] remain as thin named wrappers.
//!
//! The engine's data path is asynchronous: [`io::IoPipeline`] runs
//! schedule-lookahead parameter prefetch and checkpoint write-behind on
//! dedicated `ssd-read` / `ssd-write` / `param-upload` lanes
//! ([`crate::exec::LaneExecutor`]), overlapping SSD traffic with compute the
//! way Figs. 6–8 overlap pipeline rows. The lookahead depth is
//! [`state::TrainerConfig::io_depth`] (`--io-depth` on the CLI); depth 0
//! reproduces the synchronous engine bit-for-bit, and
//! [`engine::StepStats`] reports prefetch hits/misses and the compute
//! thread's I/O stall time so the overlap win is directly measurable.
//!
//! Every coordinator I/O path goes through the pluggable
//! [`crate::memory::store::TensorStore`] tier rather than a concrete SSD
//! type: `--ssds N` stripes objects across N throttled devices
//! ([`crate::memory::StripedStore`]) and `--cpu-cache-mb` puts a bounded
//! DRAM write-back cache in front ([`crate::memory::CachedStore`]). The
//! backends are bit-identical by contract — they move the same bytes to
//! different places — so every equivalence suite in this crate holds
//! across them; [`engine::StepStats`] additionally reports the cache
//! tier's hit/miss/evict counters.
//!
//! The data-parallel dimension lives in [`dist`]: `--workers W` partitions
//! each step's micro-batches across W worker engines (own I/O lanes, one
//! shared throttled SSD) and combines gradients with a deterministic
//! chunked ring all-reduce whose fixed reduction order makes every W
//! bit-identical to W = 1 — see [`dist`]'s module docs for the contract.
//! `--shard-optimizer` turns the rank-0 optimizer into ZeRO-style
//! partitioned states: the ring becomes a reduce-scatter, every rank
//! updates its contiguous 1/W parameter shard through the shared
//! [`opt::OptimizerStepCoordinator`] (α split per shard, per-rank moment
//! SSD objects), and the updated shards all-gather before the next
//! iteration's prefetch — same bit-identity contract.

pub mod ckpt;
pub mod dist;
pub mod engine;
pub mod horizontal;
pub mod io;
pub mod opt;
pub mod schedule;
pub mod serve;
pub mod state;
pub mod streamer;
pub mod vertical;

pub use ckpt::InterLayerCoordinator;
pub use dist::{DataParallelEngine, DistStepStats, RingReduce};
pub use engine::{StepEngine, StepStats};
pub use horizontal::HorizontalScheduler;
pub use io::{IoPipeline, IoStats};
pub use opt::OptimizerStepCoordinator;
pub use schedule::{
    ChunkedVerticalSchedule, HorizontalSchedule, Schedule, VerticalSchedule,
};
pub use serve::{ServeEngine, ServeModel, ServeStats};
pub use state::{ModelState, TrainerConfig};
pub use streamer::{LayerStreamer, ParamCache};
pub use vertical::VerticalScheduler;
