//! Pluggable schedules: pure traversal policies over the (layer ×
//! micro-batch) grid.
//!
//! The paper's core observation (§3) is that horizontal vs vertical
//! traversal of the grid — not any kernel or format trick — is what decides
//! how many times layer parameters cross the SSD/host/GPU boundary. Related
//! systems (ZeRO-Infinity, TeraIO, MLP-Offload's subgroup ordering,
//! SSDTrain's activation ordering) are each "yet another traversal policy".
//! This module makes that explicit: a [`Schedule`] is *data about order*,
//! and all execution machinery lives in [`super::engine::StepEngine`].
//!
//! A policy emits a forward and a backward visit order plus three knobs:
//! whether a layer's optimizer update is flushed eagerly the moment its
//! gradient finishes accumulating, whether the delayed-α optimizer split is
//! supported, and whether the step barriers on all optimizer work before
//! returning. Everything else — stage dispatch, checkpoint put/take,
//! resident gradient accumulation, SSD byte accounting — is
//! schedule-agnostic.
//!
//! Legality: a forward order must visit every grid cell exactly once with
//! each micro-batch's layers ascending (activations flow l → l+1); a
//! backward order is the same with layers descending. The engine validates
//! this every step (O(N·M), negligible next to stage execution), so a buggy
//! third-party policy fails loudly instead of training on stale
//! activations.

use anyhow::{bail, Result};

/// A traversal policy over the (layer × micro-batch) grid.
pub trait Schedule {
    /// Human-readable name, also used by the `--schedule` CLI grammar.
    fn name(&self) -> String;

    /// Forward visit order: every `(layer, micro_batch)` cell exactly once;
    /// per micro-batch, layers strictly ascending.
    fn forward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)>;

    /// Backward visit order: every cell exactly once; per micro-batch,
    /// layers strictly descending.
    fn backward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)>;

    /// Flush a layer's eager optimizer share as soon as its last backward
    /// visit retires (overlapping the optimizer with the rest of the
    /// backward pass, Fig. 7). When `false` the engine submits all layers
    /// after the full backward pass — ZeRO-Infinity's §3.3 behavior.
    fn eager_optimizer(&self) -> bool {
        true
    }

    /// Whether the delayed-α optimizer split (§4.4) may run under this
    /// policy. Requires that the engine waits on a layer's pending updates
    /// before its first forward visit — true for any legal order — but
    /// baseline policies model systems without the feature.
    fn supports_delay(&self) -> bool {
        true
    }

    /// Barrier on all pending optimizer work before the step returns
    /// (no overlap into the next iteration's forward).
    fn end_of_step_barrier(&self) -> bool {
        false
    }
}

/// Micro-batch execution order for a layer under the vertical schedule:
/// consecutive layers alternate direction so the boundary micro-batch's
/// activation stays in GPU memory (§4.2).
pub fn mb_order(layer: usize, m: usize) -> Vec<usize> {
    if layer % 2 == 0 {
        (0..m).collect()
    } else {
        (0..m).rev().collect()
    }
}

/// GreedySnake's vertical schedule (§3.4): every layer visits ALL
/// micro-batches before the next layer, with the §4.2 alternating
/// micro-batch order. Parameters cross the boundary once per pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct VerticalSchedule;

impl Schedule for VerticalSchedule {
    fn name(&self) -> String {
        "vertical".to_string()
    }

    fn forward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_layers * m);
        for l in 0..n_layers {
            for j in mb_order(l, m) {
                order.push((l, j));
            }
        }
        order
    }

    fn backward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_layers * m);
        for l in (0..n_layers).rev() {
            for j in mb_order(l, m) {
                order.push((l, j));
            }
        }
        order
    }
}

/// The horizontal baseline (ZeRO-Infinity, §3.3): each micro-batch runs
/// through ALL layers before the next, parameters reload for every
/// micro-batch, and the optimizer runs only after the last backward.
#[derive(Clone, Copy, Debug, Default)]
pub struct HorizontalSchedule;

impl Schedule for HorizontalSchedule {
    fn name(&self) -> String {
        "horizontal".to_string()
    }

    fn forward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_layers * m);
        for j in 0..m {
            for l in 0..n_layers {
                order.push((l, j));
            }
        }
        order
    }

    fn backward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_layers * m);
        for j in 0..m {
            for l in (0..n_layers).rev() {
                order.push((l, j));
            }
        }
        order
    }

    fn eager_optimizer(&self) -> bool {
        false
    }

    fn supports_delay(&self) -> bool {
        false
    }

    fn end_of_step_barrier(&self) -> bool {
        true
    }
}

/// Chunked-vertical: micro-batches are processed in contiguous chunks of
/// `group`, and each chunk is swept vertically through the whole layer
/// stack. This is the vertical schedule's graceful degradation when all M
/// activation fronts don't fit in GPU memory: only `group` of them are
/// resident at a time, at the cost of reloading parameters once per chunk.
///
/// * `group >= m`  ⇒ one chunk ⇒ identical traffic to [`VerticalSchedule`]
///   (parameters cross the boundary once per pass);
/// * `group == 1`  ⇒ M chunks ⇒ the horizontal per-micro-batch parameter
///   reload behavior at every chunk boundary;
/// * in between, parameter traffic scales with ⌈M/group⌉, strictly between
///   the two extremes (the `vertical ≤ chunked ≤ horizontal` SSD-read
///   ordering is property-tested in `traffic` and `tests/integration.rs`).
#[derive(Clone, Copy, Debug)]
pub struct ChunkedVerticalSchedule {
    /// Micro-batches per vertical chunk (≥ 1).
    pub group: usize,
}

impl ChunkedVerticalSchedule {
    pub fn new(group: usize) -> Self {
        ChunkedVerticalSchedule { group: group.max(1) }
    }

    fn chunks(&self, m: usize) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        let g = self.group.max(1);
        (0..m.div_ceil(g)).map(move |c| (c * g)..((c + 1) * g).min(m))
    }
}

impl Schedule for ChunkedVerticalSchedule {
    fn name(&self) -> String {
        format!("chunked:{}", self.group)
    }

    fn forward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_layers * m);
        for chunk in self.chunks(m) {
            for l in 0..n_layers {
                for j in chunk.clone() {
                    order.push((l, j));
                }
            }
        }
        order
    }

    fn backward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_layers * m);
        for chunk in self.chunks(m) {
            for l in (0..n_layers).rev() {
                for j in chunk.clone() {
                    order.push((l, j));
                }
            }
        }
        order
    }
}

/// MLP-Offload's cache-friendly subgroup ordering (`cachesweep:G`): the
/// forward pass sweeps micro-batch chunks exactly like
/// [`ChunkedVerticalSchedule`], but the backward pass visits the chunks in
/// REVERSE order — and the micro-batches within each chunk last-in
/// first-out — so the chunk whose checkpoints were written most recently,
/// the one still resident in the DRAM tier ([`CachedStore`] LRU /
/// [`PlannedStore`] DRAM path), is consumed before anything evicts it.
/// Parameter traffic is identical to `chunked:G` (the `traffic` closed
/// forms are shared); only the visit order — and therefore the DRAM hit
/// rate — differs.
///
/// [`CachedStore`]: crate::memory::store::CachedStore
/// [`PlannedStore`]: crate::memory::store::PlannedStore
#[derive(Clone, Copy, Debug)]
pub struct CacheSweepSchedule {
    /// Micro-batches per vertical chunk (≥ 1).
    pub group: usize,
}

impl CacheSweepSchedule {
    pub fn new(group: usize) -> Self {
        CacheSweepSchedule { group: group.max(1) }
    }

    fn chunks(&self, m: usize) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        let g = self.group.max(1);
        (0..m.div_ceil(g)).map(move |c| (c * g)..((c + 1) * g).min(m))
    }
}

impl Schedule for CacheSweepSchedule {
    fn name(&self) -> String {
        format!("cachesweep:{}", self.group)
    }

    fn forward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        ChunkedVerticalSchedule::new(self.group).forward_order(n_layers, m)
    }

    fn backward_order(&self, n_layers: usize, m: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_layers * m);
        let chunks: Vec<_> = self.chunks(m).collect();
        for chunk in chunks.into_iter().rev() {
            for l in (0..n_layers).rev() {
                for j in chunk.clone().rev() {
                    order.push((l, j));
                }
            }
        }
        order
    }
}

/// Validate a visit order: a permutation of the grid whose per-micro-batch
/// layer sequence is strictly ascending (forward) or descending (backward).
pub fn validate_order(
    order: &[(usize, usize)],
    n_layers: usize,
    m: usize,
    backward: bool,
) -> Result<()> {
    if order.len() != n_layers * m {
        bail!("order has {} visits, grid has {}", order.len(), n_layers * m);
    }
    if n_layers == 0 || m == 0 {
        return Ok(()); // empty grid, empty order
    }
    // last layer seen per micro-batch; None = not visited yet
    let mut last: Vec<Option<usize>> = vec![None; m];
    for &(l, j) in order {
        if l >= n_layers || j >= m {
            bail!("visit ({l}, {j}) outside the {n_layers}x{m} grid");
        }
        let expected = match (last[j], backward) {
            (None, false) => Some(0),
            (None, true) => Some(n_layers - 1),
            (Some(prev), false) => Some(prev + 1),
            (Some(0), true) => None, // micro-batch already finished
            (Some(prev), true) => Some(prev - 1),
        };
        if expected != Some(l) {
            bail!(
                "micro-batch {j} visits layer {l} after {:?} ({} order must be contiguous and {})",
                last[j],
                if backward { "backward" } else { "forward" },
                if backward { "descending" } else { "ascending" },
            );
        }
        last[j] = Some(l);
    }
    for (j, l) in last.iter().enumerate() {
        let want = if backward { Some(0) } else { Some(n_layers - 1) };
        if *l != want {
            bail!("micro-batch {j} stopped at layer {l:?}, expected {want:?}");
        }
    }
    Ok(())
}

/// Number of parameter (re)loads a single-layer parameter cache performs
/// over `order` — the schedule-dependent share of SSD/host parameter
/// traffic, in units of one layer's parameter bytes.
pub fn param_loads(order: &[(usize, usize)]) -> usize {
    let mut loads = 0;
    let mut cached: Option<usize> = None;
    for &(l, _) in order {
        if cached != Some(l) {
            loads += 1;
            cached = Some(l);
        }
    }
    loads
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_valid(s: &dyn Schedule, nl: usize, m: usize) {
        validate_order(&s.forward_order(nl, m), nl, m, false)
            .unwrap_or_else(|e| panic!("{} forward {nl}x{m}: {e}", s.name()));
        validate_order(&s.backward_order(nl, m), nl, m, true)
            .unwrap_or_else(|e| panic!("{} backward {nl}x{m}: {e}", s.name()));
    }

    #[test]
    fn all_policies_emit_legal_orders() {
        for nl in [1, 2, 3, 8] {
            for m in [1, 2, 3, 4, 7] {
                all_valid(&VerticalSchedule, nl, m);
                all_valid(&HorizontalSchedule, nl, m);
                for g in [1, 2, 3, 64] {
                    all_valid(&ChunkedVerticalSchedule::new(g), nl, m);
                    all_valid(&CacheSweepSchedule::new(g), nl, m);
                }
            }
        }
    }

    #[test]
    fn validator_rejects_bad_orders() {
        // duplicate visit
        assert!(validate_order(&[(0, 0), (0, 0)], 1, 2, false).is_err());
        // skips a layer
        assert!(validate_order(&[(0, 0), (2, 0), (1, 0)], 3, 1, false).is_err());
        // ascending order handed to the backward validator
        assert!(validate_order(&[(0, 0), (1, 0)], 2, 1, true).is_err());
        // out of grid
        assert!(validate_order(&[(0, 5)], 1, 1, false).is_err());
    }

    #[test]
    fn chunked_limits_degenerate_to_vertical_and_horizontal() {
        let (nl, m) = (4, 6);
        // group >= m: one chunk, layer-major — vertical order modulo the
        // §4.2 alternating micro-batch direction (same param-load count).
        let big = ChunkedVerticalSchedule::new(m).forward_order(nl, m);
        assert_eq!(param_loads(&big), param_loads(&VerticalSchedule.forward_order(nl, m)));
        // group == 1: micro-batch-major — exactly the horizontal order.
        let one = ChunkedVerticalSchedule::new(1).forward_order(nl, m);
        assert_eq!(one, HorizontalSchedule.forward_order(nl, m));
    }

    #[test]
    fn param_loads_interpolate_monotonically() {
        let (nl, m) = (6, 8);
        let v = param_loads(&VerticalSchedule.forward_order(nl, m));
        let c4 = param_loads(&ChunkedVerticalSchedule::new(4).forward_order(nl, m));
        let c2 = param_loads(&ChunkedVerticalSchedule::new(2).forward_order(nl, m));
        let h = param_loads(&HorizontalSchedule.forward_order(nl, m));
        assert_eq!(v, nl);
        assert_eq!(h, nl * m);
        assert_eq!(c4, nl * 2);
        assert_eq!(c2, nl * 4);
        assert!(v < c4 && c4 < c2 && c2 < h);
    }

    /// Replay forward checkpoint writes + backward reads through a tiny
    /// LRU: cachesweep's reversed backward chunk order re-reads the
    /// freshest chunk straight out of the cache and must strictly beat
    /// chunked's ascending revisit on misses.
    #[test]
    fn cachesweep_backward_maximizes_dram_reuse() {
        fn lru_misses(fwd: &[(usize, usize)], bwd: &[(usize, usize)], cap: usize) -> usize {
            // Vec as LRU: back = most recently used
            fn touch(cache: &mut Vec<(usize, usize)>, cell: (usize, usize), cap: usize) -> bool {
                if let Some(pos) = cache.iter().position(|&c| c == cell) {
                    cache.remove(pos);
                    cache.push(cell);
                    true
                } else {
                    if cache.len() == cap {
                        cache.remove(0);
                    }
                    cache.push(cell);
                    false
                }
            }
            let mut cache = Vec::new();
            for &cell in fwd {
                touch(&mut cache, cell, cap);
            }
            bwd.iter().filter(|&&cell| !touch(&mut cache, cell, cap)).count()
        }
        let (nl, m, g, cap) = (4, 8, 2, 8);
        let sweep = CacheSweepSchedule::new(g);
        let chunk = ChunkedVerticalSchedule::new(g);
        let sweep_misses =
            lru_misses(&sweep.forward_order(nl, m), &sweep.backward_order(nl, m), cap);
        let chunk_misses =
            lru_misses(&chunk.forward_order(nl, m), &chunk.backward_order(nl, m), cap);
        assert_eq!(sweep_misses, 24, "the freshest chunk is served from DRAM");
        assert_eq!(chunk_misses, 32, "the ascending revisit misses every cell");
        // identical parameter traffic — only the visit order differs
        assert_eq!(
            param_loads(&sweep.forward_order(nl, m)),
            param_loads(&chunk.forward_order(nl, m))
        );
        assert_eq!(
            param_loads(&sweep.backward_order(nl, m)),
            param_loads(&chunk.backward_order(nl, m))
        );
    }

    #[test]
    fn vertical_keeps_boundary_micro_batch_resident() {
        for m in [1, 2, 5] {
            for l in 0..6 {
                let cur = mb_order(l, m);
                let next = mb_order(l + 1, m);
                assert_eq!(cur.last(), next.first(), "l={l} m={m}");
            }
        }
    }
}
