//! Forward-only multi-tenant serving on the phase-generic streaming core.
//!
//! The serving engine is the second phase built on
//! [`LayerStreamer`](super::streamer::LayerStreamer): token generation
//! streams layer weights from the [`TensorStore`] under a decode access
//! pattern — every token step is one forward sweep of the layer stack over
//! the batch's concurrent lanes, scheduled by the *same*
//! [`Schedule`](super::schedule::Schedule) policies as training (a decode
//! batch of B sequences is a (layer × B) grid exactly like a training step's
//! (layer × micro-batch) grid), prefetched by the same `--io-depth K`
//! [`IoPipeline`](super::io::IoPipeline) lanes.
//!
//! # Multi-tenancy: one base image, per-tenant deltas
//!
//! T fine-tuned model variants share ONE base parameter image on the SSD
//! (`base_l{l}_t{t}` / `base_emb_{i}` keys). Each tenant owns only small
//! per-layer delta objects (`adapter_{tenant}_l{l}_t{t}`, sized
//! [`adapter_len`] = numel/64 elements), applied at the typed f32 boundary
//! when a layer is streamed in: `w[i] += delta[i]` over the delta's prefix.
//! Per-tenant SSD footprint is therefore ≈ adapter bytes only — the sharing
//! law [`crate::traffic::Workload::serve_working_set_bytes`] mirrors in
//! closed form and `benches/fig18_serve.rs` asserts from store counters.
//! [`crate::memory::CacheAdmission::PerTenant`] bounds each tenant's DRAM
//! cache share so one hot tenant cannot evict the shared base image.
//!
//! # Determinism contract
//!
//! Serving is deterministic end to end:
//!
//! * **Batching** — [`form_batches`] is invariant to request *arrival
//!   order*: batches are formed from the sorted (tenant, request-id) view,
//!   so any permutation of the same request set yields byte-identical
//!   batches (property-pinned in `tests/proptests.rs`). Batches are
//!   single-tenant by construction — one adapter set per decode pass.
//! * **Tokens** — without AOT artifacts the engine emits
//!   [`det_token`]-hashed tokens (pure function of seed, tenant, request,
//!   step); with a [`Runtime`] the token is a digest of the real forward
//!   hidden state. Either way, equal inputs give equal outputs.
//! * **Bytes** — each token step loads parameters with a FRESH one-layer
//!   residency ([`ParamCache`](super::streamer::ParamCache)), so the
//!   per-pass load count equals
//!   [`param_loads`](super::schedule::param_loads) of the forward order
//!   *exactly*, for every schedule and every io-depth: per-pass base bytes
//!   = loads × layer bytes, matching the
//!   [`crate::traffic::Workload::serve_param_read_bytes`] closed form.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::memory::store::TensorStore;
use crate::memory::CacheStats;
use crate::runtime::tensor::{HostTensor, TokenTensor};
use crate::runtime::{Manifest, Runtime, Stage};
use crate::util::prng::Prng;

use super::schedule::{validate_order, Schedule};
use super::streamer::{LayerStreamer, ParamCache};

/// Store key of base-image tensor `t` of layer `l` (shared by all tenants).
pub fn base_key(l: usize, t: usize) -> String {
    format!("base_l{l}_t{t}")
}

/// Store key of shared embedding tensor `i`.
pub fn embed_key(i: usize) -> String {
    format!("base_emb_{i}")
}

/// Store key of `tenant`'s delta over tensor `t` of layer `l`.
pub fn adapter_key(tenant: u64, l: usize, t: usize) -> String {
    format!("adapter_{tenant}_l{l}_t{t}")
}

/// Elements in a tenant delta over a `numel`-element base tensor: the
/// low-rank-adapter stand-in is a 1/64 dense prefix delta (≥ 1 element so
/// every tensor is tenant-adjustable).
pub fn adapter_len(numel: usize) -> usize {
    (numel / 64).max(1)
}

/// The serve-side model shape: tensor shapes only — weights live in the
/// [`TensorStore`], streamed per layer visit.
#[derive(Clone, Debug)]
pub struct ServeModel {
    pub n_layers: usize,
    /// Per-layer parameter tensor shapes (identical across layers).
    pub layer_shapes: Vec<Vec<usize>>,
    /// Embedding tensor shapes (`base_emb_{i}` objects).
    pub embed_shapes: Vec<Vec<usize>>,
    pub vocab: usize,
    /// Stage grid of the AOT artifacts (real-compute decode only).
    pub micro_batch: usize,
    pub seq_len: usize,
}

impl ServeModel {
    /// Manifest-free model for stores/tests/CI: one tensor per layer, one
    /// embedding tensor.
    pub fn synthetic(n_layers: usize, layer_numel: usize, embed_numel: usize, vocab: usize) -> Self {
        ServeModel {
            n_layers,
            layer_shapes: vec![vec![layer_numel]],
            embed_shapes: vec![vec![embed_numel]],
            vocab,
            micro_batch: 1,
            seq_len: 1,
        }
    }

    /// Mirror a training manifest (the fig18 runtime leg: serve the model
    /// the AOT artifacts were compiled for).
    pub fn from_manifest(m: &Manifest) -> Self {
        ServeModel {
            n_layers: m.config.n_layers,
            layer_shapes: m.layer_params.iter().map(|p| p.shape.clone()).collect(),
            embed_shapes: m.embed_params.iter().map(|p| p.shape.clone()).collect(),
            vocab: m.config.vocab,
            micro_batch: m.config.micro_batch,
            seq_len: m.config.seq_len,
        }
    }

    /// Elements in one layer's base tensors.
    pub fn layer_numel(&self) -> usize {
        self.layer_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }

    /// f32 bytes one layer's BASE stream moves per load.
    pub fn base_layer_bytes(&self) -> u64 {
        self.layer_numel() as u64 * 4
    }

    /// f32 bytes one layer's tenant-delta stream moves per load.
    pub fn adapter_layer_bytes(&self) -> u64 {
        self.layer_shapes
            .iter()
            .map(|s| adapter_len(s.iter().product::<usize>()) as u64 * 4)
            .sum()
    }

    /// Elements across the embedding tensors.
    pub fn embed_numel(&self) -> usize {
        self.embed_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// Byte footprint written by [`provision`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ProvisionReport {
    /// Shared base image (layers + embeddings) — written ONCE, not per
    /// tenant.
    pub base_bytes: u64,
    /// One tenant's adapter set.
    pub adapter_bytes_per_tenant: u64,
}

/// Write a deterministic synthetic base image plus `tenants` adapter sets
/// into `store`. The base is shared: total footprint is
/// `base_bytes + tenants × adapter_bytes_per_tenant`.
pub fn provision(
    store: &dyn TensorStore,
    model: &ServeModel,
    tenants: u64,
    seed: u64,
) -> Result<ProvisionReport> {
    let mut rng = Prng::new(seed);
    let mut rep = ProvisionReport::default();
    for (i, shape) in model.embed_shapes.iter().enumerate() {
        let n: usize = shape.iter().product();
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v, 0.02);
        store.put_f32(&embed_key(i), &v)?;
        rep.base_bytes += n as u64 * 4;
    }
    for l in 0..model.n_layers {
        for (t, shape) in model.layer_shapes.iter().enumerate() {
            let n: usize = shape.iter().product();
            let mut v = vec![0f32; n];
            rng.fill_normal(&mut v, 0.02);
            store.put_f32(&base_key(l, t), &v)?;
            rep.base_bytes += n as u64 * 4;
        }
    }
    for tenant in 0..tenants {
        rep.adapter_bytes_per_tenant = 0;
        for l in 0..model.n_layers {
            for (t, shape) in model.layer_shapes.iter().enumerate() {
                let alen = adapter_len(shape.iter().product());
                let mut v = vec![0f32; alen];
                rng.fill_normal(&mut v, 0.001);
                store.put_f32(&adapter_key(tenant, l, t), &v)?;
                rep.adapter_bytes_per_tenant += alen as u64 * 4;
            }
        }
    }
    Ok(rep)
}

/// One generation request (tenant selects the adapter set).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Request {
    pub tenant: u64,
    pub id: u64,
}

/// A formed decode batch: single-tenant, ≤ `max_batch` lanes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Batch {
    pub tenant: u64,
    /// Request ids, ascending (the batch's decode lanes).
    pub requests: Vec<u64>,
}

/// Deterministic batch formation: sort requests by (tenant, id), then chunk
/// each tenant's run into batches of ≤ `max_batch` lanes. The output is a
/// pure function of the request SET — any arrival permutation of the same
/// requests forms identical batches (proptest-pinned), and every batch is
/// single-tenant so one adapter set serves the whole pass.
pub fn form_batches(requests: &[Request], max_batch: usize) -> Vec<Batch> {
    let max_batch = max_batch.max(1);
    let mut sorted: Vec<Request> = requests.to_vec();
    sorted.sort();
    let mut out: Vec<Batch> = Vec::new();
    for r in sorted {
        match out.last_mut() {
            Some(b) if b.tenant == r.tenant && b.requests.len() < max_batch => {
                b.requests.push(r.id)
            }
            _ => out.push(Batch { tenant: r.tenant, requests: vec![r.id] }),
        }
    }
    out
}

/// Deterministic synthetic request traffic (the CLI / fig18 heavy
/// concurrent-load generator): `n` requests spread over `tenants` tenants
/// in a hash-scrambled arrival order.
pub fn synthetic_requests(tenants: u64, n: usize, seed: u64) -> Vec<Request> {
    let tenants = tenants.max(1);
    let mut reqs: Vec<Request> = (0..n as u64)
        .map(|id| Request { tenant: mix(seed ^ mix(id)) % tenants, id })
        .collect();
    // scramble arrival order deterministically; form_batches must not care
    reqs.sort_by_key(|r| mix(seed.wrapping_add(1) ^ mix(r.id)));
    reqs
}

/// splitmix64 finalizer — the stream-only token hash.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58476d1ce4e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Stream-only decode token: a pure deterministic function of (seed,
/// tenant, request, step) — the artifact-free stand-in for real sampling.
pub fn det_token(seed: u64, tenant: u64, request: u64, step: u64, vocab: usize) -> u32 {
    (mix(seed ^ mix(tenant.wrapping_add(0x9e3779b97f4a7c15) ^ mix(request ^ mix(step))))
        % vocab.max(1) as u64) as u32
}

/// Cumulative serve counters (see the module docs' byte laws).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub tokens: u64,
    /// Layer-parameter loads (each = one base + one adapter stream).
    pub param_loads: u64,
    pub base_bytes_loaded: u64,
    pub adapter_bytes_loaded: u64,
    pub embed_bytes_loaded: u64,
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
    pub stall_seconds: f64,
    pub store_bytes_read: u64,
    pub store_bytes_written: u64,
    pub cache: CacheStats,
}

/// The forward-only token-generation engine: schedule-driven decode passes
/// over the streaming core, one tenant's adapter set per batch.
pub struct ServeEngine {
    model: ServeModel,
    store: Arc<dyn TensorStore>,
    core: LayerStreamer,
    seed: u64,
    tokens: u64,
    param_loads: u64,
    adapter_bytes_loaded: u64,
    embed_bytes_loaded: u64,
}

impl ServeEngine {
    pub fn new(model: ServeModel, store: Arc<dyn TensorStore>, io_depth: usize, seed: u64) -> Self {
        let layer_bytes = model.base_layer_bytes();
        ServeEngine {
            model,
            store,
            core: LayerStreamer::new(io_depth, layer_bytes),
            seed,
            tokens: 0,
            param_loads: 0,
            adapter_bytes_loaded: 0,
            embed_bytes_loaded: 0,
        }
    }

    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    /// Generate `new_tokens` tokens for every lane of `batch`. Each token
    /// step is one schedule-ordered forward sweep with a FRESH one-layer
    /// residency, so per-step loads equal `param_loads(forward_order)`
    /// exactly. With `rt`, lanes run the real EmbedFwd/LayerFwd artifacts
    /// and the token digests the final hidden state; without, tokens are
    /// [`det_token`] hashes — byte traffic is identical either way.
    pub fn decode(
        &mut self,
        schedule: &dyn Schedule,
        batch: &Batch,
        new_tokens: usize,
        rt: Option<&Runtime>,
    ) -> Result<Vec<Vec<u32>>> {
        let lanes = batch.requests.len();
        ensure!(lanes > 0, "empty decode batch");
        let nl = self.model.n_layers;
        let order = schedule.forward_order(nl, lanes);
        validate_order(&order, nl, lanes, false)
            .with_context(|| format!("serve forward order ({})", schedule.name()))?;
        let mut out: Vec<Vec<u32>> = vec![Vec::with_capacity(new_tokens); lanes];
        for step in 0..new_tokens as u64 {
            self.core.begin_pass()?;
            let mut cache = ParamCache::empty();
            // shared embedding: streamed once per token step
            let embed_hosts = {
                let t0 = Instant::now();
                let hosts = self.load_embed();
                self.core.io_mut().note_sync_stall(t0.elapsed());
                hosts?
            };
            self.embed_bytes_loaded += self.model.embed_numel() as u64 * 4;
            let mut acts: Vec<Option<xla::Literal>> = (0..lanes).map(|_| None).collect();
            if let Some(rt) = rt {
                ensure!(embed_hosts.len() >= 2, "real-compute decode needs wte+wpe");
                let wte = embed_hosts[0].to_literal()?;
                let wpe = embed_hosts[1].to_literal()?;
                for (lane, &req) in batch.requests.iter().enumerate() {
                    let tok = self.prompt_tokens(batch.tenant, req, step)?;
                    let o = rt.execute(
                        Stage::EmbedFwd,
                        &[tok.to_literal()?, wte.clone(), wpe.clone()],
                    )?;
                    acts[lane] = Some(o.into_iter().next().expect("embed_fwd output"));
                }
            }
            for (idx, &(l, j)) in order.iter().enumerate() {
                if cache.layer != Some(l) {
                    // meter at miss detection (the adapter rides every load)
                    self.param_loads += 1;
                    self.adapter_bytes_loaded += self.model.adapter_layer_bytes();
                }
                {
                    let model = &self.model;
                    let store = &self.store;
                    let tenant = batch.tenant;
                    self.core.ensure_params(&mut cache, l, || {
                        let hosts = load_layer_hosts(store.as_ref(), model, tenant, l)?;
                        hosts.iter().map(HostTensor::to_literal).collect()
                    })?;
                    self.core.lookahead(
                        &order,
                        idx,
                        |io, l2| {
                            let st = Arc::clone(store);
                            let m2 = model.clone();
                            io.prefetch_with(l2, move || {
                                load_layer_hosts(st.as_ref(), &m2, tenant, l2)
                                    .map_err(|e| e.to_string())
                            });
                        },
                        |_io, _l, _j| {},
                    );
                }
                if let Some(rt) = rt {
                    let x_lit = acts[j].take().expect("lane activation");
                    let mut inputs: Vec<&xla::Literal> = vec![&x_lit];
                    inputs.extend(cache.literals.iter());
                    let o = rt.execute(Stage::LayerFwd, &inputs)?;
                    acts[j] = Some(o.into_iter().next().expect("layer_fwd output"));
                }
            }
            for (lane, &req) in batch.requests.iter().enumerate() {
                let tok = match &acts[lane] {
                    Some(lit) => {
                        // digest the real hidden state into a token id
                        let h = HostTensor::from_literal(lit)?;
                        (h.sq_sum().to_bits() % self.model.vocab.max(1) as u64) as u32
                    }
                    None => det_token(self.seed, batch.tenant, req, step, self.model.vocab),
                };
                out[lane].push(tok);
            }
            self.tokens += lanes as u64;
            self.core.flush()?;
        }
        Ok(out)
    }

    /// Drive a whole request set: form deterministic batches, decode each.
    /// Returns `(request id, tokens)` pairs in batch order.
    pub fn serve(
        &mut self,
        schedule: &dyn Schedule,
        requests: &[Request],
        max_batch: usize,
        new_tokens: usize,
        rt: Option<&Runtime>,
    ) -> Result<Vec<(u64, Vec<u32>)>> {
        let mut out = Vec::with_capacity(requests.len());
        for batch in form_batches(requests, max_batch) {
            let toks = self.decode(schedule, &batch, new_tokens, rt)?;
            for (req, t) in batch.requests.iter().zip(toks) {
                out.push((*req, t));
            }
        }
        Ok(out)
    }

    pub fn stats(&self) -> ServeStats {
        let io = self.core.stats();
        ServeStats {
            tokens: self.tokens,
            param_loads: self.param_loads,
            base_bytes_loaded: self.core.param_bytes_loaded(),
            adapter_bytes_loaded: self.adapter_bytes_loaded,
            embed_bytes_loaded: self.embed_bytes_loaded,
            prefetch_hits: io.prefetch_hits,
            prefetch_misses: io.prefetch_misses,
            stall_seconds: io.stall_seconds,
            store_bytes_read: self.store.bytes_read(),
            store_bytes_written: self.store.bytes_written(),
            cache: self.store.cache_stats(),
        }
    }

    fn load_embed(&self) -> Result<Vec<HostTensor>> {
        let mut out = Vec::with_capacity(self.model.embed_shapes.len());
        let mut buf = Vec::new();
        for (i, shape) in self.model.embed_shapes.iter().enumerate() {
            self.store.get_f32(&embed_key(i), &mut buf)?;
            out.push(HostTensor::from_vec(shape, buf.clone())?);
        }
        Ok(out)
    }

    /// Deterministic prompt tokens for the real-compute leg, shaped to the
    /// AOT stage grid.
    fn prompt_tokens(&self, tenant: u64, req: u64, step: u64) -> Result<TokenTensor> {
        let n = self.model.micro_batch * self.model.seq_len;
        let data: Vec<i32> = (0..n as u64)
            .map(|i| {
                (mix(self.seed ^ mix(tenant) ^ mix(req) ^ mix(step ^ mix(i)))
                    % self.model.vocab.max(1) as u64) as i32
            })
            .collect();
        TokenTensor::new(&[self.model.micro_batch, self.model.seq_len], data)
    }
}

/// Stream one layer for one tenant: base tensors plus the tenant's delta,
/// applied at the typed f32 boundary (`w[i] += delta[i]` over the delta
/// prefix). This closure body runs synchronously on the compute thread at
/// depth 0 and on the `param-upload` lane under lookahead — identical reads
/// either way, so the byte laws hold at every io-depth.
fn load_layer_hosts(
    store: &dyn TensorStore,
    model: &ServeModel,
    tenant: u64,
    l: usize,
) -> Result<Vec<HostTensor>> {
    let mut out = Vec::with_capacity(model.layer_shapes.len());
    let mut base = Vec::new();
    let mut delta = Vec::new();
    for (t, shape) in model.layer_shapes.iter().enumerate() {
        store
            .get_f32(&base_key(l, t), &mut base)
            .with_context(|| format!("base image l{l} t{t}"))?;
        store
            .get_f32(&adapter_key(tenant, l, t), &mut delta)
            .with_context(|| format!("adapter tenant {tenant} l{l} t{t}"))?;
        ensure!(
            delta.len() <= base.len(),
            "adapter longer than base ({} > {})",
            delta.len(),
            base.len()
        );
        for (b, d) in base.iter_mut().zip(delta.iter()) {
            *b += *d;
        }
        out.push(HostTensor::from_vec(shape, base.clone())?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::{
        param_loads, ChunkedVerticalSchedule, HorizontalSchedule, VerticalSchedule,
    };
    use crate::memory::{CacheAdmission, CachedStore, SsdStorage};

    fn tmp(tag: &str) -> std::path::PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static UNIQ: AtomicU64 = AtomicU64::new(0);
        let u = UNIQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("gs_serve_{tag}_{}_{u}", std::process::id()))
    }

    fn raw_store(tag: &str) -> Arc<dyn TensorStore> {
        Arc::new(SsdStorage::create_unthrottled(tmp(tag)).unwrap())
    }

    #[test]
    fn batcher_is_arrival_order_invariant_and_single_tenant() {
        let reqs: Vec<Request> = [(1, 3), (0, 1), (1, 0), (0, 7), (2, 2), (0, 4), (1, 9)]
            .iter()
            .map(|&(tenant, id)| Request { tenant, id })
            .collect();
        let baseline = form_batches(&reqs, 2);
        // any permutation forms identical batches
        let mut rev = reqs.clone();
        rev.reverse();
        assert_eq!(form_batches(&rev, 2), baseline);
        let mut rot = reqs.clone();
        rot.rotate_left(3);
        assert_eq!(form_batches(&rot, 2), baseline);
        // single-tenant, ≤ max_batch, ids ascending, nothing dropped
        let mut seen = 0;
        for b in &baseline {
            assert!(b.requests.len() <= 2);
            assert!(b.requests.windows(2).all(|w| w[0] < w[1]));
            seen += b.requests.len();
        }
        assert_eq!(seen, reqs.len());
        assert_eq!(
            baseline.iter().map(|b| (b.tenant, b.requests.len())).collect::<Vec<_>>(),
            vec![(0, 2), (0, 1), (1, 2), (1, 1), (2, 1)]
        );
    }

    #[test]
    fn decode_bytes_match_schedule_closed_form_across_depths() {
        let model = ServeModel::synthetic(3, 64, 32, 997);
        let schedules: Vec<Box<dyn Schedule>> = vec![
            Box::new(VerticalSchedule),
            Box::new(HorizontalSchedule),
            Box::new(ChunkedVerticalSchedule::new(2)),
        ];
        for sched in &schedules {
            for depth in [0usize, 2] {
                let store = raw_store("bytes");
                provision(store.as_ref(), &model, 2, 7).unwrap();
                let w0 = store.bytes_written();
                let mut eng = ServeEngine::new(model.clone(), Arc::clone(&store), depth, 11);
                let batch = Batch { tenant: 1, requests: vec![0, 1, 2, 3] };
                let tokens = 2usize;
                eng.decode(sched.as_ref(), &batch, tokens, None).unwrap();
                let s = eng.stats();
                let order = sched.forward_order(model.n_layers, batch.requests.len());
                let loads = param_loads(&order) as u64 * tokens as u64;
                let tag = format!("{} depth={depth}", sched.name());
                assert_eq!(s.param_loads, loads, "{tag}");
                assert_eq!(s.base_bytes_loaded, loads * model.base_layer_bytes(), "{tag}");
                assert_eq!(s.adapter_bytes_loaded, loads * model.adapter_layer_bytes(), "{tag}");
                assert_eq!(s.embed_bytes_loaded, tokens as u64 * 32 * 4, "{tag}");
                // the uncached store moved exactly the metered bytes
                assert_eq!(
                    s.store_bytes_read,
                    s.base_bytes_loaded + s.adapter_bytes_loaded + s.embed_bytes_loaded,
                    "{tag}"
                );
                assert_eq!(s.store_bytes_written, w0, "{tag}: decode must not write");
                assert_eq!(s.tokens, (tokens * batch.requests.len()) as u64, "{tag}");
            }
        }
    }

    #[test]
    fn decode_tokens_deterministic_and_depth_invariant() {
        let model = ServeModel::synthetic(2, 32, 16, 50021);
        let batch = Batch { tenant: 0, requests: vec![4, 9] };
        let mut outs = Vec::new();
        for depth in [0usize, 2] {
            let store = raw_store("det");
            provision(store.as_ref(), &model, 1, 3).unwrap();
            let mut eng = ServeEngine::new(model.clone(), store, depth, 42);
            outs.push(eng.decode(&VerticalSchedule, &batch, 8, None).unwrap());
        }
        assert_eq!(outs[0], outs[1], "tokens must not depend on io-depth");
        assert_eq!(outs[0][0].len(), 8);
        // a different tenant's adapter set yields a different stream
        let store = raw_store("det2");
        provision(store.as_ref(), &model, 2, 3).unwrap();
        let mut eng = ServeEngine::new(model.clone(), store, 0, 42);
        let other = eng
            .decode(&VerticalSchedule, &Batch { tenant: 1, requests: vec![4, 9] }, 8, None)
            .unwrap();
        assert_ne!(outs[0], other, "tenant must influence the token stream");
    }

    #[test]
    fn multi_tenant_footprint_is_base_plus_adapters() {
        let model = ServeModel::synthetic(4, 256, 64, 101);
        let store = raw_store("foot");
        let rep = provision(store.as_ref(), &model, 4, 5).unwrap();
        assert_eq!(rep.base_bytes, (4 * 256 + 64) as u64 * 4);
        assert_eq!(rep.adapter_bytes_per_tenant, 4 * adapter_len(256) as u64 * 4);
        // T tenants share ONE base image: footprint grows only by adapters
        assert_eq!(store.footprint(), rep.base_bytes + 4 * rep.adapter_bytes_per_tenant);
        assert!(4 * rep.adapter_bytes_per_tenant < rep.base_bytes / 8);
    }

    #[test]
    fn shared_base_hits_grow_with_cache_and_adapters_stay_per_tenant() {
        let model = ServeModel::synthetic(2, 64, 16, 211);
        let dev = Arc::new(SsdStorage::create_unthrottled(tmp("cacheadm")).unwrap());
        let store: Arc<dyn TensorStore> = Arc::new(CachedStore::with_admission(
            dev,
            1 << 20,
            CacheAdmission::PerTenant { per_tenant_bytes: 1 << 16 },
        ));
        provision(store.as_ref(), &model, 2, 9).unwrap();
        let mut eng = ServeEngine::new(model.clone(), Arc::clone(&store), 0, 1);
        for tenant in 0..2u64 {
            let b = Batch { tenant, requests: vec![0, 1] };
            eng.decode(&VerticalSchedule, &b, 2, None).unwrap();
        }
        let cs = store.cache_stats();
        use crate::memory::Category;
        let params = cs.by_cat.get(&Category::Parameters).cloned().unwrap_or_default();
        let adapters = cs.by_cat.get(&Category::Adapters).cloned().unwrap_or_default();
        // base image: both tenants hit the SAME cached objects after the
        // provisioning write-back / first read
        assert!(params.hits > 0, "shared base must hit: {params:?}");
        assert!(adapters.hits + adapters.misses > 0, "adapter reads tracked: {adapters:?}");
    }

    #[test]
    fn serve_drives_batches_and_counts_tokens() {
        let model = ServeModel::synthetic(2, 32, 16, 307);
        let store = raw_store("serve");
        provision(store.as_ref(), &model, 3, 2).unwrap();
        let mut eng = ServeEngine::new(model.clone(), store, 0, 8);
        let reqs = synthetic_requests(3, 10, 77);
        assert!(reqs.iter().all(|r| r.tenant < 3));
        let out = eng.serve(&VerticalSchedule, &reqs, 4, 3, None).unwrap();
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|(_, toks)| toks.len() == 3));
        assert_eq!(eng.stats().tokens, 30);
        // served ids are exactly the request ids
        let mut ids: Vec<u64> = out.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }
}
