//! The lane executor: a dependency-aware pipelined scheduler over named
//! serial *lanes* (one worker thread each).
//!
//! Submitting `(lane, deps, closure)` returns an [`OpId`]. An op becomes
//! *ready* when all its dependencies completed, then runs FIFO-in-ready-order
//! on its lane. Lanes execute concurrently, which is exactly how the paper
//! overlaps GPU compute with CPU↔GPU transfers, SSD traffic, and the CPU
//! optimizer step (Figures 6–8): each row of those pipeline diagrams is a
//! lane here.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Identifier of a submitted operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(u64);

type OpFn = Box<dyn FnOnce() + Send + 'static>;

struct Pending {
    lane: usize,
    remaining_deps: usize,
    f: Option<OpFn>,
    dependents: Vec<OpId>,
    /// A dependency panicked: this op's closure must never run (it would
    /// observe broken state), but the op still *completes* so waiters wake
    /// instead of deadlocking.
    poisoned: bool,
}

#[derive(Default)]
struct State {
    pending: HashMap<OpId, Pending>,
    completed: u64,
    submitted: u64,
    panicked: Option<String>,
}

struct Shared {
    state: Mutex<State>,
    done_cv: Condvar,
    /// `None` after shutdown — dropping the senders disconnects the lanes.
    lane_txs: Mutex<Option<Vec<Sender<(OpId, OpFn)>>>>,
}

impl Shared {
    /// Send to a lane if the executor is still live.
    fn send(&self, lane: usize, msg: (OpId, OpFn)) {
        if let Some(txs) = self.lane_txs.lock().unwrap().as_ref() {
            let _ = txs[lane].send(msg);
        }
    }
}

/// Dependency-aware executor over named serial lanes.
pub struct LaneExecutor {
    shared: Arc<Shared>,
    lane_names: Vec<String>,
    workers: Vec<JoinHandle<()>>,
    next_id: u64,
}

impl LaneExecutor {
    pub fn new(lane_names: &[&str]) -> Self {
        assert!(!lane_names.is_empty());
        let mut txs = Vec::new();
        let mut rxs = Vec::new();
        for _ in lane_names {
            let (tx, rx) = channel::<(OpId, OpFn)>();
            txs.push(tx);
            rxs.push(rx);
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            done_cv: Condvar::new(),
            lane_txs: Mutex::new(Some(txs)),
        });
        let workers = rxs
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                let shared = Arc::clone(&shared);
                let name = lane_names[i].to_string();
                std::thread::Builder::new()
                    .name(format!("lane-{name}"))
                    .spawn(move || {
                        while let Ok((id, f)) = rx.recv() {
                            // Fault injection (`lane:{name}` site): the op
                            // "dies" before running — completed as a panic so
                            // the sticky-poison path is exercised exactly as
                            // a real mid-step lane failure would.
                            if crate::util::fault::any_armed()
                                && crate::util::fault::should_fail(&format!("lane:{name}"))
                            {
                                shared.complete(
                                    id,
                                    Some(format!("injected fault: lane '{name}' op")),
                                );
                                continue;
                            }
                            let result = catch_unwind(AssertUnwindSafe(f));
                            shared.complete(id, result.err().map(|e| panic_msg(&e)));
                        }
                    })
                    .expect("spawn lane worker")
            })
            .collect();
        LaneExecutor {
            shared,
            lane_names: lane_names.iter().map(|s| s.to_string()).collect(),
            workers,
            next_id: 0,
        }
    }

    pub fn lane_index(&self, name: &str) -> usize {
        self.lane_names
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("unknown lane '{name}'"))
    }

    /// Submit an operation on `lane` that runs after all `deps` complete.
    pub fn submit<F: FnOnce() + Send + 'static>(
        &mut self,
        lane: usize,
        deps: &[OpId],
        f: F,
    ) -> OpId {
        assert!(lane < self.lane_names.len());
        let id = OpId(self.next_id);
        self.next_id += 1;
        let mut st = self.shared.state.lock().unwrap();
        st.submitted += 1;
        // Count only dependencies that have not yet completed.
        let mut remaining = 0;
        for d in deps {
            if let Some(p) = st.pending.get_mut(d) {
                p.dependents.push(id);
                remaining += 1;
            }
        }
        let mut pending = Pending {
            lane,
            remaining_deps: remaining,
            f: Some(Box::new(f)),
            dependents: Vec::new(),
            poisoned: false,
        };
        if remaining == 0 {
            let f = pending.f.take().unwrap();
            st.pending.insert(id, pending); // still tracked until completion
            drop(st);
            self.shared.send(lane, (id, f));
        } else {
            st.pending.insert(id, pending);
        }
        id
    }

    /// Convenience: submit by lane name.
    pub fn submit_on<F: FnOnce() + Send + 'static>(
        &mut self,
        lane: &str,
        deps: &[OpId],
        f: F,
    ) -> OpId {
        self.submit(self.lane_index(lane), deps, f)
    }

    /// Block until every submitted op has completed. Panics if any op panicked.
    pub fn wait_all(&self) {
        if let Err(msg) = self.try_wait_all() {
            panic!("lane op panicked: {msg}");
        }
    }

    /// Block until a specific op completes.
    pub fn wait(&self, id: OpId) {
        if let Err(msg) = self.try_wait(id) {
            panic!("lane op panicked: {msg}");
        }
    }

    /// Block until every submitted op has completed; `Err(message)` instead
    /// of panicking when any op panicked. The panic message is *sticky*: once
    /// an op has panicked the executor is poisoned and every subsequent wait
    /// reports it, so callers can surface the failure as a proper error at
    /// their boundary (the engine wraps it in `anyhow`) instead of unwinding.
    pub fn try_wait_all(&self) -> Result<(), String> {
        let mut st = self.shared.state.lock().unwrap();
        while st.completed < st.submitted && st.panicked.is_none() {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        match &st.panicked {
            Some(msg) => Err(msg.clone()),
            None => Ok(()),
        }
    }

    /// Block until a specific op completes; `Err(message)` when the op — or
    /// any op, the poison is executor-wide — panicked. An op whose dependency
    /// panicked never runs but still completes (poisoned), so this returns
    /// instead of deadlocking.
    pub fn try_wait(&self, id: OpId) -> Result<(), String> {
        let mut st = self.shared.state.lock().unwrap();
        while st.pending.contains_key(&id) && st.panicked.is_none() {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        match &st.panicked {
            Some(msg) => Err(msg.clone()),
            None => Ok(()),
        }
    }

    /// The sticky panic message, if any op has panicked.
    pub fn panicked(&self) -> Option<String> {
        self.shared.state.lock().unwrap().panicked.clone()
    }

    pub fn n_lanes(&self) -> usize {
        self.lane_names.len()
    }
}

impl Shared {
    fn complete(&self, id: OpId, panic: Option<String>) {
        let mut ready: Vec<(usize, OpId, OpFn)> = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            let failed = panic.is_some();
            if let Some(msg) = panic {
                st.panicked.get_or_insert(msg);
            }
            // Worklist: the op itself plus any poisoned dependents that
            // become ready — those complete immediately (their closures are
            // dropped, never run) so waiters wake instead of deadlocking.
            let mut work: Vec<(OpId, bool)> = vec![(id, failed)];
            while let Some((cur, cur_failed)) = work.pop() {
                let p = st.pending.remove(&cur).expect("completing unknown op");
                st.completed += 1;
                for dep_id in p.dependents {
                    if let Some(dp) = st.pending.get_mut(&dep_id) {
                        dp.remaining_deps -= 1;
                        if cur_failed {
                            dp.poisoned = true;
                        }
                        if dp.remaining_deps == 0 {
                            if dp.poisoned {
                                dp.f = None; // never runs
                                work.push((dep_id, true));
                            } else {
                                let f = dp.f.take().expect("ready op has fn");
                                ready.push((dp.lane, dep_id, f));
                            }
                        }
                    }
                }
            }
            self.done_cv.notify_all();
        }
        for (lane, rid, f) in ready {
            // Send outside the state lock; no-op if the executor is already
            // shutting down (ops are dropped — the executor is being dropped).
            self.send(lane, (rid, f));
        }
    }
}

impl Drop for LaneExecutor {
    fn drop(&mut self) {
        // Drop every Sender: lane recv()s disconnect, workers drain their
        // queues and exit, and we can join them cleanly.
        *self.shared.lane_txs.lock().unwrap() = None;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn respects_dependencies() {
        let mut ex = LaneExecutor::new(&["a", "b"]);
        let log = Arc::new(Mutex::new(Vec::new()));
        let l1 = Arc::clone(&log);
        let op1 = ex.submit_on("a", &[], move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            l1.lock().unwrap().push(1);
        });
        let l2 = Arc::clone(&log);
        let _op2 = ex.submit_on("b", &[op1], move || l2.lock().unwrap().push(2));
        ex.wait_all();
        assert_eq!(*log.lock().unwrap(), vec![1, 2]);
    }

    #[test]
    fn lanes_run_concurrently() {
        let mut ex = LaneExecutor::new(&["x", "y"]);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let b1 = Arc::clone(&barrier);
        let b2 = Arc::clone(&barrier);
        // Both block on the barrier; completes only if lanes are parallel.
        ex.submit_on("x", &[], move || {
            b1.wait();
        });
        ex.submit_on("y", &[], move || {
            b2.wait();
        });
        ex.wait_all();
    }

    #[test]
    fn same_lane_is_serial() {
        let mut ex = LaneExecutor::new(&["only"]);
        let active = Arc::new(AtomicUsize::new(0));
        let max_active = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let a = Arc::clone(&active);
            let m = Arc::clone(&max_active);
            ex.submit_on("only", &[], move || {
                let now = a.fetch_add(1, Ordering::SeqCst) + 1;
                m.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(2));
                a.fetch_sub(1, Ordering::SeqCst);
            });
        }
        ex.wait_all();
        assert_eq!(max_active.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn diamond_dependency() {
        let mut ex = LaneExecutor::new(&["a", "b", "c"]);
        let acc = Arc::new(Mutex::new(String::new()));
        let (a1, a2, a3, a4) =
            (Arc::clone(&acc), Arc::clone(&acc), Arc::clone(&acc), Arc::clone(&acc));
        let root = ex.submit_on("a", &[], move || a1.lock().unwrap().push('r'));
        let left = ex.submit_on("b", &[root], move || a2.lock().unwrap().push('l'));
        let right = ex.submit_on("c", &[root], move || a3.lock().unwrap().push('R'));
        let _join = ex.submit_on("a", &[left, right], move || a4.lock().unwrap().push('j'));
        ex.wait_all();
        let s = acc.lock().unwrap().clone();
        assert!(s.starts_with('r') && s.ends_with('j') && s.len() == 4, "{s}");
    }

    #[test]
    fn wait_specific_op() {
        let mut ex = LaneExecutor::new(&["a"]);
        let flag = Arc::new(AtomicUsize::new(0));
        let f1 = Arc::clone(&flag);
        let op = ex.submit_on("a", &[], move || {
            f1.store(1, Ordering::SeqCst);
        });
        ex.wait(op);
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn completed_deps_do_not_block() {
        let mut ex = LaneExecutor::new(&["a"]);
        let op1 = ex.submit_on("a", &[], || {});
        ex.wait(op1);
        // op1 already gone from pending; new op must still run.
        let ran = Arc::new(AtomicUsize::new(0));
        let r = Arc::clone(&ran);
        ex.submit_on("a", &[op1], move || {
            r.store(1, Ordering::SeqCst);
        });
        ex.wait_all();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    /// Regression: a panicked lane op used to unwind waiters (and a panicked
    /// dependency could leave a dependent waiter hanging). Now the panic is
    /// sticky, dependents are poisoned (completed without running), and the
    /// `try_*` APIs surface the failure as an error.
    #[test]
    fn panicked_op_fails_waiters_and_poisons_dependents() {
        let mut ex = LaneExecutor::new(&["a", "b"]);
        let ran = Arc::new(AtomicUsize::new(0));
        let r = Arc::clone(&ran);
        let bad = ex.submit_on("a", &[], || panic!("kaboom"));
        let child = ex.submit_on("b", &[bad], move || {
            r.store(1, Ordering::SeqCst);
        });
        // The dependent completes (poisoned) instead of hanging, and the
        // wait reports the failure as an error rather than panicking.
        assert!(ex.try_wait(child).unwrap_err().contains("kaboom"));
        assert_eq!(ran.load(Ordering::SeqCst), 0, "poisoned op must not run");
        // Sticky: every later wait sees the same poisoned executor.
        assert!(ex.try_wait_all().is_err());
        assert!(ex.try_wait(bad).is_err());
        assert_eq!(ex.panicked().unwrap(), "kaboom");
    }

    /// A chain behind a panicked root is poisoned transitively; unrelated
    /// ops submitted before the panic still ran to completion.
    #[test]
    fn poison_cascades_through_chains() {
        let mut ex = LaneExecutor::new(&["a", "b"]);
        let count = Arc::new(AtomicUsize::new(0));
        let c1 = Arc::clone(&count);
        let ok = ex.submit_on("b", &[], move || {
            c1.fetch_add(1, Ordering::SeqCst);
        });
        // the root panics only after `ok` completed, so the count below
        // is deterministic
        let bad = ex.submit_on("a", &[ok], || panic!("root failure"));
        let mut prev = bad;
        for _ in 0..4 {
            let c = Arc::clone(&count);
            prev = ex.submit_on("b", &[prev], move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert!(ex.try_wait(prev).is_err());
        assert!(ex.try_wait(ok).is_err(), "sticky poison applies to all waits");
        // only the healthy op ran; the poisoned chain never executed
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn try_wait_is_ok_on_healthy_executor() {
        let mut ex = LaneExecutor::new(&["a"]);
        let op = ex.submit_on("a", &[], || {});
        assert!(ex.try_wait(op).is_ok());
        assert!(ex.try_wait_all().is_ok());
        assert!(ex.panicked().is_none());
    }

    /// The `lane:{name}` fault site kills exactly the armed nth op on that
    /// lane (one-shot), and the kill is indistinguishable from a panic:
    /// sticky poison, error-returning waits, the closure never runs.
    #[test]
    fn injected_lane_fault_poisons_like_a_panic() {
        crate::util::fault::arm("lane:faulty", 1);
        let mut ex = LaneExecutor::new(&["faulty"]);
        let count = Arc::new(AtomicUsize::new(0));
        let c0 = Arc::clone(&count);
        let ok = ex.submit_on("faulty", &[], move || {
            c0.fetch_add(1, Ordering::SeqCst);
        });
        ex.wait(ok); // hit 0: not the armed nth — runs normally
        let c1 = Arc::clone(&count);
        let bad = ex.submit_on("faulty", &[], move || {
            c1.fetch_add(1, Ordering::SeqCst);
        });
        let err = ex.try_wait(bad).unwrap_err();
        assert!(err.contains("injected fault"), "{err}");
        assert_eq!(count.load(Ordering::SeqCst), 1, "faulted op must not run");
        // one-shot: the site disarmed itself when it fired
        assert!(!crate::util::fault::should_fail("lane:faulty"));
    }

    #[test]
    fn many_ops_stress() {
        let mut ex = LaneExecutor::new(&["a", "b", "c", "d"]);
        let count = Arc::new(AtomicUsize::new(0));
        let mut prev: Option<OpId> = None;
        for i in 0..500 {
            let c = Arc::clone(&count);
            let deps: Vec<OpId> = prev.into_iter().collect();
            prev = Some(ex.submit(i % 4, &deps, move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        ex.wait_all();
        assert_eq!(count.load(Ordering::SeqCst), 500);
    }
}
