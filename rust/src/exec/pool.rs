//! Fixed-size thread pool with joinable task handles.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads; `submit` returns a [`TaskHandle`] that can
/// be waited on for the closure's return value.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

/// Shared completion slot.
struct Slot<T> {
    value: Mutex<Option<std::thread::Result<T>>>,
    cv: Condvar,
}

/// Handle to a submitted task.
pub struct TaskHandle<T> {
    slot: Arc<Slot<T>>,
}

impl<T> TaskHandle<T> {
    /// Block until the task finishes; re-panics if the task panicked.
    pub fn wait(self) -> T {
        let mut guard = self.slot.value.lock().unwrap();
        while guard.is_none() {
            guard = self.slot.cv.wait(guard).unwrap();
        }
        match guard.take().unwrap() {
            Ok(v) => v,
            Err(e) => std::panic::resume_unwind(e),
        }
    }

    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        self.slot.value.lock().unwrap().is_some()
    }
}

impl ThreadPool {
    pub fn new(n_workers: usize) -> Self {
        assert!(n_workers > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n_workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a closure; returns a handle for its result.
    pub fn submit<T, F>(&self, f: F) -> TaskHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let slot = Arc::new(Slot { value: Mutex::new(None), cv: Condvar::new() });
        let slot2 = Arc::clone(&slot);
        let job: Job = Box::new(move || {
            let result = catch_unwind(AssertUnwindSafe(f));
            *slot2.value.lock().unwrap() = Some(result);
            slot2.cv.notify_all();
        });
        self.tx.as_ref().unwrap().send(job).expect("pool alive");
        TaskHandle { slot }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn runs_tasks_and_returns_values() {
        let pool = ThreadPool::new(4);
        let handles: Vec<_> = (0..16).map(|i| pool.submit(move || i * i)).collect();
        let sum: i32 = handles.into_iter().map(|h| h.wait()).sum();
        assert_eq!(sum, (0..16).map(|i| i * i).sum());
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU32::new(0));
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&counter);
                let b = Arc::clone(&barrier);
                pool.submit(move || {
                    b.wait(); // deadlocks unless all 4 run concurrently
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn panics_propagate_on_wait() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(|| panic!("boom"));
        h.wait();
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let h = pool.submit(|| 7);
        assert_eq!(h.wait(), 7);
        drop(pool); // must not hang
    }

    #[test]
    fn is_done_flips() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(20)));
        // Eventually done; poll with timeout.
        let t0 = std::time::Instant::now();
        while !h.is_done() {
            assert!(t0.elapsed().as_secs() < 5);
            std::thread::yield_now();
        }
    }
}
