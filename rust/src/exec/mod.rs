//! Execution substrate: a fixed thread pool and a *lane executor* — the
//! in-tree replacement for the asyncio pipeline GreedySnake reuses from
//! ZeRO-Infinity.
//!
//! The lane executor models the resource dimension of the paper's
//! two-dimensional resource-time pipeline (§5): each *lane* is one serially
//! ordered hardware resource (GPU compute, CPU→GPU copy, GPU→CPU copy,
//! SSD read, SSD write, CPU compute), operations are submitted with explicit
//! dependencies, and lanes run concurrently — exactly the structure of
//! Figures 6–8, where boxes on one row execute in order and rows overlap.

pub mod lanes;
pub mod pool;

pub use lanes::{LaneExecutor, OpId};
pub use pool::ThreadPool;
