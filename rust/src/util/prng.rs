//! Deterministic xoshiro256++ PRNG — seeds every stochastic component
//! (parameter init, synthetic corpora, property tests) so runs reproduce
//! bit-for-bit. No external `rand` needed.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // modulo bias is < 2^-40 for all n used in this crate.
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, std²) fp32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.next_normal() as f32 * std;
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` — synthetic-corpus
    /// token sampling (natural text is near-Zipfian).
    pub fn next_zipf(&mut self, n: u64, s: f64) -> u64 {
        // Inverse-CDF on the truncated zeta distribution via rejection
        // (Devroye); cheap because s ~ 1 and n is modest.
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = ((n as f64 + 1.0).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
            let xf = x.floor();
            if xf < 1.0 || xf > n as f64 {
                continue;
            }
            let ratio = (xf / x).powf(s) * x / xf;
            if v * ratio <= 1.0 {
                return xf as u64 - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut p = Prng::new(3);
        let mean: f64 = (0..100_000).map(|_| p.next_f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let xs: Vec<f64> = (0..100_000).map(|_| p.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut p = Prng::new(5);
        let mut counts = [0u32; 16];
        for _ in 0..20_000 {
            let x = p.next_zipf(16, 1.1) as usize;
            assert!(x < 16);
            counts[x] += 1;
        }
        assert!(counts[0] > counts[8] * 3, "{counts:?}");
    }

    #[test]
    fn next_below_bounds() {
        let mut p = Prng::new(9);
        for n in [1u64, 2, 3, 17, 1000] {
            for _ in 0..100 {
                assert!(p.next_below(n) < n);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
