//! Utility substrates built in-tree because the build is fully offline:
//! a PRNG, summary statistics, bf16/f16 conversion, a JSON parser (for the AOT
//! manifest), TSV report tables, a CLI argument parser, a micro-benchmark
//! harness (the criterion stand-in driving `cargo bench`), and a property
//! testing harness (the proptest stand-in).

pub mod bench;
pub mod bf16;
pub mod cli;
pub mod f16;
pub mod fault;
pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;

pub use bench::Bench;
pub use prng::Prng;
