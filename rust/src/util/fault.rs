//! Deterministic fault-injection registry for crash/recovery testing.
//!
//! Tests (and the `GS_TEST_FAULT` CI leg) *arm* a named site to fail on its
//! n-th hit; production code *checks* sites at a handful of crash-relevant
//! points — lane op execution ([`crate::exec::lanes::LaneExecutor`]), the
//! post-reduce-scatter boundary in
//! [`crate::coordinator::dist::DataParallelEngine`], the delayed optimizer
//! dispatch, and store `put` paths (torn writes in the journal layer, extent
//! failures in [`crate::memory::store::PlannedStore`]).
//!
//! Design constraints:
//! * **Zero cost when disarmed** — `should_fail` is a single relaxed atomic
//!   load when nothing is armed, so the hooks are compiled into release
//!   builds and exercised by integration tests without a test-only cfg.
//! * **One-shot and deterministic** — an armed site fires exactly once, on
//!   its n-th matching hit (0-based), then disarms itself. Recovery retries
//!   therefore succeed without the test having to race a disarm call.
//! * **Process-global** — faults cross thread boundaries (lane workers, the
//!   optimizer pool), which is the point: the "crash" lands wherever the
//!   victim code runs. Hooks on production paths shared by many parallel
//!   tests check scope-qualified names ([`scoped`], fed from
//!   `TrainerConfig::fault_scope` or a store's `with_fault_scope`), so
//!   each test arms sites only its own objects can hit; bare-name sites
//!   are reserved for tests that own the hooked object outright (e.g. a
//!   uniquely named lane).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

struct Arm {
    /// Fire on the `nth` matching `should_fail` call (0-based).
    nth: u64,
    /// Hits observed so far.
    seen: u64,
}

static ARMED_SITES: AtomicUsize = AtomicUsize::new(0);

fn registry() -> &'static Mutex<HashMap<String, Arm>> {
    static REG: OnceLock<Mutex<HashMap<String, Arm>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Arm `site` to fail on its `nth` (0-based) subsequent hit. Re-arming an
/// already-armed site resets its hit counter.
pub fn arm(site: &str, nth: u64) {
    let mut reg = registry().lock().unwrap();
    if reg.insert(site.to_string(), Arm { nth, seen: 0 }).is_none() {
        ARMED_SITES.fetch_add(1, Ordering::Relaxed);
    }
}

/// Disarm one site (idempotent).
pub fn disarm(site: &str) {
    let mut reg = registry().lock().unwrap();
    if reg.remove(site).is_some() {
        ARMED_SITES.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Disarm everything — test teardown.
pub fn disarm_all() {
    let mut reg = registry().lock().unwrap();
    let n = reg.len();
    reg.clear();
    if n > 0 {
        ARMED_SITES.fetch_sub(n, Ordering::Relaxed);
    }
}

/// True iff any site is currently armed (cheap pre-check for hooks that
/// would otherwise have to format a dynamic site name).
pub fn any_armed() -> bool {
    ARMED_SITES.load(Ordering::Relaxed) != 0
}

/// Scope-qualify a site name: `"{site}@{scope}"`, or `site` unchanged for
/// an empty scope. Hooks whose call sites are shared by many parallel
/// tests — the trainer/engine/store sites, which fire inside ordinary
/// production paths like `dispatch_delayed` or `JournalStore::put` —
/// qualify their name with a per-config scope (`TrainerConfig::fault_scope`
/// for the coordinator stack, `with_fault_scope` on the stores), so a test
/// arming its own scoped site never has hits consumed — or faults
/// injected — by an unrelated test exercising the same code path. The
/// production default is an empty scope (bare site names).
pub fn scoped(site: &str, scope: &str) -> String {
    if scope.is_empty() {
        site.to_string()
    } else {
        format!("{site}@{scope}")
    }
}

/// Hook: returns `true` exactly once, on the armed `nth` hit of `site`,
/// and disarms the site. Returns `false` (one atomic load) when nothing
/// is armed anywhere.
pub fn should_fail(site: &str) -> bool {
    if ARMED_SITES.load(Ordering::Relaxed) == 0 {
        return false;
    }
    let mut reg = registry().lock().unwrap();
    let fire = match reg.get_mut(site) {
        Some(a) => {
            let fire = a.seen == a.nth;
            a.seen += 1;
            fire
        }
        None => false,
    };
    if fire {
        reg.remove(site);
        ARMED_SITES.fetch_sub(1, Ordering::Relaxed);
    }
    fire
}

#[cfg(test)]
mod tests {
    use super::*;

    // NB: the registry is process-global and tests in this binary run in
    // parallel — these tests only touch their own `t:*` site names and never
    // call `disarm_all` (which would disarm other tests' sites mid-flight).

    #[test]
    fn fires_once_on_nth_hit() {
        arm("t:once", 2);
        assert!(any_armed());
        assert!(!should_fail("t:once"));
        assert!(!should_fail("t:once"));
        assert!(should_fail("t:once"));
        // one-shot: disarmed after firing
        assert!(!should_fail("t:once"));
    }

    #[test]
    fn unarmed_sites_never_fire() {
        arm("t:other", 0);
        assert!(!should_fail("t:unrelated"));
        assert!(should_fail("t:other"));
        assert!(!should_fail("t:other"));
    }

    #[test]
    fn scoped_names_are_disjoint() {
        assert_eq!(scoped("t:site", ""), "t:site");
        assert_eq!(scoped("t:site", "cfg1"), "t:site@cfg1");
        arm(&scoped("t:site", "cfg2"), 0);
        // the bare site and other scopes never consume cfg2's arm
        assert!(!should_fail("t:site"));
        assert!(!should_fail(&scoped("t:site", "cfg3")));
        assert!(should_fail(&scoped("t:site", "cfg2")));
    }
}
