//! bf16 <-> f32 conversion for the mixed-precision parameter path.
//!
//! The paper's "low-precision parameters" are BF16; master parameters and
//! optimizer states stay FP32 (§2.1). The Rust side stores the low-precision
//! copy as raw `u16` words (round-to-nearest-even truncation of the f32 high
//! half) — the PJRT client ingests them via `buffer_from_host_raw_bytes`.

/// f32 -> bf16 with round-to-nearest-even (matches hardware + numpy).
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserving sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x0000_7FFF + lsb) >> 16) as u16
}

/// bf16 -> f32 (exact).
#[inline]
pub fn bf16_to_f32(x: u16) -> f32 {
    f32::from_bits((x as u32) << 16)
}

/// Convert a slice, appending into `out`.
pub fn f32_slice_to_bf16(src: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.extend(src.iter().map(|&x| f32_to_bf16(x)));
}

/// Convert a bf16 word slice to f32s.
pub fn bf16_slice_to_f32(src: &[u16], out: &mut Vec<f32>) {
    out.clear();
    out.extend(src.iter().map(|&x| bf16_to_f32(x)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 2.0, -3.0, 1024.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)), x, "{x}");
        }
    }

    #[test]
    fn rounding_error_bounded() {
        // bf16 has 8 significand bits -> relative error <= 2^-8.
        let mut p = crate::util::prng::Prng::new(0);
        for _ in 0..10_000 {
            let x = (p.next_f64() as f32 - 0.5) * 100.0;
            let y = bf16_to_f32(f32_to_bf16(x));
            if x != 0.0 {
                assert!(((y - x) / x).abs() <= 1.0 / 256.0, "{x} -> {y}");
            }
        }
    }

    #[test]
    fn nan_and_inf() {
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between bf16(1.0) and the next value;
        // RNE keeps the even significand (1.0).
        let halfway = f32::from_bits(0x3F80_4000 >> 6 << 6); // construct carefully below
        let _ = halfway;
        let x = f32::from_bits(0x3F80_8000); // 1.00390625 -> halfway, rounds to even
        let y = f32_to_bf16(x);
        assert_eq!(y & 1, 0, "halfway case must round to even, got {y:#x}");
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32 * 0.25).collect();
        let mut b = Vec::new();
        f32_slice_to_bf16(&xs, &mut b);
        let mut back = Vec::new();
        bf16_slice_to_f32(&b, &mut back);
        for (a, c) in xs.iter().zip(&back) {
            assert!((a - c).abs() <= a.abs() / 256.0 + 1e-6);
        }
    }
}
