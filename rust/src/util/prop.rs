//! Property-testing harness (the proptest stand-in): deterministic generator
//! functions over a seeded [`Prng`], N-case runners, and shrinking-free but
//! seed-reporting failure messages. Coordinator invariants (routing, batching,
//! state placement) are property-tested with this in `rust/tests/proptests.rs`.

use super::prng::Prng;

/// Run `cases` random cases of `prop`; on failure, panic with the exact seed
/// so the case can be replayed (`Prng::new(seed)` is pure).
pub fn check<F: Fn(&mut Prng) -> Result<(), String>>(name: &str, cases: u32, prop: F) {
    for i in 0..cases {
        let seed = 0xC0FFEE ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Prng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-like helper producing `Result<(), String>` for use inside `check`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Generators.
pub mod gen {
    use super::Prng;

    pub fn usize_in(rng: &mut Prng, lo: usize, hi: usize) -> usize {
        lo + rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(rng: &mut Prng, lo: f64, hi: f64) -> f64 {
        lo + rng.next_f64() * (hi - lo)
    }

    pub fn vec_f32(rng: &mut Prng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f32() - 0.5) * 2.0 * scale).collect()
    }

    /// A random partition of `total` into `parts` non-negative chunks.
    pub fn partition(rng: &mut Prng, total: usize, parts: usize) -> Vec<usize> {
        if parts == 0 {
            return vec![];
        }
        let mut cuts: Vec<usize> = (0..parts - 1).map(|_| rng.next_below(total as u64 + 1) as usize).collect();
        cuts.sort_unstable();
        let mut out = Vec::with_capacity(parts);
        let mut prev = 0;
        for c in cuts {
            out.push(c - prev);
            prev = c;
        }
        out.push(total - prev);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |rng| {
            let a = rng.next_f64();
            let b = rng.next_f64();
            if a + b == b + a {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn failing_property_reports_seed() {
        check("always-fails", 1, |_| Err("nope".into()));
    }

    #[test]
    fn partition_sums() {
        check("partition-sums", 100, |rng| {
            let total = gen::usize_in(rng, 0, 1000);
            let parts = gen::usize_in(rng, 1, 10);
            let p = gen::partition(rng, total, parts);
            if p.len() == parts && p.iter().sum::<usize>() == total {
                Ok(())
            } else {
                Err(format!("bad partition {p:?} of {total}"))
            }
        });
    }
}
