//! IEEE 754 binary16 (half) <-> f32 conversion for the storage codec layer.
//!
//! Unlike bf16 (a truncated f32, see [`crate::util::bf16`]), f16 keeps 10
//! significand bits but only 5 exponent bits, so conversion must handle
//! exponent rebiasing, gradual underflow into f16 subnormals, and overflow
//! to infinity. All roundings are round-to-nearest-even (matches hardware
//! and numpy's `astype(float16)`).

/// f32 -> f16 with round-to-nearest-even, gradual underflow and overflow
/// to infinity.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        // NaN (quiet, preserving sign) or infinity.
        return if abs > 0x7F80_0000 { sign | 0x7E00 } else { sign | 0x7C00 };
    }
    let e16 = (abs >> 23) as i32 - 127 + 15;
    if e16 >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e16 <= 0 {
        // Subnormal (or zero) in f16: shift the 24-bit significand right by
        // 14 - e16 places with round+sticky.
        if e16 < -10 {
            return sign; // too small even for the largest shift -> signed zero
        }
        let man = (abs & 0x007F_FFFF) | 0x0080_0000;
        let s = (14 - e16) as u32;
        let res = man >> s;
        let round = (man >> (s - 1)) & 1;
        let sticky = u32::from(man & ((1 << (s - 1)) - 1) != 0);
        // A carry out of the subnormal significand lands in exponent 1 —
        // exactly the smallest normal, so plain addition is correct.
        return sign | (res + (round & (sticky | (res & 1)))) as u16;
    }
    let v = ((e16 as u32) << 10) | ((abs >> 13) & 0x3FF);
    let round = (abs >> 12) & 1;
    let sticky = u32::from(abs & 0xFFF != 0);
    // Mantissa carry propagates into the exponent; 65520 ties up to inf,
    // which is the correct RNE result.
    sign | (v + (round & (sticky | (v & 1)))) as u16
}

/// f16 -> f32 (exact: every f16 value is representable in f32).
#[inline]
pub fn f16_to_f32(x: u16) -> f32 {
    let sign = ((x as u32) & 0x8000) << 16;
    let exp = (x >> 10) & 0x1F;
    let man = (x & 0x3FF) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // signed zero
        }
        // Subnormal: normalize into an f32 normal.
        let mut e = 113u32;
        let mut m = man;
        while m & 0x0400 == 0 {
            m <<= 1;
            e -= 1;
        }
        return f32::from_bits(sign | (e << 23) | ((m & 0x3FF) << 13));
    }
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13)); // inf / NaN
    }
    f32::from_bits(sign | ((exp as u32 + 112) << 23) | (man << 13))
}

/// Convert a slice, appending into `out`.
pub fn f32_slice_to_f16(src: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.extend(src.iter().map(|&x| f32_to_f16(x)));
}

/// Convert an f16 word slice to f32s.
pub fn f16_slice_to_f32(src: &[u16], out: &mut Vec<f32>) {
    out.clear();
    out.extend(src.iter().map(|&x| f16_to_f32(x)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, -3.0, 1024.0, 65504.0] {
            let y = f16_to_f32(f32_to_f16(x));
            assert_eq!(y.to_bits(), x.to_bits(), "{x}");
        }
    }

    #[test]
    fn rounding_error_bounded() {
        // f16 has 10 significand bits -> relative error <= 2^-11 for normals.
        let mut p = crate::util::prng::Prng::new(0);
        for _ in 0..10_000 {
            let x = (p.next_f64() as f32 - 0.5) * 100.0;
            let y = f16_to_f32(f32_to_f16(x));
            // the relative bound only holds for f16 normals (|x| >= 2^-14);
            // a draw landing below that is in gradual-underflow territory
            if x.abs() >= 6.2e-5 {
                assert!(((y - x) / x).abs() <= 1.0 / 2048.0, "{x} -> {y}");
            }
        }
    }

    #[test]
    fn nan_and_inf() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(-f32::NAN)).is_nan());
    }

    #[test]
    fn overflow_saturates_to_inf() {
        // Anything above the f16 max (65504) rounds to +/-inf.
        assert_eq!(f16_to_f32(f32_to_f16(65520.0)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(1e30)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e30)), f32::NEG_INFINITY);
        // ... but the max itself is exact.
        assert_eq!(f16_to_f32(f32_to_f16(65504.0)), 65504.0);
        // 65519.996... is below the halfway point and stays finite.
        assert_eq!(f16_to_f32(f32_to_f16(65519.0)), 65504.0);
    }

    #[test]
    fn subnormals_roundtrip() {
        // Smallest positive f16 subnormal is 2^-24; all f16 subnormals are
        // exact in f32, so decode(encode(x)) == x when x is one of them.
        for k in 1u16..=0x3FF {
            let x = f16_to_f32(k); // k is a subnormal bit pattern (exp = 0)
            assert_eq!(f32_to_f16(x), k, "subnormal {k:#x}");
            assert!(x > 0.0 && x < 6.11e-5, "{x}");
        }
        // Values below half the smallest subnormal flush to signed zero.
        assert_eq!(f32_to_f16(1e-9), 0x0000);
        assert_eq!(f32_to_f16(-1e-9), 0x8000);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between f16(1.0) and the next
        // value; RNE keeps the even significand (1.0).
        let x = f32::from_bits(0x3F80_1000);
        let y = f32_to_f16(x);
        assert_eq!(y, 0x3C00, "halfway case must round to even, got {y:#x}");
        // 1.0 + 3*2^-11 is halfway between f16 codes 1 and 2 above 1.0;
        // RNE picks 2 (even).
        let x2 = f32::from_bits(0x3F80_3000);
        assert_eq!(f32_to_f16(x2), 0x3C02);
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32 * 0.25).collect();
        let mut h = Vec::new();
        f32_slice_to_f16(&xs, &mut h);
        let mut back = Vec::new();
        f16_slice_to_f32(&h, &mut back);
        for (a, c) in xs.iter().zip(&back) {
            assert_eq!(a, c); // all representable exactly (small integers/0.25 steps)
        }
    }
}
