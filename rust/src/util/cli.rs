//! Tiny CLI argument parser (the clap stand-in): `--key value`, `--flag`,
//! and positional arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declarative option spec + parsed values.
#[derive(Debug, Default)]
pub struct Cli {
    name: String,
    about: String,
    specs: Vec<(String, String, Option<String>)>, // (key, help, default)
    flags: Vec<(String, String)>,
    values: BTreeMap<String, String>,
    set_flags: Vec<String>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(name: &str, about: &str) -> Self {
        Cli { name: name.into(), about: about.into(), ..Default::default() }
    }

    /// Register `--key <value>` with an optional default.
    pub fn opt(mut self, key: &str, help: &str, default: Option<&str>) -> Self {
        self.specs.push((key.into(), help.into(), default.map(String::from)));
        self
    }

    /// Register a boolean `--flag`.
    pub fn flag(mut self, key: &str, help: &str) -> Self {
        self.flags.push((key.into(), help.into()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for (k, h, d) in &self.specs {
            let dflt = d.as_deref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{k} <v>   {h}{dflt}\n"));
        }
        for (k, h) in &self.flags {
            s.push_str(&format!("  --{k}   {h}\n"));
        }
        s.push_str("  --help   print this help\n");
        s
    }

    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(key) = a.strip_prefix("--") {
                if self.flags.iter().any(|(k, _)| k == key) {
                    self.set_flags.push(key.to_string());
                } else if self.specs.iter().any(|(k, _, _)| k == key) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{key} expects a value"))?;
                    self.values.insert(key.to_string(), v);
                } else {
                    bail!("unknown option --{key}\n\n{}", self.usage());
                }
            } else {
                self.positional.push(a);
            }
        }
        Ok(self)
    }

    /// Parse from the process arguments.
    pub fn parse(self) -> Result<Self> {
        self.parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<String> {
        if let Some(v) = self.values.get(key) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|(k, _, _)| k == key)
            .and_then(|(_, _, d)| d.clone())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(key)
            .ok_or_else(|| anyhow!("missing required option --{key}"))?;
        raw.parse::<T>()
            .map_err(|e| anyhow!("invalid value for --{key}: '{raw}' ({e})"))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.set_flags.iter().any(|k| k == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_opts_flags_positionals() {
        let c = Cli::new("t", "test")
            .opt("steps", "n steps", Some("10"))
            .opt("preset", "preset", None)
            .flag("verbose", "talk")
            .parse_from(args(&["--steps", "20", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(c.get_parsed::<u32>("steps").unwrap(), 20);
        assert!(c.has_flag("verbose"));
        assert_eq!(c.positional(), &["pos1".to_string()]);
        assert!(c.get("preset").is_none());
    }

    #[test]
    fn defaults_apply() {
        let c = Cli::new("t", "")
            .opt("steps", "", Some("10"))
            .parse_from(args(&[]))
            .unwrap();
        assert_eq!(c.get_parsed::<u32>("steps").unwrap(), 10);
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Cli::new("t", "").parse_from(args(&["--nope"]));
        assert!(r.is_err());
    }

    #[test]
    fn missing_value_rejected() {
        let r = Cli::new("t", "").opt("k", "", None).parse_from(args(&["--k"]));
        assert!(r.is_err());
    }

    #[test]
    fn bad_parse_reported() {
        let c = Cli::new("t", "")
            .opt("steps", "", Some("abc"))
            .parse_from(args(&[]))
            .unwrap();
        assert!(c.get_parsed::<u32>("steps").is_err());
    }
}
