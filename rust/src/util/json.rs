//! Minimal JSON parser + writer — enough for the AOT `manifest.json` and for
//! emitting machine-readable benchmark reports. Built in-tree because the
//! offline vendor set has no serde.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Numbers are kept as f64 (the manifest only holds
/// shapes/counts well below 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"config":{"hidden":64,"n_layers":2},"names":["a","b"],"x":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn usize_extraction() {
        assert_eq!(Json::parse("17").unwrap().as_usize().unwrap(), 17);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-3").unwrap().as_usize().is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
            "preset": "tiny",
            "config": {"micro_batch": 2, "seq_len": 32, "hidden": 64},
            "layer_params": [{"name": "ln1_w", "shape": [64], "numel": 64, "init": "ones"}]
        }"#;
        let v = Json::parse(src).unwrap();
        let p = &v.get("layer_params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("numel").unwrap().as_usize().unwrap(), 64);
    }
}
