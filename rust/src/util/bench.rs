//! Micro-benchmark harness (the criterion stand-in) driving the
//! `harness = false` `cargo bench` targets: warmup, timed iterations,
//! and mean/σ/median/p95 reporting.

use std::time::Instant;

use super::stats::{fmt_duration, Summary};

/// One benchmark group; prints a line per measured closure.
pub struct Bench {
    name: String,
    warmup_iters: u32,
    measure_iters: u32,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup_iters: 3,
            measure_iters: 10,
            results: Vec::new(),
        }
    }

    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup_iters = n;
        self
    }

    pub fn iters(mut self, n: u32) -> Self {
        self.measure_iters = n;
        self
    }

    /// Time `f` (which should do one unit of work and return a value that is
    /// black-boxed to defeat DCE).
    pub fn run<T, F: FnMut() -> T>(&mut self, label: &str, mut f: F) -> &Summary {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            black_box(f());
            s.add(t0.elapsed().as_secs_f64());
        }
        println!(
            "{}/{}: mean {} ± {}  median {}  p95 {}  ({} iters)",
            self.name,
            label,
            fmt_duration(s.mean()),
            fmt_duration(s.stddev()),
            fmt_duration(s.median()),
            fmt_duration(s.percentile(95.0)),
            s.count(),
        );
        self.results.push((label.to_string(), s));
        &self.results.last().unwrap().1
    }

    /// Mean of a previously run label.
    pub fn mean_of(&self, label: &str) -> Option<f64> {
        self.results.iter().find(|(l, _)| l == label).map(|(_, s)| s.mean())
    }

    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }
}

/// Optimization barrier (stable-Rust pattern used by bencher/criterion).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("t").warmup(1).iters(3);
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.mean() > 0.0);
        assert_eq!(s.count(), 3);
        assert!(b.mean_of("spin").unwrap() > 0.0);
    }
}
