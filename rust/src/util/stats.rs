//! Summary statistics for benchmark timings and simulator outputs.

/// Running summary of a sample set (Welford for numerical stability).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.samples.push(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Exact percentile (nearest-rank) over the retained samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Geometric mean of positive values (speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Human-readable duration.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2}s", secs)
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b;
    let mut u = 0;
    while v.abs() >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.var() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.median(), 3.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(95.0), 95.0);
    }

    #[test]
    fn geomean_pairs() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(0.5e-9 * 3.0), "1.5ns");
        assert_eq!(fmt_duration(2.5e-3), "2.50ms");
        assert_eq!(fmt_bytes(1536.0), "1.50KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0 * 1024.0), "3.00GiB");
    }

    #[test]
    fn welford_matches_naive_on_large_offset() {
        let mut s = Summary::new();
        let data: Vec<f64> = (0..1000).map(|i| 1e9 + i as f64).collect();
        for &x in &data {
            s.add(x);
        }
        let mean = data.iter().sum::<f64>() / 1000.0;
        assert!((s.mean() - mean).abs() < 1e-3);
    }
}
