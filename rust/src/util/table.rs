//! Aligned-text and TSV report tables — every bench prints the rows the
//! corresponding paper table/figure reports, in both human and
//! machine-readable form.

/// A simple column-aligned table with an optional TSV dump.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str("== ");
            out.push_str(&self.title);
            out.push_str(" ==\n");
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as TSV (header prefixed with '#').
    pub fn to_tsv(&self) -> String {
        let mut out = format!("#{}\n", self.header.join("\t"));
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }

    /// Print the aligned form to stdout and optionally persist the TSV.
    pub fn emit(&self, tsv_path: Option<&str>) {
        println!("{}", self.render());
        if let Some(path) = tsv_path {
            if let Some(dir) = std::path::Path::new(path).parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(path, self.to_tsv()) {
                eprintln!("warning: failed to write {path}: {e}");
            } else {
                println!("[tsv written to {path}]");
            }
        }
    }
}

/// Format a float with a fixed number of significant decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row_strs(&["a", "1"]).row_strs(&["long-name", "22"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("long-name"));
        let lines: Vec<&str> = r.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn tsv_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["1", "2"]);
        assert_eq!(t.to_tsv(), "#a\tb\n1\t2\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["1"]);
    }
}
