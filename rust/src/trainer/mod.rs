//! End-to-end training: synthetic corpus, the training loop over either
//! scheduler, and loss-curve logging (EXPERIMENTS.md's validation run and
//! the Figure-13 equivalence experiment both drive this).

use anyhow::Result;

use crate::coordinator::vertical::StepStats;
use crate::coordinator::{HorizontalScheduler, ModelState, TrainerConfig, VerticalScheduler};
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::TokenTensor;
use crate::runtime::Runtime;
use crate::util::prng::Prng;

/// Synthetic corpus: a Zipf-distributed token stream with a planted bigram
/// structure (each token strongly predicts a successor), so a language model
/// has real signal to learn and the loss visibly decreases within a few
/// hundred steps.
pub struct SyntheticCorpus {
    vocab: usize,
    successor: Vec<u32>,
    rng: Prng,
    /// Probability a position follows the planted bigram (vs fresh Zipf).
    coherence: f64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed ^ 0x5EED);
        let mut successor: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut successor);
        SyntheticCorpus { vocab, successor, rng, coherence: 0.75 }
    }

    /// Sample one (tokens, targets) micro-batch of shape (b, t); targets are
    /// the next-token shift.
    pub fn sample(&mut self, b: usize, t: usize) -> Result<(TokenTensor, TokenTensor)> {
        let mut toks = Vec::with_capacity(b * (t + 1));
        for _ in 0..b {
            let mut cur = self.rng.next_zipf(self.vocab as u64, 1.1) as u32;
            toks.push(cur as i32);
            for _ in 0..t {
                cur = if self.rng.next_f64() < self.coherence {
                    self.successor[cur as usize]
                } else {
                    self.rng.next_zipf(self.vocab as u64, 1.1) as u32
                };
                toks.push(cur as i32);
            }
        }
        let mut input = Vec::with_capacity(b * t);
        let mut target = Vec::with_capacity(b * t);
        for row in 0..b {
            let base = row * (t + 1);
            input.extend_from_slice(&toks[base..base + t]);
            target.extend_from_slice(&toks[base + 1..base + t + 1]);
        }
        Ok((TokenTensor::new(&[b, t], input)?, TokenTensor::new(&[b, t], target)?))
    }
}

/// Which scheduler drives training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    Vertical,
    Horizontal,
}

impl std::str::FromStr for ScheduleKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "vertical" | "greedysnake" => Ok(ScheduleKind::Vertical),
            "horizontal" | "zero-infinity" => Ok(ScheduleKind::Horizontal),
            other => anyhow::bail!("unknown schedule '{other}'"),
        }
    }
}

/// A recorded training run.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub losses: Vec<f64>,
    pub grad_norms: Vec<f64>,
    pub step_seconds: Vec<f64>,
    pub ssd_read: u64,
    pub ssd_written: u64,
}

impl RunLog {
    pub fn tokens_per_s(&self, tokens_per_step: usize) -> f64 {
        let total: f64 = self.step_seconds.iter().sum();
        (self.losses.len() * tokens_per_step) as f64 / total
    }

    /// Mean loss over the final quarter of training.
    pub fn final_loss(&self) -> f64 {
        let n = self.losses.len();
        let tail = &self.losses[n - (n / 4).max(1)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }
}

/// Train `steps` iterations of `m` micro-batches. Prints one line per
/// `log_every` steps when it is > 0.
pub fn train(
    manifest: Manifest,
    cfg: TrainerConfig,
    kind: ScheduleKind,
    steps: u64,
    m: usize,
    log_every: u64,
) -> Result<RunLog> {
    let shape = manifest.config;
    let rt = Runtime::load(&manifest)?;
    let state = ModelState::init(manifest, cfg)?;
    let mut corpus = SyntheticCorpus::new(shape.vocab, state.cfg.seed);
    let mut log = RunLog::default();

    let mut run_step = |step_fn: &mut dyn FnMut(&[TokenTensor], &[TokenTensor]) -> Result<StepStats>|
     -> Result<()> {
        for s in 0..steps {
            let mut toks = Vec::with_capacity(m);
            let mut tgts = Vec::with_capacity(m);
            for _ in 0..m {
                let (a, b) = corpus.sample(shape.micro_batch, shape.seq_len)?;
                toks.push(a);
                tgts.push(b);
            }
            let t0 = std::time::Instant::now();
            let stats = step_fn(&toks, &tgts)?;
            let dt = t0.elapsed().as_secs_f64();
            log.losses.push(stats.loss);
            log.grad_norms.push(stats.grad_norm);
            log.step_seconds.push(dt);
            log.ssd_read += stats.ssd_bytes_read;
            log.ssd_written += stats.ssd_bytes_written;
            if log_every > 0 && (s % log_every == 0 || s + 1 == steps) {
                println!(
                    "step {s:>5}  loss {:.4}  |g| {:.3}  {:.2}s/step  ssd r/w {}/{}",
                    stats.loss,
                    stats.grad_norm,
                    dt,
                    crate::util::stats::fmt_bytes(stats.ssd_bytes_read as f64),
                    crate::util::stats::fmt_bytes(stats.ssd_bytes_written as f64),
                );
            }
        }
        Ok(())
    };

    match kind {
        ScheduleKind::Vertical => {
            let mut sched = VerticalScheduler::new(&state, &rt)?;
            run_step(&mut |t, g| sched.step(t, g))?;
            sched.drain()?;
        }
        ScheduleKind::Horizontal => {
            let mut sched = HorizontalScheduler::new(&state, &rt)?;
            run_step(&mut |t, g| sched.step(t, g))?;
        }
    }
    Ok(log)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tag: &str) -> TrainerConfig {
        TrainerConfig {
            alpha: 0.0,
            opt_on_ssd: false,
            overlap: false,
            ssd_path: std::env::temp_dir()
                .join(format!("gs_trainer_{tag}_{}", std::process::id())),
            ..Default::default()
        }
    }

    #[test]
    fn corpus_is_learnable_structure() {
        let mut c = SyntheticCorpus::new(256, 0);
        let (toks, tgts) = c.sample(4, 64).unwrap();
        assert_eq!(toks.data.len(), 4 * 64);
        // targets are the shifted inputs
        assert_eq!(&toks.data[1..64], &tgts.data[..63]);
        // planted bigram: successor matches for most positions
        let succ = &c.successor;
        let mut hits = 0;
        for i in 0..63 {
            if tgts.data[i] as u32 == succ[toks.data[i] as usize] {
                hits += 1;
            }
        }
        assert!(hits > 30, "{hits}/63 bigram hits");
    }

    #[test]
    fn corpus_deterministic_per_seed() {
        let mut a = SyntheticCorpus::new(128, 7);
        let mut b = SyntheticCorpus::new(128, 7);
        assert_eq!(a.sample(2, 16).unwrap().0.data, b.sample(2, 16).unwrap().0.data);
    }

    #[test]
    fn vertical_training_reduces_loss_tiny() {
        let manifest = Manifest::load("artifacts/tiny").unwrap();
        let log = train(manifest, cfg("vred"), ScheduleKind::Vertical, 30, 2, 0).unwrap();
        let first = log.losses[0];
        let last = log.final_loss();
        assert!(
            last < first - 0.3,
            "loss must drop: {first:.3} -> {last:.3} ({:?})",
            &log.losses
        );
    }

    #[test]
    fn schedule_kind_parses() {
        assert_eq!("vertical".parse::<ScheduleKind>().unwrap(), ScheduleKind::Vertical);
        assert_eq!(
            "zero-infinity".parse::<ScheduleKind>().unwrap(),
            ScheduleKind::Horizontal
        );
        assert!("diagonal".parse::<ScheduleKind>().is_err());
    }
}
