//! End-to-end training: synthetic corpus, the schedule-agnostic training
//! loop over the [`StepEngine`], and loss-curve logging (EXPERIMENTS.md's
//! validation run and the Figure-13 equivalence experiment both drive this).
//!
//! [`ScheduleKind`] is the user-facing schedule name shared by the real
//! runtime, the discrete-event simulator ([`ScheduleKind::sim_schedule`]),
//! and the analytic traffic model ([`ScheduleKind::traffic`]): `vertical`
//! (GreedySnake), `horizontal` (ZeRO-Infinity), `chunked:G` (vertical
//! sweeps over chunks of G micro-batches), and `cachesweep:G` (chunked
//! with the backward chunk order reversed for DRAM-tier reuse).

use anyhow::{bail, Result};

use std::sync::Arc;

use crate::coordinator::schedule::{
    CacheSweepSchedule, ChunkedVerticalSchedule, HorizontalSchedule, Schedule, VerticalSchedule,
};
use crate::coordinator::{
    DataParallelEngine, ModelState, OptimizerStepCoordinator, StepEngine, StepStats, TrainerConfig,
};
use crate::memory::store::TensorStore;
use crate::perfmodel::StorageRatios;
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::TokenTensor;
use crate::runtime::Runtime;
use crate::sim;
use crate::traffic::{Traffic, Workload};
use crate::util::prng::Prng;

/// Synthetic corpus: a Zipf-distributed token stream with a planted bigram
/// structure (each token strongly predicts a successor), so a language model
/// has real signal to learn and the loss visibly decreases within a few
/// hundred steps.
pub struct SyntheticCorpus {
    vocab: usize,
    successor: Vec<u32>,
    rng: Prng,
    /// Probability a position follows the planted bigram (vs fresh Zipf).
    coherence: f64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed ^ 0x5EED);
        let mut successor: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut successor);
        SyntheticCorpus { vocab, successor, rng, coherence: 0.75 }
    }

    /// Sample one (tokens, targets) micro-batch of shape (b, t); targets are
    /// the next-token shift.
    pub fn sample(&mut self, b: usize, t: usize) -> Result<(TokenTensor, TokenTensor)> {
        let mut toks = Vec::with_capacity(b * (t + 1));
        for _ in 0..b {
            let mut cur = self.rng.next_zipf(self.vocab as u64, 1.1) as u32;
            toks.push(cur as i32);
            for _ in 0..t {
                cur = if self.rng.next_f64() < self.coherence {
                    self.successor[cur as usize]
                } else {
                    self.rng.next_zipf(self.vocab as u64, 1.1) as u32
                };
                toks.push(cur as i32);
            }
        }
        let mut input = Vec::with_capacity(b * t);
        let mut target = Vec::with_capacity(b * t);
        for row in 0..b {
            let base = row * (t + 1);
            input.extend_from_slice(&toks[base..base + t]);
            target.extend_from_slice(&toks[base + 1..base + t + 1]);
        }
        Ok((TokenTensor::new(&[b, t], input)?, TokenTensor::new(&[b, t], target)?))
    }
}

/// Which schedule drives training.
///
/// Grammar (CLI `--schedule`, also accepted by `simulate --system`):
/// `vertical` | `greedysnake` | `horizontal` | `zero-infinity` |
/// `chunked:G` | `cachesweep:G` with G ≥ 1 micro-batches per vertical
/// chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    Vertical,
    Horizontal,
    /// Vertical sweeps over chunks of G micro-batches (`chunked:G`).
    ChunkedVertical(usize),
    /// `chunked:G` traffic with the backward chunk order reversed so the
    /// freshest chunk's checkpoints are consumed while still DRAM-resident
    /// (`cachesweep:G`, MLP-Offload's cache-friendly subgroup ordering).
    CacheSweep(usize),
}

impl std::str::FromStr for ScheduleKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "vertical" | "greedysnake" => Ok(ScheduleKind::Vertical),
            "horizontal" | "zero-infinity" => Ok(ScheduleKind::Horizontal),
            other => {
                if let Some(g) = other.strip_prefix("chunked:") {
                    let group: usize = g
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad chunk group '{g}' in '{other}': {e}"))?;
                    if group == 0 {
                        bail!("chunk group must be >= 1 in '{other}'");
                    }
                    return Ok(ScheduleKind::ChunkedVertical(group));
                }
                if let Some(g) = other.strip_prefix("cachesweep:") {
                    let group: usize = g
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad chunk group '{g}' in '{other}': {e}"))?;
                    if group == 0 {
                        bail!("chunk group must be >= 1 in '{other}'");
                    }
                    return Ok(ScheduleKind::CacheSweep(group));
                }
                bail!("unknown schedule '{other}' (vertical|horizontal|chunked:G|cachesweep:G)")
            }
        }
    }
}

impl std::fmt::Display for ScheduleKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleKind::Vertical => write!(f, "vertical"),
            ScheduleKind::Horizontal => write!(f, "horizontal"),
            ScheduleKind::ChunkedVertical(g) => write!(f, "chunked:{g}"),
            ScheduleKind::CacheSweep(g) => write!(f, "cachesweep:{g}"),
        }
    }
}

impl ScheduleKind {
    /// The traversal policy driving [`StepEngine`].
    pub fn policy(&self) -> Box<dyn Schedule> {
        match self {
            ScheduleKind::Vertical => Box::new(VerticalSchedule),
            ScheduleKind::Horizontal => Box::new(HorizontalSchedule),
            ScheduleKind::ChunkedVertical(g) => Box::new(ChunkedVerticalSchedule::new(*g)),
            ScheduleKind::CacheSweep(g) => Box::new(CacheSweepSchedule::new(*g)),
        }
    }

    /// Whether the delayed-α optimizer split may run under this schedule.
    pub fn supports_delay(&self) -> bool {
        self.policy().supports_delay()
    }

    /// The discrete-event simulator's model of this schedule (the analytic
    /// stack names schedules the same way the runtime does).
    pub fn sim_schedule(&self, alpha: f64, x: StorageRatios) -> sim::Schedule {
        match self {
            ScheduleKind::Vertical => sim::Schedule::GreedySnake { alpha, x },
            ScheduleKind::Horizontal => sim::Schedule::ZeroInfinity,
            ScheduleKind::ChunkedVertical(g) => {
                sim::Schedule::ChunkedVertical { group: *g as u64, x }
            }
            ScheduleKind::CacheSweep(g) => sim::Schedule::CacheSweep { group: *g as u64, x },
        }
    }

    /// The closed-form per-iteration traffic of this schedule (§3.3/§3.4).
    pub fn traffic(&self, w: &Workload) -> Traffic {
        match self {
            ScheduleKind::Vertical => w.vertical(),
            ScheduleKind::Horizontal => w.horizontal(),
            ScheduleKind::ChunkedVertical(g) => w.chunked_vertical(*g as u64),
            // Same per-iteration bytes as chunked:G — cachesweep only
            // reorders the backward visit sequence for DRAM-tier reuse.
            ScheduleKind::CacheSweep(g) => w.chunked_vertical(*g as u64),
        }
    }
}

/// A recorded training run.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub losses: Vec<f64>,
    pub grad_norms: Vec<f64>,
    pub step_seconds: Vec<f64>,
    pub ssd_read: u64,
    pub ssd_written: u64,
    /// Layer-parameter bytes uploaded to the device (schedule-dependent).
    pub param_bytes: u64,
    /// I/O-pipeline lookahead loads already in flight when needed
    /// (0 at `io_depth == 0`).
    pub prefetch_hits: u64,
    /// Loads performed synchronously despite async mode.
    pub prefetch_misses: u64,
    /// Total seconds the compute thread stalled on I/O (summed across
    /// workers in a `--workers W` run).
    pub io_stall_s: f64,
    /// Per-worker share of `io_stall_s`, cumulative over the run — one
    /// entry per ACTIVE worker in rank order (ranks whose micro-batch
    /// partition is empty, i.e. W > M, do no work and get no entry, so
    /// per-worker averages aren't diluted by idle ranks). A single-worker
    /// run has one entry.
    pub worker_stall_s: Vec<f64>,
    /// Total wall seconds in the deterministic ring all-reduce (0 at W = 1).
    pub allreduce_s: f64,
    /// Total ring gradient traffic, summed across ranks (0 at W = 1):
    /// all-reduce bytes on the rank-0 optimizer path, reduce-scatter bytes
    /// under `--shard-optimizer`.
    pub allreduce_bytes: u64,
    /// Total parameter all-gather traffic under `--shard-optimizer`
    /// (0 at W = 1 and on the rank-0 path).
    pub allgather_bytes: u64,
    /// DRAM cache-tier hits over the run (0 without `--cpu-cache-mb`).
    pub cache_hits: u64,
    /// Cache-tier misses (reads that fell through to the SSD tier).
    pub cache_misses: u64,
    /// Cache-tier LRU evictions (dirty victims wrote back to the SSD).
    pub cache_evictions: u64,
    /// Per-category cumulative cache counters at end of run — one
    /// `(category, [hits, misses, evictions])` entry per data category the
    /// cache saw (`OptimizerStates`, `Checkpoints`, …). Empty without a
    /// cache tier.
    pub cache_by_cat: Vec<(String, [u64; 3])>,
    /// Σx² over all parameters after the final drain — a deterministic
    /// digest the W-equivalence suite compares bit-for-bit.
    pub param_sq_norm: f64,
    /// Σx² over all optimizer moments (CPU- or SSD-resident) after the
    /// final drain — same role as `param_sq_norm`.
    pub moment_sq_norm: f64,
    /// Journal recoveries performed (`--journal`): failed steps replayed
    /// from the last committed epoch boundary. 0 on a clean run; the
    /// kill-a-worker suite asserts the recovered run's losses and digests
    /// are bit-identical to an uninterrupted one.
    pub recoveries: u64,
    /// Per-rank parameter-shard store bytes READ under `--param-persist`
    /// (one entry per rank; empty without param persistence) — the runtime
    /// evidence of the ~1/W per-rank round-trip scaling.
    pub param_shard_reads: Vec<u64>,
    /// Per-rank parameter-shard store bytes WRITTEN under `--param-persist`.
    pub param_shard_writes: Vec<u64>,
}

impl RunLog {
    /// Training throughput; 0.0 for an empty run (no division by zero).
    pub fn tokens_per_s(&self, tokens_per_step: usize) -> f64 {
        let total: f64 = self.step_seconds.iter().sum();
        if self.losses.is_empty() || total <= 0.0 {
            return 0.0;
        }
        (self.losses.len() * tokens_per_step) as f64 / total
    }

    /// Mean loss over the final quarter of training; 0.0 for an empty run.
    pub fn final_loss(&self) -> f64 {
        let n = self.losses.len();
        if n == 0 {
            return 0.0;
        }
        let tail = &self.losses[n - (n / 4).max(1)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }
}

/// Train `steps` iterations of `m` micro-batches under `kind`'s schedule.
/// Prints one line per `log_every` steps when it is > 0. Every schedule
/// runs through the same engine and drains uniformly at the end.
///
/// `cfg.workers` picks the driver: 1 runs the single [`StepEngine`]
/// (today's path, byte-for-byte); W > 1 runs the
/// [`DataParallelEngine`], whose deterministic ring all-reduce makes the
/// run bit-identical to W = 1 — same losses, gradient norms, and (via
/// [`RunLog::param_sq_norm`]/[`RunLog::moment_sq_norm`]) parameters and
/// optimizer moments.
pub fn train(
    manifest: Manifest,
    cfg: TrainerConfig,
    kind: ScheduleKind,
    steps: u64,
    m: usize,
    log_every: u64,
) -> Result<RunLog> {
    enum Driver<'a> {
        Single(StepEngine<'a>),
        Dist(DataParallelEngine<'a>),
    }
    impl Driver<'_> {
        fn opt(&self) -> Arc<OptimizerStepCoordinator> {
            match self {
                Driver::Single(e) => Arc::clone(&e.opt),
                Driver::Dist(e) => Arc::clone(&e.opt),
            }
        }
        fn set_steps_done(&mut self, n: u64) {
            match self {
                Driver::Single(e) => e.set_steps_done(n),
                Driver::Dist(e) => e.set_steps_done(n),
            }
        }
    }
    /// Per-step cap on journal recovery retries — a fault that persists
    /// across replays of the same boundary is a real failure, not a crash.
    const MAX_RECOVERY_RETRIES: u32 = 3;
    if cfg.param_persist && !cfg.opt_on_ssd {
        bail!("--param-persist requires --opt-on-ssd: the store is the master-parameter home");
    }
    if cfg.journal && !(cfg.param_persist && cfg.opt_on_ssd) {
        bail!(
            "--journal recovery requires --param-persist and --opt-on-ssd, which make the \
             store the single source of truth a rollback can restore from"
        );
    }
    let shape = manifest.config;
    let rt = Runtime::load(&manifest)?;
    let state = ModelState::init(manifest, cfg)?;
    let mut corpus = SyntheticCorpus::new(shape.vocab, state.cfg.seed);
    let workers = state.cfg.workers.max(1);
    let journal = state.cfg.journal;
    // worker_stall_s grows to the per-step ACTIVE worker count on first use
    let mut log = RunLog::default();

    let policy = kind.policy();
    fn build_driver<'a>(
        state: &'a ModelState,
        rt: &'a Runtime,
        workers: usize,
    ) -> Result<Driver<'a>> {
        Ok(if workers <= 1 {
            Driver::Single(StepEngine::new(state, rt)?)
        } else {
            Driver::Dist(DataParallelEngine::new(state, rt, workers)?)
        })
    }
    // epoch boundary: everything this step's replay must see is on the store
    let commit_boundary = |driver: &Driver<'_>| -> Result<()> {
        let opt = driver.opt();
        opt.quiesce();
        opt.persist_resume_state(&state)?;
        state.store.commit_epoch()
    };
    // Option so recovery can drop the wounded driver (joining its lane
    // threads and draining the optimizer pool) BEFORE rolling the store
    // back — no in-flight task may write behind the rollback.
    let mut driver: Option<Driver<'_>> = Some(build_driver(&state, &rt, workers)?);
    if journal {
        // epoch 0: the freshly seeded initial state is the first boundary
        commit_boundary(driver.as_ref().expect("driver"))?;
    }
    for s in 0..steps {
        let mut toks = Vec::with_capacity(m);
        let mut tgts = Vec::with_capacity(m);
        for _ in 0..m {
            let (a, b) = corpus.sample(shape.micro_batch, shape.seq_len)?;
            toks.push(a);
            tgts.push(b);
        }
        let t0 = std::time::Instant::now();
        let mut attempts = 0u32;
        let (stats, per_worker): (StepStats, Vec<f64>) = loop {
            let result: Result<(StepStats, Vec<f64>)> =
                match driver.as_mut().expect("driver present") {
                    Driver::Single(engine) => engine.step(policy.as_ref(), &toks, &tgts).map(|st| {
                        let stall = st.io_stall_s;
                        (st, vec![stall])
                    }),
                    Driver::Dist(engine) => engine
                        .step(policy.as_ref(), &toks, &tgts)
                        .map(|d| (d.stats, d.worker_stall_s)),
                };
            match result {
                Ok(r) => break r,
                Err(e) => {
                    if !journal || attempts >= MAX_RECOVERY_RETRIES {
                        return Err(e.context(format!("step {s} failed")));
                    }
                    attempts += 1;
                    log.recoveries += 1;
                    if log_every > 0 {
                        println!(
                            "step {s:>5}  recovering from mid-step failure \
                             (attempt {attempts}/{MAX_RECOVERY_RETRIES}): {e:#}"
                        );
                    }
                    // 1. Tear the wounded driver down completely: dropping it
                    //    joins the lane threads and drains the optimizer pool.
                    driver = None;
                    // 2. Roll the store back to the last committed epoch.
                    state.store.recover()?;
                    // 3. Rebuild (seed_ssd is contains-guarded, so the rolled
                    //    back state is not overwritten) and restore the host
                    //    half: step counter, clip/held/embed snapshot, and
                    //    the layer params from the persisted shards.
                    let mut d = build_driver(&state, &rt, workers)?;
                    d.set_steps_done(s);
                    d.opt().restore_resume_state(&state)?;
                    state.load_params_from_shards()?;
                    driver = Some(d);
                    // 4. Retry the SAME batch (the loss curve must replay).
                }
            }
        };
        if journal {
            commit_boundary(driver.as_ref().expect("driver"))?;
        }
        let dt = t0.elapsed().as_secs_f64();
        log.losses.push(stats.loss);
        log.grad_norms.push(stats.grad_norm);
        log.step_seconds.push(dt);
        log.ssd_read += stats.ssd_bytes_read;
        log.ssd_written += stats.ssd_bytes_written;
        log.param_bytes += stats.param_bytes_loaded;
        log.prefetch_hits += stats.prefetch_hits;
        log.prefetch_misses += stats.prefetch_misses;
        log.io_stall_s += stats.io_stall_s;
        log.allreduce_s += stats.allreduce_s;
        log.allreduce_bytes += stats.allreduce_bytes;
        log.allgather_bytes += stats.allgather_bytes;
        log.cache_hits += stats.cache_hits;
        log.cache_misses += stats.cache_misses;
        log.cache_evictions += stats.cache_evictions;
        for (i, v) in per_worker.iter().enumerate() {
            if log.worker_stall_s.len() <= i {
                log.worker_stall_s.push(0.0);
            }
            log.worker_stall_s[i] += v;
        }
        if log_every > 0 && (s % log_every == 0 || s + 1 == steps) {
            println!(
                "step {s:>5}  loss {:.4}  |g| {:.3}  {:.2}s/step  ssd r/w {}/{}",
                stats.loss,
                stats.grad_norm,
                dt,
                crate::util::stats::fmt_bytes(stats.ssd_bytes_read as f64),
                crate::util::stats::fmt_bytes(stats.ssd_bytes_written as f64),
            );
        }
    }
    match driver.as_mut().expect("driver present") {
        Driver::Single(engine) => engine.drain()?,
        Driver::Dist(engine) => engine.drain()?,
    }
    if state.cfg.param_persist {
        let opt = driver.as_ref().expect("driver").opt();
        log.param_shard_reads = opt.param_counters.read_by_rank();
        log.param_shard_writes = opt.param_counters.written_by_rank();
    }
    log.param_sq_norm = state.param_sq_norm();
    log.moment_sq_norm = state.moment_sq_norm()?;
    for (cat, c) in &state.store.cache_stats().by_cat {
        log.cache_by_cat.push((format!("{cat:?}"), [c.hits, c.misses, c.evictions]));
    }
    Ok(log)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tag: &str) -> TrainerConfig {
        TrainerConfig::for_test(tag)
    }

    #[test]
    fn corpus_is_learnable_structure() {
        let mut c = SyntheticCorpus::new(256, 0);
        let (toks, tgts) = c.sample(4, 64).unwrap();
        assert_eq!(toks.data.len(), 4 * 64);
        // targets are the shifted inputs
        assert_eq!(&toks.data[1..64], &tgts.data[..63]);
        // planted bigram: successor matches for most positions
        let succ = &c.successor;
        let mut hits = 0;
        for i in 0..63 {
            if tgts.data[i] as u32 == succ[toks.data[i] as usize] {
                hits += 1;
            }
        }
        assert!(hits > 30, "{hits}/63 bigram hits");
    }

    #[test]
    fn corpus_deterministic_per_seed() {
        let mut a = SyntheticCorpus::new(128, 7);
        let mut b = SyntheticCorpus::new(128, 7);
        assert_eq!(a.sample(2, 16).unwrap().0.data, b.sample(2, 16).unwrap().0.data);
    }

    #[test]
    fn vertical_training_reduces_loss_tiny() {
        let Some(manifest) = crate::runtime::test_artifacts("artifacts/tiny") else { return };
        let log = train(manifest, cfg("vred"), ScheduleKind::Vertical, 30, 2, 0).unwrap();
        let first = log.losses[0];
        let last = log.final_loss();
        assert!(
            last < first - 0.3,
            "loss must drop: {first:.3} -> {last:.3} ({:?})",
            &log.losses
        );
    }

    #[test]
    fn zero_step_training_yields_empty_log() {
        let Some(manifest) = crate::runtime::test_artifacts("artifacts/tiny") else { return };
        let log = train(manifest, cfg("zero"), ScheduleKind::Vertical, 0, 2, 0).unwrap();
        assert!(log.losses.is_empty());
        assert_eq!(log.tokens_per_s(1024), 0.0);
        assert_eq!(log.final_loss(), 0.0);
    }

    #[test]
    fn schedule_kind_parses() {
        assert_eq!("vertical".parse::<ScheduleKind>().unwrap(), ScheduleKind::Vertical);
        assert_eq!(
            "zero-infinity".parse::<ScheduleKind>().unwrap(),
            ScheduleKind::Horizontal
        );
        assert_eq!(
            "chunked:4".parse::<ScheduleKind>().unwrap(),
            ScheduleKind::ChunkedVertical(4)
        );
        assert_eq!(
            "cachesweep:4".parse::<ScheduleKind>().unwrap(),
            ScheduleKind::CacheSweep(4)
        );
        assert!("diagonal".parse::<ScheduleKind>().is_err());
        assert!("chunked:0".parse::<ScheduleKind>().is_err());
        assert!("chunked:x".parse::<ScheduleKind>().is_err());
        assert!("chunked:".parse::<ScheduleKind>().is_err());
        assert!("cachesweep:0".parse::<ScheduleKind>().is_err());
        assert!("cachesweep:x".parse::<ScheduleKind>().is_err());
    }

    #[test]
    fn schedule_kind_display_roundtrips() {
        for kind in [
            ScheduleKind::Vertical,
            ScheduleKind::Horizontal,
            ScheduleKind::ChunkedVertical(3),
            ScheduleKind::CacheSweep(3),
        ] {
            assert_eq!(kind.to_string().parse::<ScheduleKind>().unwrap(), kind);
            assert_eq!(kind.policy().name(), kind.to_string());
        }
    }

    /// Chunk-group boundary legs: `G = 1` is the smallest accepted group
    /// (0 is a parse error, pinned above) and degenerates the whole
    /// analytic family to horizontal per-micro-batch reloads; any
    /// `G > M` clamps to a single chunk — fully vertical traffic — for
    /// the training round trips and the serve forward forms alike.
    #[test]
    fn chunk_group_boundaries_match_named_schedules() {
        let m = 4u64;
        let w = Workload {
            model: crate::modelcfg::GPT_65B,
            micro_batch: 2,
            seq_len: crate::modelcfg::SEQ_LEN,
            m,
            shards: 1,
        };
        let one: ScheduleKind = "chunked:1".parse().unwrap();
        assert_eq!(one, ScheduleKind::ChunkedVertical(1));
        assert_eq!(
            one.traffic(&w).param_load,
            ScheduleKind::Horizontal.traffic(&w).param_load,
            "G=1 must reload like horizontal"
        );
        assert_eq!(w.serve_param_read_bytes(1), m * w.ms_lp());
        // every G > M (the boundary G = M+1 and far beyond) is accepted
        // and clamps to one vertical sweep
        for g in [m + 1, 10 * m, 1_000_000] {
            let big: ScheduleKind = format!("chunked:{g}").parse().unwrap();
            assert_eq!(big, ScheduleKind::ChunkedVertical(g as usize));
            assert_eq!(
                big.traffic(&w).param_load,
                ScheduleKind::Vertical.traffic(&w).param_load,
                "G={g} > M must load like vertical"
            );
            assert_eq!(w.serve_param_read_bytes(g), w.ms_lp());
            // the emitted order is legal and single-sweep at the boundary
            let order = big.policy().forward_order(3, m as usize);
            assert_eq!(crate::coordinator::schedule::param_loads(&order), 3);
        }
        // cachesweep shares the byte family at both boundaries
        assert_eq!(
            "cachesweep:1".parse::<ScheduleKind>().unwrap().traffic(&w).param_load,
            one.traffic(&w).param_load
        );
        assert_eq!(
            format!("cachesweep:{}", m + 1).parse::<ScheduleKind>().unwrap().traffic(&w).param_load,
            ScheduleKind::Vertical.traffic(&w).param_load
        );
    }

    /// A `--journal` run that loses a "worker" mid-run (injected fault at
    /// the delayed-dispatch site) replays the failed step from the last
    /// committed epoch boundary and ends bit-identical to an uninterrupted
    /// run: same loss curve, same Σx² digests.
    #[test]
    fn journal_recovery_replays_bit_identical() {
        let mk = |tag: &str| {
            let mut c = cfg(tag);
            c.opt_on_ssd = true;
            c.param_persist = true;
            c.journal = true;
            c
        };
        let Some(m1) = crate::runtime::test_artifacts("artifacts/tiny") else { return };
        let clean = train(m1, mk("jr-clean"), ScheduleKind::Vertical, 4, 2, 0).unwrap();
        assert_eq!(clean.recoveries, 0);

        let m2 = crate::runtime::test_artifacts("artifacts/tiny").unwrap();
        let c = mk("jr-fault");
        // dispatch_delayed runs once per step: hit 2 = the start of step 2.
        // The site is scoped to this config so parallel tests exercising
        // dispatch_delayed can neither consume the arm nor absorb the fault.
        crate::util::fault::arm(&crate::util::fault::scoped("opt:delayed", &c.fault_scope), 2);
        let faulted = train(m2, c, ScheduleKind::Vertical, 4, 2, 0).unwrap();
        assert_eq!(faulted.recoveries, 1, "the injected fault must trigger recovery");
        assert_eq!(clean.losses, faulted.losses, "replayed loss curve must be unchanged");
        assert_eq!(clean.grad_norms, faulted.grad_norms);
        assert_eq!(clean.param_sq_norm.to_bits(), faulted.param_sq_norm.to_bits());
        assert_eq!(clean.moment_sq_norm.to_bits(), faulted.moment_sq_norm.to_bits());
    }

    /// `--journal` without the store-of-truth flags must refuse to run, and
    /// `--param-persist` without SSD-resident moments likewise.
    #[test]
    fn journal_config_prerequisites_enforced() {
        let Some(manifest) = crate::runtime::test_artifacts("artifacts/tiny") else { return };
        let mut c = cfg("jr-bad");
        c.journal = true;
        let err = train(manifest, c, ScheduleKind::Vertical, 1, 1, 0).unwrap_err();
        assert!(err.to_string().contains("--journal"), "{err:#}");

        let Some(manifest) = crate::runtime::test_artifacts("artifacts/tiny") else { return };
        let mut c = cfg("pp-bad");
        c.param_persist = true;
        let err = train(manifest, c, ScheduleKind::Vertical, 1, 1, 0).unwrap_err();
        assert!(err.to_string().contains("--param-persist"), "{err:#}");
    }

    /// Regression: both metrics used to panic / return NaN on `steps == 0`.
    #[test]
    fn runlog_empty_run_is_zero_not_panic() {
        let log = RunLog::default();
        assert_eq!(log.tokens_per_s(4096), 0.0);
        assert_eq!(log.final_loss(), 0.0);
        // a one-step log with a zero-resolution timer must not be infinite
        let log = RunLog { losses: vec![1.0], step_seconds: vec![0.0], ..Default::default() };
        assert_eq!(log.tokens_per_s(4096), 0.0);
        assert_eq!(log.final_loss(), 1.0);
    }
}
