//! The PJRT client wrapper: compile-once executable cache + typed execute.

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::manifest::Manifest;

/// Stages the AOT pipeline emits (fixed set; see `aot.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    EmbedFwd,
    LayerFwd,
    LayerBwd,
    HeadLoss,
    EmbedBwd,
    AdamStep,
}

impl Stage {
    pub const ALL: [Stage; 6] = [
        Stage::EmbedFwd,
        Stage::LayerFwd,
        Stage::LayerBwd,
        Stage::HeadLoss,
        Stage::EmbedBwd,
        Stage::AdamStep,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::EmbedFwd => "embed_fwd",
            Stage::LayerFwd => "layer_fwd",
            Stage::LayerBwd => "layer_bwd",
            Stage::HeadLoss => "head_loss",
            Stage::EmbedBwd => "embed_bwd",
            Stage::AdamStep => "adam_step",
        }
    }
}

/// Whether a PJRT CPU client can actually be created in this build —
/// `false` under the vendored xla stub (see `rust/vendor/xla`), `true`
/// with the real `xla` crate and its native libraries. Probed once per
/// process (client construction is not free under real PJRT).
pub fn pjrt_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| xla::PjRtClient::cpu().is_ok())
}

/// PJRT CPU client + compiled executables for every stage.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<Stage, xla::PjRtLoadedExecutable>,
    /// Cumulative stage invocation counts (observability).
    calls: std::cell::RefCell<HashMap<Stage, u64>>,
}

impl Runtime {
    /// Compile all artifacts in `manifest` on the PJRT CPU client.
    pub fn load(manifest: &Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for stage in Stage::ALL {
            let path = manifest.artifact_path(stage.name())?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling stage {}", stage.name()))?;
            executables.insert(stage, exe);
        }
        Ok(Runtime { client, executables, calls: Default::default() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a stage. Inputs are positional per the manifest calling
    /// convention; the jax lowering uses `return_tuple=True`, so the single
    /// output literal is a tuple that we decompose for the caller.
    ///
    /// Accepts owned literals or references (`&[Literal]` / `&[&Literal]`)
    /// so hot paths can reuse uploaded parameter literals across
    /// micro-batches without deep-copying (§Perf).
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        stage: Stage,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(&stage)
            .with_context(|| format!("stage {stage:?} not loaded"))?;
        *self.calls.borrow_mut().entry(stage).or_insert(0) += 1;
        // Upload inputs to device buffers ourselves and use `execute_b`: the
        // C shim behind literal-taking `execute` leaks its internal
        // literal→buffer conversions (~1.5 GB/step at 100M scale, found via
        // RSS probing — EXPERIMENTS.md §Perf); buffers created here are
        // dropped (and freed) by their Rust Drop impls.
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|lit| self.client.buffer_from_host_literal(None, lit.borrow()))
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("uploading {} inputs", stage.name()))?;
        let result = exe
            .execute_b(&buffers)
            .with_context(|| format!("executing {}", stage.name()))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", stage.name()))?;
        Ok(tuple.decompose_tuple()?)
    }

    pub fn call_count(&self, stage: Stage) -> u64 {
        self.calls.borrow().get(&stage).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::{HostTensor, TokenTensor};
    use crate::util::prng::Prng;

    /// `None` (skip) when artifacts were never built or PJRT is stubbed.
    fn rt() -> Option<(Manifest, Runtime)> {
        let m = crate::runtime::test_artifacts("artifacts/tiny")?;
        let r = Runtime::load(&m).expect("compile artifacts");
        Some((m, r))
    }

    #[test]
    fn loads_and_reports_platform() {
        let Some((_, r)) = rt() else { return };
        assert!(r.platform().to_lowercase().contains("cpu") || !r.platform().is_empty());
    }

    #[test]
    fn embed_fwd_shapes() {
        let Some((m, r)) = rt() else { return };
        let c = m.config;
        let tokens =
            TokenTensor::new(&[c.micro_batch, c.seq_len], vec![1; c.micro_batch * c.seq_len])
                .unwrap();
        let wte = HostTensor::zeros(&[c.vocab, c.hidden]);
        let wpe = HostTensor::zeros(&[c.seq_len, c.hidden]);
        let out = r
            .execute(
                Stage::EmbedFwd,
                &[
                    tokens.to_literal().unwrap(),
                    wte.to_literal().unwrap(),
                    wpe.to_literal().unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let x = HostTensor::from_literal(&out[0]).unwrap();
        assert_eq!(x.shape, vec![c.micro_batch, c.seq_len, c.hidden]);
        assert_eq!(r.call_count(Stage::EmbedFwd), 1);
    }

    #[test]
    fn layer_fwd_then_bwd_roundtrip() {
        let Some((m, r)) = rt() else { return };
        let c = m.config;
        let mut rng = Prng::new(7);
        let x_shape = [c.micro_batch, c.seq_len, c.hidden];
        let mut x = HostTensor::zeros(&x_shape);
        rng.fill_normal(&mut x.data, 1.0);
        let params: Vec<HostTensor> = m
            .layer_params
            .iter()
            .map(|s| HostTensor::init(s, c.n_layers, &mut rng))
            .collect();

        let mut inputs = vec![x.to_literal().unwrap()];
        inputs.extend(params.iter().map(|p| p.to_literal().unwrap()));
        let out = r.execute(Stage::LayerFwd, &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let y = HostTensor::from_literal(&out[0]).unwrap();
        assert_eq!(y.shape, x_shape.to_vec());
        assert!(y.data.iter().all(|v| v.is_finite()));

        // backward: 1 dx + 12 dparams
        let mut dy = HostTensor::zeros(&x_shape);
        dy.data.fill(1.0);
        let mut binputs = vec![x.to_literal().unwrap(), dy.to_literal().unwrap()];
        binputs.extend(params.iter().map(|p| p.to_literal().unwrap()));
        let bout = r.execute(Stage::LayerBwd, &binputs).unwrap();
        assert_eq!(bout.len(), 13);
        let dx = HostTensor::from_literal(&bout[0]).unwrap();
        assert_eq!(dx.shape, x_shape.to_vec());
        for (lit, spec) in bout[1..].iter().zip(&m.layer_params) {
            let g = HostTensor::from_literal(lit).unwrap();
            assert_eq!(g.shape, spec.shape, "{}", spec.name);
        }
    }

    #[test]
    fn adam_step_matches_rust_reference() {
        let Some((m, r)) = rt() else { return };
        let n = m.config.adam_chunk;
        let mut rng = Prng::new(3);
        let mut p = vec![0.0f32; n];
        rng.fill_normal(&mut p, 1.0);
        let mut g = vec![0.0f32; n];
        rng.fill_normal(&mut g, 0.1);
        let mv = vec![0.0f32; n];
        let hyper: Vec<f32> =
            vec![1e-3, 0.9, 0.999, 1e-8, 0.0, 1.0 - 0.9, 1.0 - 0.999, 1.0];
        let mk = |v: &[f32]| xla::Literal::vec1(v);
        let out = r
            .execute(Stage::AdamStep, &[mk(&p), mk(&mv), mk(&mv), mk(&g), mk(&hyper)])
            .unwrap();
        assert_eq!(out.len(), 3);
        let p_new = out[0].to_vec::<f32>().unwrap();
        // rust reference for element 0
        let m_new = 0.1 * g[0];
        let v_new = 0.001 * g[0] * g[0];
        let m_hat = m_new / (1.0 - 0.9);
        let v_hat = v_new / (1.0 - 0.999);
        let want = p[0] - 1e-3 * (m_hat / (v_hat.sqrt() + 1e-8));
        assert!((p_new[0] - want).abs() < 1e-5, "{} vs {want}", p_new[0]);
    }
}
