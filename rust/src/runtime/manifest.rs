//! AOT manifest: shapes, calling convention, and initialization spec emitted
//! by `python/compile/aot.py` alongside the HLO artifacts.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Initialization class for a parameter tensor (mirrors `aot._init_kind`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    Zeros,
    Ones,
    /// N(0, 0.02²)
    Normal,
    /// N(0, (0.02/√(2L))²) — residual-path projections
    NormalResidual,
    /// N(0, 0.01²) — positional embeddings
    NormalPos,
}

/// One parameter tensor's spec.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    pub init: Init,
}

/// Static model configuration baked into the artifacts.
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    pub micro_batch: usize,
    pub seq_len: usize,
    pub hidden: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub n_layers: usize,
    pub ffn_mult: usize,
    pub adam_chunk: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub dir: PathBuf,
    pub config: ModelShape,
    pub layer_params: Vec<ParamSpec>,
    pub embed_params: Vec<ParamSpec>,
    pub head_params: Vec<ParamSpec>,
    pub artifacts: Vec<(String, String)>,
}

fn parse_init(s: &str) -> Init {
    match s {
        "zeros" => Init::Zeros,
        "ones" => Init::Ones,
        "normal" => Init::Normal,
        "normal_residual" => Init::NormalResidual,
        "normal_pos" => Init::NormalPos,
        other => panic!("unknown init kind '{other}'"),
    }
}

fn parse_params(v: &Json) -> Result<Vec<ParamSpec>> {
    v.as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                numel: p.get("numel")?.as_usize()?,
                init: parse_init(p.get("init")?.as_str()?),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first?)"))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let c = v.get("config")?;
        let config = ModelShape {
            micro_batch: c.get("micro_batch")?.as_usize()?,
            seq_len: c.get("seq_len")?.as_usize()?,
            hidden: c.get("hidden")?.as_usize()?,
            n_heads: c.get("n_heads")?.as_usize()?,
            vocab: c.get("vocab")?.as_usize()?,
            n_layers: c.get("n_layers")?.as_usize()?,
            ffn_mult: c.get("ffn_mult")?.as_usize()?,
            adam_chunk: c.get("adam_chunk")?.as_usize()?,
        };
        let artifacts = v
            .get("artifacts")?
            .as_obj()?
            .iter()
            .map(|(k, f)| Ok((k.clone(), f.as_str()?.to_string())))
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            preset: v.get("preset")?.as_str()?.to_string(),
            dir,
            config,
            layer_params: parse_params(v.get("layer_params")?)?,
            embed_params: parse_params(v.get("embed_params")?)?,
            head_params: parse_params(v.get("head_params")?)?,
            artifacts,
        })
    }

    /// Load `<dir>/manifest.json` if the AOT artifacts were built, else
    /// `None` with a skip notice. Tests that need artifacts gate on this so
    /// `cargo test -q` is meaningful on a fresh clone (artifacts come from
    /// `python/compile/aot.py`, which needs the JAX toolchain). A present
    /// but unparsable manifest still fails loudly — only absence skips.
    pub fn load_if_built<P: AsRef<Path>>(dir: P) -> Option<Manifest> {
        let dir = dir.as_ref();
        if !dir.join("manifest.json").exists() {
            eprintln!(
                "skipping: AOT artifacts not found at {dir:?} \
                 (run python/compile/aot.py / `make artifacts` to build them)"
            );
            return None;
        }
        Some(Manifest::load(dir).expect("artifacts present but manifest unloadable"))
    }

    /// Total elements in one layer's 12 parameter tensors.
    pub fn layer_numel(&self) -> usize {
        self.layer_params.iter().map(|p| p.numel).sum()
    }

    /// Total trainable elements in the whole model.
    pub fn total_numel(&self) -> usize {
        self.config.n_layers * self.layer_numel()
            + self.embed_params.iter().map(|p| p.numel).sum::<usize>()
            + self.head_params.iter().map(|p| p.numel).sum::<usize>()
    }

    /// Path of a stage's HLO file.
    pub fn artifact_path(&self, stage: &str) -> Result<PathBuf> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == stage)
            .map(|(_, f)| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("no artifact for stage '{stage}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests run from the crate root; `None` skips when artifacts are absent.
    fn tiny() -> Option<Manifest> {
        Manifest::load_if_built(PathBuf::from("artifacts/tiny"))
    }

    #[test]
    fn loads_tiny_manifest() {
        let Some(m) = tiny() else { return };
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.config.hidden, 64);
        assert_eq!(m.config.n_layers, 2);
        assert_eq!(m.layer_params.len(), 12);
        assert_eq!(m.artifacts.len(), 6);
    }

    #[test]
    fn layer_numel_closed_form() {
        let Some(m) = tiny() else { return };
        let d = m.config.hidden;
        let f = m.config.ffn_mult * d;
        let closed = 4 * d + 3 * d * d + 3 * d + d * d + d + d * f + f + f * d + d;
        assert_eq!(m.layer_numel(), closed);
    }

    #[test]
    fn artifact_paths_exist() {
        let Some(m) = tiny() else { return };
        for stage in ["embed_fwd", "layer_fwd", "layer_bwd", "head_loss", "embed_bwd",
                      "adam_step"] {
            let p = m.artifact_path(stage).unwrap();
            assert!(p.exists(), "{p:?}");
        }
        assert!(m.artifact_path("nope").is_err());
    }

    #[test]
    fn init_kinds_parsed() {
        let Some(m) = tiny() else { return };
        let by_name = |n: &str| m.layer_params.iter().find(|p| p.name == n).unwrap().init;
        assert_eq!(by_name("ln1_w"), Init::Ones);
        assert_eq!(by_name("b_qkv"), Init::Zeros);
        assert_eq!(by_name("w_o"), Init::NormalResidual);
    }
}
