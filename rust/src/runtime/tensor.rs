//! Host-side tensors: plain `Vec`-backed buffers with shapes, convertible to
//! and from PJRT literals. The trainer keeps all persistent state in these
//! (master/“GPU”/CPU copies alike — on the CPU PJRT substrate the device
//! memory *is* host memory; the [`crate::memory::Tier`] accounting supplies
//! the capacity semantics of the real hierarchy).

use anyhow::{ensure, Result};

use crate::util::prng::Prng;

use super::manifest::{Init, ParamSpec};

/// A dense fp32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} != len {}",
            shape,
            data.len()
        );
        Ok(HostTensor { shape: shape.to_vec(), data })
    }

    /// Initialize per the manifest spec (GPT-2 scheme; deterministic).
    pub fn init(spec: &ParamSpec, n_layers: usize, rng: &mut Prng) -> Self {
        let mut t = HostTensor::zeros(&spec.shape);
        match spec.init {
            Init::Zeros => {}
            Init::Ones => t.data.fill(1.0),
            Init::Normal => rng.fill_normal(&mut t.data, 0.02),
            Init::NormalResidual => {
                rng.fill_normal(&mut t.data, 0.02 / (2.0 * n_layers as f32).sqrt())
            }
            Init::NormalPos => rng.fill_normal(&mut t.data, 0.01),
        }
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Convert to a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read a literal back into a HostTensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        HostTensor::from_vec(&dims, data)
    }

    /// Accumulate `other` element-wise (gradient accumulation).
    pub fn add_assign(&mut self, other: &HostTensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Sum of squares (for gradient-norm computation).
    pub fn sq_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
}

/// An i32 token tensor.
#[derive(Clone, Debug)]
pub struct TokenTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TokenTensor {
    pub fn new(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        ensure!(shape.iter().product::<usize>() == data.len(), "token shape mismatch");
        Ok(TokenTensor { shape: shape.to_vec(), data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_from_vec() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        assert!(HostTensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
    }

    #[test]
    fn init_kinds() {
        let mut rng = Prng::new(1);
        let ones = HostTensor::init(
            &ParamSpec { name: "w".into(), shape: vec![4], numel: 4, init: Init::Ones },
            2,
            &mut rng,
        );
        assert_eq!(ones.data, vec![1.0; 4]);
        let nrm = HostTensor::init(
            &ParamSpec { name: "n".into(), shape: vec![1000], numel: 1000, init: Init::Normal },
            2,
            &mut rng,
        );
        let std = (nrm.sq_sum() / 1000.0).sqrt();
        assert!((std - 0.02).abs() < 0.005, "{std}");
    }

    #[test]
    fn add_assign_and_sq_sum() {
        let mut a = HostTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::from_vec(&[3], vec![0.5, 0.5, 0.5]).unwrap();
        a.add_assign(&b);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5]);
        assert!((a.sq_sum() - (2.25 + 6.25 + 12.25)).abs() < 1e-9);
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}
