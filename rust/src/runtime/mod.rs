//! PJRT runtime: load the AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the only place the `xla` crate is touched. The interchange format
//! is HLO *text* (see `python/compile/aot.py`); every stage compiles once at
//! startup into a cached `PjRtLoadedExecutable` and is then invoked from the
//! training hot path with zero Python involvement.
//!
//! PJRT handles are not `Send` (raw C pointers), so all runtime calls happen
//! on the coordinator thread — matching the single-GPU-stream execution
//! model; SSD I/O and the CPU optimizer overlap on [`crate::exec`] lanes.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{pjrt_available, Runtime, Stage};
pub use manifest::{Manifest, ParamSpec};
pub use tensor::HostTensor;

/// Test gate for everything that executes stages: `Some(manifest)` only
/// when the AOT artifacts at `dir` exist AND a PJRT client can be created
/// (i.e. not the vendored xla stub). Prints a skip notice otherwise, so
/// `cargo test -q` stays green and honest on a fresh clone.
pub fn test_artifacts(dir: &str) -> Option<Manifest> {
    let m = Manifest::load_if_built(dir)?;
    if !pjrt_available() {
        eprintln!("skipping: PJRT unavailable (vendored xla stub build)");
        return None;
    }
    Some(m)
}
