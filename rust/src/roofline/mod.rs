//! The §3.1 roofline model of SSD-offloaded training.
//!
//! Two bounds on training throughput (tokens/s) as a function of global
//! batch size:
//!
//! * **I/O access roofline** — a line through the origin: every iteration
//!   must round-trip the optimizer states through the SSD once, so
//!   `throughput ≤ batch_tokens / t_io(optimizer states)`.
//! * **Compute roofline** — a horizontal line: `throughput ≤
//!   aggregate_flops / flops_per_token`.
//!
//! An ideal system rides the I/O line and then saturates at the compute
//! line; the paper's Figure 3.

use crate::machine::NodeSpec;
use crate::modelcfg::{ModelCfg, BYTES_FP};

/// Roofline evaluator for one (model, node, micro-batch, seq-len) setting.
#[derive(Clone, Copy, Debug)]
pub struct Roofline {
    pub node: NodeSpec,
    pub model: ModelCfg,
    pub micro_batch: u64,
    pub seq_len: u64,
}

impl Roofline {
    /// Optimizer-state SSD round-trip time per iteration (whole model,
    /// master+momentum+variance in FP32), assuming 100 % of optimizer
    /// states live on SSD — the fundamental bound of §3.1.
    pub fn t_io_opt_states(&self) -> f64 {
        let bytes = (self.model.n_layers * self.model.layer_opt_state_bytes()) as f64
            + (self.model.vocab * self.model.hidden * 3 * BYTES_FP) as f64;
        // Reads and writes stream on independent NVMe channels; the slower
        // one bounds the iteration.
        (bytes / self.node.ssd_read_bw()).max(bytes / self.node.ssd_write_bw())
    }

    /// FLOPs per trained token (fwd + recompute + bwd over all layers).
    pub fn flops_per_token(&self) -> f64 {
        let per_iter = self.model.iter_flops(self.micro_batch, self.seq_len, 1);
        per_iter / (self.micro_batch * self.seq_len) as f64
    }

    /// I/O roofline: max tokens/s at `m` micro-batches per GPU.
    pub fn io_bound_tokens_per_s(&self, m: u64) -> f64 {
        let tokens = (self.node.n_gpus * m * self.micro_batch * self.seq_len) as f64;
        tokens / self.t_io_opt_states()
    }

    /// Compute roofline: max tokens/s regardless of batch.
    pub fn compute_bound_tokens_per_s(&self) -> f64 {
        self.node.total_flops() / self.flops_per_token()
    }

    /// min(IO line, compute line) — the ideal envelope of Figure 3.
    pub fn ideal_tokens_per_s(&self, m: u64) -> f64 {
        self.io_bound_tokens_per_s(m).min(self.compute_bound_tokens_per_s())
    }

    /// Micro-batch count where the two rooflines cross (the ideal knee).
    pub fn knee_m(&self) -> f64 {
        let per_m = (self.micro_batch * self.seq_len * self.node.n_gpus) as f64
            / self.t_io_opt_states();
        self.compute_bound_tokens_per_s() / per_m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MACHINE2_A100;
    use crate::modelcfg::{GPT_175B, GPT_65B, SEQ_LEN};

    fn rl() -> Roofline {
        Roofline {
            node: MACHINE2_A100.with_gpus(1),
            model: GPT_65B,
            micro_batch: 2,
            seq_len: SEQ_LEN,
        }
    }

    #[test]
    fn io_line_through_origin_and_linear() {
        let r = rl();
        let t1 = r.io_bound_tokens_per_s(1);
        let t4 = r.io_bound_tokens_per_s(4);
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn compute_line_flat() {
        let r = rl();
        assert!(r.compute_bound_tokens_per_s() > 0.0);
    }

    #[test]
    fn envelope_is_min() {
        let r = rl();
        let knee = r.knee_m();
        assert!(knee > 1.0, "knee {knee} must exceed one micro-batch");
        let below = r.ideal_tokens_per_s((knee * 0.5) as u64 + 1);
        let above = r.ideal_tokens_per_s((knee * 4.0) as u64 + 1);
        assert!(below < r.compute_bound_tokens_per_s());
        assert!((above - r.compute_bound_tokens_per_s()).abs() < 1e-6);
    }

    #[test]
    fn bigger_model_needs_more_io_time() {
        let small = rl();
        let big = Roofline { model: GPT_175B, ..small };
        assert!(big.t_io_opt_states() > small.t_io_opt_states());
    }

    #[test]
    fn io_time_is_minutes_scale_for_65b() {
        // 65B × 12 B/param ≈ 0.78 TB; at ~3 GB/s each way this is hundreds
        // of seconds — the motivation for the whole paper.
        let t = rl().t_io_opt_states();
        assert!(t > 100.0 && t < 2000.0, "{t}");
    }
}
