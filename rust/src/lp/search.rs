//! Algorithm 1 — the LP-based configuration search.
//!
//! `solve_config(ℳ, n, α)` builds the small LP over storage ratios
//! x = (ckpt, param, opt) ∈ [0,1]³ (gradients pinned to CPU) minimizing the
//! effective per-layer `t_f + t_b` with an SSD-traffic regularizer, subject
//! to the CPU-memory capacity constraint and the §4.4 gradient-reuse
//! constraint. `find_optimal_config(ℳ)` wraps it in the paper's outer loop:
//! increase the micro-batch count n (argmax over the delay-ratio grid
//! A = {0.01 … 0.50} at each n) until throughput stops improving by ≥ 1 %.
//!
//! `solve_config_cached` is the cache-*aware* variant: when a DRAM cache
//! (`--cpu-cache-mb`) or the planned store's DRAM path covers the
//! placement-implied SSD working set, the SSD channels stop bounding the
//! per-layer times and the placement re-optimizes under the fit-or-nothing
//! absorption law (closing PR 5's stale-ratio note).

use crate::perfmodel::{StorageRatios, SystemParams};

use super::simplex::{LinProg, LpOutcome};

/// Regularizer weight on SSD traffic seconds (small: tie-break only).
const SSD_REG: f64 = 1e-3;

/// Result of one LP solve / of the full search.
#[derive(Clone, Copy, Debug)]
pub struct ConfigResult {
    pub m: u64,
    pub alpha: f64,
    pub ratios: StorageRatios,
    /// Effective per-layer forward / backward times, seconds.
    pub t_f: f64,
    pub t_b: f64,
    /// Whole-iteration time (layers + embed/head overhead), seconds.
    pub t_iter: f64,
    /// Node tokens/s.
    pub tokens_per_s: f64,
}

/// Solve the inner LP for fixed (n, α). Returns `None` when infeasible
/// (configuration cannot fit CPU memory).
pub fn solve_config(sp: &SystemParams, m: u64, alpha: f64) -> Option<ConfigResult> {
    let mf = m as f64;
    let n_layers = sp.model.n_layers as f64;
    let (p, g, o, c) = (sp.p_lp(), sp.g_fp(), sp.o_bytes(), sp.c_bytes());
    let (r, w) = (ssd_r(sp), ssd_w(sp));

    // Lower bounds on tf/tb that do not depend on x.
    let compute_f = mf * sp.t_fwd_mb();
    let pcie_f = (p + (mf - 1.0) * c).max(mf * c) / pcie(sp);
    let cpu_f = alpha * sp.t_adam_layer();
    let cf = compute_f.max(pcie_f).max(cpu_f);

    let compute_b = mf * sp.t_bwd_mb();
    let pcie_b = (p + (2.0 * mf - 1.0) * c).max((mf - 1.0) * c + g) / pcie(sp);
    let cpu_b = (1.0 - alpha) * sp.t_adam_layer();
    let cb = compute_b.max(pcie_b).max(cpu_b);

    // SSD channel times as a0 + ac·xc + ap·xp + ao·xo (a_i ≤ 0 for i>0);
    // reads and writes are independent channels, so each stage gets TWO
    // lower-bound constraints (the LP realizes the max).
    // Forward reads: (1-xp)p + α(1-xo)o.
    let r0_f = p / r + alpha * o / r;
    let rp_f = -p / r;
    let ro_f = -alpha * o / r;
    // Forward writes: α(1-xo)o + α(1-xp)p + (1-xc)·m·c.
    let w0_f = alpha * o / w + alpha * p / w + mf * c / w;
    let wc_f = -mf * c / w;
    let wp_f = -alpha * p / w;
    let wo_f = -alpha * o / w;
    // Backward reads: (1-xc)mc + (1-xp)p + (1-α)(1-xo)o.
    let r0_b = mf * c / r + p / r + (1.0 - alpha) * o / r;
    let rc_b = -mf * c / r;
    let rp_b = -p / r;
    let ro_b = -(1.0 - alpha) * o / r;
    // Backward writes: (1-α)(1-xo)o + (1-α)(1-xp)p.
    let w0_b = (1.0 - alpha) * (o / w + p / w);
    let wp_b = -(1.0 - alpha) * p / w;
    let wo_b = -(1.0 - alpha) * o / w;
    // Regularizer coefficients: total SSD seconds saved per unit of x.
    let ac_reg = wc_f + rc_b;
    let ap_reg = rp_f + wp_f + rp_b + wp_b;
    let ao_reg = ro_f + wo_f + ro_b + wo_b;

    // CPU memory available for the three placed categories. Only ~3 layers'
    // gradient buffers are live at once under vertical scheduling (the
    // pipelined optimizer consumes them, Fig. 7); the α-delayed share reuses
    // reclaimed memory and is bounded by the §4.4 constraint below.
    let dram_avail = sp.dram_share() * 0.96 - 3.0 * g - 6.0 * p - 4.0 * mf * c;
    if dram_avail < 0.0 {
        return None; // working set alone does not fit
    }

    // Variables: [xc, xp, xo, tf, tb]
    let mut lp = LinProg::new(5);
    // min tf + tb + ε(ssd traffic seconds)  ⇔  max -(…)
    lp.maximize(&[-SSD_REG * ac_reg, -SSD_REG * ap_reg, -SSD_REG * ao_reg, -1.0, -1.0]);
    // box constraints
    lp.leq(&[1.0, 0.0, 0.0, 0.0, 0.0], 1.0);
    lp.leq(&[0.0, 1.0, 0.0, 0.0, 0.0], 1.0);
    lp.leq(&[0.0, 0.0, 1.0, 0.0, 0.0], 1.0);
    // tf ≥ cf ; tb ≥ cb
    lp.geq(&[0.0, 0.0, 0.0, 1.0, 0.0], cf);
    lp.geq(&[0.0, 0.0, 0.0, 0.0, 1.0], cb);
    // tf ≥ read_f(x), tf ≥ write_f(x); likewise for tb (duplex channels).
    lp.geq(&[0.0, -rp_f, -ro_f, 1.0, 0.0], r0_f);
    lp.geq(&[-wc_f, -wp_f, -wo_f, 1.0, 0.0], w0_f);
    lp.geq(&[-rc_b, -rp_b, -ro_b, 0.0, 1.0], r0_b);
    lp.geq(&[0.0, -wp_b, -wo_b, 0.0, 1.0], w0_b);
    // memory: xc·(N m c) + xp·(N p) + xo·(N o) ≤ dram_avail
    lp.leq(&[n_layers * mf * c, n_layers * p, n_layers * o, 0.0, 0.0], dram_avail);
    // §4.4 gradient reuse: α·g ≤ xp·p + xc·m·c  (per layer)
    lp.geq(&[mf * c, p, 0.0, 0.0, 0.0], alpha * g);

    match lp.solve() {
        LpOutcome::Optimal(x, _) => {
            let ratios = StorageRatios {
                ckpt_cpu: x[0].clamp(0.0, 1.0),
                param_cpu: x[1].clamp(0.0, 1.0),
                opt_cpu: x[2].clamp(0.0, 1.0),
            };
            let (t_f, t_b) = (x[3], x[4]);
            let t_iter = n_layers * (t_f + t_b) + 1.5 * (t_f + t_b);
            let tokens =
                (sp.node.n_gpus * m * sp.micro_batch * sp.seq_len) as f64;
            Some(ConfigResult {
                m,
                alpha,
                ratios,
                t_f,
                t_b,
                t_iter,
                tokens_per_s: tokens / t_iter,
            })
        }
        _ => None,
    }
}

/// Placement-implied SSD working set, bytes: what the store tier holds
/// under ratios `x` — the quantity a DRAM cache must cover to absorb the
/// steady-state SSD traffic (the runtime twin is
/// `traffic::Workload::ssd_working_set_bytes`).
pub fn ssd_working_set(sp: &SystemParams, m: u64, x: StorageRatios) -> f64 {
    let n = sp.model.n_layers as f64;
    let mf = m as f64;
    n * ((1.0 - x.param_cpu) * sp.p_lp()
        + (1.0 - x.opt_cpu) * sp.o_bytes()
        + (1.0 - x.ckpt_cpu) * mf * sp.c_bytes())
}

/// Cache-aware variant of [`solve_config`] — the PR 5 stale-ratio fix.
///
/// [`solve_config`] prices SSD channel time as if every SSD-placed byte
/// paid the SSD rate, even when a DRAM cache (`--cpu-cache-mb`, or the
/// planned store's DRAM path) absorbs the whole working set. This solve
/// applies the fit-or-nothing absorption law as a two-pass fixed point:
///
/// 1. solve uncached and measure the placement-implied working set;
/// 2. if `cache_bytes` covers it, re-solve with the SSD channel rows
///    removed (per-layer times fall to the compute/PCIe/CPU floors, the
///    traffic regularizer alone steers x toward maximal absorbed
///    placement) and keep that solution only if its shifted working set
///    still fits the cache.
///
/// With `cache_bytes == 0` this IS [`solve_config`] exactly.
pub fn solve_config_cached(
    sp: &SystemParams,
    m: u64,
    alpha: f64,
    cache_bytes: u64,
) -> Option<ConfigResult> {
    let uncached = solve_config(sp, m, alpha)?;
    if cache_bytes == 0 {
        return Some(uncached);
    }
    let cache = cache_bytes as f64;
    if ssd_working_set(sp, m, uncached.ratios) > cache {
        return Some(uncached); // absorption is fit-or-nothing
    }
    let absorbed = solve_config_absorbed(sp, m, alpha)?;
    if ssd_working_set(sp, m, absorbed.ratios) <= cache {
        Some(absorbed)
    } else {
        Some(uncached)
    }
}

/// The inner LP with the SSD channel rows removed: per-layer times are
/// bounded only by the compute/PCIe/CPU floors, and the (ε-weighted) SSD
/// traffic regularizer is the only pressure on x — the solve maximizes
/// the absorbed placement within the memory budget.
fn solve_config_absorbed(sp: &SystemParams, m: u64, alpha: f64) -> Option<ConfigResult> {
    let mf = m as f64;
    let n_layers = sp.model.n_layers as f64;
    let (p, g, o, c) = (sp.p_lp(), sp.g_fp(), sp.o_bytes(), sp.c_bytes());
    let (r, w) = (ssd_r(sp), ssd_w(sp));

    let compute_f = mf * sp.t_fwd_mb();
    let pcie_f = (p + (mf - 1.0) * c).max(mf * c) / pcie(sp);
    let cpu_f = alpha * sp.t_adam_layer();
    let cf = compute_f.max(pcie_f).max(cpu_f);

    let compute_b = mf * sp.t_bwd_mb();
    let pcie_b = (p + (2.0 * mf - 1.0) * c).max((mf - 1.0) * c + g) / pcie(sp);
    let cpu_b = (1.0 - alpha) * sp.t_adam_layer();
    let cb = compute_b.max(pcie_b).max(cpu_b);

    // same regularizer coefficients as solve_config (traffic seconds per
    // unit of x) — with the channel rows gone they are the whole objective
    // on x
    let rp_f = -p / r;
    let ro_f = -alpha * o / r;
    let wc_f = -mf * c / w;
    let wp_f = -alpha * p / w;
    let wo_f = -alpha * o / w;
    let rc_b = -mf * c / r;
    let rp_b = -p / r;
    let ro_b = -(1.0 - alpha) * o / r;
    let wp_b = -(1.0 - alpha) * p / w;
    let wo_b = -(1.0 - alpha) * o / w;
    let ac_reg = wc_f + rc_b;
    let ap_reg = rp_f + wp_f + rp_b + wp_b;
    let ao_reg = ro_f + wo_f + ro_b + wo_b;

    let dram_avail = sp.dram_share() * 0.96 - 3.0 * g - 6.0 * p - 4.0 * mf * c;
    if dram_avail < 0.0 {
        return None;
    }

    let mut lp = LinProg::new(5);
    lp.maximize(&[-SSD_REG * ac_reg, -SSD_REG * ap_reg, -SSD_REG * ao_reg, -1.0, -1.0]);
    lp.leq(&[1.0, 0.0, 0.0, 0.0, 0.0], 1.0);
    lp.leq(&[0.0, 1.0, 0.0, 0.0, 0.0], 1.0);
    lp.leq(&[0.0, 0.0, 1.0, 0.0, 0.0], 1.0);
    lp.geq(&[0.0, 0.0, 0.0, 1.0, 0.0], cf);
    lp.geq(&[0.0, 0.0, 0.0, 0.0, 1.0], cb);
    lp.leq(&[n_layers * mf * c, n_layers * p, n_layers * o, 0.0, 0.0], dram_avail);
    lp.geq(&[mf * c, p, 0.0, 0.0, 0.0], alpha * g);

    match lp.solve() {
        LpOutcome::Optimal(x, _) => {
            let ratios = StorageRatios {
                ckpt_cpu: x[0].clamp(0.0, 1.0),
                param_cpu: x[1].clamp(0.0, 1.0),
                opt_cpu: x[2].clamp(0.0, 1.0),
            };
            let (t_f, t_b) = (x[3], x[4]);
            let t_iter = n_layers * (t_f + t_b) + 1.5 * (t_f + t_b);
            let tokens = (sp.node.n_gpus * m * sp.micro_batch * sp.seq_len) as f64;
            Some(ConfigResult {
                m,
                alpha,
                ratios,
                t_f,
                t_b,
                t_iter,
                tokens_per_s: tokens / t_iter,
            })
        }
        _ => None,
    }
}

/// The α grid Algorithm 1 searches (0.01 … 0.50 in steps of 0.01) — shared
/// with the [`crate::autotune`] refinement so both searches quantize the
/// delay ratio identically.
pub fn alpha_grid() -> Vec<f64> {
    (1..=50).map(|i| i as f64 / 100.0).collect()
}

/// The outer search of Algorithm 1.
pub fn find_optimal_config(sp: &SystemParams) -> Option<ConfigResult> {
    let alphas: Vec<f64> = alpha_grid();
    let mut best_overall: Option<ConfigResult> = None;
    let mut max_throughput = 0.0_f64;
    let mut m = 0u64;
    loop {
        m += 1;
        // α* = argmax_α throughput(n, α)
        let mut best_at_m: Option<ConfigResult> = None;
        for &a in &alphas {
            if let Some(res) = solve_config(sp, m, a) {
                if best_at_m.is_none_or(|b| res.tokens_per_s > b.tokens_per_s) {
                    best_at_m = Some(res);
                }
            }
        }
        let Some(res) = best_at_m else {
            if m > 512 {
                return best_overall;
            }
            continue;
        };
        if res.tokens_per_s >= 1.01 * max_throughput {
            max_throughput = res.tokens_per_s;
            best_overall = Some(res);
        } else {
            return best_overall;
        }
        if m > 1024 {
            return best_overall; // safety net
        }
    }
}

fn ssd_r(sp: &SystemParams) -> f64 {
    sp.node.ssd_read_bw() / sp.node.n_gpus as f64
}

fn ssd_w(sp: &SystemParams) -> f64 {
    sp.node.ssd_write_bw() / sp.node.n_gpus as f64
}

fn pcie(sp: &SystemParams) -> f64 {
    sp.node.pcie_bw_per_gpu()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MACHINE2_A100;
    use crate::modelcfg::{GPT_175B, GPT_65B, SEQ_LEN};
    use crate::perfmodel::SystemParams;

    fn sp() -> SystemParams {
        SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_65B, 2, SEQ_LEN)
    }

    #[test]
    fn lp_solution_matches_perfmodel_times() {
        // The LP's (tf, tb) must equal the perfmodel's evaluation of the
        // same (m, α, x) — the LP *is* the linearized perfmodel.
        let sp = sp();
        let res = solve_config(&sp, 8, 0.25).expect("feasible");
        let ((tf, _), (tb, _)) = sp.vertical_layer_times(8, 0.25, res.ratios);
        assert!((tf - res.t_f).abs() / tf < 1e-6, "{tf} vs {}", res.t_f);
        assert!((tb - res.t_b).abs() / tb < 1e-6, "{tb} vs {}", res.t_b);
    }

    #[test]
    fn lp_respects_memory_constraint() {
        let sp = sp();
        let res = solve_config(&sp, 8, 0.25).expect("feasible");
        let used = sp.cpu_bytes_vertical(8, res.ratios);
        assert!(used <= sp.dram_share() * 1.001, "{used} > {}", sp.dram_share());
    }

    #[test]
    fn lp_spends_the_memory_budget() {
        // The regularizer should leave no large idle DRAM while SSD traffic
        // remains: the chosen placement uses most of the capacity.
        let sp = sp();
        let res = solve_config(&sp, 4, 0.1).expect("feasible");
        let used = sp.cpu_bytes_vertical(4, res.ratios);
        assert!(used > 0.8 * sp.dram_share(), "{used} of {}", sp.dram_share());
        // and something was placed in CPU at all
        let x = res.ratios;
        assert!(x.ckpt_cpu + x.param_cpu + x.opt_cpu > 0.5, "{x:?}");
    }

    #[test]
    fn search_terminates_and_saturates() {
        let sp = sp();
        let best = find_optimal_config(&sp).expect("some config");
        assert!(best.m >= 4, "m={}", best.m);
        assert!(best.m <= 512);
        assert!(best.alpha >= 0.01 && best.alpha <= 0.50);
        // saturated throughput must beat m=1 substantially
        let m1 = solve_config(&sp, 1, 0.01).unwrap();
        assert!(best.tokens_per_s > 2.0 * m1.tokens_per_s);
    }

    #[test]
    fn gpt175b_on_one_a100_is_feasible() {
        // The pipelined gradient lifetime is what lets GreedySnake train
        // GPT-175B on a single 400 GB node (Fig. 10 rightmost panel): only
        // ~3 layers of fp32 gradients are ever live, not all 96.
        let sp = SystemParams::new(MACHINE2_A100.with_gpus(1), GPT_175B, 2, SEQ_LEN);
        let res = solve_config(&sp, 4, 0.2).expect("175B/1GPU must be feasible");
        // capacity forces most optimizer state onto SSD
        assert!(res.ratios.opt_cpu < 0.6, "{:?}", res.ratios);
    }

    #[test]
    fn cache_aware_solve_is_identity_at_zero_cache() {
        let sp = sp();
        let a = solve_config(&sp, 8, 0.25).expect("feasible");
        let b = solve_config_cached(&sp, 8, 0.25, 0).expect("feasible");
        assert_eq!(a.t_iter.to_bits(), b.t_iter.to_bits());
        assert_eq!(a.ratios.ckpt_cpu.to_bits(), b.ratios.ckpt_cpu.to_bits());
        assert_eq!(a.ratios.param_cpu.to_bits(), b.ratios.param_cpu.to_bits());
        assert_eq!(a.ratios.opt_cpu.to_bits(), b.ratios.opt_cpu.to_bits());
    }

    /// PR 5 regression: the uncached LP prices SSD channel time even when
    /// the DRAM cache absorbs the whole working set. A covering cache must
    /// shift the solution; a non-covering one must change nothing
    /// (fit-or-nothing).
    #[test]
    fn cache_aware_lp_shifts_when_cache_covers_working_set() {
        let sp = sp();
        let un = solve_config(&sp, 4, 0.25).expect("feasible");
        let ws = ssd_working_set(&sp, 4, un.ratios);
        assert!(ws > 0.0, "uncached placement must leave something on SSD");
        // below the working set: identical to the uncached solve
        let small = solve_config_cached(&sp, 4, 0.25, (ws * 0.5) as u64).unwrap();
        assert_eq!(small.t_iter.to_bits(), un.t_iter.to_bits());
        assert_eq!(small.ratios.opt_cpu.to_bits(), un.ratios.opt_cpu.to_bits());
        // covering the working set: the SSD bound vanishes, iteration time
        // falls to the compute/PCIe floor and the placement stays absorbable
        let cache = (ws * 4.0) as u64;
        let big = solve_config_cached(&sp, 4, 0.25, cache).unwrap();
        assert!(big.t_iter < un.t_iter, "{} !< {}", big.t_iter, un.t_iter);
        assert!(
            ssd_working_set(&sp, 4, big.ratios) <= cache as f64,
            "shifted placement must stay absorbable"
        );
    }

    #[test]
    fn delayed_alpha_chosen_nonzero_in_io_bound_regime() {
        // At small m the system is I/O bound; the argmax over α should pick
        // a clearly positive delay.
        let sp = sp();
        let mut best: Option<ConfigResult> = None;
        for i in 1..=50 {
            let a = i as f64 / 100.0;
            if let Some(r) = solve_config(&sp, 4, a) {
                if best.is_none_or(|b| r.tokens_per_s > b.tokens_per_s) {
                    best = Some(r);
                }
            }
        }
        let best = best.unwrap();
        assert!(best.alpha >= 0.10, "α = {}", best.alpha);
    }
}
