//! Dense two-phase primal simplex with Bland's rule.
//!
//! Solves  max cᵀx  s.t.  Ax ≤ b,  x ≥ 0  — `b` may be negative (phase 1
//! drives artificial variables out of the basis first). Problems in this
//! crate are tiny (≤ ~10 variables / ~20 constraints from Algorithm 1), so a
//! dense tableau with Bland's anti-cycling rule is both simple and exact
//! enough (f64 with an epsilon band).

const EPS: f64 = 1e-9;

/// Problem description under construction.
#[derive(Clone, Debug, Default)]
pub struct LinProg {
    /// Objective coefficients (maximization).
    c: Vec<f64>,
    /// Constraint rows (a, b): aᵀx ≤ b.
    rows: Vec<(Vec<f64>, f64)>,
}

/// Solver outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum LpOutcome {
    /// Optimal solution (x, objective value).
    Optimal(Vec<f64>, f64),
    Infeasible,
    Unbounded,
}

impl LinProg {
    pub fn new(n_vars: usize) -> Self {
        LinProg { c: vec![0.0; n_vars], rows: Vec::new() }
    }

    pub fn n_vars(&self) -> usize {
        self.c.len()
    }

    /// Set the maximization objective.
    pub fn maximize(&mut self, c: &[f64]) -> &mut Self {
        assert_eq!(c.len(), self.c.len());
        self.c = c.to_vec();
        self
    }

    /// Add aᵀx ≤ b.
    pub fn leq(&mut self, a: &[f64], b: f64) -> &mut Self {
        assert_eq!(a.len(), self.c.len());
        self.rows.push((a.to_vec(), b));
        self
    }

    /// Add aᵀx ≥ b (stored as -aᵀx ≤ -b).
    pub fn geq(&mut self, a: &[f64], b: f64) -> &mut Self {
        let neg: Vec<f64> = a.iter().map(|x| -x).collect();
        self.rows.push((neg, -b));
        self
    }

    /// Add aᵀx = b (as a pair of inequalities).
    pub fn eq(&mut self, a: &[f64], b: f64) -> &mut Self {
        self.leq(a, b);
        self.geq(a, b)
    }

    /// Solve with two-phase simplex.
    pub fn solve(&self) -> LpOutcome {
        // Row-normalize so EPS comparisons are scale-free: divide each
        // constraint by its largest |coefficient| (callers pass raw byte
        // counts with magnitudes up to ~1e12).
        let mut scaled = self.clone();
        for (a, b) in &mut scaled.rows {
            let scale = a.iter().fold(b.abs(), |acc, x| acc.max(x.abs()));
            if scale > 1.0 {
                for x in a.iter_mut() {
                    *x /= scale;
                }
                *b /= scale;
            }
        }
        scaled.solve_scaled()
    }

    fn solve_scaled(&self) -> LpOutcome {
        let n = self.c.len();
        let m = self.rows.len();
        // Tableau layout: columns [x (n)][slack (m)][artificial (≤m)][rhs]
        // Build rows with positive RHS by multiplying through by -1 where
        // needed; negative-RHS rows get artificial variables.
        let mut need_art: Vec<bool> = Vec::with_capacity(m);
        for (_, b) in &self.rows {
            need_art.push(*b < -EPS);
        }
        let n_art = need_art.iter().filter(|&&x| x).count();
        let cols = n + m + n_art + 1;
        let mut t = vec![vec![0.0; cols]; m];
        let mut basis = vec![0usize; m];
        let mut art_idx = 0;
        for (i, (a, b)) in self.rows.iter().enumerate() {
            let sign = if need_art[i] { -1.0 } else { 1.0 };
            for j in 0..n {
                t[i][j] = sign * a[j];
            }
            t[i][n + i] = sign; // slack
            t[i][cols - 1] = sign * b;
            if need_art[i] {
                let col = n + m + art_idx;
                t[i][col] = 1.0;
                basis[i] = col;
                art_idx += 1;
            } else {
                basis[i] = n + i;
            }
        }

        // --- Phase 1: minimize sum of artificials (maximize -sum) ---
        if n_art > 0 {
            // Phase-1 objective: maximize -(Σ artificials). With artificials
            // basic, the reduced-cost row is obj[j] = z_j - c_j =
            // -Σ_{art rows} t[i][j] (the -c_j = +1 on artificial columns is
            // irrelevant: allowed_cols bars them from re-entering). The RHS
            // cell then holds -(Σ artificial values) = -w.
            let mut obj = vec![0.0; cols];
            for i in 0..m {
                if basis[i] >= n + m {
                    for j in 0..cols {
                        obj[j] -= t[i][j];
                    }
                }
            }
            if !Self::pivot_loop(&mut t, &mut basis, &mut obj, n + m) {
                return LpOutcome::Unbounded; // cannot happen in phase 1
            }
            if obj[cols - 1] < -EPS {
                return LpOutcome::Infeasible;
            }
            // Drive any remaining artificial out of the basis (degenerate).
            for i in 0..m {
                if basis[i] >= n + m {
                    if let Some(j) = (0..n + m).find(|&j| t[i][j].abs() > EPS) {
                        Self::pivot(&mut t, &mut basis, i, j, &mut obj);
                    }
                    // else: all-zero row, redundant constraint; fine.
                }
            }
        }

        // --- Phase 2: original objective ---
        // Build reduced-cost row: z_j - c_j form. Start from -c and add back
        // contributions of basic variables.
        let mut obj = vec![0.0; cols];
        for j in 0..n {
            obj[j] = -self.c[j];
        }
        for i in 0..m {
            let bj = basis[i];
            let cb = if bj < n { self.c[bj] } else { 0.0 };
            if cb != 0.0 {
                for j in 0..cols {
                    obj[j] += cb * t[i][j];
                }
            }
        }
        if !Self::pivot_loop(&mut t, &mut basis, &mut obj, n + m) {
            return LpOutcome::Unbounded;
        }

        let mut x = vec![0.0; n];
        for i in 0..m {
            if basis[i] < n {
                x[basis[i]] = t[i][cols - 1];
            }
        }
        let value: f64 = self.c.iter().zip(&x).map(|(c, x)| c * x).sum();
        LpOutcome::Optimal(x, value)
    }

    /// Run simplex pivots until optimal; returns false on unboundedness.
    /// Only columns `< allowed_cols` may enter the basis.
    fn pivot_loop(
        t: &mut [Vec<f64>],
        basis: &mut [usize],
        obj: &mut [f64],
        allowed_cols: usize,
    ) -> bool {
        let cols = obj.len();
        let m = t.len();
        for _iter in 0..10_000 {
            // Bland: smallest-index column with negative reduced cost.
            let enter = (0..allowed_cols).find(|&j| obj[j] < -EPS);
            let Some(enter) = enter else {
                return true; // optimal
            };
            // Ratio test (Bland ties by smallest basis index).
            let mut leave: Option<usize> = None;
            let mut best = f64::INFINITY;
            for i in 0..m {
                if t[i][enter] > EPS {
                    let ratio = t[i][cols - 1] / t[i][enter];
                    if ratio < best - EPS
                        || (ratio < best + EPS
                            && leave.is_some_and(|l| basis[i] < basis[l]))
                    {
                        best = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(leave) = leave else {
                return false; // unbounded
            };
            Self::pivot(t, basis, leave, enter, obj);
        }
        true // iteration cap; tiny LPs never get here
    }

    fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, obj: &mut [f64]) {
        let cols = obj.len();
        let piv = t[row][col];
        for j in 0..cols {
            t[row][j] /= piv;
        }
        for i in 0..t.len() {
            if i != row && t[i][col].abs() > EPS {
                let f = t[i][col];
                for j in 0..cols {
                    t[i][j] -= f * t[row][j];
                }
            }
        }
        if obj[col].abs() > EPS {
            let f = obj[col];
            for j in 0..cols {
                obj[j] -= f * t[row][j];
            }
        }
        basis[row] = col;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(outcome: &LpOutcome, x_want: &[f64], v_want: f64) {
        match outcome {
            LpOutcome::Optimal(x, v) => {
                assert!((v - v_want).abs() < 1e-6, "value {v} != {v_want}");
                for (a, b) in x.iter().zip(x_want) {
                    assert!((a - b).abs() < 1e-6, "{x:?} != {x_want:?}");
                }
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_2var() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18  → (2, 6), 36.
        let mut lp = LinProg::new(2);
        lp.maximize(&[3.0, 5.0])
            .leq(&[1.0, 0.0], 4.0)
            .leq(&[0.0, 2.0], 12.0)
            .leq(&[3.0, 2.0], 18.0);
        assert_opt(&lp.solve(), &[2.0, 6.0], 36.0);
    }

    #[test]
    fn geq_constraints_phase1() {
        // min x + y s.t. x + y ≥ 2, x ≤ 3, y ≤ 3 → value 2.
        let mut lp = LinProg::new(2);
        lp.maximize(&[-1.0, -1.0])
            .geq(&[1.0, 1.0], 2.0)
            .leq(&[1.0, 0.0], 3.0)
            .leq(&[0.0, 1.0], 3.0);
        match lp.solve() {
            LpOutcome::Optimal(x, v) => {
                assert!((v + 2.0).abs() < 1e-6);
                assert!((x[0] + x[1] - 2.0).abs() < 1e-6);
            }
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn infeasible_detected() {
        // x ≤ 1 and x ≥ 2.
        let mut lp = LinProg::new(1);
        lp.maximize(&[1.0]).leq(&[1.0], 1.0).geq(&[1.0], 2.0);
        assert_eq!(lp.solve(), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = LinProg::new(1);
        lp.maximize(&[1.0]).geq(&[1.0], 0.0);
        assert_eq!(lp.solve(), LpOutcome::Unbounded);
    }

    #[test]
    fn equality_constraint() {
        // max x + 2y s.t. x + y = 1, x,y ≥ 0 → (0,1), 2.
        let mut lp = LinProg::new(2);
        lp.maximize(&[1.0, 2.0]).eq(&[1.0, 1.0], 1.0);
        assert_opt(&lp.solve(), &[0.0, 1.0], 2.0);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degenerate example; must terminate with Bland's rule.
        let mut lp = LinProg::new(3);
        lp.maximize(&[0.75, -150.0, 0.02])
            .leq(&[0.25, -60.0, -0.04], 0.0)
            .leq(&[0.5, -90.0, -0.02], 0.0)
            .leq(&[0.0, 0.0, 1.0], 1.0);
        match lp.solve() {
            LpOutcome::Optimal(_, v) => assert!(v >= -1e-9),
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn minimization_via_negation() {
        // min 2x + 3y s.t. x + y ≥ 4, x ≥ 1 → x=3? No: cheapest is x big:
        // coefficient of x (2) < y (3), so x=4... but x≥1 only lower-bounds.
        // Optimal: y=0, x=4, cost 8.
        let mut lp = LinProg::new(2);
        lp.maximize(&[-2.0, -3.0]).geq(&[1.0, 1.0], 4.0).geq(&[1.0, 0.0], 1.0);
        match lp.solve() {
            LpOutcome::Optimal(x, v) => {
                assert!((v + 8.0).abs() < 1e-6, "{v}");
                assert!((x[0] - 4.0).abs() < 1e-6);
            }
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn bounded_box_with_mixed_objective() {
        let mut lp = LinProg::new(3);
        lp.maximize(&[1.0, -1.0, 0.5]);
        for i in 0..3 {
            let mut a = [0.0; 3];
            a[i] = 1.0;
            lp.leq(&a, 1.0);
        }
        assert_opt(&lp.solve(), &[1.0, 0.0, 1.0], 1.5);
    }

    #[test]
    fn random_lps_satisfy_constraints() {
        use crate::util::prng::Prng;
        let mut rng = Prng::new(2024);
        for trial in 0..50 {
            let n = 2 + (trial % 3);
            let mut lp = LinProg::new(n);
            let c: Vec<f64> = (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
            lp.maximize(&c);
            for i in 0..n {
                let mut a = vec![0.0; n];
                a[i] = 1.0;
                lp.leq(&a, 1.0 + rng.next_f64());
            }
            for _ in 0..3 {
                let a: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
                lp.leq(&a, 0.5 + rng.next_f64());
            }
            match lp.solve() {
                LpOutcome::Optimal(x, _) => {
                    for xi in &x {
                        assert!(*xi >= -1e-7);
                    }
                    for (a, b) in &lp.rows {
                        let lhs: f64 = a.iter().zip(&x).map(|(a, x)| a * x).sum();
                        assert!(lhs <= b + 1e-6, "violated: {lhs} > {b}");
                    }
                }
                o => panic!("trial {trial}: {o:?}"),
            }
        }
    }
}
