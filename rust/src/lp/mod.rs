//! Linear programming: a dense two-phase simplex solver (the substrate) and
//! the paper's Algorithm 1 configuration search built on top of it
//! (`search`, which combines the solver with [`crate::perfmodel`]).

pub mod search;
pub mod simplex;

pub use search::{
    alpha_grid, find_optimal_config, solve_config, solve_config_cached, ssd_working_set,
    ConfigResult,
};
pub use simplex::{LinProg, LpOutcome};
